//! Serving-subsystem integration tests: train/serve margin parity
//! (bit-for-bit), hot-swap atomicity under concurrent traffic, corrupt
//! artifact rejection, and malformed-request handling (4xx, never a
//! panic or hang).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dglmnet::config::{EngineKind, ServeConfig, TrainConfig};
use dglmnet::data::sparse::CsrMatrix;
use dglmnet::data::synth;
use dglmnet::serve::{prediction_line, ServedModel, Server, ServerHandle};
use dglmnet::solver::{lambda_max, DGlmnetSolver, SparseModel};
use dglmnet::util::json;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dglmnet_serve_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn start(artifact: &Path, watch: bool) -> ServerHandle {
    let cfg = ServeConfig {
        listen: "127.0.0.1:0".into(),
        threads: 2,
        max_batch: 64,
        watch,
        poll_interval_secs: 0.05,
    };
    Server::start(artifact, &cfg).expect("server starts")
}

/// Minimal test client: keep-alive POST/GET with a read deadline, so a
/// hanging server fails the test instead of wedging it.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn send_raw(&mut self, raw: &str) {
        self.stream.write_all(raw.as_bytes()).unwrap();
    }

    fn post(&mut self, path: &str, body: &str) -> (u16, String) {
        let req = format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.send_raw(&req);
        self.read_response()
    }

    fn get(&mut self, path: &str) -> (u16, String) {
        self.send_raw(&format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"));
        self.read_response()
    }

    fn read_response(&mut self) -> (u16, String) {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("bad status line {line:?}"));
        let mut content_length = 0usize;
        let mut chunked = false;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).unwrap();
            let h = h.trim().to_ascii_lowercase();
            if h.is_empty() {
                break;
            }
            if let Some(v) = h.strip_prefix("content-length:") {
                content_length = v.trim().parse().unwrap();
            }
            if h.starts_with("transfer-encoding:") && h.contains("chunked") {
                chunked = true;
            }
        }
        let mut body = Vec::new();
        if chunked {
            loop {
                let mut sz = String::new();
                self.reader.read_line(&mut sz).unwrap();
                let n = usize::from_str_radix(sz.trim(), 16).unwrap();
                let mut buf = vec![0u8; n + 2];
                self.reader.read_exact(&mut buf).unwrap();
                if n == 0 {
                    break;
                }
                body.extend_from_slice(&buf[..n]);
            }
        } else {
            body.resize(content_length, 0);
            self.reader.read_exact(&mut body).unwrap();
        }
        (status, String::from_utf8(body).unwrap())
    }
}

/// The satellite pin: `Model::predict` on the training set reproduces the
/// final fit's freshly-rebuilt margins bit-for-bit. M = 1 so the cluster
/// rebuild has a single machine-order-free summation per example; the
/// shared kernel makes the row-wise (serve) and column-wise (train) paths
/// agree exactly.
#[test]
fn predict_reproduces_final_fit_margins_bit_for_bit() {
    let ds = synth::dna_like(600, 120, 8, 5);
    let cfg = TrainConfig::builder()
        .machines(1)
        .engine(EngineKind::Native)
        .lambda(lambda_max(&ds) / 8.0)
        .max_iter(20)
        .build();
    let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let fit = solver.fit(None).unwrap();
    assert!(fit.nnz() > 0, "trivial all-zero fit would make this vacuous");
    // rebuild the cluster's margins from the final β (a fresh recompute,
    // not the incrementally-updated fit state)
    solver.set_beta(&fit.model.to_dense()).unwrap();
    let served = fit.model.predict_margins(&ds.x);
    assert_eq!(served.len(), solver.margins.len());
    for i in 0..served.len() {
        assert_eq!(
            served[i].to_bits(),
            solver.margins[i].to_bits(),
            "margin {i} differs between train rebuild and model predict"
        );
    }
}

#[test]
fn serve_scores_match_offline_and_malformed_requests_get_4xx() {
    let dir = tmp_dir("basic");
    let artifact = dir.join("model.artifact");
    let model = SparseModel::from_dense(&[0.5, 0.0, -1.25, 2.0, 0.75], 0.25)
        .with_meta(10, "dglmnet");
    model.save(&artifact).unwrap();
    let handle = start(&artifact, false);
    let mut c = Client::connect(handle.addr);

    // health reflects the artifact metadata
    let (status, body) = c.get("/healthz");
    assert_eq!(status, 200);
    let h = json::parse(&body).unwrap();
    assert_eq!(h.get("p").unwrap().as_usize(), Some(5));
    assert_eq!(h.get("nnz").unwrap().as_usize(), Some(4));
    assert_eq!(h.get("solver").unwrap().as_str(), Some("dglmnet"));
    let version = h.get("model_version").unwrap().as_str().unwrap().to_string();
    assert_eq!(version, format!("{:016x}", model.checksum()));

    // single predict matches ServedModel::score exactly
    let (status, body) = c.post("/predict", r#"{"indices":[0,2,4],"values":[2,1,1]}"#);
    assert_eq!(status, 200);
    // f32 values are serialized with the shortest round-trip repr, so
    // parse → f32 recovers the exact bits
    let f32_field = |v: &json::Json, key: &str| -> f32 {
        v.get(key).unwrap().as_f64().unwrap() as f32
    };
    let served = ServedModel::from_model(model.clone());
    let (margin, proba) = served.score(&[0, 2, 4], &[2.0, 1.0, 1.0]);
    let v = json::parse(&body).unwrap();
    assert_eq!(f32_field(&v, "margin").to_bits(), margin.to_bits());
    assert_eq!(f32_field(&v, "proba").to_bits(), proba.to_bits());
    assert_eq!(v.get("model_version").unwrap().as_str(), Some(version.as_str()));

    // duplicate + unsorted indices are canonicalized, out-of-range ignored
    let (status, body2) =
        c.post("/predict", r#"{"indices":[4,0,0,99],"values":[1,1,1,3]}"#);
    assert_eq!(status, 200);
    let (m2, _) = served.score(&[0, 4], &[2.0, 1.0]);
    let v2 = json::parse(&body2).unwrap();
    assert_eq!(f32_field(&v2, "margin").to_bits(), m2.to_bits());

    // batch stream: lines byte-identical to the offline prediction_line
    let (status, body) = c.post(
        "/predict_batch",
        r#"{"examples":[{"indices":[0],"values":[1]},{"indices":[],"values":[]},{"indices":[3],"values":[2]}]}"#,
    );
    assert_eq!(status, 200);
    let mut x = CsrMatrix::new(5);
    x.push_row(&[(0, 1.0)]);
    x.push_row(&[]);
    x.push_row(&[(3, 2.0)]);
    let margins = model.predict_margins(&x);
    let expected: Vec<String> = margins
        .iter()
        .enumerate()
        .map(|(i, &m)| {
            prediction_line(i, m, dglmnet::util::math::sigmoid(m as f64) as f32)
        })
        .collect();
    let got: Vec<&str> = body.lines().collect();
    assert_eq!(got, expected.iter().map(String::as_str).collect::<Vec<_>>());

    // malformed requests: 4xx with a JSON error, the connection answers —
    // never a panic, never a hang (the client read deadline proves it)
    for (body, want) in [
        ("this is not json", 400u16),
        (r#"{"indices":[0],"values":[1,2]}"#, 400),
        (r#"{"indices":"nope","values":[]}"#, 400),
        (r#"{"values":[1]}"#, 400),
        (r#"{"indices":[-1],"values":[1]}"#, 400),
    ] {
        let mut c = Client::connect(handle.addr);
        let (status, err) = c.post("/predict", body);
        assert_eq!(status, want, "body {body:?}");
        assert!(json::parse(&err).unwrap().get("error").is_some());
    }
    // batch over max_batch → 413
    let examples: Vec<String> =
        (0..65).map(|_| r#"{"indices":[0],"values":[1]}"#.to_string()).collect();
    let (status, _) =
        c.post("/predict_batch", &format!("{{\"examples\":[{}]}}", examples.join(",")));
    assert_eq!(status, 413);
    // unknown path / wrong method
    let (status, _) = c.get("/nope");
    assert_eq!(status, 404);
    let (status, _) = c.get("/predict");
    assert_eq!(status, 405);
    // broken framing gets a 400 before the connection closes
    let mut raw = Client::connect(handle.addr);
    raw.send_raw("GARBAGE\r\n\r\n");
    let (status, _) = raw.read_response();
    assert_eq!(status, 400);

    let (_, metrics) = c.get("/metrics");
    let m = json::parse(&metrics).unwrap();
    assert!(m.get("client_errors").unwrap().as_usize().unwrap() >= 7);
    assert_eq!(m.get("swaps").unwrap().as_usize(), Some(0));
    handle.stop();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hot_swap_is_atomic_and_corrupt_artifacts_are_skipped() {
    let dir = tmp_dir("swap");
    let artifact = dir.join("model.artifact");
    let model_a = SparseModel::from_dense(&[1.0, -2.0, 0.5], 0.5).with_meta(10, "a");
    let model_b = SparseModel::from_dense(&[-0.25, 3.0, 1.5], 0.25).with_meta(10, "b");
    model_a.save(&artifact).unwrap();
    let served_a = ServedModel::from_model(model_a.clone());
    let served_b = ServedModel::from_model(model_b.clone());
    let (margin_a, _) = served_a.score(&[0, 1], &[1.0, 1.0]);
    let (margin_b, _) = served_b.score(&[0, 1], &[1.0, 1.0]);
    assert_ne!(margin_a.to_bits(), margin_b.to_bits());

    let handle = start(&artifact, true);
    let addr = handle.addr;
    let stop_flag = Arc::new(AtomicBool::new(false));

    // hammer /predict from two clients while the artifact is rewritten;
    // every response must be 200 and every margin must be EXACTLY the old
    // or the new model's answer, consistent with the reported version
    let version_a = served_a.version.clone();
    let version_b = served_b.version.clone();
    let hammers: Vec<_> = (0..2)
        .map(|_| {
            let stop = Arc::clone(&stop_flag);
            let (va, vb) = (version_a.clone(), version_b.clone());
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let mut seen = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let (status, body) =
                        c.post("/predict", r#"{"indices":[0,1],"values":[1,1]}"#);
                    assert_eq!(status, 200, "request failed during hot-swap");
                    let v = json::parse(&body).unwrap();
                    let margin = v.get("margin").unwrap().as_f64().unwrap() as f32;
                    let version = v.get("model_version").unwrap().as_str().unwrap();
                    let expected = if version == va {
                        margin_a
                    } else if version == vb {
                        margin_b
                    } else {
                        panic!("unknown model version {version}")
                    };
                    assert_eq!(
                        margin.to_bits(),
                        expected.to_bits(),
                        "torn model: margin does not match version {version}"
                    );
                    seen += 1;
                }
                seen
            })
        })
        .collect();

    let mut health = Client::connect(addr);
    let wait_version = |health: &mut Client, want: &str| {
        let t0 = Instant::now();
        loop {
            let (_, body) = health.get("/healthz");
            let v = json::parse(&body).unwrap();
            if v.get("model_version").unwrap().as_str() == Some(want) {
                return;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "server never served version {want}"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    };

    // a corrupt mid-write artifact must be skipped: old model keeps serving
    std::fs::write(&artifact, "dglmnet-model v2 p=3 n=10 lambda=0.5 solver=a nnz=3 checksum=0000000000000000\n0 1\n").unwrap();
    let t0 = Instant::now();
    while handle.stats.swap_failures.load(Ordering::Relaxed) == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "watcher never examined the corrupt artifact"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let (_, body) = health.get("/healthz");
    assert_eq!(
        json::parse(&body).unwrap().get("model_version").unwrap().as_str(),
        Some(version_a.as_str()),
        "corrupt artifact must not replace the served model"
    );

    // real swaps, several times, while the hammers run
    for _ in 0..3 {
        model_b.save(&artifact).unwrap();
        wait_version(&mut health, &version_b);
        model_a.save(&artifact).unwrap();
        wait_version(&mut health, &version_a);
    }

    stop_flag.store(true, Ordering::Relaxed);
    let total: usize = hammers.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total > 0, "hammer threads never got a request through");
    assert!(handle.stats.swaps.load(Ordering::Relaxed) >= 6);
    assert!(handle.stats.swap_failures.load(Ordering::Relaxed) >= 1);
    assert_eq!(handle.stats.server_errors.load(Ordering::Relaxed), 0);
    handle.stop();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn server_rejects_invalid_artifact_at_startup() {
    let dir = tmp_dir("badstart");
    let artifact = dir.join("model.artifact");
    std::fs::write(&artifact, "not a model\n").unwrap();
    let cfg = ServeConfig { listen: "127.0.0.1:0".into(), ..ServeConfig::default() };
    let err = Server::start(&artifact, &cfg).unwrap_err().to_string();
    assert!(err.contains("not a dglmnet model artifact"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
