//! Failover and elasticity acceptance tests (PR 6):
//!
//! * a wedged (alive but silent) socket worker trips the configured recv
//!   deadline instead of hanging the fit forever;
//! * with supervision on, a corrupted link mid-fit (garbage frame) rolls
//!   back to the recovery checkpoint and finishes with a trajectory
//!   **bit-identical** to the undisturbed run — final β, per-iteration
//!   objectives, and the charged comm ledger all match, with the
//!   supervisor's own traffic accounted in a separate recovery bucket;
//! * a socket worker that dies mid-fit is probed out, a replacement
//!   process is re-admitted on the retained listener (validated against
//!   the shard identity), and the completed fit is again bit-identical;
//! * a replacement announcing a mismatched shard is rejected with an
//!   actionable error, never silently admitted;
//! * elastic join/leave: resharding a store M → M−1 between λ steps and
//!   continuing from the current β reproduces a fresh fit at the new
//!   machine count warm-started from the same β, bit for bit;
//! * the whole matrix holds under `topology = tree` too: a killed tree
//!   worker is replaced and the topology re-issued to every worker under a
//!   fresh epoch (the completed fit stays bit-identical to the undisturbed
//!   tree run), a wedged tree root trips the recv deadline cleanly, and
//!   elastic resharding composes with the tree knob.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::thread::JoinHandle;
use std::time::Duration;

use dglmnet::cluster::protocol::{crc_u32, NodeMessage};
use dglmnet::cluster::transport::{Fault, FaultyTransport, PeerTable, SocketTransport};
use dglmnet::cluster::WorkerNode;
use dglmnet::config::{EngineKind, TopologyKind, TrainConfig};
use dglmnet::data::dataset::Dataset;
use dglmnet::data::store::ShardStore;
use dglmnet::data::synth;
use dglmnet::solver::pool::spawn_local_socket_workers;
use dglmnet::solver::{lambda_max, DGlmnetSolver, FitResult};

fn native_cfg(m: usize, lambda: f64, max_iter: usize) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(EngineKind::Native)
        .lambda(lambda)
        .max_iter(max_iter)
        .build()
}

fn supervised_cfg(m: usize, lambda: f64, max_iter: usize) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(EngineKind::Native)
        .lambda(lambda)
        .max_iter(max_iter)
        .supervise(true)
        .heartbeat_timeout_secs(2.0)
        .build()
}

fn tmp_dir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dglmnet_failover_{}_{name}", std::process::id()))
}

/// Two completed fits must agree on every bit the recovery contract pins:
/// iteration count, final objective, the charged comm ledger, every
/// per-iteration record, and the final β.
fn assert_bit_identical(a: &FitResult, beta_a: &[f32], b: &FitResult, beta_b: &[f32]) {
    assert_eq!(a.iterations, b.iterations, "iteration counts diverged");
    assert_eq!(
        a.objective.to_bits(),
        b.objective.to_bits(),
        "objectives diverged: {} vs {}",
        a.objective,
        b.objective
    );
    assert_eq!(a.comm_bytes, b.comm_bytes, "charged comm ledger diverged");
    assert_eq!(a.trace.len(), b.trace.len());
    for (x, y) in a.trace.iter().zip(&b.trace) {
        assert_eq!(x.objective.to_bits(), y.objective.to_bits(), "iter {}", x.iter);
        assert_eq!(x.alpha.to_bits(), y.alpha.to_bits(), "iter {}", x.iter);
        assert_eq!(x.comm_bytes, y.comm_bytes, "iter {}", x.iter);
    }
    assert_eq!(beta_a.len(), beta_b.len());
    for (j, (x, y)) in beta_a.iter().zip(beta_b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "beta[{j}]");
    }
}

/// Run one fit over real TCP sockets with well-behaved workers — the
/// undisturbed reference the chaos runs are compared against.
fn socket_fit(ds: &Dataset, cfg: &TrainConfig, lambda: f64) -> (FitResult, Vec<f32>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let workers = spawn_local_socket_workers(cfg, ds, addr);
    let mut solver = DGlmnetSolver::from_dataset_socket(ds, cfg, listener).unwrap();
    let fit = solver.fit_lambda(lambda).unwrap();
    let beta = solver.beta.clone();
    assert_eq!(solver.recovery_comm_bytes(), 0, "undisturbed run must not probe");
    drop(solver); // sends Shutdown to every node
    for h in workers {
        h.join().expect("worker thread panicked").unwrap();
    }
    (fit, beta)
}

/// A well-behaved socket worker thread for one machine; tolerates the
/// leader erroring out or replacing it (its serve result is ignored).
fn good_worker(
    ds: &Dataset,
    cfg: &TrainConfig,
    machine: usize,
    addr: SocketAddr,
) -> JoinHandle<()> {
    let shard = DGlmnetSolver::shard_for(ds, cfg, machine);
    let y = std::sync::Arc::new(ds.y.clone());
    let p = ds.n_features();
    let cfg = cfg.clone();
    std::thread::spawn(move || {
        let mut node =
            WorkerNode::from_shard(&cfg, shard, y, p, std::path::Path::new("artifacts"))
                .unwrap();
        let mut t = SocketTransport::connect_retry(addr, Duration::from_secs(20)).unwrap();
        let _ = node.serve(&mut t, None);
    })
}

/// A worker whose transport dies on its `dies_at`-th recv — the
/// worker-side view of `kill -9` mid-fit, injected with the
/// fault-injection harness.
fn doomed_worker(
    ds: &Dataset,
    cfg: &TrainConfig,
    machine: usize,
    addr: SocketAddr,
    dies_at: usize,
) -> JoinHandle<()> {
    let shard = DGlmnetSolver::shard_for(ds, cfg, machine);
    let y = std::sync::Arc::new(ds.y.clone());
    let p = ds.n_features();
    let cfg = cfg.clone();
    std::thread::spawn(move || {
        let mut node =
            WorkerNode::from_shard(&cfg, shard, y, p, std::path::Path::new("artifacts"))
                .unwrap();
        let socket = SocketTransport::connect_retry(addr, Duration::from_secs(20)).unwrap();
        let mut t = FaultyTransport::new(Box::new(socket), Fault::Drop, dies_at);
        let _ = node.serve(&mut t, None);
    })
}

fn read_frame_opt(stream: &mut TcpStream) -> Option<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len).ok()?;
    let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
    stream.read_exact(&mut body).ok()?;
    Some(body)
}

fn write_frame(stream: &mut TcpStream, body: &[u8]) {
    stream.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
    stream.write_all(body).unwrap();
    stream.flush().unwrap();
}

fn join_body(ds: &Dataset, cfg: &TrainConfig, machine: usize) -> Vec<u8> {
    let partition = DGlmnetSolver::partition_for(ds, cfg);
    let cols = partition.features_of(machine);
    NodeMessage::Join {
        machine: machine as u32,
        n: ds.n_examples() as u32,
        p: ds.n_features() as u32,
        local_features: cols.len() as u32,
        cols_checksum: crc_u32(&cols),
        engine: "native".into(),
        family: "logistic".into(),
        listen_addr: String::new(),
    }
    .encode()
}

/// A worker that joins and then goes silent — alive at the TCP level but
/// never replying — must trip the configured recv deadline as a clean,
/// prompt, attributable error, not hang the fit forever.
#[test]
fn wedged_worker_trips_the_recv_deadline_instead_of_hanging() {
    let ds = synth::dna_like(200, 20, 4, 801);
    let cfg = TrainConfig::builder()
        .machines(2)
        .engine(EngineKind::Native)
        .lambda(0.2)
        .max_iter(10)
        .recv_timeout_secs(1.0)
        .build();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let good = good_worker(&ds, &cfg, 0, addr);
    let join = join_body(&ds, &cfg, 1);
    let wedged = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        write_frame(&mut s, &join);
        let _welcome = read_frame_opt(&mut s).expect("welcome");
        let _sweep = read_frame_opt(&mut s).expect("first sweep");
        // wedge: stay connected, drain frames, never answer
        while read_frame_opt(&mut s).is_some() {}
    });

    let mut solver = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
    let err = solver.fit_lambda(0.2).unwrap_err().to_string();
    assert!(err.contains("worker 1"), "{err}");
    assert!(err.contains("timed out"), "{err}");
    drop(solver); // closes the link, unblocking the wedged peer's drain
    wedged.join().unwrap();
    good.join().unwrap();
}

/// Supervised recovery from a corrupted link: the garbage frame fails the
/// iteration, the supervisor probes every worker (all alive — a damaged
/// wire, not a dead process), rolls back to the recovery checkpoint, and
/// the completed fit is bit-identical to the undisturbed run.
#[test]
fn supervised_recovery_from_a_corrupted_link_is_bit_identical() {
    let ds = synth::dna_like(400, 40, 5, 802);
    let lam = lambda_max(&ds) / 64.0;
    let cfg = supervised_cfg(3, lam, 40);

    let mut clean = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let fit_clean = clean.fit_lambda(lam).unwrap();
    assert!(fit_clean.iterations >= 4, "need a fit long enough to disturb");
    assert_eq!(clean.recovery_comm_bytes(), 0);

    let mut hurt = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    hurt.wrap_worker_link(1, Fault::Corrupt, 7);
    let fit_hurt = hurt.fit_lambda(lam).unwrap();

    assert!(hurt.recovery_comm_bytes() > 0, "the supervisor must have probed");
    assert_bit_identical(&fit_clean, &clean.beta, &fit_hurt, &hurt.beta);
}

/// The tentpole chaos pin: a socket worker dies mid-fit, the supervisor
/// probes it out, re-admits a replacement process on the retained
/// listener, rolls back, and the completed fit reproduces the undisturbed
/// run's final β, objective trajectory, and charged comm ledger exactly.
#[test]
fn killed_socket_worker_is_replaced_and_the_fit_stays_bit_identical() {
    let ds = synth::dna_like(400, 40, 5, 803);
    let lam = lambda_max(&ds) / 64.0;
    let cfg = supervised_cfg(2, lam, 40);

    let (fit_ref, beta_ref) = socket_fit(&ds, &cfg, lam);
    assert!(fit_ref.iterations >= 4, "need a fit long enough to kill");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let good = good_worker(&ds, &cfg, 0, addr);
    let doomed = doomed_worker(&ds, &cfg, 1, addr, 5);
    let mut solver = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
    // the stand-in connects only after admission closed, so it waits in
    // the listener backlog until the supervisor re-admits machine 1
    let replacement = good_worker(&ds, &cfg, 1, addr);

    let fit_chaos = solver.fit_lambda(lam).unwrap();
    assert!(
        solver.recovery_comm_bytes() > 0,
        "the supervisor must have probed and re-admitted"
    );
    let beta_chaos = solver.beta.clone();
    assert_bit_identical(&fit_ref, &beta_ref, &fit_chaos, &beta_chaos);
    drop(solver); // sends Shutdown to the survivors
    doomed.join().unwrap();
    replacement.join().unwrap();
    good.join().unwrap();
}

/// A replacement peer announcing the right machine index but the wrong
/// shard identity (here: machine 1 of a three-machine layout offered to a
/// two-machine fit) must be rejected with an actionable error — admitting
/// it would silently corrupt the fit.
#[test]
fn a_replacement_with_a_mismatched_shard_is_rejected() {
    let ds = synth::dna_like(400, 40, 5, 804);
    let lam = lambda_max(&ds) / 64.0;
    let cfg = supervised_cfg(2, lam, 40);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let good = good_worker(&ds, &cfg, 0, addr);
    let doomed = doomed_worker(&ds, &cfg, 1, addr, 5);
    let mut solver = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
    let bad_join = join_body(&ds, &native_cfg(3, lam, 40), 1);
    let rogue = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        write_frame(&mut s, &bad_join);
        // read the Abort (and whatever follows) until the leader hangs up
        while read_frame_opt(&mut s).is_some() {}
    });

    let err = solver.fit_lambda(lam).unwrap_err().to_string();
    assert!(err.contains("announced"), "{err}");
    assert!(err.contains("expects"), "{err}");
    drop(solver);
    rogue.join().unwrap();
    doomed.join().unwrap();
    good.join().unwrap();
}

// ---------------------------------------------------------------------------
// the same chaos matrix under topology = tree
// ---------------------------------------------------------------------------

/// A well-behaved tree worker: binds a peer listener and serves with it.
fn tree_good_worker(
    ds: &Dataset,
    cfg: &TrainConfig,
    machine: usize,
    addr: SocketAddr,
) -> JoinHandle<()> {
    let shard = DGlmnetSolver::shard_for(ds, cfg, machine);
    let y = std::sync::Arc::new(ds.y.clone());
    let p = ds.n_features();
    let cfg = cfg.clone();
    std::thread::spawn(move || {
        let mut node =
            WorkerNode::from_shard(&cfg, shard, y, p, std::path::Path::new("artifacts"))
                .unwrap();
        let mut t = SocketTransport::connect_retry(addr, Duration::from_secs(20)).unwrap();
        let mut peers = PeerTable::bind(t.local_ip().unwrap()).unwrap();
        let _ = node.serve(&mut t, Some(&mut peers));
    })
}

/// A tree worker whose **leader link** is injured on its `at`-th delivered
/// message — kill or wedge the bracket root mid-fit while its peer links
/// stay healthy.
fn tree_faulty_worker(
    ds: &Dataset,
    cfg: &TrainConfig,
    machine: usize,
    addr: SocketAddr,
    fault: Fault,
    at: usize,
) -> JoinHandle<()> {
    let shard = DGlmnetSolver::shard_for(ds, cfg, machine);
    let y = std::sync::Arc::new(ds.y.clone());
    let p = ds.n_features();
    let cfg = cfg.clone();
    std::thread::spawn(move || {
        let mut node =
            WorkerNode::from_shard(&cfg, shard, y, p, std::path::Path::new("artifacts"))
                .unwrap();
        let socket = SocketTransport::connect_retry(addr, Duration::from_secs(20)).unwrap();
        let mut peers = PeerTable::bind(socket.local_ip().unwrap()).unwrap();
        let mut t = FaultyTransport::new(Box::new(socket), fault, at);
        let _ = node.serve(&mut t, Some(&mut peers));
    })
}

/// The tree tentpole chaos pin: kill the bracket root (machine 0 — the one
/// worker whose leader link carries the whole data plane) mid-fit. The
/// supervisor probes it out, re-admits a replacement (welcomed *without* a
/// topology — it idles at epoch 0 answering star-style), re-issues the
/// tree to **every** worker under a bumped epoch, and the completed fit
/// reproduces the undisturbed tree run bit for bit.
#[test]
fn killed_tree_worker_is_replaced_and_the_fit_stays_bit_identical() {
    let ds = synth::dna_like(400, 40, 5, 806);
    let lam = lambda_max(&ds) / 64.0;
    let mut cfg = supervised_cfg(3, lam, 40);
    cfg.topology = TopologyKind::Tree;

    let (fit_ref, beta_ref) = socket_fit(&ds, &cfg, lam);
    assert!(fit_ref.iterations >= 4, "need a fit long enough to kill");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let w1 = tree_good_worker(&ds, &cfg, 1, addr);
    let w2 = tree_good_worker(&ds, &cfg, 2, addr);
    let doomed = tree_faulty_worker(&ds, &cfg, 0, addr, Fault::Drop, 5);
    let mut solver = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
    assert_eq!(solver.topology_epoch(), 1, "admission installs the first epoch");
    // connects only after admission closed; waits in the listener backlog
    // until the supervisor re-admits machine 0
    let replacement = tree_good_worker(&ds, &cfg, 0, addr);

    let fit_chaos = solver.fit_lambda(lam).unwrap();
    assert!(
        solver.recovery_comm_bytes() > 0,
        "the supervisor must have probed and re-admitted"
    );
    assert!(
        solver.topology_epoch() >= 2,
        "recovery must re-issue the tree under a fresh epoch, got {}",
        solver.topology_epoch()
    );
    let beta_chaos = solver.beta.clone();
    assert_bit_identical(&fit_ref, &beta_ref, &fit_chaos, &beta_chaos);
    drop(solver); // sends Shutdown to the survivors
    doomed.join().unwrap();
    replacement.join().unwrap();
    w1.join().unwrap();
    w2.join().unwrap();
}

/// A wedged tree root — alive at the TCP level but sitting on the leader's
/// request — must trip the configured recv deadline as a clean, prompt,
/// attributable error, exactly like the star case.
#[test]
fn wedged_tree_root_trips_the_recv_deadline_instead_of_hanging() {
    let ds = synth::dna_like(200, 20, 4, 807);
    let cfg = TrainConfig::builder()
        .machines(3)
        .engine(EngineKind::Native)
        .lambda(0.2)
        .max_iter(10)
        .recv_timeout_secs(1.0)
        .topology(TopologyKind::Tree)
        .build();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let w1 = tree_good_worker(&ds, &cfg, 1, addr);
    let w2 = tree_good_worker(&ds, &cfg, 2, addr);
    let wedged = tree_faulty_worker(
        &ds,
        &cfg,
        0,
        addr,
        Fault::Delay(Duration::from_secs(4)),
        2,
    );

    let mut solver = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
    let err = solver.fit_lambda(0.2).unwrap_err().to_string();
    assert!(err.contains("worker 0"), "{err}");
    assert!(err.contains("timed out"), "{err}");
    drop(solver); // closes the links, unblocking every serve loop
    wedged.join().unwrap();
    w1.join().unwrap();
    w2.join().unwrap();
}

/// Elastic resharding composes with the tree knob: under an in-process
/// transport `topology = tree` stays leader-staged, so the resized
/// continuation must still match a fresh fit at the new machine count
/// bit for bit.
#[test]
fn elastic_resize_under_a_tree_config_matches_a_fresh_fit() {
    let ds = synth::dna_like(400, 40, 5, 808);
    let lam = lambda_max(&ds);
    let (lam1, lam2) = (lam / 8.0, lam / 32.0);
    let mut cfg3 = native_cfg(3, lam1, 40);
    cfg3.topology = TopologyKind::Tree;

    let dir3 = tmp_dir("elastic_tree_src");
    let partition3 = DGlmnetSolver::partition_for(&ds, &cfg3);
    let store3 = ShardStore::create(&dir3, &ds, &partition3, "round-robin").unwrap();
    let mut s3 = DGlmnetSolver::from_store(&store3, &cfg3).unwrap();
    s3.fit_lambda(lam1).unwrap();
    let warm = s3.beta.clone();

    let dir2 = tmp_dir("elastic_tree_dst");
    let mut resized = s3.elastic_resize(&store3, 2, &dir2).unwrap();
    let fit_resized = resized.fit_lambda(lam2).unwrap();
    assert!(fit_resized.iterations >= 2, "need a non-trivial continuation");

    let mut cfg2 = native_cfg(2, lam2, 40);
    cfg2.topology = TopologyKind::Tree;
    let mut fresh = DGlmnetSolver::from_dataset(&ds, &cfg2).unwrap();
    fresh.set_beta(&warm).unwrap();
    let fit_fresh = fresh.fit_lambda(lam2).unwrap();

    assert_bit_identical(&fit_fresh, &fresh.beta, &fit_resized, &resized.beta);
    for d in [dir3, dir2] {
        std::fs::remove_dir_all(&d).ok();
    }
}

/// Elastic join/leave between λ steps: reshard the store 3 → 2, continue
/// from the current β, and the continuation is bit-identical to a fresh
/// M = 2 fit warm-started from the same β.
#[test]
fn elastic_resize_matches_a_fresh_fit_at_the_new_machine_count() {
    let ds = synth::dna_like(400, 40, 5, 805);
    let lam = lambda_max(&ds);
    let (lam1, lam2) = (lam / 8.0, lam / 32.0);
    let cfg3 = native_cfg(3, lam1, 40);

    let dir3 = tmp_dir("elastic_src");
    let partition3 = DGlmnetSolver::partition_for(&ds, &cfg3);
    let store3 = ShardStore::create(&dir3, &ds, &partition3, "round-robin").unwrap();
    let mut s3 = DGlmnetSolver::from_store(&store3, &cfg3).unwrap();
    s3.fit_lambda(lam1).unwrap();
    let warm = s3.beta.clone();

    // one machine leaves: reshard 3 -> 2 and continue at the next λ
    let dir2 = tmp_dir("elastic_dst");
    let mut resized = s3.elastic_resize(&store3, 2, &dir2).unwrap();
    let fit_resized = resized.fit_lambda(lam2).unwrap();
    assert!(fit_resized.iterations >= 2, "need a non-trivial continuation");

    // the reference: a fresh M = 2 cluster warm-started from the same β
    let cfg2 = native_cfg(2, lam2, 40);
    let mut fresh = DGlmnetSolver::from_dataset(&ds, &cfg2).unwrap();
    fresh.set_beta(&warm).unwrap();
    let fit_fresh = fresh.fit_lambda(lam2).unwrap();

    assert_bit_identical(&fit_fresh, &fresh.beta, &fit_resized, &resized.beta);
    for d in [dir3, dir2] {
        std::fs::remove_dir_all(&d).ok();
    }
}
