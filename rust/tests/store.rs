//! Acceptance tests for the out-of-core sharded data plane:
//!
//! * the distributed per-shard λ_max reduce is **bit-identical** to the
//!   in-memory computation on dna-like and webspam-like shapes, for
//!   M ∈ {1, 3, 8}, in-process and over sockets;
//! * a store-driven in-process cluster reproduces the pure in-memory
//!   (`from_shards`) trajectory bit-for-bit — loading shards from disk
//!   changes nothing;
//! * the distributed warmstart install (`set_beta` without any leader-held
//!   X) leaves margins consistent with β;
//! * store/config mismatches fail with actionable errors.

use std::net::TcpListener;

use dglmnet::cluster::partition::FeaturePartition;
use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::dataset::Dataset;
use dglmnet::data::shuffle::shard_in_memory;
use dglmnet::data::store::ShardStore;
use dglmnet::data::synth;
use dglmnet::solver::pool::spawn_local_socket_workers_from_store;
use dglmnet::solver::{lambda_max, DGlmnetSolver};

fn native_cfg(m: usize, lambda: f64, max_iter: usize) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(EngineKind::Native)
        .lambda(lambda)
        .max_iter(max_iter)
        .build()
}

fn temp_store(ds: &Dataset, cfg: &TrainConfig, tag: &str) -> (std::path::PathBuf, ShardStore) {
    let dir = std::env::temp_dir()
        .join(format!("dglmnet_store_test_{}_{tag}", std::process::id()));
    let partition = DGlmnetSolver::partition_for(ds, cfg);
    let store = ShardStore::create(&dir, ds, &partition, "round-robin").unwrap();
    (dir, store)
}

/// The λ_max parity matrix: distributed per-shard reduce == in-memory
/// scan, bit for bit, across dataset shapes, machine counts, and both
/// transports.
#[test]
fn distributed_lambda_max_is_bit_identical_across_m_and_transports() {
    let problems = [
        ("dna-like", synth::dna_like(400, 48, 5, 901)),
        ("webspam-like", synth::webspam_like(300, 2_000, 10, 902)),
    ];
    for (name, ds) in problems {
        let want = lambda_max(&ds);
        assert!(want > 0.0);
        for m in [1usize, 3, 8] {
            let cfg = native_cfg(m, 1.0, 5);

            // in-process (which itself runs from a temp store)
            let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
            let got = solver.lambda_max_distributed().unwrap();
            assert_eq!(
                want.to_bits(),
                got.to_bits(),
                "{name} M={m} in-process: {want} vs {got}"
            );
            drop(solver);

            // socket: workers self-load shard files, leader holds no X
            let (dir, store) = temp_store(&ds, &cfg, &format!("lmax_{name}_{m}"));
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let workers = spawn_local_socket_workers_from_store(&cfg, &store, addr);
            let mut solver =
                DGlmnetSolver::from_store_socket(&store, &cfg, listener).unwrap();
            let got = solver.lambda_max_distributed().unwrap();
            assert_eq!(
                want.to_bits(),
                got.to_bits(),
                "{name} M={m} socket: {want} vs {got}"
            );
            drop(solver);
            for h in workers {
                h.join().expect("worker panicked").unwrap();
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

/// Loading shards from disk must change nothing: a store-driven in-process
/// cluster and a pure in-memory `from_shards` cluster produce bit-identical
/// fits (objective trajectory, ledger, β).
#[test]
fn store_cluster_matches_pure_in_memory_cluster_bitwise() {
    let ds = synth::dna_like(500, 60, 6, 903);
    let lam = lambda_max(&ds) / 8.0;
    let cfg = native_cfg(4, lam, 20);

    // pure in-memory reference: shards built in RAM, no store anywhere
    let partition = DGlmnetSolver::partition_for(&ds, &cfg);
    let shards = shard_in_memory(&ds.x, &partition);
    let mut mem =
        DGlmnetSolver::from_shards(&ds, &cfg, partition, shards).unwrap();
    let fit_mem = mem.fit(None).unwrap();

    // explicit store cluster
    let (dir, store) = temp_store(&ds, &cfg, "adapter");
    let mut st = DGlmnetSolver::from_store(&store, &cfg).unwrap();
    let fit_store = st.fit(None).unwrap();

    assert_eq!(fit_mem.iterations, fit_store.iterations);
    assert_eq!(fit_mem.objective.to_bits(), fit_store.objective.to_bits());
    assert_eq!(fit_mem.comm_bytes, fit_store.comm_bytes);
    for (a, b) in fit_mem.trace.iter().zip(&fit_store.trace) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "iter {}", a.iter);
        assert_eq!(a.comm_bytes, b.comm_bytes, "iter {}", a.iter);
    }
    assert_eq!(mem.beta, st.beta);
    drop(st);
    std::fs::remove_dir_all(&dir).ok();
}

/// The distributed warmstart install: set_beta rebuilds margins from the
/// workers' shards (the leader holds no X) and the next fit behaves like a
/// converged warmstart.
#[test]
fn distributed_set_beta_rebuilds_consistent_margins() {
    let ds = synth::dna_like(400, 40, 5, 904);
    let lam = lambda_max(&ds) / 8.0;
    let cfg = native_cfg(3, lam, 40);
    let mut a = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let fit_a = a.fit(None).unwrap();

    let mut b = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    b.set_beta(&fit_a.model.to_dense()).unwrap();
    // margins must agree with the by-example SpMV within f32 accumulation
    // noise
    let want = ds.x.margins(&b.beta);
    for i in (0..400).step_by(23) {
        assert!(
            (b.margins[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
            "margins[{i}]: {} vs {}",
            b.margins[i],
            want[i]
        );
    }
    let fit_b = b.fit_lambda(lam).unwrap();
    assert!(fit_b.iterations <= 3, "warmstart took {} iterations", fit_b.iterations);
    assert!((fit_b.objective - fit_a.objective).abs() / fit_a.objective < 1e-3);
}

/// Store/config mismatches fail loudly with actionable messages.
#[test]
fn store_mismatches_error_actionably() {
    let ds = synth::dna_like(200, 24, 4, 905);
    let cfg3 = native_cfg(3, 0.5, 5);
    let (dir, store) = temp_store(&ds, &cfg3, "mismatch");

    // machine-count mismatch names both counts and the fix
    let cfg4 = native_cfg(4, 0.5, 5);
    let err = DGlmnetSolver::from_store(&store, &cfg4).unwrap_err().to_string();
    assert!(err.contains("3 machines"), "{err}");
    assert!(err.contains("--workers"), "{err}");

    // a worker asked for a machine the store does not have
    assert!(store.load_shard(7).is_err());

    // [data] store / --store routing: from_config opens the configured
    // store; without one it errors actionably
    let mut cfg_store = native_cfg(3, 0.5, 5);
    cfg_store.store = Some(dir.to_string_lossy().into_owned());
    let solver = DGlmnetSolver::from_config(&cfg_store).unwrap();
    assert_eq!(solver.n_features(), 24);
    drop(solver);
    let err = DGlmnetSolver::from_config(&cfg3).unwrap_err().to_string();
    assert!(err.contains("--store"), "{err}");

    // a store whose shard files disagree with the manifest (simulated by
    // deleting one) errors at partition reconstruction
    std::fs::remove_file(dglmnet::data::store::shard_path(&dir, 1)).unwrap();
    assert!(store.partition().is_err());
    std::fs::remove_dir_all(&dir).ok();

    // feature lists that do not cover the space are rejected
    assert!(FeaturePartition::from_feature_lists(&[vec![0, 2]], 3).is_err());
}
