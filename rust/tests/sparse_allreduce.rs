//! Comm-subsystem correctness and communication regression tests: the wire
//! codecs and exchange strategies must change *accounting only* —
//! identical sums to the dense path on any mix of ragged/empty
//! contributions, bit-identical objective trajectories across lossless
//! strategies — and must actually cut `comm_bytes` on the paper's sparse
//! regime (webspam-like, p >> n, high λ), with the tree-merge work running
//! inside the `WorkerPool` rather than on the leader thread.

mod common;

use common::prop_check;
use dglmnet::cluster::allreduce::{AllReduceScratch, TreeAllReduce};
use dglmnet::cluster::network::{NetworkLedger, NetworkModel};
use dglmnet::config::{EngineKind, ExchangeStrategy, TrainConfig};
use dglmnet::data::sparse::SparseVec;
use dglmnet::data::synth;
use dglmnet::solver::{lambda_max, DGlmnetSolver};

#[test]
fn prop_sparse_and_dense_allreduce_sum_identically() {
    prop_check("sparse-dense-allreduce-equal", 100, |rng, _| {
        let m = 1 + rng.below(10);
        let dim = 1 + rng.below(500);
        // ragged sparsity: every machine gets its own density, some machines
        // contribute nothing at all
        let dense: Vec<Vec<f32>> = (0..m)
            .map(|_| {
                let density = match rng.below(4) {
                    0 => 0.0, // all-zero contribution
                    1 => 0.02,
                    2 => 0.2,
                    _ => 0.9, // past the fallback threshold
                };
                (0..dim)
                    .map(|_| {
                        if rng.uniform() < density {
                            (rng.normal() * 3.0) as f32
                        } else {
                            0.0
                        }
                    })
                    .collect()
            })
            .collect();
        let sparse: Vec<SparseVec> = dense.iter().map(|d| SparseVec::from_dense(d)).collect();

        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let sparse_ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        ar.sum_sparse_into(sparse.iter(), dim, &sparse_ledger, &mut scratch, &mut out);
        let got = out.to_dense();

        let dense_ledger = NetworkLedger::new();
        let (want, _) = ar.sum(&dense, &dense_ledger);

        assert_eq!(got.len(), want.len());
        for i in 0..dim {
            // identical pairwise f64 tree order => identical f32 sums
            assert_eq!(got[i], want[i], "i = {i}");
        }
        // and against the serial reference, with float tolerance
        for i in 0..dim {
            let serial: f64 = dense.iter().map(|c| c[i] as f64).sum();
            assert!(
                (got[i] as f64 - serial).abs() <= 1e-4 * (1.0 + serial.abs()),
                "i = {i}: {} vs {serial}",
                got[i]
            );
        }
        // the sparse wire format must never cost more than the dense one
        assert!(
            sparse_ledger.total_bytes() <= dense_ledger.total_bytes(),
            "sparse {} > dense {}",
            sparse_ledger.total_bytes(),
            dense_ledger.total_bytes()
        );
    });
}

#[test]
fn all_zero_contributions_sum_to_zero_for_free() {
    let contribs: Vec<SparseVec> = (0..6).map(|_| SparseVec::new(123)).collect();
    let ar = TreeAllReduce::new(NetworkModel::gigabit());
    let ledger = NetworkLedger::new();
    let mut scratch = AllReduceScratch::default();
    let mut out = SparseVec::new(0);
    ar.sum_sparse_into(contribs.iter(), 123, &ledger, &mut scratch, &mut out);
    assert_eq!(out.nnz(), 0);
    assert_eq!(out.dim, 123);
    assert_eq!(ledger.total_bytes(), 0, "empty messages move no payload");
}

/// The headline regression: on a webspam-like problem (p >> n) at high λ
/// with M = 8 machines, the sparse wire format must cut per-fit
/// `comm_bytes` by at least 5× versus the dense baseline while reaching an
/// objective within 1e-6 — the sums are bit-identical, only the accounting
/// differs.
#[test]
fn sparse_allreduce_cuts_comm_bytes_on_webspam_like() {
    let ds = synth::webspam_like(800, 16_000, 10, 424);
    let lam = lambda_max(&ds) / 4.0;
    let mk = |dense_allreduce: bool| {
        TrainConfig::builder()
            .machines(8)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(25)
            .dense_allreduce(dense_allreduce)
            .build()
    };

    let mut sparse = DGlmnetSolver::from_dataset(&ds, &mk(false)).unwrap();
    let fit_sparse = sparse.fit(None).unwrap();
    let mut dense = DGlmnetSolver::from_dataset(&ds, &mk(true)).unwrap();
    let fit_dense = dense.fit(None).unwrap();

    assert!(fit_sparse.comm_bytes > 0);
    assert_eq!(
        fit_sparse.iterations, fit_dense.iterations,
        "wire format must not change the optimization trajectory"
    );
    let rel = (fit_sparse.objective - fit_dense.objective).abs()
        / fit_dense.objective.abs().max(1.0);
    assert!(
        rel <= 1e-6,
        "objectives diverged: sparse {} vs dense {}",
        fit_sparse.objective,
        fit_dense.objective
    );
    let reduction = fit_dense.comm_bytes as f64 / fit_sparse.comm_bytes as f64;
    assert!(
        reduction >= 5.0,
        "expected >= 5x comm reduction, got {reduction:.2}x \
         (sparse {} vs dense {} bytes)",
        fit_sparse.comm_bytes,
        fit_dense.comm_bytes
    );
    // simulated network time must reflect the same win
    assert!(fit_sparse.sim_comm_secs < fit_dense.sim_comm_secs);
}

/// Per-iteration `comm_bytes` in the trace are true deltas and the sparse
/// path's traffic shrinks as the support stabilizes (later iterations move
/// fewer Δβ entries than the dense format would).
#[test]
fn trace_comm_bytes_stay_below_dense_equivalent() {
    let ds = synth::webspam_like(600, 8_000, 10, 425);
    let lam = lambda_max(&ds) / 4.0;
    let cfg = TrainConfig::builder()
        .machines(4)
        .engine(EngineKind::Native)
        .lambda(lam)
        .max_iter(15)
        .build();
    let mut s = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let fit = s.fit(None).unwrap();
    let total: u64 = fit.trace.iter().map(|r| r.comm_bytes).sum();
    assert_eq!(total, fit.comm_bytes, "trace must hold per-iteration deltas");
    // dense equivalent per iteration: 2 allreduces moving (n + p) floats
    // over (M-1) reduce + (M-1) per-edge broadcast messages
    let edges = 2 * (4 - 1); // M = 4
    let dense_per_iter = (edges * (600 + 8_000) * 4) as u64;
    for r in &fit.trace {
        assert!(
            r.comm_bytes <= dense_per_iter,
            "iter {}: {} bytes exceeds dense equivalent {dense_per_iter}",
            r.iter,
            r.comm_bytes
        );
    }
}

/// The PR-3 acceptance criteria in one place: on a webspam-like problem at
/// λ_max/4 with M = 8, the cost-model-selected strategy must cut total
/// `comm_bytes` ≥ 2× versus the sparse reduce-Δm path, with a bit-identical
/// objective trajectory (lossless codecs), and the tree-merge work must
/// run inside the `WorkerPool` — never on the leader thread.
#[test]
fn auto_exchange_halves_comm_with_bit_identical_trajectory() {
    let ds = synth::webspam_like(800, 16_000, 10, 426);
    let lam = lambda_max(&ds) / 4.0;
    let mk = |exchange: ExchangeStrategy| {
        TrainConfig::builder()
            .machines(8)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(25)
            .exchange(exchange)
            .build()
    };

    let mut auto = DGlmnetSolver::from_dataset(&ds, &mk(ExchangeStrategy::Auto)).unwrap();
    let fit_auto = auto.fit(None).unwrap();
    let mut reduce = DGlmnetSolver::from_dataset(&ds, &mk(ExchangeStrategy::ReduceDm)).unwrap();
    let fit_reduce = reduce.fit(None).unwrap();

    // ≥ 2× cheaper than the current sparse-with-dense-fallback path
    assert!(fit_auto.comm_bytes > 0);
    assert!(
        fit_auto.comm_bytes * 2 <= fit_reduce.comm_bytes,
        "auto {} bytes vs reduce-Δm {} bytes: expected ≥ 2× reduction",
        fit_auto.comm_bytes,
        fit_reduce.comm_bytes
    );

    // lossless codecs: bit-identical trajectory, iteration for iteration
    assert_eq!(fit_auto.iterations, fit_reduce.iterations);
    for (a, b) in fit_auto.trace.iter().zip(&fit_reduce.trace) {
        assert_eq!(
            a.objective.to_bits(),
            b.objective.to_bits(),
            "iter {}: trajectories diverged",
            a.iter
        );
    }
    assert_eq!(auto.beta, reduce.beta);

    // the cost model must actually have chosen allgather-Δβ here (Δm is
    // the dominant payload at λ_max/4), and the merges ran on workers
    assert!(
        fit_auto
            .trace
            .iter()
            .any(|r| r.exchange == Some(ExchangeStrategy::AllGatherBeta)),
        "cost model never picked allgather-Δβ on the webspam regime"
    );
    assert!(auto.merge_tasks_executed() > 0, "no merge ran inside the worker pool");
    assert!(reduce.merge_tasks_executed() > 0);
}
