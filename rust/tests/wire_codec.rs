//! Wire-codec property tests: lossless codecs round-trip bit-exactly, the
//! f16 codec's error is bounded, charged bytes equal encoded length for
//! every codec, and the allgather-Δβ exchange reproduces the reduce-Δm
//! objective trajectory exactly on dna-like and webspam-like problems.

mod common;

use common::prop_check;
use dglmnet::cluster::codec::{
    f16_round_trip, CodecPolicy, MessageClass, WireCodec,
};
use dglmnet::config::{EngineKind, ExchangeStrategy, TrainConfig};
use dglmnet::data::sparse::SparseVec;
use dglmnet::data::synth;
use dglmnet::solver::{lambda_max, DGlmnetSolver};
use dglmnet::util::rng::Xoshiro256;

/// Random sparse message with nonzero values in the f16 normal range
/// (magnitudes 0.5..64 — away from subnormals and overflow so the lossy
/// round-trip bound is the generic 2^-11 relative one).
fn random_message(rng: &mut Xoshiro256) -> SparseVec {
    let dim = 1 + rng.below(900);
    let density = match rng.below(3) {
        0 => 0.02,
        1 => 0.3,
        _ => 0.8,
    };
    let mut v = SparseVec::new(dim);
    for i in 0..dim {
        if rng.uniform() < density {
            let mag = rng.uniform_in(0.5, 64.0) as f32;
            let val = if rng.bernoulli(0.5) { mag } else { -mag };
            v.push(i as u32, val);
        }
    }
    v
}

#[test]
fn prop_lossless_codecs_round_trip_bit_exact() {
    prop_check("lossless-codec-roundtrip", 200, |rng, _| {
        let msg = random_message(rng);
        for codec in [WireCodec::DenseF32, WireCodec::SparseU32F32] {
            assert!(codec.is_lossless());
            let bytes = codec.encode(&msg);
            let back = codec.decode(&bytes, msg.dim).unwrap();
            assert_eq!(back.dim, msg.dim, "{}", codec.name());
            assert_eq!(back.indices, msg.indices, "{}", codec.name());
            for (a, b) in msg.values.iter().zip(&back.values) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", codec.name());
            }
        }
        // delta-varint round-trips the *indices* bit-exactly too
        let bytes = WireCodec::DeltaVarintF16.encode(&msg);
        let back = WireCodec::DeltaVarintF16.decode(&bytes, msg.dim).unwrap();
        assert_eq!(back.indices, msg.indices);
    });
}

#[test]
fn prop_charged_bytes_match_encoded_length_for_every_codec() {
    prop_check("codec-cost-exact", 200, |rng, _| {
        let msg = random_message(rng);
        for codec in
            [WireCodec::DenseF32, WireCodec::SparseU32F32, WireCodec::DeltaVarintF16]
        {
            let encoded = codec.encode(&msg);
            assert_eq!(
                codec.encoded_bytes(&msg),
                encoded.len() as u64,
                "{}: cost model must equal the real encoded length",
                codec.name()
            );
        }
        // and the policy's pick never exceeds the dense equivalent
        for class in [MessageClass::Margins, MessageClass::Beta] {
            for policy in [
                CodecPolicy::lossless(),
                CodecPolicy { f16_margins: true, f16_beta: true, ..CodecPolicy::default() },
            ] {
                let (_, cost) = policy.pick(&msg.indices, msg.dim, class);
                assert!(cost <= msg.dim as u64 * 4);
            }
        }
    });
}

#[test]
fn prop_f16_codec_error_is_bounded() {
    prop_check("f16-codec-error-bound", 200, |rng, _| {
        let msg = random_message(rng);
        let bytes = WireCodec::DeltaVarintF16.encode(&msg);
        let back = WireCodec::DeltaVarintF16.decode(&bytes, msg.dim).unwrap();
        assert_eq!(back.nnz(), msg.nnz());
        for ((_, want), (_, got)) in msg.iter().zip(back.iter()) {
            let rel = ((got - want) / want).abs();
            assert!(rel <= 1.0 / 1024.0, "want {want}, got {got}, rel {rel}");
            // the decoded value is exactly the f16 quantization
            assert_eq!(got.to_bits(), f16_round_trip(want).to_bits());
        }
    });
}

#[test]
fn truncated_payloads_error_instead_of_panicking() {
    let msg = SparseVec::from_dense(&[0.0, 1.5, 0.0, -2.0]);
    for codec in [WireCodec::DenseF32, WireCodec::SparseU32F32, WireCodec::DeltaVarintF16] {
        let mut bytes = codec.encode(&msg);
        bytes.pop();
        assert!(codec.decode(&bytes, msg.dim).is_err(), "{}", codec.name());
    }
    // out-of-range indices are rejected
    let bytes = WireCodec::SparseU32F32.encode(&msg);
    assert!(WireCodec::SparseU32F32.decode(&bytes, 2).is_err());

    // non-ascending sparse payloads are rejected, not silently accepted
    let mut unsorted = Vec::new();
    for (i, v) in [(5u32, 1.0f32), (3, 2.0)] {
        unsorted.extend_from_slice(&i.to_le_bytes());
        unsorted.extend_from_slice(&v.to_le_bytes());
    }
    assert!(WireCodec::SparseU32F32.decode(&unsorted, 10).is_err());

    // a zero gap after the first delta entry would duplicate an index
    let dup = [0x01, 0x00, 0x3C, 0x00, 0x00, 0x3C]; // idx 1, then gap 0
    assert!(WireCodec::DeltaVarintF16.decode(&dup, 10).is_err());

    // an over-wide varint (5th byte carrying > 4 payload bits) errors
    // instead of silently truncating the index
    let wide = [0x81, 0x80, 0x80, 0x80, 0x7F, 0x00, 0x3C];
    assert!(WireCodec::DeltaVarintF16.decode(&wide, 10).is_err());
}

/// The allgather-Δβ strategy satellite: identical trajectories to
/// reduce-Δm on both the dna-like (n >> p) and webspam-like (p >> n)
/// shapes, while never costing more on the wire.
#[test]
fn allgather_beta_reproduces_reduce_dm_trajectory() {
    let problems = [
        ("dna-like", synth::dna_like(900, 80, 6, 640)),
        ("webspam-like", synth::webspam_like(400, 6_000, 10, 641)),
    ];
    for (name, ds) in problems {
        let lam = lambda_max(&ds) / 4.0;
        let mk = |exchange: ExchangeStrategy| {
            TrainConfig::builder()
                .machines(6)
                .engine(EngineKind::Native)
                .lambda(lam)
                .max_iter(20)
                .exchange(exchange)
                .build()
        };
        let mut red = DGlmnetSolver::from_dataset(&ds, &mk(ExchangeStrategy::ReduceDm)).unwrap();
        let mut gat =
            DGlmnetSolver::from_dataset(&ds, &mk(ExchangeStrategy::AllGatherBeta)).unwrap();
        let fr = red.fit(None).unwrap();
        let fg = gat.fit(None).unwrap();
        assert_eq!(fr.iterations, fg.iterations, "{name}");
        for (a, b) in fr.trace.iter().zip(&fg.trace) {
            assert_eq!(
                a.objective.to_bits(),
                b.objective.to_bits(),
                "{name} iter {}",
                a.iter
            );
        }
        assert_eq!(red.beta, gat.beta, "{name}");
        assert!(
            fg.comm_bytes <= fr.comm_bytes,
            "{name}: allgather-Δβ must never cost more ({} vs {})",
            fg.comm_bytes,
            fr.comm_bytes
        );
    }
}

/// Opting into the lossy f16 codec for Δ-margin messages (reduce-Δm
/// strategy, where Δm actually crosses the wire) must cut bytes and stay
/// within a small objective tolerance of the lossless path.
#[test]
fn f16_margins_cut_bytes_within_objective_tolerance() {
    let ds = synth::webspam_like(600, 8_000, 10, 642);
    let lam = lambda_max(&ds) / 4.0;
    let mk = |f16: bool| {
        TrainConfig::builder()
            .machines(8)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(25)
            .exchange(ExchangeStrategy::ReduceDm)
            .wire_f16_margins(f16)
            .build()
    };
    let mut lossless = DGlmnetSolver::from_dataset(&ds, &mk(false)).unwrap();
    let f_lossless = lossless.fit(None).unwrap();
    let mut lossy = DGlmnetSolver::from_dataset(&ds, &mk(true)).unwrap();
    let f_lossy = lossy.fit(None).unwrap();

    assert!(
        f_lossy.comm_bytes < f_lossless.comm_bytes,
        "f16 wire must be cheaper: {} vs {}",
        f_lossy.comm_bytes,
        f_lossless.comm_bytes
    );
    let rel = (f_lossy.objective - f_lossless.objective).abs()
        / f_lossless.objective.abs().max(1.0);
    assert!(
        rel <= 2e-2,
        "f16 objective drifted too far: {} vs {} (rel {rel:.2e})",
        f_lossy.objective,
        f_lossless.objective
    );
}
