//! Wire-codec property tests: lossless codecs round-trip bit-exactly, the
//! f16 codec's error is bounded, charged bytes equal encoded length for
//! every codec, the allgather-Δβ exchange reproduces the reduce-Δm
//! objective trajectory exactly on dna-like and webspam-like problems, and
//! the physical peer-to-peer tree topology is pinned: tree-edge frames
//! carry exactly the bytes the ledger charges, and a tree-socket fit is
//! bit-identical — trajectory, β, and charged ledger — to star-socket and
//! in-process at M ∈ {3, 8} while the leader moves strictly fewer bytes.

mod common;

use std::net::TcpListener;

use common::prop_check;
use dglmnet::cluster::codec::{
    f16_round_trip, CodecPolicy, MessageClass, WireCodec,
};
use dglmnet::cluster::protocol::{
    EdgeStat, NodeMessage, OriginStat, TreePayload, TreeSwept,
};
use dglmnet::config::{EngineKind, ExchangeStrategy, TopologyKind, TrainConfig};
use dglmnet::data::dataset::Dataset;
use dglmnet::data::sparse::SparseVec;
use dglmnet::data::synth;
use dglmnet::solver::pool::spawn_local_socket_workers_counted;
use dglmnet::solver::{lambda_max, DGlmnetSolver, FitResult};
use dglmnet::util::rng::Xoshiro256;

/// Random sparse message with nonzero values in the f16 normal range
/// (magnitudes 0.5..64 — away from subnormals and overflow so the lossy
/// round-trip bound is the generic 2^-11 relative one).
fn random_message(rng: &mut Xoshiro256) -> SparseVec {
    let dim = 1 + rng.below(900);
    let density = match rng.below(3) {
        0 => 0.02,
        1 => 0.3,
        _ => 0.8,
    };
    let mut v = SparseVec::new(dim);
    for i in 0..dim {
        if rng.uniform() < density {
            let mag = rng.uniform_in(0.5, 64.0) as f32;
            let val = if rng.bernoulli(0.5) { mag } else { -mag };
            v.push(i as u32, val);
        }
    }
    v
}

#[test]
fn prop_lossless_codecs_round_trip_bit_exact() {
    prop_check("lossless-codec-roundtrip", 200, |rng, _| {
        let msg = random_message(rng);
        for codec in [WireCodec::DenseF32, WireCodec::SparseU32F32] {
            assert!(codec.is_lossless());
            let bytes = codec.encode(&msg);
            let back = codec.decode(&bytes, msg.dim).unwrap();
            assert_eq!(back.dim, msg.dim, "{}", codec.name());
            assert_eq!(back.indices, msg.indices, "{}", codec.name());
            for (a, b) in msg.values.iter().zip(&back.values) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", codec.name());
            }
        }
        // delta-varint round-trips the *indices* bit-exactly too
        let bytes = WireCodec::DeltaVarintF16.encode(&msg);
        let back = WireCodec::DeltaVarintF16.decode(&bytes, msg.dim).unwrap();
        assert_eq!(back.indices, msg.indices);
    });
}

#[test]
fn prop_charged_bytes_match_encoded_length_for_every_codec() {
    prop_check("codec-cost-exact", 200, |rng, _| {
        let msg = random_message(rng);
        for codec in
            [WireCodec::DenseF32, WireCodec::SparseU32F32, WireCodec::DeltaVarintF16]
        {
            let encoded = codec.encode(&msg);
            assert_eq!(
                codec.encoded_bytes(&msg),
                encoded.len() as u64,
                "{}: cost model must equal the real encoded length",
                codec.name()
            );
        }
        // and the policy's pick never exceeds the dense equivalent
        for class in [MessageClass::Margins, MessageClass::Beta] {
            for policy in [
                CodecPolicy::lossless(),
                CodecPolicy { f16_margins: true, f16_beta: true, ..CodecPolicy::default() },
            ] {
                let (_, cost) = policy.pick(&msg.indices, msg.dim, class);
                assert!(cost <= msg.dim as u64 * 4);
            }
        }
    });
}

#[test]
fn prop_f16_codec_error_is_bounded() {
    prop_check("f16-codec-error-bound", 200, |rng, _| {
        let msg = random_message(rng);
        let bytes = WireCodec::DeltaVarintF16.encode(&msg);
        let back = WireCodec::DeltaVarintF16.decode(&bytes, msg.dim).unwrap();
        assert_eq!(back.nnz(), msg.nnz());
        for ((_, want), (_, got)) in msg.iter().zip(back.iter()) {
            let rel = ((got - want) / want).abs();
            assert!(rel <= 1.0 / 1024.0, "want {want}, got {got}, rel {rel}");
            // the decoded value is exactly the f16 quantization
            assert_eq!(got.to_bits(), f16_round_trip(want).to_bits());
        }
    });
}

#[test]
fn truncated_payloads_error_instead_of_panicking() {
    let msg = SparseVec::from_dense(&[0.0, 1.5, 0.0, -2.0]);
    for codec in [WireCodec::DenseF32, WireCodec::SparseU32F32, WireCodec::DeltaVarintF16] {
        let mut bytes = codec.encode(&msg);
        bytes.pop();
        assert!(codec.decode(&bytes, msg.dim).is_err(), "{}", codec.name());
    }
    // out-of-range indices are rejected
    let bytes = WireCodec::SparseU32F32.encode(&msg);
    assert!(WireCodec::SparseU32F32.decode(&bytes, 2).is_err());

    // non-ascending sparse payloads are rejected, not silently accepted
    let mut unsorted = Vec::new();
    for (i, v) in [(5u32, 1.0f32), (3, 2.0)] {
        unsorted.extend_from_slice(&i.to_le_bytes());
        unsorted.extend_from_slice(&v.to_le_bytes());
    }
    assert!(WireCodec::SparseU32F32.decode(&unsorted, 10).is_err());

    // a zero gap after the first delta entry would duplicate an index
    let dup = [0x01, 0x00, 0x3C, 0x00, 0x00, 0x3C]; // idx 1, then gap 0
    assert!(WireCodec::DeltaVarintF16.decode(&dup, 10).is_err());

    // an over-wide varint (5th byte carrying > 4 payload bits) errors
    // instead of silently truncating the index
    let wide = [0x81, 0x80, 0x80, 0x80, 0x7F, 0x00, 0x3C];
    assert!(WireCodec::DeltaVarintF16.decode(&wide, 10).is_err());
}

/// The allgather-Δβ strategy satellite: identical trajectories to
/// reduce-Δm on both the dna-like (n >> p) and webspam-like (p >> n)
/// shapes, while never costing more on the wire.
#[test]
fn allgather_beta_reproduces_reduce_dm_trajectory() {
    let problems = [
        ("dna-like", synth::dna_like(900, 80, 6, 640)),
        ("webspam-like", synth::webspam_like(400, 6_000, 10, 641)),
    ];
    for (name, ds) in problems {
        let lam = lambda_max(&ds) / 4.0;
        let mk = |exchange: ExchangeStrategy| {
            TrainConfig::builder()
                .machines(6)
                .engine(EngineKind::Native)
                .lambda(lam)
                .max_iter(20)
                .exchange(exchange)
                .build()
        };
        let mut red = DGlmnetSolver::from_dataset(&ds, &mk(ExchangeStrategy::ReduceDm)).unwrap();
        let mut gat =
            DGlmnetSolver::from_dataset(&ds, &mk(ExchangeStrategy::AllGatherBeta)).unwrap();
        let fr = red.fit(None).unwrap();
        let fg = gat.fit(None).unwrap();
        assert_eq!(fr.iterations, fg.iterations, "{name}");
        for (a, b) in fr.trace.iter().zip(&fg.trace) {
            assert_eq!(
                a.objective.to_bits(),
                b.objective.to_bits(),
                "{name} iter {}",
                a.iter
            );
        }
        assert_eq!(red.beta, gat.beta, "{name}");
        assert!(
            fg.comm_bytes <= fr.comm_bytes,
            "{name}: allgather-Δβ must never cost more ({} vs {})",
            fg.comm_bytes,
            fr.comm_bytes
        );
    }
}

/// Satellite pin: the bytes a tree edge frames for an f32-exact payload
/// equal the ledger's charged codec cost **byte-for-byte** under the
/// default lossless policy — the payload section is exactly the charged
/// cost plus the fixed 10-byte mode/header envelope the accounting
/// contract excludes (mode byte + `[u32 dim][u8 codec][u32 len]`). A
/// genuine f64 merge intermediate frames in raw mode with a fully
/// predictable size too: `1 + 8 + 12·nnz` bytes — wider than the f32
/// framing the model charges, which is why only interior Δm edges (whose
/// overlapping sums don't round-trip f32) ever pay it.
#[test]
fn prop_tree_edge_frames_cost_exactly_what_the_ledger_charges() {
    prop_check("tree-edge-frame-cost", 100, |rng, _| {
        let policy = CodecPolicy::lossless();
        let db_sv = random_message(rng);
        let dm_sv = random_message(rng);
        let widen = |sv: &SparseVec| TreePayload {
            dim: sv.dim as u32,
            indices: sv.indices.clone(),
            values: sv.values.iter().map(|&v| v as f64).collect(),
        };
        let (db, dm) = (widen(&db_sv), widen(&dm_sv));
        assert!(db.is_f32_exact() && dm.is_f32_exact());
        let origins = vec![
            OriginStat { machine: 1, compute_secs: 0.5, db_nnz: 3, dm_nnz: 4 },
            OriginStat { machine: 2, compute_secs: 0.25, db_nnz: 1, dm_nnz: 9 },
        ];
        let edges = vec![EdgeStat { into: 1, from: 2, db_nnz: 1, dm_nnz: 9 }];

        let body = NodeMessage::TreeSwept(TreeSwept {
            db,
            dm,
            origins: origins.clone(),
            edges: edges.clone(),
        })
        .encode();

        let (_, db_cost) = policy.pick(&db_sv.indices, db_sv.dim, MessageClass::Beta);
        let (_, dm_cost) = policy.pick(&dm_sv.indices, dm_sv.dim, MessageClass::Margins);
        let db_sec = 10 + db_cost as usize;
        let dm_sec = 10 + dm_cost as usize;
        let meta = 4 + 20 * origins.len() + 4 + 16 * edges.len();
        assert_eq!(
            body.len(),
            1 + db_sec + dm_sec + meta,
            "f32-exact tree payload must frame exactly the charged bytes"
        );

        // force a non-f32-exact Δm (an interior-edge merge sum) and pin the
        // raw-f64 section size: mode byte + dim + len + (u32 idx, f64 val)
        if dm_sv.nnz() > 0 {
            let mut raw = widen(&dm_sv);
            for v in &mut raw.values {
                *v += 1e-12;
            }
            assert!(!raw.is_f32_exact());
            let raw_sec = 1 + 8 + 12 * raw.nnz();
            let body_raw = NodeMessage::TreeSwept(TreeSwept {
                db: widen(&db_sv),
                dm: raw,
                origins: origins.clone(),
                edges: edges.clone(),
            })
            .encode();
            assert_eq!(body_raw.len(), body.len() - dm_sec + raw_sec);
        }
    });
}

fn topology_cfg(m: usize, lambda: f64, topology: TopologyKind) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(EngineKind::Native)
        .lambda(lambda)
        .max_iter(12)
        .topology(topology)
        .build()
}

/// One socket fit at the configured topology; returns the fit, the final
/// β, and the leader's measured bytes on the wire (sent, received).
fn socket_fit(ds: &Dataset, cfg: &TrainConfig, lambda: f64) -> (FitResult, Vec<f32>, (u64, u64)) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (workers, _counters) = spawn_local_socket_workers_counted(cfg, ds, addr);
    let mut solver = DGlmnetSolver::from_dataset_socket(ds, cfg, listener).unwrap();
    let fit = solver.fit_lambda(lambda).unwrap();
    let beta = solver.beta.clone();
    let wire = solver.leader_wire_bytes();
    drop(solver); // sends Shutdown to every node
    for h in workers {
        h.join().expect("worker thread panicked").unwrap();
    }
    (fit, beta, wire)
}

/// The tentpole acceptance pin: routing the merge bracket's edges over
/// physical worker↔worker links must not change a single bit — objective
/// trajectory, per-iteration records (including the auto strategy pick),
/// the charged comm ledger, and the final β all match the star-socket and
/// in-process runs exactly, on both dataset shapes at M ∈ {3, 8} — while
/// the leader's measured bytes on the wire strictly drop (its data plane
/// shrinks to the O(1) root edge).
#[test]
fn physical_tree_is_bit_identical_to_star_and_in_process() {
    let problems = [
        ("dna-like", synth::dna_like(900, 80, 6, 640)),
        ("webspam-like", synth::webspam_like(400, 6_000, 10, 641)),
    ];
    for (name, ds) in &problems {
        let lam = lambda_max(ds) / 4.0;
        for m in [3usize, 8] {
            let cfg_star = topology_cfg(m, lam, TopologyKind::Star);
            let cfg_tree = topology_cfg(m, lam, TopologyKind::Tree);

            let mut local = DGlmnetSolver::from_dataset(ds, &cfg_star).unwrap();
            let fit_local = local.fit_lambda(lam).unwrap();
            let (fit_star, beta_star, wire_star) = socket_fit(ds, &cfg_star, lam);
            let (fit_tree, beta_tree, wire_tree) = socket_fit(ds, &cfg_tree, lam);
            assert!(fit_local.iterations >= 2, "{name} M={m}: need a non-trivial fit");

            for (fit, beta, kind) in
                [(&fit_star, &beta_star, "star"), (&fit_tree, &beta_tree, "tree")]
            {
                assert_eq!(fit_local.iterations, fit.iterations, "{name} M={m} {kind}");
                assert_eq!(
                    fit_local.objective.to_bits(),
                    fit.objective.to_bits(),
                    "{name} M={m} {kind}: objective diverged"
                );
                assert_eq!(
                    fit_local.comm_bytes, fit.comm_bytes,
                    "{name} M={m} {kind}: charged ledger diverged"
                );
                assert_eq!(fit_local.trace.len(), fit.trace.len(), "{name} M={m} {kind}");
                for (a, b) in fit_local.trace.iter().zip(&fit.trace) {
                    assert_eq!(
                        a.objective.to_bits(),
                        b.objective.to_bits(),
                        "{name} M={m} {kind} iter {}",
                        a.iter
                    );
                    assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "{name} M={m} {kind}");
                    assert_eq!(a.comm_bytes, b.comm_bytes, "{name} M={m} {kind}");
                    assert_eq!(a.exchange, b.exchange, "{name} M={m} {kind}");
                }
                for (j, (a, b)) in local.beta.iter().zip(beta).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{name} M={m} {kind} beta[{j}]");
                }
            }

            // the leader's *measured* traffic must strictly drop under the
            // tree: its per-iteration data plane is one Sweep↓ + one merged
            // TreeSwept↑ + one Apply↓ + one Ack↑ on the root edge, vs M of
            // each under the star
            let (star_total, tree_total) =
                (wire_star.0 + wire_star.1, wire_tree.0 + wire_tree.1);
            assert!(
                tree_total < star_total,
                "{name} M={m}: tree leader must move fewer bytes ({tree_total} vs {star_total})"
            );
        }
    }
}

/// Opting into the lossy f16 codec for Δ-margin messages (reduce-Δm
/// strategy, where Δm actually crosses the wire) must cut bytes and stay
/// within a small objective tolerance of the lossless path.
#[test]
fn f16_margins_cut_bytes_within_objective_tolerance() {
    let ds = synth::webspam_like(600, 8_000, 10, 642);
    let lam = lambda_max(&ds) / 4.0;
    let mk = |f16: bool| {
        TrainConfig::builder()
            .machines(8)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(25)
            .exchange(ExchangeStrategy::ReduceDm)
            .wire_f16_margins(f16)
            .build()
    };
    let mut lossless = DGlmnetSolver::from_dataset(&ds, &mk(false)).unwrap();
    let f_lossless = lossless.fit(None).unwrap();
    let mut lossy = DGlmnetSolver::from_dataset(&ds, &mk(true)).unwrap();
    let f_lossy = lossy.fit(None).unwrap();

    assert!(
        f_lossy.comm_bytes < f_lossless.comm_bytes,
        "f16 wire must be cheaper: {} vs {}",
        f_lossy.comm_bytes,
        f_lossless.comm_bytes
    );
    let rel = (f_lossy.objective - f_lossless.objective).abs()
        / f_lossless.objective.abs().max(1.0);
    assert!(
        rel <= 2e-2,
        "f16 objective drifted too far: {} vs {} (rel {rel:.2e})",
        f_lossy.objective,
        f_lossless.objective
    );
}
