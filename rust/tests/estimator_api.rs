//! Acceptance tests for the unified Estimator / FitDriver API:
//!
//! * stepwise-vs-monolithic equivalence — driving `FitDriver::step()` to
//!   convergence is bit-identical (objective, β, per-iteration and total
//!   comm-bytes ledger) to the one-shot `fit()` path, on both a sparse
//!   (dna-like) and a dense (epsilon-like) problem;
//! * checkpoint/resume round-trip — a checkpoint saved at iteration k and
//!   resumed in a fresh solver (as a fresh process would) reproduces the
//!   uninterrupted final objective exactly;
//! * all four solvers behind `&mut dyn Estimator`;
//! * observer early-stop and `TrainConfig::budget` caps.

use dglmnet::config::{EngineKind, FitBudget, TrainConfig};
use dglmnet::data::dataset::Dataset;
use dglmnet::data::synth;
use dglmnet::solver::{
    fit_cold, lambda_max, Checkpoint, DGlmnetSolver, Estimator, FitControl, FitObserver,
    FitStep, NoopObserver, RecordingObserver, StepOutcome, StopReason,
};

fn native_cfg(m: usize, lambda: f64) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(EngineKind::Native)
        .lambda(lambda)
        .max_iter(40)
        .build()
}

fn assert_stepwise_equals_monolithic(ds: &Dataset, cfg: &TrainConfig, lambda: f64) {
    let mut mono = DGlmnetSolver::from_dataset(ds, cfg).unwrap();
    let fit_mono = mono.fit_lambda(lambda).unwrap();

    let mut stepped = DGlmnetSolver::from_dataset(ds, cfg).unwrap();
    let mut driver = stepped.driver(lambda);
    let mut steps = 0usize;
    loop {
        match driver.step().unwrap() {
            StepOutcome::Progress(_) => steps += 1,
            StepOutcome::Finished { record, reason } => {
                if record.is_some() {
                    steps += 1;
                }
                assert_ne!(reason, StopReason::Observer);
                break;
            }
        }
    }
    let fit_step = driver.finish();

    assert_eq!(fit_mono.iterations, fit_step.iterations);
    assert_eq!(steps, fit_step.iterations);
    assert_eq!(fit_mono.converged, fit_step.converged);
    assert_eq!(
        fit_mono.objective.to_bits(),
        fit_step.objective.to_bits(),
        "objective must be bit-identical: {} vs {}",
        fit_mono.objective,
        fit_step.objective
    );
    assert_eq!(fit_mono.comm_bytes, fit_step.comm_bytes, "comm ledger must match");
    assert_eq!(fit_mono.trace.len(), fit_step.trace.len());
    for (a, b) in fit_mono.trace.iter().zip(&fit_step.trace) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "iter {}", a.iter);
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "iter {}", a.iter);
        assert_eq!(a.comm_bytes, b.comm_bytes, "iter {}", a.iter);
        assert_eq!(a.fast_path, b.fast_path, "iter {}", a.iter);
    }
    assert_eq!(mono.beta.len(), stepped.beta.len());
    for (j, (a, b)) in mono.beta.iter().zip(&stepped.beta).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "beta[{j}]");
    }
}

#[test]
fn stepwise_equals_monolithic_on_dna_like() {
    let ds = synth::dna_like(600, 50, 5, 101);
    let lam = lambda_max(&ds) / 8.0;
    assert_stepwise_equals_monolithic(&ds, &native_cfg(4, lam), lam);
}

#[test]
fn stepwise_equals_monolithic_on_epsilon_like() {
    let ds = synth::epsilon_like(500, 32, 102);
    let lam = lambda_max(&ds) / 16.0;
    assert_stepwise_equals_monolithic(&ds, &native_cfg(3, lam), lam);
}

#[test]
fn checkpoint_resume_reproduces_uninterrupted_objective_exactly() {
    let ds = synth::dna_like(500, 40, 5, 103);
    let lam = lambda_max(&ds) / 64.0; // small λ => plenty of iterations
    let cfg = native_cfg(4, lam);

    let mut whole = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let fit_whole = whole.fit_lambda(lam).unwrap();
    assert!(fit_whole.iterations > 3, "need a fit long enough to interrupt");

    // run 3 iterations, checkpoint, and abandon the driver (simulated crash)
    let mut partial = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let ck = {
        let mut driver = partial.driver(lam);
        for _ in 0..3 {
            match driver.step().unwrap() {
                StepOutcome::Progress(_) => {}
                StepOutcome::Finished { .. } => panic!("finished before the checkpoint"),
            }
        }
        driver.checkpoint().unwrap()
    };
    assert_eq!(ck.iter, 3);

    // round-trip through disk, then resume in a fresh solver ("fresh
    // process": nothing shared with `partial` but the dataset + config)
    let path = std::env::temp_dir().join(format!("dglmnet_resume_{}.json", std::process::id()));
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(ck, loaded);

    let mut fresh = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let fit_resumed = fresh
        .driver_from_checkpoint(&loaded)
        .unwrap()
        .run(&mut NoopObserver)
        .unwrap();

    assert_eq!(
        fit_whole.objective.to_bits(),
        fit_resumed.objective.to_bits(),
        "resumed objective must be exact: {} vs {}",
        fit_whole.objective,
        fit_resumed.objective
    );
    assert_eq!(fit_whole.iterations, fit_resumed.iterations);
    assert_eq!(fit_whole.converged, fit_resumed.converged);
    assert_eq!(fit_whole.comm_bytes, fit_resumed.comm_bytes);
    for (j, (a, b)) in whole.beta.iter().zip(&fresh.beta).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "beta[{j}]");
    }
}

/// The GLM-subsystem seed-exactness pin: a config that *explicitly* asks
/// for the default family (logistic, pure L1) must be indistinguishable —
/// objective trace, comm ledger, final β, and the saved artifact's bytes —
/// from a config that never mentions families at all. Run on both synth
/// shapes (tall-sparse dna-like, wide webspam-like) so both sweep layouts
/// are covered.
#[test]
fn explicit_logistic_pure_l1_is_bit_identical_to_defaults() {
    use dglmnet::family::FamilyKind;
    let cases = [
        ("dna-like", synth::dna_like(600, 50, 5, 112)),
        ("webspam-like", synth::webspam_like(300, 1_200, 15, 113)),
    ];
    for (name, ds) in &cases {
        let lam = lambda_max(ds) / 8.0;
        let mut plain = DGlmnetSolver::from_dataset(ds, &native_cfg(3, lam)).unwrap();
        let fit_plain = plain.fit_lambda(lam).unwrap();
        assert!(fit_plain.iterations >= 2, "{name}: need a non-trivial fit");

        let explicit_cfg = TrainConfig::builder()
            .machines(3)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(40)
            .family(FamilyKind::Logistic)
            .enet_alpha(1.0)
            .build();
        let mut explicit = DGlmnetSolver::from_dataset(ds, &explicit_cfg).unwrap();
        let fit_explicit = explicit.fit_lambda(lam).unwrap();

        assert_eq!(fit_plain.iterations, fit_explicit.iterations, "{name}");
        assert_eq!(
            fit_plain.objective.to_bits(),
            fit_explicit.objective.to_bits(),
            "{name}: objectives diverged"
        );
        assert_eq!(fit_plain.comm_bytes, fit_explicit.comm_bytes, "{name}: comm ledger");
        assert_eq!(fit_plain.trace.len(), fit_explicit.trace.len());
        for (a, b) in fit_plain.trace.iter().zip(&fit_explicit.trace) {
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{name} iter {}", a.iter);
            assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "{name} iter {}", a.iter);
            assert_eq!(a.comm_bytes, b.comm_bytes, "{name} iter {}", a.iter);
        }
        for (j, (a, b)) in plain.beta.iter().zip(&explicit.beta).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{name} beta[{j}]");
        }

        // ... and the artifacts both write are the seed's format, byte for
        // byte: a default fit must carry no family=/alpha= header tokens
        let pid = std::process::id();
        let pa = std::env::temp_dir().join(format!("dglmnet_pin_a_{pid}_{name}.model"));
        let pb = std::env::temp_dir().join(format!("dglmnet_pin_b_{pid}_{name}.model"));
        fit_plain.model.clone().with_meta(ds.n_examples(), "dglmnet").save(&pa).unwrap();
        fit_explicit.model.clone().with_meta(ds.n_examples(), "dglmnet").save(&pb).unwrap();
        let (ba, bb) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        std::fs::remove_file(&pa).ok();
        std::fs::remove_file(&pb).ok();
        assert_eq!(ba, bb, "{name}: artifact bytes diverged");
        let text = String::from_utf8_lossy(&ba);
        assert!(!text.contains("family="), "{name}: default artifact named a family");
        assert!(!text.contains("alpha="), "{name}: default artifact carried alpha");
    }
}

#[test]
fn checkpoint_rejects_mismatched_solver() {
    let ds = synth::dna_like(200, 20, 4, 104);
    let cfg = native_cfg(2, 0.5);
    let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let ck = solver.driver(0.5).checkpoint().unwrap();
    let other = synth::dna_like(150, 30, 4, 105);
    let mut wrong = DGlmnetSolver::from_dataset(&other, &native_cfg(2, 0.5)).unwrap();
    assert!(wrong.driver_from_checkpoint(&ck).is_err());
}

#[test]
fn all_four_solvers_fit_through_dyn_estimator() {
    use dglmnet::baselines::{
        DistributedOnlineEstimator, ShotgunEstimator, TruncatedGradientEstimator,
    };
    let ds = synth::dna_like(400, 30, 5, 106);
    let lam = lambda_max(&ds) / 8.0;
    let mut dg = DGlmnetSolver::from_dataset(&ds, &native_cfg(2, lam)).unwrap();
    let mut sg = ShotgunEstimator::new(lam, 4, 30, 7);
    let mut tg = TruncatedGradientEstimator::new(0.3, 0.8, lam, 4, 7);
    let mut ol = DistributedOnlineEstimator::new(2, 0.3, 0.8, lam, 4, 7);
    let ests: Vec<&mut dyn Estimator> = vec![&mut dg, &mut sg, &mut tg, &mut ol];

    let mut names = Vec::new();
    for est in ests {
        let name = est.name();
        let fit = fit_cold(est, &ds, &mut NoopObserver)
            .unwrap_or_else(|e| panic!("{name} failed: {e}"));
        assert!(fit.objective.is_finite(), "{name}");
        assert!(fit.iterations > 0, "{name}");
        assert_eq!(fit.nnz(), est.model().nnz(), "{name}");
        assert_eq!(fit.lambda, est.lambda(), "{name}");
        names.push(name);
    }
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), 4, "estimator names must be distinct: {names:?}");
}

struct StopAfter(usize);

impl FitObserver for StopAfter {
    fn on_iteration(&mut self, step: &FitStep<'_>) -> FitControl {
        if step.record.iter >= self.0 {
            FitControl::Stop
        } else {
            FitControl::Continue
        }
    }
}

#[test]
fn observer_early_stop_ends_the_fit() {
    let ds = synth::dna_like(500, 40, 5, 107);
    let lam = lambda_max(&ds) / 64.0;
    let mut solver = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, lam)).unwrap();
    let fit = Estimator::fit(&mut solver, &ds, &mut StopAfter(3)).unwrap();
    assert_eq!(fit.iterations, 3);
    assert!(!fit.converged);
    // the model reflects the 3 applied updates
    assert_eq!(fit.nnz(), Estimator::model(&solver).nnz());
}

#[test]
fn recording_observer_sees_the_whole_trace() {
    let ds = synth::dna_like(300, 25, 4, 108);
    let lam = lambda_max(&ds) / 8.0;
    let mut solver = DGlmnetSolver::from_dataset(&ds, &native_cfg(2, lam)).unwrap();
    let mut obs = RecordingObserver::default();
    let fit = Estimator::fit(&mut solver, &ds, &mut obs).unwrap();
    assert_eq!(obs.records.len(), fit.trace.len());
    for (a, b) in obs.records.iter().zip(&fit.trace) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }
}

#[test]
fn iteration_budget_stops_between_iterations() {
    let ds = synth::dna_like(500, 40, 5, 109);
    let lam = lambda_max(&ds) / 64.0;
    let mut cfg = native_cfg(4, lam);
    cfg.budget = FitBudget { iterations: Some(2), ..FitBudget::default() };
    let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let mut driver = solver.driver(lam);
    assert!(matches!(driver.step().unwrap(), StepOutcome::Progress(_)));
    assert!(matches!(driver.step().unwrap(), StepOutcome::Progress(_)));
    match driver.step().unwrap() {
        StepOutcome::Finished { record, reason } => {
            assert!(record.is_none());
            assert_eq!(reason, StopReason::IterationBudget);
        }
        other => panic!("expected budget stop, got {other:?}"),
    }
    let fit = driver.finish();
    assert_eq!(fit.iterations, 2);
    assert!(!fit.converged);
}

#[test]
fn comm_budget_stops_after_first_traffic() {
    let ds = synth::dna_like(500, 40, 5, 110);
    let lam = lambda_max(&ds) / 64.0;
    let mut cfg = native_cfg(4, lam);
    cfg.budget.comm_bytes = Some(1); // any traffic at all exhausts it
    let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let fit = solver.fit_lambda(lam).unwrap();
    assert_eq!(fit.iterations, 1);
    assert!(!fit.converged);
    assert!(fit.comm_bytes >= 1);
}

#[test]
fn budget_spans_resume_boundaries() {
    // 5-iteration budget, interrupted at 2: the resumed driver may only run
    // 3 more
    let ds = synth::dna_like(500, 40, 5, 111);
    let lam = lambda_max(&ds) / 64.0;
    let mut cfg = native_cfg(4, lam);
    cfg.budget.iterations = Some(5);
    let mut a = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let ck = {
        let mut driver = a.driver(lam);
        for _ in 0..2 {
            assert!(matches!(driver.step().unwrap(), StepOutcome::Progress(_)));
        }
        driver.checkpoint().unwrap()
    };
    let mut b = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let fit = b
        .driver_from_checkpoint(&ck)
        .unwrap()
        .run(&mut NoopObserver)
        .unwrap();
    assert_eq!(fit.iterations, 5); // 2 carried + 3 fresh
    assert!(!fit.converged);
    assert_eq!(fit.trace.len(), 3);
}
