//! Acceptance tests for the transport-agnostic node protocol (PR 4):
//!
//! * in-process vs socket transports produce **bit-identical** β
//!   trajectories (objective, per-iteration records, comm ledger) on
//!   dna-like and webspam-like shapes;
//! * under worker-held β shards the merged-Δβ broadcast no longer exists,
//!   so `comm_bytes` strictly decreases vs the PR-3 accounting (pinned via
//!   the `charge_beta_broadcast` compat ablation) on webspam-like at
//!   λ_max/4 with M = 8;
//! * transport faults surface cleanly: a worker that dies mid-sweep and a
//!   worker that sends malformed frames both produce a prompt `Err` on the
//!   leader — no hang, no partial merge applied;
//! * checkpoints capture the worker-held shard state, and a resume
//!   mid-path under `transport = socket` reproduces the uninterrupted
//!   run's objective and comm ledger exactly.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::thread::JoinHandle;
use std::time::Duration;

use dglmnet::cluster::protocol::{crc_u32, NodeMessage};
use dglmnet::cluster::transport::SocketTransport;
use dglmnet::cluster::WorkerNode;
use dglmnet::config::{EngineKind, ExchangeStrategy, TrainConfig};
use dglmnet::data::dataset::Dataset;
use dglmnet::data::store::ShardStore;
use dglmnet::data::synth;
use dglmnet::solver::pool::{
    spawn_local_socket_workers, spawn_local_socket_workers_from_store,
};
use dglmnet::solver::{
    lambda_max, Checkpoint, DGlmnetSolver, FitResult, NoopObserver, StepOutcome,
};

fn native_cfg(m: usize, lambda: f64, max_iter: usize) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(EngineKind::Native)
        .lambda(lambda)
        .max_iter(max_iter)
        .build()
}

/// Run one fit over real TCP sockets: bind an ephemeral port, launch one
/// worker thread per partition block (each serving a `WorkerNode` over its
/// own connection), fit, and join the workers.
fn socket_fit(ds: &Dataset, cfg: &TrainConfig, lambda: f64) -> (FitResult, Vec<f32>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let workers = spawn_local_socket_workers(cfg, ds, addr);
    let mut solver = DGlmnetSolver::from_dataset_socket(ds, cfg, listener).unwrap();
    assert_eq!(solver.transport_kind(), "socket");
    let fit = solver.fit_lambda(lambda).unwrap();
    let beta = solver.beta.clone();
    drop(solver); // sends Shutdown to every node
    for h in workers {
        h.join().expect("worker thread panicked").unwrap();
    }
    (fit, beta)
}

fn in_process_fit(ds: &Dataset, cfg: &TrainConfig, lambda: f64) -> (FitResult, Vec<f32>) {
    let mut solver = DGlmnetSolver::from_dataset(ds, cfg).unwrap();
    assert_eq!(solver.transport_kind(), "in-process");
    let fit = solver.fit_lambda(lambda).unwrap();
    let beta = solver.beta.clone();
    (fit, beta)
}

/// The headline acceptance pin: the transport must not change a single bit
/// of the trajectory — objectives, per-iteration records, the comm ledger,
/// and the final β all match exactly on both dataset shapes.
#[test]
fn socket_and_in_process_trajectories_are_bit_identical() {
    let problems = [
        ("dna-like", synth::dna_like(600, 50, 5, 701), 8.0),
        ("webspam-like", synth::webspam_like(400, 6_000, 10, 702), 4.0),
    ];
    for (name, ds, div) in problems {
        let lam = lambda_max(&ds) / div;
        let cfg = native_cfg(4, lam, 15);
        let (fit_local, beta_local) = in_process_fit(&ds, &cfg, lam);
        let (fit_socket, beta_socket) = socket_fit(&ds, &cfg, lam);

        assert_eq!(fit_local.iterations, fit_socket.iterations, "{name}");
        assert_eq!(
            fit_local.objective.to_bits(),
            fit_socket.objective.to_bits(),
            "{name}: objectives diverged"
        );
        assert_eq!(fit_local.comm_bytes, fit_socket.comm_bytes, "{name}: ledger diverged");
        assert_eq!(fit_local.trace.len(), fit_socket.trace.len(), "{name}");
        for (a, b) in fit_local.trace.iter().zip(&fit_socket.trace) {
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{name} iter {}", a.iter);
            assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "{name} iter {}", a.iter);
            assert_eq!(a.comm_bytes, b.comm_bytes, "{name} iter {}", a.iter);
            assert_eq!(a.exchange, b.exchange, "{name} iter {}", a.iter);
        }
        assert_eq!(beta_local.len(), beta_socket.len(), "{name}");
        for (j, (a, b)) in beta_local.iter().zip(&beta_socket).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{name} beta[{j}]");
        }
    }
}

/// Out-of-core acceptance pin: a socket-transport fit driven **entirely
/// from a sharded on-disk store** — every worker self-loads only its own
/// shard file, and the leader (built by `from_store_socket`) never
/// constructs a CSR/CSC matrix of X — produces a bit-identical objective
/// trajectory, comm-bytes ledger, and final β to the in-memory in-process
/// run.
#[test]
fn store_driven_socket_fit_is_bit_identical_to_in_memory() {
    let ds = synth::webspam_like(500, 4_000, 10, 708);
    let lam = lambda_max(&ds) / 4.0;
    let cfg = native_cfg(3, lam, 15);

    // in-memory reference (in-process transport)
    let (fit_mem, beta_mem) = in_process_fit(&ds, &cfg, lam);
    assert!(fit_mem.iterations >= 2, "need a non-trivial fit");

    // shard to disk, then drive the whole fit from the store over sockets
    let dir = std::env::temp_dir()
        .join(format!("dglmnet_store_e2e_{}", std::process::id()));
    let partition = DGlmnetSolver::partition_for(&ds, &cfg);
    let store = ShardStore::create(&dir, &ds, &partition, "round-robin").unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let workers = spawn_local_socket_workers_from_store(&cfg, &store, addr);
    let mut solver = DGlmnetSolver::from_store_socket(&store, &cfg, listener).unwrap();
    assert_eq!(solver.transport_kind(), "socket");
    let fit_store = solver.fit_lambda(lam).unwrap();
    let beta_store = solver.beta.clone();
    drop(solver); // sends Shutdown to every node
    for h in workers {
        h.join().expect("store worker panicked").unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();

    assert_eq!(fit_mem.iterations, fit_store.iterations);
    assert_eq!(
        fit_mem.objective.to_bits(),
        fit_store.objective.to_bits(),
        "store-driven objective diverged"
    );
    assert_eq!(fit_mem.comm_bytes, fit_store.comm_bytes, "ledger diverged");
    for (a, b) in fit_mem.trace.iter().zip(&fit_store.trace) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "iter {}", a.iter);
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "iter {}", a.iter);
        assert_eq!(a.comm_bytes, b.comm_bytes, "iter {}", a.iter);
        assert_eq!(a.exchange, b.exchange, "iter {}", a.iter);
    }
    for (j, (a, b)) in beta_mem.iter().zip(&beta_store).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "beta[{j}]");
    }
}

/// PR-4 acceptance: with worker-held β shards the per-sweep merged-Δβ
/// broadcast is gone, so total `comm_bytes` strictly decreases versus the
/// PR-3 accounting (reproduced bit-for-bit by the `charge_beta_broadcast`
/// ablation) — same trajectory, strictly cheaper wire — on the webspam
/// regime at λ_max/4 with M = 8.
#[test]
fn worker_held_shards_strictly_cut_comm_bytes_vs_pr3() {
    let ds = synth::webspam_like(800, 16_000, 10, 703);
    let lam = lambda_max(&ds) / 4.0;
    let cfg_new = native_cfg(8, lam, 25);
    let mut cfg_pr3 = native_cfg(8, lam, 25);
    cfg_pr3.charge_beta_broadcast = true;

    let mut new = DGlmnetSolver::from_dataset(&ds, &cfg_new).unwrap();
    let fit_new = new.fit(None).unwrap();
    let mut pr3 = DGlmnetSolver::from_dataset(&ds, &cfg_pr3).unwrap();
    let fit_pr3 = pr3.fit(None).unwrap();

    // accounting changes only: the trajectories are bit-identical
    assert_eq!(fit_new.iterations, fit_pr3.iterations);
    for (a, b) in fit_new.trace.iter().zip(&fit_pr3.trace) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "iter {}", a.iter);
    }
    assert_eq!(new.beta, pr3.beta);

    // the strict decrease, and a meaningful one (the broadcast retrace was
    // the majority of every allgather-Δβ exchange's bytes)
    assert!(fit_new.comm_bytes > 0);
    assert!(
        fit_new.comm_bytes < fit_pr3.comm_bytes,
        "gather-only accounting must strictly cut bytes: {} vs {}",
        fit_new.comm_bytes,
        fit_pr3.comm_bytes
    );
    assert!(
        fit_new.comm_bytes * 3 <= fit_pr3.comm_bytes * 2,
        "expected at least a third of the traffic gone, got {} vs {}",
        fit_new.comm_bytes,
        fit_pr3.comm_bytes
    );
    // the pin covers the regime it claims: the cost model actually picked
    // allgather-Δβ here
    assert!(fit_new
        .trace
        .iter()
        .any(|r| r.exchange == Some(ExchangeStrategy::AllGatherBeta)));
}

// ---------------------------------------------------------------------------
// fault handling
// ---------------------------------------------------------------------------

/// A well-behaved worker thread for one machine; tolerates the leader
/// erroring out (its serve result is ignored).
fn good_worker(
    ds: &Dataset,
    cfg: &TrainConfig,
    machine: usize,
    addr: SocketAddr,
) -> JoinHandle<()> {
    let shard = DGlmnetSolver::shard_for(ds, cfg, machine);
    let y = std::sync::Arc::new(ds.y.clone());
    let p = ds.n_features();
    let cfg = cfg.clone();
    std::thread::spawn(move || {
        let mut node =
            WorkerNode::from_shard(&cfg, shard, y, p, std::path::Path::new("artifacts"))
                .unwrap();
        let mut t = SocketTransport::connect_retry(addr, Duration::from_secs(20)).unwrap();
        let _ = node.serve(&mut t, None);
    })
}

fn read_frame(stream: &mut TcpStream) -> Vec<u8> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len).unwrap();
    let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
    stream.read_exact(&mut body).unwrap();
    body
}

fn write_frame(stream: &mut TcpStream, body: &[u8]) {
    stream.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
    stream.write_all(body).unwrap();
    stream.flush().unwrap();
}

fn join_body(ds: &Dataset, cfg: &TrainConfig, machine: usize) -> Vec<u8> {
    let partition = DGlmnetSolver::partition_for(ds, cfg);
    let cols = partition.features_of(machine);
    NodeMessage::Join {
        machine: machine as u32,
        n: ds.n_examples() as u32,
        p: ds.n_features() as u32,
        local_features: cols.len() as u32,
        cols_checksum: crc_u32(&cols),
        engine: "native".into(),
        family: "logistic".into(),
        listen_addr: String::new(),
    }
    .encode()
}

/// A worker process dying mid-sweep must surface as a clean, prompt error
/// on the leader — no hang, and no partial merge is ever applied (the
/// iteration errors out before the exchange).
#[test]
fn dead_worker_mid_sweep_surfaces_a_clean_error() {
    let ds = synth::dna_like(200, 20, 4, 704);
    let cfg = native_cfg(2, 0.2, 10);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let good = good_worker(&ds, &cfg, 0, addr);
    let join = join_body(&ds, &cfg, 1);
    let rogue = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        write_frame(&mut s, &join);
        let _welcome = read_frame(&mut s);
        let _sweep = read_frame(&mut s);
        // die without replying — mid-sweep from the leader's view
    });

    let mut solver = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
    let before = solver.beta.clone();
    let err = solver.fit_lambda(0.2).unwrap_err().to_string();
    assert!(err.contains("worker 1"), "{err}");
    assert!(err.contains("hung up"), "{err}");
    // no partial merge was applied to the leader state
    assert_eq!(solver.beta, before);
    drop(solver);
    rogue.join().unwrap();
    good.join().unwrap();
}

/// Malformed frames error through the protocol decoder exactly like the
/// codec truncation tests — a parse error naming the problem, not a panic
/// or a silently-wrong merge.
#[test]
fn malformed_frames_from_a_worker_error_cleanly() {
    let ds = synth::dna_like(200, 20, 4, 705);
    let cfg = native_cfg(2, 0.2, 10);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let good = good_worker(&ds, &cfg, 0, addr);
    let join = join_body(&ds, &cfg, 1);
    let rogue = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        write_frame(&mut s, &join);
        let _welcome = read_frame(&mut s);
        let _sweep = read_frame(&mut s);
        // reply with a frame whose tag does not exist
        write_frame(&mut s, &[77, 1, 2]);
        // hold the socket open until the leader has had its say
        let _ = read_frame(&mut s);
    });

    let mut solver = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
    let err = solver.fit_lambda(0.2).unwrap_err().to_string();
    assert!(err.contains("unknown message tag"), "{err}");
    drop(solver); // Shutdown frame unblocks the rogue's final read
    rogue.join().unwrap();
    good.join().unwrap();
}

// ---------------------------------------------------------------------------
// checkpoint / resume with worker-held state
// ---------------------------------------------------------------------------

/// The checkpoint captures the worker-held shard states (pulled over the
/// protocol) and they agree bit-for-bit with the leader's global β.
#[test]
fn checkpoint_captures_worker_shard_state() {
    let ds = synth::dna_like(300, 30, 4, 706);
    let lam = lambda_max(&ds) / 16.0;
    let cfg = native_cfg(3, lam, 20);
    let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let ck = {
        let mut driver = solver.driver(lam);
        for _ in 0..2 {
            assert!(matches!(driver.step().unwrap(), StepOutcome::Progress(_)));
        }
        driver.checkpoint().unwrap()
    };
    assert_eq!(ck.shards.len(), 3);
    assert!(ck.est_shrink.is_some());
    let partition = solver.partition().clone();
    for (k, shard) in ck.shards.iter().enumerate() {
        let cols = partition.features_of(k);
        assert_eq!(shard.len(), cols.len(), "machine {k}");
        for (l, &g) in cols.iter().enumerate() {
            assert_eq!(
                shard[l].to_bits(),
                ck.beta[g as usize].to_bits(),
                "machine {k} local {l}"
            );
        }
    }
}

/// Satellite acceptance: interrupt a socket-transport fit mid-path,
/// checkpoint (shard states included), resume into a *fresh* socket
/// cluster, and reproduce the uninterrupted socket run — objective and
/// comm ledger — exactly.
#[test]
fn socket_resume_mid_path_is_bit_exact() {
    let ds = synth::dna_like(500, 40, 5, 707);
    let lam = lambda_max(&ds) / 64.0; // plenty of iterations
    let cfg = native_cfg(3, lam, 40);

    // the uninterrupted reference, over sockets
    let (fit_whole, beta_whole) = socket_fit(&ds, &cfg, lam);
    assert!(fit_whole.iterations > 3, "need a fit long enough to interrupt");

    // partial run over sockets: 3 iterations, checkpoint, simulated crash
    let ck = {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let workers = spawn_local_socket_workers(&cfg, &ds, addr);
        let mut partial = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
        let ck = {
            let mut driver = partial.driver(lam);
            for _ in 0..3 {
                match driver.step().unwrap() {
                    StepOutcome::Progress(_) => {}
                    StepOutcome::Finished { .. } => panic!("finished before the checkpoint"),
                }
            }
            driver.checkpoint().unwrap()
        };
        drop(partial);
        for h in workers {
            h.join().unwrap().unwrap();
        }
        ck
    };
    assert_eq!(ck.iter, 3);
    assert_eq!(ck.shards.len(), 3);

    // round-trip through disk, then resume in a fresh socket cluster
    let path = std::env::temp_dir()
        .join(format!("dglmnet_socket_resume_{}.json", std::process::id()));
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(ck, loaded);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let workers = spawn_local_socket_workers(&cfg, &ds, addr);
    let mut fresh = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
    let fit_resumed = fresh
        .driver_from_checkpoint(&loaded)
        .unwrap()
        .run(&mut NoopObserver)
        .unwrap();
    let beta_resumed = fresh.beta.clone();
    drop(fresh);
    for h in workers {
        h.join().unwrap().unwrap();
    }

    assert_eq!(
        fit_whole.objective.to_bits(),
        fit_resumed.objective.to_bits(),
        "resumed objective must be exact: {} vs {}",
        fit_whole.objective,
        fit_resumed.objective
    );
    assert_eq!(fit_whole.iterations, fit_resumed.iterations);
    assert_eq!(fit_whole.comm_bytes, fit_resumed.comm_bytes);
    for (j, (a, b)) in beta_whole.iter().zip(&beta_resumed).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "beta[{j}]");
    }
}
