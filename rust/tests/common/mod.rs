//! From-scratch mini property-testing harness (no `proptest` in the
//! vendored set): deterministic case generation from a seeded RNG, failure
//! reporting with the seed that reproduces it.

use dglmnet::util::rng::Xoshiro256;

/// Run `check(rng, case_index)` for `cases` generated cases; panic with the
/// reproducing seed on the first failure (check panics or returns Err).
pub fn prop_check(name: &str, cases: usize, check: impl Fn(&mut Xoshiro256, usize)) {
    for case in 0..cases {
        let seed = 0xD1CE_0000u64 + case as u64;
        let mut rng = Xoshiro256::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(&mut rng, case);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Random sparse problem drawn from the generators, small enough for
/// hundreds of property cases.
pub fn random_small_dataset(rng: &mut Xoshiro256) -> dglmnet::data::Dataset {
    use dglmnet::data::synth;
    let n = 50 + rng.below(150);
    let kind = rng.below(3);
    let seed = rng.next_u64();
    match kind {
        0 => synth::epsilon_like(n, 8 + rng.below(24), seed),
        1 => synth::webspam_like(n, 100 + rng.below(400), 5 + rng.below(10), seed),
        _ => synth::dna_like(n, 16 + rng.below(48), 3 + rng.below(6), seed),
    }
}
