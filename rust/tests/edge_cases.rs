//! Edge cases and failure injection: degenerate datasets, bad
//! configurations, missing artifacts, and boundary shapes — the paths a
//! production deployment hits first.

mod common;

use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::dataset::Dataset;
use dglmnet::data::sparse::CsrMatrix;
use dglmnet::data::synth;
use dglmnet::metrics;
use dglmnet::solver::{lambda_max, DGlmnetSolver};

fn native(m: usize, lam: f64) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(EngineKind::Native)
        .lambda(lam)
        .max_iter(20)
        .build()
}

#[test]
fn all_positive_labels_converges_without_blowup() {
    // Degenerate class balance: loss is minimized by margins -> +inf, but
    // L1 keeps beta bounded and the solver must terminate finitely.
    let mut x = CsrMatrix::new(4);
    let mut y = Vec::new();
    for i in 0..50 {
        x.push_row(&[(0, 1.0), (1 + (i % 3) as u32, 0.5)]);
        y.push(1.0);
    }
    let ds = Dataset::new("allpos", x, y);
    let mut s = DGlmnetSolver::from_dataset(&ds, &native(2, 0.5)).unwrap();
    let fit = s.fit(None).unwrap();
    assert!(fit.objective.is_finite());
    assert!(fit.model.to_dense().iter().all(|b| b.is_finite()));
}

#[test]
fn single_example_dataset() {
    let mut x = CsrMatrix::new(2);
    x.push_row(&[(0, 1.0), (1, -1.0)]);
    let ds = Dataset::new("one", x, vec![1.0]);
    let mut s = DGlmnetSolver::from_dataset(&ds, &native(2, 0.01)).unwrap();
    let fit = s.fit(None).unwrap();
    assert!(fit.objective.is_finite());
}

#[test]
fn feature_never_observed_stays_zero() {
    // column 3 is all-zero: its coefficient must remain exactly 0
    let mut x = CsrMatrix::new(5);
    let mut y = Vec::new();
    for i in 0..80 {
        x.push_row(&[(0, 1.0), (1, (i % 5) as f32), (4, 1.0)]);
        y.push(if i % 2 == 0 { 1.0 } else { -1.0 });
    }
    let ds = Dataset::new("hole", x, y);
    let mut s = DGlmnetSolver::from_dataset(&ds, &native(2, 0.1)).unwrap();
    let fit = s.fit(None).unwrap();
    let dense = fit.model.to_dense();
    assert_eq!(dense[2], 0.0);
    assert_eq!(dense[3], 0.0);
}

#[test]
fn missing_artifacts_xla_errors_and_auto_falls_back() {
    // one test (not two) because it mutates process-wide env state
    std::env::set_var("DGLMNET_ARTIFACTS", "/nonexistent/definitely/missing");

    // explicit XLA: clean, actionable error
    let ds = synth::dna_like(100, 20, 4, 61);
    let mut cfg = native(2, 0.1);
    cfg.engine = EngineKind::Xla;
    let e = DGlmnetSolver::from_dataset(&ds, &cfg)
        .err()
        .expect("must fail without artifacts");
    assert!(e.to_string().contains("make artifacts"), "{e}");

    // Auto: silently falls back to the native engine
    let ds2 = synth::dna_like(120, 20, 4, 62);
    let mut cfg2 = native(2, 0.1);
    cfg2.engine = EngineKind::Auto;
    let mut s = DGlmnetSolver::from_dataset(&ds2, &cfg2)
        .expect("Auto must fall back to the native engine");
    assert!(s.fit(None).unwrap().objective.is_finite());

    std::env::remove_var("DGLMNET_ARTIFACTS");
}

#[test]
fn zero_lambda_is_plain_logistic_regression() {
    // λ = 0: no shrinkage — the model should fit the planted signal well
    // and produce a dense-ish beta.
    let ds = synth::epsilon_like(1_000, 16, 63);
    let mut s = DGlmnetSolver::from_dataset(&ds, &native(2, 0.0)).unwrap();
    let fit = s.fit(None).unwrap();
    let margins = fit.model.predict_margins(&ds.x);
    assert!(metrics::roc_auc(&margins, &ds.y) > 0.85);
}

#[test]
fn warmstart_across_solvers_via_set_beta() {
    let ds = synth::dna_like(400, 30, 5, 64);
    let lam = lambda_max(&ds) / 8.0;
    let mut a = DGlmnetSolver::from_dataset(&ds, &native(2, lam)).unwrap();
    let fit_a = a.fit(None).unwrap();
    // a fresh solver warmstarted at the solution must converge immediately
    let mut b = DGlmnetSolver::from_dataset(&ds, &native(3, lam)).unwrap();
    b.set_beta(&fit_a.model.to_dense()).unwrap();
    let fit_b = b.fit_lambda(lam).unwrap();
    assert!(fit_b.iterations <= 3, "warmstarted iters = {}", fit_b.iterations);
    assert!((fit_b.objective - fit_a.objective).abs() / fit_a.objective < 1e-3);
}

#[test]
fn margins_state_consistent_after_fit() {
    // solver invariant: margins == X·beta after every fit
    let ds = synth::webspam_like(300, 500, 12, 65);
    let lam = lambda_max(&ds) / 16.0;
    let mut s = DGlmnetSolver::from_dataset(&ds, &native(4, lam)).unwrap();
    s.fit(None).unwrap();
    let want = ds.x.margins(&s.beta);
    for i in (0..300).step_by(17) {
        assert!(
            (s.margins[i] - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()),
            "margins[{i}] drifted: {} vs {}",
            s.margins[i],
            want[i]
        );
    }
}
