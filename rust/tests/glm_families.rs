//! Acceptance tests for the GLM family subsystem (PR 9):
//!
//! * **Serial-reference convergence** — every family (logistic, gaussian,
//!   poisson; pure L1 and elastic-net mixes) fit by the distributed solver
//!   at M ∈ {1, 3} reaches the objective of an *independent* serial
//!   reference implementation (proximal gradient / ISTA with backtracking,
//!   written here from the subgradient optimality conditions, sharing no
//!   code with the solver) within tolerance;
//! * **Transport equivalence** — a real-TCP socket fit is bit-identical to
//!   the in-process fit at the same machine count, for non-logistic
//!   families too (the handshake carries family + alpha);
//! * **Checkpoint resume** — a gaussian/poisson fit interrupted mid-run and
//!   resumed in a fresh solver reproduces the uninterrupted final β and
//!   objective exactly;
//! * **Supervised failover** — a killed socket worker mid-poisson-fit is
//!   probed out, replaced, and the completed fit stays bit-identical;
//! * **Rejection paths** — alpha outside (0, 1], labels a family cannot
//!   handle, and family/alpha-mismatched checkpoints all fail fast with
//!   actionable errors instead of silently corrupting a fit.

use std::net::{SocketAddr, TcpListener};
use std::thread::JoinHandle;
use std::time::Duration;

use dglmnet::cluster::transport::{Fault, FaultyTransport, SocketTransport};
use dglmnet::cluster::WorkerNode;
use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::dataset::Dataset;
use dglmnet::data::synth;
use dglmnet::family::FamilyKind;
use dglmnet::solver::pool::spawn_local_socket_workers;
use dglmnet::solver::regpath::lambda_max_family;
use dglmnet::solver::{DGlmnetSolver, FitResult, NoopObserver, StepOutcome};

fn family_cfg(m: usize, lambda: f64, family: FamilyKind, alpha: f64) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(EngineKind::Native)
        .lambda(lambda)
        .max_iter(60)
        .family(family)
        .enet_alpha(alpha)
        .build()
}

// ---------------------------------------------------------------------------
// The independent serial reference: proximal gradient (ISTA) with
// backtracking, in f64 throughout. Shares only the family loss definitions
// with the crate — the optimization path is entirely different from the
// solver's block-diagonal Newton sweeps, so agreement means both found the
// same optimum, not the same bugs.
// ---------------------------------------------------------------------------

fn ref_margins(ds: &Dataset, beta: &[f64]) -> Vec<f64> {
    (0..ds.n_examples())
        .map(|i| {
            let (cols, vals) = ds.x.row(i);
            cols.iter().zip(vals).map(|(&j, &v)| v as f64 * beta[j as usize]).sum()
        })
        .collect()
}

fn ref_loss(ds: &Dataset, family: FamilyKind, margins: &[f64]) -> f64 {
    let fam = family.family();
    margins.iter().zip(&ds.y).map(|(&m, &y)| fam.loss(y as f64, m)).sum()
}

fn ref_grad(ds: &Dataset, family: FamilyKind, margins: &[f64]) -> Vec<f64> {
    let fam = family.family();
    let mut g = vec![0f64; ds.n_features()];
    for i in 0..ds.n_examples() {
        let d = fam.dloss(ds.y[i] as f64, margins[i]);
        let (cols, vals) = ds.x.row(i);
        for (&j, &v) in cols.iter().zip(vals) {
            g[j as usize] += d * v as f64;
        }
    }
    g
}

fn ref_penalty(beta: &[f64], lambda: f64, alpha: f64) -> f64 {
    let l1: f64 = beta.iter().map(|v| v.abs()).sum();
    let sq: f64 = beta.iter().map(|v| v * v).sum();
    lambda * (alpha * l1 + 0.5 * (1.0 - alpha) * sq)
}

/// Elastic-net proximal operator of the gradient step `v = β_j − t·g_j`:
/// soft-threshold by tλα, then shrink by the ridge term.
fn ref_prox(v: f64, t: f64, lambda: f64, alpha: f64) -> f64 {
    let s = v.abs() - t * lambda * alpha;
    let soft = if s > 0.0 { v.signum() * s } else { 0.0 };
    soft / (1.0 + t * lambda * (1.0 - alpha))
}

/// Minimize Σᵢ ℓ(yᵢ, βᵀxᵢ) + λ(α‖β‖₁ + (1−α)/2·‖β‖₂²) by ISTA with
/// backtracking line search; returns the optimal objective value.
fn reference_objective(ds: &Dataset, family: FamilyKind, lambda: f64, alpha: f64) -> f64 {
    let p = ds.n_features();
    let mut beta = vec![0f64; p];
    let mut t = 1.0f64;
    let mut prev_obj = f64::INFINITY;
    for _ in 0..5_000 {
        let m = ref_margins(ds, &beta);
        let l0 = ref_loss(ds, family, &m);
        let g = ref_grad(ds, family, &m);
        // backtrack until the quadratic upper bound holds at step t
        let next = loop {
            let cand: Vec<f64> = beta
                .iter()
                .zip(&g)
                .map(|(&b, &gj)| ref_prox(b - t * gj, t, lambda, alpha))
                .collect();
            let gd: f64 =
                g.iter().zip(&cand).zip(&beta).map(|((&gj, &c), &b)| gj * (c - b)).sum();
            let sq: f64 = cand.iter().zip(&beta).map(|(&c, &b)| (c - b) * (c - b)).sum();
            let l_c = ref_loss(ds, family, &ref_margins(ds, &cand));
            if l_c <= l0 + gd + sq / (2.0 * t) + 1e-12 {
                break cand;
            }
            t *= 0.5;
            assert!(t > 1e-18, "reference backtracking collapsed");
        };
        beta = next;
        let obj = ref_loss(ds, family, &ref_margins(ds, &beta))
            + ref_penalty(&beta, lambda, alpha);
        if (prev_obj - obj).abs() <= 1e-10 * obj.abs().max(1.0) {
            return obj;
        }
        prev_obj = obj;
        t *= 1.5; // let the step recover between iterations
    }
    prev_obj
}

struct Case {
    name: &'static str,
    ds: Dataset,
    family: FamilyKind,
    alpha: f64,
}

fn cases() -> Vec<Case> {
    vec![
        Case {
            name: "logistic-l1",
            ds: synth::dna_like(400, 40, 5, 903),
            family: FamilyKind::Logistic,
            alpha: 1.0,
        },
        Case {
            name: "gaussian-l1",
            ds: synth::gaussian_like(400, 60, 6, 901),
            family: FamilyKind::Gaussian,
            alpha: 1.0,
        },
        Case {
            name: "gaussian-enet",
            ds: synth::gaussian_like(350, 50, 6, 904),
            family: FamilyKind::Gaussian,
            alpha: 0.5,
        },
        Case {
            name: "poisson-l1",
            ds: synth::poisson_like(400, 60, 6, 902),
            family: FamilyKind::Poisson,
            alpha: 1.0,
        },
        Case {
            name: "poisson-enet",
            ds: synth::poisson_like(300, 40, 6, 905),
            family: FamilyKind::Poisson,
            alpha: 0.6,
        },
    ]
}

/// Relative objective agreement between a solver fit and the serial
/// reference. The solver runs f32 margins/β, the reference pure f64, and
/// both stop on their own tolerances — 2e-3 relative covers that without
/// hiding a wrong-optimum bug (block-diagonal mistakes move objectives by
/// orders of magnitude more).
fn assert_near_reference(name: &str, m: usize, fit: &FitResult, want: f64) {
    let got = fit.objective;
    let rel = (got - want).abs() / want.abs().max(1.0);
    assert!(
        rel < 2e-3,
        "{name} (M = {m}): solver objective {got} vs reference {want} (rel {rel:.2e})"
    );
}

#[test]
fn families_converge_to_the_serial_reference_in_process() {
    for case in cases() {
        let lam = lambda_max_family(&case.ds, case.family, case.alpha) / 8.0;
        let want = reference_objective(&case.ds, case.family, lam, case.alpha);
        assert!(want.is_finite(), "{}: reference diverged", case.name);
        for m in [1usize, 3] {
            let cfg = family_cfg(m, lam, case.family, case.alpha);
            let mut solver = DGlmnetSolver::from_dataset(&case.ds, &cfg).unwrap();
            let fit = solver.fit_lambda(lam).unwrap();
            assert!(fit.iterations >= 1, "{}", case.name);
            assert_near_reference(case.name, m, &fit, want);
            // the fitted model records its family + alpha for downstream
            // artifact/serve validation
            assert_eq!(fit.model.family, case.family, "{}", case.name);
            assert_eq!(fit.model.enet_alpha.to_bits(), case.alpha.to_bits(), "{}", case.name);
        }
    }
}

/// Run one fit over real TCP sockets with well-behaved workers.
fn socket_fit(ds: &Dataset, cfg: &TrainConfig, lambda: f64) -> (FitResult, Vec<f32>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let workers = spawn_local_socket_workers(cfg, ds, addr);
    let mut solver = DGlmnetSolver::from_dataset_socket(ds, cfg, listener).unwrap();
    let fit = solver.fit_lambda(lambda).unwrap();
    let beta = solver.beta.clone();
    drop(solver); // sends Shutdown to every node
    for h in workers {
        h.join().expect("worker thread panicked").unwrap();
    }
    (fit, beta)
}

fn assert_bit_identical(a: &FitResult, beta_a: &[f32], b: &FitResult, beta_b: &[f32]) {
    assert_eq!(a.iterations, b.iterations, "iteration counts diverged");
    assert_eq!(
        a.objective.to_bits(),
        b.objective.to_bits(),
        "objectives diverged: {} vs {}",
        a.objective,
        b.objective
    );
    assert_eq!(a.comm_bytes, b.comm_bytes, "charged comm ledger diverged");
    assert_eq!(a.trace.len(), b.trace.len());
    for (x, y) in a.trace.iter().zip(&b.trace) {
        assert_eq!(x.objective.to_bits(), y.objective.to_bits(), "iter {}", x.iter);
        assert_eq!(x.alpha.to_bits(), y.alpha.to_bits(), "iter {}", x.iter);
        assert_eq!(x.comm_bytes, y.comm_bytes, "iter {}", x.iter);
    }
    assert_eq!(beta_a.len(), beta_b.len());
    for (j, (x, y)) in beta_a.iter().zip(beta_b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "beta[{j}]");
    }
}

/// The socket transport must be invisible to the math for every family:
/// same machine count, same bits — margins, (w, z) stats, and the Δβ
/// exchange all ride the wire without perturbation, and the handshake's
/// family/alpha fields admit the workers.
#[test]
fn socket_fits_are_bit_identical_to_in_process_for_every_family() {
    for case in [
        Case {
            name: "gaussian-l1",
            ds: synth::gaussian_like(300, 40, 6, 911),
            family: FamilyKind::Gaussian,
            alpha: 1.0,
        },
        Case {
            name: "poisson-enet",
            ds: synth::poisson_like(300, 40, 6, 912),
            family: FamilyKind::Poisson,
            alpha: 0.7,
        },
    ] {
        let lam = lambda_max_family(&case.ds, case.family, case.alpha) / 8.0;
        let cfg = family_cfg(2, lam, case.family, case.alpha);

        let mut local = DGlmnetSolver::from_dataset(&case.ds, &cfg).unwrap();
        let fit_local = local.fit_lambda(lam).unwrap();
        assert!(fit_local.iterations >= 2, "{}: fit too short to mean much", case.name);

        let (fit_socket, beta_socket) = socket_fit(&case.ds, &cfg, lam);
        assert_bit_identical(&fit_local, &local.beta, &fit_socket, &beta_socket);

        // and the socket run sits at the reference optimum too
        let want = reference_objective(&case.ds, case.family, lam, case.alpha);
        assert_near_reference(case.name, 2, &fit_socket, want);
    }
}

/// Checkpoint/resume is family-aware: interrupt a non-logistic fit, resume
/// in a fresh solver (as a fresh process would), and the final β and
/// objective are exactly the uninterrupted run's.
#[test]
fn non_logistic_checkpoint_resume_is_exact() {
    for (name, ds, family, alpha) in [
        (
            "gaussian",
            synth::gaussian_like(350, 50, 6, 921),
            FamilyKind::Gaussian,
            1.0f64,
        ),
        (
            "poisson",
            synth::poisson_like(350, 50, 6, 922),
            FamilyKind::Poisson,
            0.8,
        ),
    ] {
        let lam = lambda_max_family(&ds, family, alpha) / 32.0;
        let cfg = family_cfg(3, lam, family, alpha);

        let mut whole = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
        let fit_whole = whole.fit_lambda(lam).unwrap();
        assert!(fit_whole.iterations > 3, "{name}: need a fit long enough to interrupt");

        let mut partial = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
        let ck = {
            let mut driver = partial.driver(lam);
            for _ in 0..2 {
                match driver.step().unwrap() {
                    StepOutcome::Progress(_) => {}
                    StepOutcome::Finished { .. } => panic!("{name}: finished early"),
                }
            }
            driver.checkpoint().unwrap()
        };
        assert_eq!(ck.family, family, "{name}");
        assert_eq!(ck.enet_alpha.to_bits(), alpha.to_bits(), "{name}");

        // round-trip through disk so the JSON family/alpha encoding is on
        // the path, then resume in a fresh solver
        let path = std::env::temp_dir()
            .join(format!("dglmnet_glm_resume_{}_{name}.json", std::process::id()));
        ck.save(&path).unwrap();
        let loaded = dglmnet::solver::Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(ck, loaded);

        let mut fresh = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
        let fit_resumed = fresh
            .driver_from_checkpoint(&loaded)
            .unwrap()
            .run(&mut NoopObserver)
            .unwrap();

        assert_eq!(
            fit_whole.objective.to_bits(),
            fit_resumed.objective.to_bits(),
            "{name}: resumed objective must be exact"
        );
        assert_eq!(fit_whole.iterations, fit_resumed.iterations, "{name}");
        for (j, (a, b)) in whole.beta.iter().zip(&fresh.beta).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{name} beta[{j}]");
        }
    }
}

/// A well-behaved socket worker thread for one machine.
fn good_worker(
    ds: &Dataset,
    cfg: &TrainConfig,
    machine: usize,
    addr: SocketAddr,
) -> JoinHandle<()> {
    let shard = DGlmnetSolver::shard_for(ds, cfg, machine);
    let y = std::sync::Arc::new(ds.y.clone());
    let p = ds.n_features();
    let cfg = cfg.clone();
    std::thread::spawn(move || {
        let mut node =
            WorkerNode::from_shard(&cfg, shard, y, p, std::path::Path::new("artifacts"))
                .unwrap();
        let mut t = SocketTransport::connect_retry(addr, Duration::from_secs(20)).unwrap();
        let _ = node.serve(&mut t, None);
    })
}

/// A worker whose transport dies on its `dies_at`-th recv — `kill -9`
/// mid-fit, seen from the worker side.
fn doomed_worker(
    ds: &Dataset,
    cfg: &TrainConfig,
    machine: usize,
    addr: SocketAddr,
    dies_at: usize,
) -> JoinHandle<()> {
    let shard = DGlmnetSolver::shard_for(ds, cfg, machine);
    let y = std::sync::Arc::new(ds.y.clone());
    let p = ds.n_features();
    let cfg = cfg.clone();
    std::thread::spawn(move || {
        let mut node =
            WorkerNode::from_shard(&cfg, shard, y, p, std::path::Path::new("artifacts"))
                .unwrap();
        let socket = SocketTransport::connect_retry(addr, Duration::from_secs(20)).unwrap();
        let mut t = FaultyTransport::new(Box::new(socket), Fault::Drop, dies_at);
        let _ = node.serve(&mut t, None);
    })
}

/// Supervised failover holds for non-logistic fits: kill a socket worker
/// mid-poisson-fit, let the supervisor probe it out and re-admit a
/// replacement, and the completed fit reproduces the undisturbed run's
/// final β, trajectory, and charged comm ledger exactly.
#[test]
fn killed_socket_worker_replacement_is_exact_for_poisson() {
    let ds = synth::poisson_like(350, 50, 6, 931);
    let family = FamilyKind::Poisson;
    let lam = lambda_max_family(&ds, family, 1.0) / 64.0; // small λ ⇒ plenty to kill
    let cfg = TrainConfig::builder()
        .machines(2)
        .engine(EngineKind::Native)
        .lambda(lam)
        .max_iter(60)
        .family(family)
        .supervise(true)
        .heartbeat_timeout_secs(2.0)
        .build();

    let (fit_ref, beta_ref) = socket_fit(&ds, &cfg, lam);
    assert!(fit_ref.iterations >= 4, "need a fit long enough to kill");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let good = good_worker(&ds, &cfg, 0, addr);
    let doomed = doomed_worker(&ds, &cfg, 1, addr, 5);
    let mut solver = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
    // the stand-in waits in the listener backlog until re-admission
    let replacement = good_worker(&ds, &cfg, 1, addr);

    let fit_chaos = solver.fit_lambda(lam).unwrap();
    assert!(
        solver.recovery_comm_bytes() > 0,
        "the supervisor must have probed and re-admitted"
    );
    let beta_chaos = solver.beta.clone();
    assert_bit_identical(&fit_ref, &beta_ref, &fit_chaos, &beta_chaos);
    drop(solver);
    doomed.join().unwrap();
    replacement.join().unwrap();
    good.join().unwrap();
}

// ---------------------------------------------------------------------------
// Rejection paths: misconfiguration fails fast, never silently
// ---------------------------------------------------------------------------

#[test]
fn alpha_outside_unit_interval_is_rejected() {
    let ds = synth::dna_like(100, 20, 4, 941);
    for bad in [0.0f64, -0.3, 1.5, f64::NAN] {
        let cfg = family_cfg(2, 0.5, FamilyKind::Logistic, bad);
        let err = match DGlmnetSolver::from_dataset(&ds, &cfg) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("alpha = {bad} must be rejected"),
        };
        assert!(err.contains("alpha"), "alpha = {bad}: {err}");
        assert!(err.contains("(0, 1]"), "alpha = {bad}: {err}");
    }
}

#[test]
fn poisson_rejects_signed_labels_at_setup() {
    // ±1 classification labels handed to a count model: fail at setup with
    // a pointer to the right family, not NaNs ten iterations in
    let ds = synth::dna_like(100, 20, 4, 942);
    let cfg = family_cfg(2, 0.5, FamilyKind::Poisson, 1.0);
    let err = match DGlmnetSolver::from_dataset(&ds, &cfg) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("poisson on ±1 labels must be rejected"),
    };
    assert!(err.contains("non-negative"), "{err}");
    assert!(err.contains("logistic"), "{err}");
}

#[test]
fn gaussian_rejects_non_finite_labels_at_setup() {
    let mut ds = synth::gaussian_like(100, 20, 4, 943);
    ds.y[17] = f32::INFINITY;
    let cfg = family_cfg(2, 0.5, FamilyKind::Gaussian, 1.0);
    let err = match DGlmnetSolver::from_dataset(&ds, &cfg) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("non-finite labels must be rejected"),
    };
    assert!(err.contains("finite"), "{err}");
}

#[test]
fn checkpoints_reject_family_and_alpha_mismatches() {
    let ds = synth::gaussian_like(150, 20, 4, 944);
    let lam = 0.5;
    let cfg = family_cfg(2, lam, FamilyKind::Gaussian, 1.0);
    let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let ck = solver.driver(lam).checkpoint().unwrap();

    // same dataset, wrong family: actionable rejection
    let mut wrong_family =
        DGlmnetSolver::from_dataset(&ds, &family_cfg(2, lam, FamilyKind::Logistic, 1.0))
            .unwrap();
    let err = match wrong_family.driver_from_checkpoint(&ck) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("family mismatch must be rejected"),
    };
    assert!(err.contains("family"), "{err}");
    assert!(err.contains("gaussian") && err.contains("logistic"), "{err}");

    // right family, wrong alpha: same contract
    let mut wrong_alpha =
        DGlmnetSolver::from_dataset(&ds, &family_cfg(2, lam, FamilyKind::Gaussian, 0.5))
            .unwrap();
    let err = match wrong_alpha.driver_from_checkpoint(&ck) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("alpha mismatch must be rejected"),
    };
    assert!(err.contains("alpha"), "{err}");
}
