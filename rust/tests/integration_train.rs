//! Integration tests: full training runs across modules (data → partition →
//! pool → solver → metrics), convergence against an independent reference
//! optimizer, warmstart paths, and the sparsity precautions end to end.

mod common;

use dglmnet::config::{EngineKind, PathConfig, TrainConfig};
use dglmnet::data::synth;
use dglmnet::metrics;
use dglmnet::solver::{lambda_max, DGlmnetSolver, RegPath};

fn cfg(m: usize, lam: f64) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(EngineKind::Native)
        .lambda(lam)
        .max_iter(80)
        .tol(1e-7)
        .build()
}

/// Reference: plain (sub)gradient descent with many iterations — slow but
/// an entirely independent optimizer for the same objective.
fn reference_objective(ds: &dglmnet::data::Dataset, lam: f64) -> f64 {
    let n = ds.n_examples();
    let p = ds.n_features();
    let mut beta = vec![0f64; p];
    let mut lr = 0.5 / n as f64;
    let mut best = f64::INFINITY;
    let mut margins = vec![0f64; n];
    for _it in 0..4000 {
        // gradient of the smooth part
        let mut grad = vec![0f64; p];
        for i in 0..n {
            let (cols, vals) = ds.x.row(i);
            let m: f64 = cols
                .iter()
                .zip(vals)
                .map(|(&c, &v)| beta[c as usize] * v as f64)
                .sum();
            margins[i] = m;
            let g = dglmnet::util::math::sigmoid(m) - (ds.y[i] as f64 + 1.0) / 2.0;
            for (&c, &v) in cols.iter().zip(vals) {
                grad[c as usize] += g * v as f64;
            }
        }
        // proximal step (ISTA)
        for j in 0..p {
            beta[j] =
                dglmnet::util::math::soft_threshold(beta[j] - lr * grad[j], lr * lam);
        }
        let f: f64 = margins
            .iter()
            .zip(&ds.y)
            .map(|(&m, &y)| dglmnet::util::math::log1pexp(-(y as f64) * m))
            .sum::<f64>()
            + lam * beta.iter().map(|b| b.abs()).sum::<f64>();
        if f < best {
            best = f;
        } else {
            lr *= 0.7; // crude backtracking
            if lr < 1e-12 {
                break;
            }
        }
    }
    best
}

#[test]
fn converges_to_ista_reference_objective() {
    let ds = synth::dna_like(500, 30, 5, 101);
    let lam = lambda_max(&ds) / 8.0;
    let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg(3, lam)).unwrap();
    let fit = solver.fit(None).unwrap();
    let reference = reference_objective(&ds, lam);
    // d-GLMNET (Newton-style) should reach at least the ISTA objective
    assert!(
        fit.objective <= reference * 1.01 + 1e-6,
        "d-GLMNET {} vs ISTA {}",
        fit.objective,
        reference
    );
}

#[test]
fn quality_improves_along_path_then_saturates() {
    let split = synth::epsilon_like(3_000, 64, 102).split(0.8, 5).unwrap();
    let path_cfg = PathConfig { steps: 8, ..Default::default() };
    let path = RegPath::run(&split.train, &split.test, &cfg(4, 1.0), &path_cfg).unwrap();
    let aucs: Vec<f64> = path.points.iter().map(|p| p.auc).collect();
    let best = aucs.iter().copied().fold(0.0, f64::max);
    assert!(best > 0.8, "best AUC along the path = {best}");
    // the head of the path (huge λ) must be worse than the best
    assert!(aucs[0] <= best);
}

#[test]
fn fitted_model_beats_random_and_majority() {
    let split = synth::webspam_like(2_000, 3_000, 30, 103).split(0.75, 9).unwrap();
    let lam = lambda_max(&split.train) / 128.0;
    let mut solver = DGlmnetSolver::from_dataset(&split.train, &cfg(4, lam)).unwrap();
    let fit = solver.fit(None).unwrap();
    let margins = fit.model.predict_margins(&split.test.x);
    let auprc = metrics::auprc(&margins, &split.test.y);
    let prevalence =
        split.test.y.iter().filter(|&&y| y > 0.0).count() as f64 / split.test.y.len() as f64;
    assert!(
        auprc > prevalence + 0.1,
        "auprc {auprc} vs prevalence {prevalence}"
    );
    assert!(metrics::accuracy(&margins, &split.test.y) > prevalence.max(1.0 - prevalence));
}

#[test]
fn solver_is_deterministic() {
    let ds = synth::dna_like(400, 32, 5, 104);
    let lam = lambda_max(&ds) / 16.0;
    let run = || {
        let mut s = DGlmnetSolver::from_dataset(&ds, &cfg(4, lam)).unwrap();
        let fit = s.fit(None).unwrap();
        (fit.objective, fit.nnz(), fit.iterations, fit.model.entries.clone())
    };
    let a = run();
    let b = run();
    assert_eq!(a.1, b.1);
    assert_eq!(a.2, b.2);
    assert!((a.0 - b.0).abs() < 1e-10);
    assert_eq!(a.3, b.3);
}

#[test]
fn external_shuffle_pipeline_matches_in_memory() {
    use dglmnet::cluster::partition::{FeaturePartition, PartitionStrategy};
    use dglmnet::data::shuffle::shuffle_to_feature_shards;

    let ds = synth::webspam_like(300, 600, 15, 105);
    let lam = lambda_max(&ds) / 8.0;
    let c = cfg(3, lam);
    let part =
        FeaturePartition::build(PartitionStrategy::RoundRobin, ds.n_features(), 3, None);
    let dir = std::env::temp_dir().join(format!("dglmnet_it_shuffle_{}", std::process::id()));
    let (shards, _) = shuffle_to_feature_shards(&ds.x, &part, &dir).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    let mut s1 = DGlmnetSolver::from_shards(&ds, &c, part, shards).unwrap();
    let f1 = s1.fit(None).unwrap();
    let mut s2 = DGlmnetSolver::from_dataset(&ds, &c).unwrap();
    let f2 = s2.fit(None).unwrap();
    assert_eq!(f1.nnz(), f2.nnz());
    assert!((f1.objective - f2.objective).abs() < 1e-9);
}

#[test]
fn sparsity_precaution_zeroes_survive_convergence() {
    // Fit at a λ strong enough that many coordinates sit at exactly 0;
    // the α = 1 retry at convergence must not resurrect them.
    let ds = synth::webspam_like(800, 1_500, 20, 106);
    let lam = lambda_max(&ds) / 4.0;
    let mut s = DGlmnetSolver::from_dataset(&ds, &cfg(4, lam)).unwrap();
    let fit = s.fit(None).unwrap();
    assert!(fit.converged);
    assert!(
        fit.nnz() < ds.n_features() / 4,
        "expected strong sparsity, got {}/{}",
        fit.nnz(),
        ds.n_features()
    );
}

#[test]
fn machines_exceeding_features_is_an_error() {
    let ds = synth::dna_like(100, 3, 2, 107);
    let c = cfg(8, 0.1);
    assert!(DGlmnetSolver::from_dataset(&ds, &c).is_err());
}
