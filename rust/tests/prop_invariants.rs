//! Property-based tests over the coordinator's core invariants (DESIGN.md
//! §7): partition disjoint-cover, allreduce ≡ serial sum, soft-threshold
//! algebra, sparse-matrix transposition, objective monotonicity of the
//! solver, and the Armijo postcondition of the line search.

mod common;

use common::{prop_check, random_small_dataset};
use dglmnet::cluster::allreduce::TreeAllReduce;
use dglmnet::cluster::network::{NetworkLedger, NetworkModel};
use dglmnet::cluster::partition::{FeaturePartition, PartitionStrategy};
use dglmnet::config::{EngineKind, LineSearchConfig, TrainConfig};
use dglmnet::solver::line_search::line_search;
use dglmnet::solver::DGlmnetSolver;
use dglmnet::util::math::{soft_threshold, working_stats};

#[test]
fn prop_partition_is_disjoint_cover() {
    prop_check("partition-disjoint-cover", 200, |rng, _| {
        let p = 1 + rng.below(500);
        let m = 1 + rng.below(16);
        let strat = match rng.below(3) {
            0 => PartitionStrategy::RoundRobin,
            1 => PartitionStrategy::Contiguous,
            _ => PartitionStrategy::NnzBalanced,
        };
        let counts: Vec<usize> = (0..p).map(|_| rng.below(100)).collect();
        let part = FeaturePartition::build(strat, p, m, Some(&counts));
        let mut seen = vec![false; p];
        for k in 0..m {
            for f in part.features_of(k) {
                assert!(!seen[f as usize], "feature {f} doubly assigned");
                seen[f as usize] = true;
                assert_eq!(part.machine_of(f as usize), k);
            }
        }
        assert!(seen.iter().all(|&s| s));
    });
}

#[test]
fn prop_allreduce_equals_serial_sum() {
    prop_check("allreduce-serial-sum", 100, |rng, _| {
        let m = 1 + rng.below(12);
        let len = 1 + rng.below(2_000);
        let contribs: Vec<Vec<f32>> = (0..m)
            .map(|_| (0..len).map(|_| (rng.normal() * 3.0) as f32).collect())
            .collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let (got, _) = ar.sum(&contribs, &ledger);
        for i in 0..len {
            let want: f64 = contribs.iter().map(|c| c[i] as f64).sum();
            assert!(
                (got[i] as f64 - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "i = {i}: {} vs {want}",
                got[i]
            );
        }
    });
}

#[test]
fn prop_soft_threshold_algebra() {
    prop_check("soft-threshold", 500, |rng, _| {
        let x = rng.normal() * 10.0;
        let a = rng.uniform() * 5.0;
        let t = soft_threshold(x, a);
        // shrinks toward zero by at most a
        assert!(t.abs() <= x.abs());
        assert!((x - t).abs() <= a + 1e-12);
        // sign preservation or exact zero
        assert!(t == 0.0 || t.signum() == x.signum());
        // zero iff |x| <= a
        assert_eq!(t == 0.0, x.abs() <= a);
    });
}

#[test]
fn prop_csr_csc_transpose_roundtrip() {
    prop_check("csr-csc-roundtrip", 60, |rng, _| {
        let ds = random_small_dataset(rng);
        let csc = ds.x.to_csc();
        let back = csc.to_csr();
        assert_eq!(back.indptr, ds.x.indptr);
        assert_eq!(back.indices, ds.x.indices);
        assert_eq!(back.values, ds.x.values);
        assert_eq!(csc.nnz(), ds.x.nnz());
    });
}

#[test]
fn prop_working_stats_bounds() {
    prop_check("working-stats-bounds", 500, |rng, _| {
        let m = rng.normal() * 20.0;
        let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        let (w, z) = working_stats(y, m);
        assert!((0.0..=0.25 + 1e-12).contains(&w), "w = {w}");
        assert!(z.is_finite());
        // z has the sign pushing the margin toward the label when wrong
        if y > 0.0 && m < 0.0 {
            assert!(z > 0.0);
        }
        if y < 0.0 && m > 0.0 {
            assert!(z < 0.0);
        }
    });
}

#[test]
fn prop_solver_objective_never_increases() {
    prop_check("solver-monotone", 12, |rng, case| {
        let ds = random_small_dataset(rng);
        let m = 1 + rng.below(4);
        if ds.n_features() < m {
            return;
        }
        let lam_max = dglmnet::solver::lambda_max(&ds);
        let lam = lam_max * 0.5f64.powi(1 + rng.below(8) as i32);
        let cfg = TrainConfig::builder()
            .machines(m)
            .engine(EngineKind::Native)
            .lambda(lam.max(1e-3))
            .max_iter(15)
            .build();
        let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
        let fit = solver.fit(None).unwrap();
        let objs: Vec<f64> = fit.trace.iter().map(|r| r.objective).collect();
        for w in objs.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-9) + 1e-9,
                "case {case}: objective increased: {objs:?}"
            );
        }
    });
}

#[test]
fn prop_line_search_armijo_postcondition() {
    prop_check("armijo-postcondition", 200, |rng, _| {
        // random smooth convex 1-D restriction: f(a) = q(a - opt)^2 + c
        let opt = rng.uniform() * 1.5;
        let q = 0.5 + rng.uniform() * 4.0;
        let c = rng.uniform() * 10.0;
        let f = move |a: f64| q * (a - opt).powi(2) + c;
        let mut losses = |alphas: &[f64]| -> dglmnet::Result<Vec<f64>> {
            Ok(alphas.iter().map(|&a| f(a)).collect())
        };
        let f0 = f(0.0);
        let grad_dot = -2.0 * q * opt; // f'(0)
        if grad_dot >= 0.0 {
            return; // not a descent direction; solver never calls it then
        }
        let mut cfg = LineSearchConfig::default();
        cfg.sufficient_decrease = f64::INFINITY; // force the search
        let out = line_search(&mut losses, &|_| 0.0, f0, grad_dot, 0.0, &cfg).unwrap();
        assert!(out.alpha > 0.0 && out.alpha <= 1.0);
        assert!(
            f(out.alpha) <= f0 + out.alpha * cfg.sigma * grad_dot + 1e-9,
            "alpha = {}, f = {}, bound = {}",
            out.alpha,
            f(out.alpha),
            f0 + out.alpha * cfg.sigma * grad_dot
        );
    });
}

#[test]
fn prop_model_sparsity_exact_zeros() {
    prop_check("model-exact-zeros", 20, |rng, _| {
        let ds = random_small_dataset(rng);
        let lam_max = dglmnet::solver::lambda_max(&ds);
        let cfg = TrainConfig::builder()
            .machines(2)
            .engine(EngineKind::Native)
            .lambda((lam_max / 4.0).max(1e-3))
            .max_iter(10)
            .build();
        if ds.n_features() < 2 {
            return;
        }
        let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
        let fit = solver.fit(None).unwrap();
        // nnz counts exact zeros — soft-thresholding must produce true 0s,
        // and the model round-trips them
        let dense = fit.model.to_dense();
        assert_eq!(
            dense.iter().filter(|&&x| x != 0.0).count(),
            fit.model.nnz()
        );
    });
}
