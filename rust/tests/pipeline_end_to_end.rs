//! End-to-end pipeline test mirroring the paper's full workflow on a small
//! problem: generate → write libsvm → read back → by-feature transform
//! (Table 1 format round-trip) → external shuffle → regularization path on
//! the simulated cluster → baseline comparison → frontier check. This is
//! the CI-sized version of `examples/online_vs_batch.rs`.

mod common;

use dglmnet::baselines::grid::{grid_frontier, online_grid_search};
use dglmnet::config::{EngineKind, PathConfig, TrainConfig};
use dglmnet::data::{libsvm, synth};
use dglmnet::solver::{lambda_max, RegPath};

#[test]
fn paper_workflow_small() {
    // 1. generate + persist + reload (ingest path)
    let ds = synth::dna_like(2_500, 80, 8, 301);
    let dir = std::env::temp_dir().join(format!("dglmnet_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let svm_path = dir.join("train.svm");
    libsvm::write_libsvm(&ds, std::fs::File::create(&svm_path).unwrap()).unwrap();
    let reloaded = libsvm::read_libsvm_file(&svm_path).unwrap();
    assert_eq!(reloaded.n_examples(), ds.n_examples());
    assert_eq!(reloaded.x.nnz(), ds.x.nnz());

    // 2. Table-1 by-feature round trip
    let csc = reloaded.x.to_csc();
    let bf_path = dir.join("train.byfeature");
    libsvm::write_by_feature(&csc, std::fs::File::create(&bf_path).unwrap()).unwrap();
    let csc2 = libsvm::read_by_feature(
        std::fs::File::open(&bf_path).unwrap(),
        reloaded.n_examples(),
    )
    .unwrap();
    assert_eq!(csc.indptr, csc2.indptr);
    assert_eq!(csc.values, csc2.values);

    // 3. split + path on the simulated cluster
    let split = reloaded.split(0.8, 301).unwrap();
    let cfg = TrainConfig::builder()
        .machines(4)
        .engine(EngineKind::Native)
        .max_iter(30)
        .build();
    let path_cfg = PathConfig { steps: 7, ..Default::default() };
    let path = RegPath::run(&split.train, &split.test, &cfg, &path_cfg).unwrap();
    assert_eq!(path.points.len(), 7);
    let best_dg = path.points.iter().map(|p| p.auprc).fold(0.0, f64::max);

    // 4. online baseline on the same split
    let lam_max = lambda_max(&split.train);
    let lambdas: Vec<f64> = (1..=6).map(|i| lam_max * 0.5f64.powi(i)).collect();
    let grid = online_grid_search(
        &split.train,
        &split.test,
        4,
        &[0.1, 0.3],
        &[0.5, 0.9],
        &lambdas,
        4,
        302,
    );
    let best_vw = grid.iter().map(|g| g.auprc).fold(0.0, f64::max);

    // 5. the paper's qualitative claim on this workload: the batch path's
    //    best quality is at least competitive with the online baseline
    assert!(
        best_dg >= best_vw - 0.02,
        "d-GLMNET best {best_dg} vs baseline best {best_vw}"
    );
    // and its frontier is non-trivial
    assert!(!path.frontier().is_empty());
    assert!(!grid_frontier(&grid).is_empty());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn communication_volume_matches_o_n_plus_p_log_m() {
    // Alg 4: per iteration the allreduce moves Θ(n + p) per tree edge.
    let ds = synth::webspam_like(1_000, 2_000, 20, 303);
    let lam = lambda_max(&ds) / 8.0;
    let bytes_per_iter = |m: usize| {
        let cfg = TrainConfig::builder()
            .machines(m)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(5)
            .build();
        let mut s = dglmnet::solver::DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
        let fit = s.fit(None).unwrap();
        fit.comm_bytes as f64 / fit.iterations as f64
    };
    let b2 = bytes_per_iter(2);
    let b8 = bytes_per_iter(8);
    // tree: 2 machines -> 1 reduce edge + 1 broadcast round;
    // 8 machines -> 7 reduce edges + 3 broadcast rounds: ratio = 10/2 = 5
    let ratio = b8 / b2;
    assert!(
        (3.0..7.0).contains(&ratio),
        "bytes/iter ratio M=8 vs M=2 = {ratio} (b2 = {b2}, b8 = {b8})"
    );
}
