//! Engine equivalence across the sweep-kernel matrix.
//!
//! Three families:
//! * **XLA vs native** full-fit equivalence (AOT Pallas via PJRT against the
//!   sparse rust engine) — skipped with a message when artifacts are missing.
//! * **Covariance vs naive kernel contracts** — the rust ports of
//!   `python/tests/test_cov_kernel.py`: the Gram-cached sweep must be
//!   numerically equivalent to the naive sweep (tolerance, not bitwise).
//! * **Threaded sweep pins** — a `sweep_threads = T` worker must reproduce
//!   the trajectory of T single-threaded machines *bit for bit* (the
//!   deterministic pairwise-merge contract).

mod common;

use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::sparse::{CscMatrix, CsrMatrix};
use dglmnet::data::synth;
use dglmnet::engine::cov::{cd_block_sweep_cov, cd_block_sweep_naive};
use dglmnet::solver::{lambda_max, DGlmnetSolver};
use dglmnet::util::math::working_stats;
use dglmnet::util::rng::Xoshiro256;

fn artifacts_present() -> bool {
    // the XLA engine needs both the compiled feature and the AOT artifacts
    cfg!(feature = "xla") && std::path::Path::new("artifacts/manifest.json").exists()
}

fn cfg(engine: EngineKind, m: usize, lam: f64) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(engine)
        .lambda(lam)
        .max_iter(25)
        .build()
}

#[test]
fn full_fit_equivalence_dna_like() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ds = synth::dna_like(700, 100, 8, 201);
    let lam = lambda_max(&ds) / 16.0;
    let mut nx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Native, 4, lam)).unwrap();
    let mut xx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Xla, 4, lam)).unwrap();
    let fn_ = nx.fit(None).unwrap();
    let fx = xx.fit(None).unwrap();
    assert!(
        (fn_.objective - fx.objective).abs() / fn_.objective < 1e-3,
        "objective: native {} vs xla {}",
        fn_.objective,
        fx.objective
    );
    // support sets should agree (small f32-vs-f64 noise near the threshold
    // may flip a borderline coordinate, hence the tolerance)
    let sn: std::collections::HashSet<u32> =
        fn_.model.entries.iter().map(|e| e.0).collect();
    let sx: std::collections::HashSet<u32> = fx.model.entries.iter().map(|e| e.0).collect();
    let sym_diff = sn.symmetric_difference(&sx).count();
    assert!(
        sym_diff <= 1 + sn.len() / 10,
        "support differs too much: {sym_diff} of {}",
        sn.len()
    );
}

#[test]
fn full_fit_equivalence_dense_epsilon_like() {
    if !artifacts_present() {
        return;
    }
    let ds = synth::epsilon_like(900, 96, 202);
    let lam = lambda_max(&ds) / 32.0;
    let mut nx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Native, 2, lam)).unwrap();
    let mut xx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Xla, 2, lam)).unwrap();
    let fn_ = nx.fit(None).unwrap();
    let fx = xx.fit(None).unwrap();
    assert!(
        (fn_.objective - fx.objective).abs() / fn_.objective < 1e-3,
        "native {} vs xla {}",
        fn_.objective,
        fx.objective
    );
}

#[test]
fn xla_engine_handles_n_between_tile_sizes() {
    if !artifacts_present() {
        return;
    }
    // n = 1500 -> pads to 4096 (not 1024): exercises the pick_n path
    let ds = synth::dna_like(1_500, 70, 6, 203);
    let lam = lambda_max(&ds) / 8.0;
    let mut xx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Xla, 2, lam)).unwrap();
    let fx = xx.fit(None).unwrap();
    let mut nx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Native, 2, lam)).unwrap();
    let fn_ = nx.fit(None).unwrap();
    assert!((fn_.objective - fx.objective).abs() / fn_.objective < 1e-3);
}

#[test]
fn xla_beta_trajectory_matches_native_first_iteration() {
    if !artifacts_present() {
        return;
    }
    // Single iteration, single machine: Δβ must match to f32 tolerance.
    let ds = synth::dna_like(400, 64, 6, 204);
    let lam = lambda_max(&ds) / 8.0;
    let mk = |engine| {
        let c = TrainConfig::builder()
            .machines(1)
            .engine(engine)
            .lambda(lam)
            .max_iter(1)
            .build();
        let mut s = DGlmnetSolver::from_dataset(&ds, &c).unwrap();
        s.fit(None).unwrap();
        s.beta.clone()
    };
    let bn = mk(EngineKind::Native);
    let bx = mk(EngineKind::Xla);
    for j in 0..64 {
        assert!(
            (bn[j] - bx[j]).abs() < 5e-3 * (1.0 + bn[j].abs()),
            "beta[{j}]: native {} vs xla {}",
            bn[j],
            bx[j]
        );
    }
}

// ---------------------------------------------------------------------------
// Covariance-kernel contracts (ports of python/tests/test_cov_kernel.py)
// ---------------------------------------------------------------------------

/// Dense n×b block as a CSC matrix, entries drawn from `gen` (row-major
/// fill, like the numpy generators in the python tests).
fn dense_block(n: usize, b: usize, gen: &mut impl FnMut(usize, usize) -> f32) -> CscMatrix {
    let mut m = CsrMatrix::new(b);
    let mut row = Vec::with_capacity(b);
    for i in 0..n {
        row.clear();
        for j in 0..b {
            row.push((j as u32, gen(i, j)));
        }
        m.push_row(&row);
    }
    m.to_csc()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len());
    for (k, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol + tol * x.abs(), "{what}[{k}]: {x} vs {y}");
    }
}

#[test]
fn cov_sweep_matches_naive_oracle_across_shapes_and_lambdas() {
    for &(n, b) in &[(16usize, 4usize), (128, 16), (500, 64)] {
        for &lam in &[0.0f32, 0.7, 5.0] {
            let mut rng = Xoshiro256::new(0xC0F0 + n as u64 * 31 + lam.to_bits() as u64);
            let nu = 1e-6f32;
            let x = dense_block(n, b, &mut |_, _| rng.normal() as f32);
            let margins: Vec<f32> = (0..n).map(|_| 0.5 * rng.normal() as f32).collect();
            let y: Vec<f32> = (0..n)
                .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
                .collect();
            let (mut w, mut z) = (Vec::with_capacity(n), Vec::with_capacity(n));
            for i in 0..n {
                let (wi, zi) = working_stats(y[i] as f64, margins[i] as f64);
                w.push(wi as f32);
                z.push(zi as f32);
            }
            let beta: Vec<f32> = (0..b)
                .map(|_| {
                    let v = rng.normal() as f32;
                    if rng.uniform() < 0.5 { v } else { 0.0 }
                })
                .collect();
            let zero = vec![0f32; b];
            let (d_naive, r_naive) = cd_block_sweep_naive(&x, &w, &z, &beta, &zero, lam, nu);
            let (d_cov, r_cov) = cd_block_sweep_cov(&x, &w, &z, &beta, &zero, lam, nu);
            assert_close(&d_cov, &d_naive, 5e-3, "delta");
            assert_close(&r_cov, &r_naive, 5e-3, "residual");
        }
    }
}

#[test]
fn cov_and_naive_agree_on_a_random_block() {
    let mut rng = Xoshiro256::new(9);
    let (n, b) = (300usize, 32usize);
    let x = dense_block(n, b, &mut |_, _| rng.normal() as f32);
    let w: Vec<f32> = (0..n).map(|_| 0.25 * rng.uniform() as f32).collect();
    let r: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let beta: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();
    let zero = vec![0f32; b];
    let (d1, r1) = cd_block_sweep_naive(&x, &w, &r, &beta, &zero, 0.3, 1e-6);
    let (d2, r2) = cd_block_sweep_cov(&x, &w, &r, &beta, &zero, 0.3, 1e-6);
    assert_close(&d2, &d1, 2e-3, "delta");
    assert_close(&r2, &r1, 2e-3, "residual");
}

#[test]
fn cov_sweep_nonzero_delta_in_carries() {
    // delta_in != 0 (multi-cycle contract) must be honored identically
    let mut rng = Xoshiro256::new(11);
    let (n, b) = (200usize, 8usize);
    let x = dense_block(n, b, &mut |_, _| rng.normal() as f32);
    let w = vec![0.25f32; n];
    let beta: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();
    let delta_in: Vec<f32> = (0..b).map(|_| 0.1 * rng.normal() as f32).collect();
    // r consistent with delta_in: r = z - X @ delta_in
    let z: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut r: Vec<f64> = z.iter().map(|&v| v as f64).collect();
    for j in 0..b {
        let (rows, vals) = x.col(j);
        for (&i, &v) in rows.iter().zip(vals) {
            r[i as usize] -= delta_in[j] as f64 * v as f64;
        }
    }
    let r: Vec<f32> = r.iter().map(|&v| v as f32).collect();
    let (d1, r1) = cd_block_sweep_naive(&x, &w, &r, &beta, &delta_in, 0.2, 1e-6);
    let (d2, r2) = cd_block_sweep_cov(&x, &w, &r, &beta, &delta_in, 0.2, 1e-6);
    assert_close(&d2, &d1, 2e-3, "delta");
    assert_close(&r2, &r1, 2e-3, "residual");
}

#[test]
fn cov_zero_columns_stay_zero() {
    let mut rng = Xoshiro256::new(12);
    let (n, b) = (64usize, 16usize);
    // columns 10.. are identically zero (push_row drops exact zeros, so
    // they become genuinely empty CSC columns)
    let x = dense_block(n, b, &mut |_, j| if j >= 10 { 0.0 } else { rng.normal() as f32 });
    let w = vec![0.25f32; n];
    let r: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let zero = vec![0f32; b];
    let (d, _) = cd_block_sweep_cov(&x, &w, &r, &zero, &zero, 0.1, 1e-6);
    for j in 10..b {
        assert_eq!(d[j], 0.0, "zero column {j} moved");
    }
    let (dn, _) = cd_block_sweep_naive(&x, &w, &r, &zero, &zero, 0.1, 1e-6);
    for j in 10..b {
        assert_eq!(dn[j], 0.0, "zero column {j} moved (naive)");
    }
}

// ---------------------------------------------------------------------------
// Threaded-sweep pins: T sweep threads ≡ T machines, bit for bit
// ---------------------------------------------------------------------------

fn fit_bits(
    ds: &dglmnet::data::Dataset,
    machines: usize,
    threads: usize,
    naive: bool,
    lam: f64,
) -> (Vec<u64>, Vec<u32>) {
    let cfg = TrainConfig::builder()
        .machines(machines)
        .sweep_threads(threads)
        .naive_sweep(naive)
        .engine(EngineKind::Native)
        .lambda(lam)
        .max_iter(12)
        .build();
    let mut s = DGlmnetSolver::from_dataset(ds, &cfg).unwrap();
    let fit = s.fit(None).unwrap();
    (
        fit.trace.iter().map(|r| r.objective.to_bits()).collect(),
        s.beta.iter().map(|v| v.to_bits()).collect(),
    )
}

#[test]
fn threaded_sweep_reproduces_the_machine_partition_trajectory_bitwise() {
    // The tentpole pin: a worker sweeping its shard on T threads must be
    // indistinguishable — objective trace AND final β, to the bit — from T
    // single-threaded machines under the matching sub-partition, for both
    // kernels. Exercises the per-block leaf emission, the pairwise Δm
    // merge mirroring the AllReduce tree, and the k-way Δβ merge.
    let cases = [
        ("dna-like", synth::dna_like(600, 120, 6, 31)),
        ("webspam-like", synth::webspam_like(400, 500, 12, 33)),
    ];
    for (name, ds) in &cases {
        let lam = lambda_max(ds) / 4.0;
        for naive in [true, false] {
            for t in [2usize, 4] {
                let threaded = fit_bits(ds, 1, t, naive, lam);
                let machines = fit_bits(ds, t, 1, naive, lam);
                assert_eq!(
                    threaded, machines,
                    "{name}: T={t} threaded run diverged from {t}-machine run (naive={naive})"
                );
            }
        }
    }
}

#[test]
fn threaded_sweep_pin_holds_under_nnz_balanced_partition() {
    // the sub-partition strategy follows the machine partition strategy —
    // pin the nnz-balanced variant too (different block shapes entirely)
    let ds = synth::webspam_like(300, 400, 10, 47);
    let lam = lambda_max(&ds) / 4.0;
    let mk = |machines: usize, threads: usize| {
        let cfg = TrainConfig::builder()
            .machines(machines)
            .sweep_threads(threads)
            .partition(dglmnet::cluster::partition::PartitionStrategy::NnzBalanced)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(10)
            .build();
        let mut s = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
        let fit = s.fit(None).unwrap();
        let bits: Vec<u64> = fit.trace.iter().map(|r| r.objective.to_bits()).collect();
        (bits, s.beta.iter().map(|v| v.to_bits()).collect::<Vec<u32>>())
    };
    assert_eq!(mk(1, 3), mk(3, 1));
}

#[test]
fn sweep_threads_validation_rejects_over_wide_requests() {
    // 4 machines × 30 features → narrowest shard has 7 columns; asking for
    // 20 sweep threads must fail fast with the actionable message
    let ds = synth::dna_like(100, 30, 4, 5);
    let cfg = TrainConfig::builder()
        .machines(4)
        .sweep_threads(20)
        .engine(EngineKind::Native)
        .lambda(0.5)
        .build();
    let err = match DGlmnetSolver::from_dataset(&ds, &cfg) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected sweep_threads validation to fail"),
    };
    assert!(err.contains("sweep_threads"), "unexpected error: {err}");
    assert!(err.contains("0 = auto"), "unexpected error: {err}");
    // 0 = auto always passes validation (it clamps instead)
    let auto = TrainConfig::builder()
        .machines(4)
        .sweep_threads(0)
        .engine(EngineKind::Native)
        .lambda(0.5)
        .build();
    DGlmnetSolver::from_dataset(&ds, &auto).unwrap();
}

#[test]
fn threaded_sweeps_are_deterministic_across_repeats() {
    // same engine, same inputs, three runs: the scoped-thread execution
    // must not introduce any run-to-run wobble
    let ds = synth::webspam_like(250, 300, 8, 21);
    let lam = lambda_max(&ds) / 4.0;
    let a = fit_bits(&ds, 1, 4, false, lam);
    let b = fit_bits(&ds, 1, 4, false, lam);
    let c = fit_bits(&ds, 1, 4, false, lam);
    assert_eq!(a, b);
    assert_eq!(b, c);
}
