//! XLA (AOT Pallas via PJRT) vs native (sparse rust) engine equivalence at
//! the *full fit* level — the strongest cross-stack correctness signal: any
//! divergence in kernel math, padding, tiling or residual threading shows
//! up as a different optimization trajectory.
//!
//! These tests are skipped (with a message) when artifacts are missing.

mod common;

use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::synth;
use dglmnet::solver::{lambda_max, DGlmnetSolver};

fn artifacts_present() -> bool {
    // the XLA engine needs both the compiled feature and the AOT artifacts
    cfg!(feature = "xla") && std::path::Path::new("artifacts/manifest.json").exists()
}

fn cfg(engine: EngineKind, m: usize, lam: f64) -> TrainConfig {
    TrainConfig::builder()
        .machines(m)
        .engine(engine)
        .lambda(lam)
        .max_iter(25)
        .build()
}

#[test]
fn full_fit_equivalence_dna_like() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ds = synth::dna_like(700, 100, 8, 201);
    let lam = lambda_max(&ds) / 16.0;
    let mut nx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Native, 4, lam)).unwrap();
    let mut xx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Xla, 4, lam)).unwrap();
    let fn_ = nx.fit(None).unwrap();
    let fx = xx.fit(None).unwrap();
    assert!(
        (fn_.objective - fx.objective).abs() / fn_.objective < 1e-3,
        "objective: native {} vs xla {}",
        fn_.objective,
        fx.objective
    );
    // support sets should agree (small f32-vs-f64 noise near the threshold
    // may flip a borderline coordinate, hence the tolerance)
    let sn: std::collections::HashSet<u32> =
        fn_.model.entries.iter().map(|e| e.0).collect();
    let sx: std::collections::HashSet<u32> = fx.model.entries.iter().map(|e| e.0).collect();
    let sym_diff = sn.symmetric_difference(&sx).count();
    assert!(
        sym_diff <= 1 + sn.len() / 10,
        "support differs too much: {sym_diff} of {}",
        sn.len()
    );
}

#[test]
fn full_fit_equivalence_dense_epsilon_like() {
    if !artifacts_present() {
        return;
    }
    let ds = synth::epsilon_like(900, 96, 202);
    let lam = lambda_max(&ds) / 32.0;
    let mut nx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Native, 2, lam)).unwrap();
    let mut xx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Xla, 2, lam)).unwrap();
    let fn_ = nx.fit(None).unwrap();
    let fx = xx.fit(None).unwrap();
    assert!(
        (fn_.objective - fx.objective).abs() / fn_.objective < 1e-3,
        "native {} vs xla {}",
        fn_.objective,
        fx.objective
    );
}

#[test]
fn xla_engine_handles_n_between_tile_sizes() {
    if !artifacts_present() {
        return;
    }
    // n = 1500 -> pads to 4096 (not 1024): exercises the pick_n path
    let ds = synth::dna_like(1_500, 70, 6, 203);
    let lam = lambda_max(&ds) / 8.0;
    let mut xx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Xla, 2, lam)).unwrap();
    let fx = xx.fit(None).unwrap();
    let mut nx = DGlmnetSolver::from_dataset(&ds, &cfg(EngineKind::Native, 2, lam)).unwrap();
    let fn_ = nx.fit(None).unwrap();
    assert!((fn_.objective - fx.objective).abs() / fn_.objective < 1e-3);
}

#[test]
fn xla_beta_trajectory_matches_native_first_iteration() {
    if !artifacts_present() {
        return;
    }
    // Single iteration, single machine: Δβ must match to f32 tolerance.
    let ds = synth::dna_like(400, 64, 6, 204);
    let lam = lambda_max(&ds) / 8.0;
    let mk = |engine| {
        let c = TrainConfig::builder()
            .machines(1)
            .engine(engine)
            .lambda(lam)
            .max_iter(1)
            .build();
        let mut s = DGlmnetSolver::from_dataset(&ds, &c).unwrap();
        s.fit(None).unwrap();
        s.beta.clone()
    };
    let bn = mk(EngineKind::Native);
    let bx = mk(EngineKind::Xla);
    for j in 0..64 {
        assert!(
            (bn[j] - bx[j]).abs() < 5e-3 * (1.0 + bn[j].abs()),
            "beta[{j}]: native {} vs xla {}",
            bn[j],
            bx[j]
        );
    }
}
