//! Compile-only stub of the `xla` PJRT bindings.
//!
//! Mirrors the API surface `dglmnet --features xla` consumes —
//! [`PjRtClient`], [`PjRtLoadedExecutable`], [`HloModuleProto`],
//! [`XlaComputation`], [`Literal`], [`Error`] — so the gated engine/leader
//! paths type-check without the real vendored bindings. Host-side
//! [`Literal`] operations are implemented for real (they are plain
//! buffers); anything that would need a PJRT runtime returns an
//! explanatory [`Error`]. The `Auto` engine never selects XLA without
//! compiled artifacts, so a stub build behaves exactly like a native-only
//! build at runtime.

use std::fmt;

/// Stub error: carries the entry point that would have needed PJRT.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Self {
        Error(format!(
            "{what} is unavailable: this build uses the compile-only xla stub \
             (vendor the real PJRT bindings into rust/vendor/xla and run \
             `make artifacts` to enable the XLA hot path)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Sized + Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// A host-side tensor of f32 values (the only element type dglmnet uses).
#[derive(Debug, Clone, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over `data`.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret the buffer under new dimensions (element count must
    /// match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error(format!(
                "cannot reshape a {}-element literal to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Untuple an execution result. Stub literals never originate from an
    /// execution, so there is nothing meaningful to untuple.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// An HLO module loaded from text interchange.
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<std::path::Path>) -> Result<Self> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// A computation handed to [`PjRtClient::compile`].
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// A device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client. The stub cannot construct one — which is the contract:
/// gated paths compile, and anything that would actually run on PJRT fails
/// with an actionable message at the construction boundary.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_ops_work_host_side() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn runtime_entry_points_error_with_guidance() {
        let e = PjRtClient::cpu().unwrap_err().to_string();
        assert!(e.contains("stub"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
