//! P1 — hot-path micro benchmarks: one worker sweep (XLA vs native), leader
//! stats, batched line-search evaluation, the simulated tree AllReduce
//! (dense vs sparse wire format), a solver-level sparse-vs-dense
//! communication comparison, and the topology section — measured leader vs
//! max-worker bytes on the wire, star vs tree, M ∈ {4, 8} (the tree's
//! leader-byte M-ratio is the O(1)-leader-bandwidth gate). Emits
//! `BENCH_iteration.json` so the perf trajectory across PRs starts from a
//! machine-readable baseline.
//!
//! Run: `cargo bench --bench bench_iteration`

use std::collections::BTreeMap;

use dglmnet::bench_harness::{bench, section, BenchStats};
use dglmnet::cluster::allreduce::{AllReduceScratch, TreeAllReduce};
use dglmnet::cluster::network::{NetworkLedger, NetworkModel};
use dglmnet::cluster::partition::{FeaturePartition, PartitionStrategy};
use dglmnet::config::{EngineKind, ExchangeStrategy, TopologyKind, TrainConfig};
use dglmnet::data::shuffle::shard_in_memory;
use dglmnet::data::sparse::SparseVec;
use dglmnet::data::synth;
use dglmnet::engine::{NativeEngine, SubproblemEngine, SweepKernel, SweepResult};
#[cfg(feature = "xla")]
use dglmnet::engine::XlaEngine;
use dglmnet::solver::leader::LeaderCompute;
use dglmnet::solver::quadratic::stats_native;
use dglmnet::solver::{lambda_max, DGlmnetSolver};
use dglmnet::util::json::Json;

fn json_stats(s: &BenchStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("median_secs".to_string(), Json::Num(s.median));
    m.insert("mean_secs".to_string(), Json::Num(s.mean));
    m.insert("min_secs".to_string(), Json::Num(s.min));
    m.insert("max_secs".to_string(), Json::Num(s.max));
    m.insert("samples".to_string(), Json::Num(s.samples.len() as f64));
    Json::Obj(m)
}

fn main() {
    let artifacts = std::path::Path::new("artifacts");
    let have_artifacts =
        cfg!(feature = "xla") && artifacts.join("manifest.json").exists();
    if !have_artifacts {
        eprintln!(
            "WARNING: xla feature/artifacts missing; XLA benches skipped \
             (build with --features xla and run `make artifacts`)"
        );
    }
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    let record = |name: &str, s: &BenchStats| {
        println!("{}", s.row());
        (name.to_string(), json_stats(s))
    };

    // A webspam-like worker shard: 1000 local features over 3000 examples.
    let ds = synth::webspam_like(3_000, 4_000, 40, 7);
    let n = ds.n_examples();
    let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 4_000, 4, None);
    let shard = shard_in_memory(&ds.x, &part).remove(0);
    let margins = vec![0f32; n];
    let (w, z, _) = stats_native(&margins, &ds.y);
    let beta = vec![0f32; shard.csc.n_cols];

    section("worker sweep (one machine, 1000 features, n = 3000)");
    {
        let mut ne = NativeEngine::new(shard.clone(), n);
        let mut out = SweepResult::default();
        let s = bench("native sparse sweep (reused buffers)", 2, 10, || {
            ne.sweep(&w, &z, &beta, 0.5, 1e-6, 0.0, &mut out).unwrap();
        });
        let (k, v) = record("native_sweep_sparse_shard", &s);
        report.insert(k, v);
    }
    // the same shard through the other sweep-kernel configurations: the
    // covariance-update kernel and the threaded deterministic-merge path
    for (key, label, kernel) in [
        (
            "native_sweep_cov_shard",
            "native cov sweep (Gram-cached)",
            SweepKernel { naive: false, threads: 1, ..Default::default() },
        ),
        (
            "native_sweep_naive_t4_shard",
            "native naive sweep (4 threads)",
            SweepKernel { naive: true, threads: 4, ..Default::default() },
        ),
        (
            "native_sweep_cov_t4_shard",
            "native cov sweep (4 threads)",
            SweepKernel { naive: false, threads: 4, ..Default::default() },
        ),
    ] {
        let mut ne = NativeEngine::with_kernel(shard.clone(), n, kernel);
        let mut out = SweepResult::default();
        let s = bench(label, 2, 10, || {
            ne.sweep(&w, &z, &beta, 0.5, 1e-6, 0.0, &mut out).unwrap();
        });
        let (k, v) = record(key, &s);
        report.insert(k, v);
    }
    #[cfg(feature = "xla")]
    if have_artifacts {
        let mut naive = XlaEngine::with_kernel(shard.clone(), n, 64, artifacts, true).unwrap();
        let mut out = SweepResult::default();
        let s = bench("xla naive sweep (b=64, per-column)", 2, 10, || {
            naive.sweep(&w, &z, &beta, 0.5, 1e-6, 0.0, &mut out).unwrap();
        });
        let (k, v) = record("xla_sweep_naive_b64", &s);
        report.insert(k, v);
        let mut xe = XlaEngine::new(shard.clone(), n, 64, artifacts).unwrap();
        let s = bench("xla cov sweep (b=64, optimized)", 2, 10, || {
            xe.sweep(&w, &z, &beta, 0.5, 1e-6, 0.0, &mut out).unwrap();
        });
        let (k, v) = record("xla_sweep_cov_b64", &s);
        report.insert(k, v);
        let mut xe128 = XlaEngine::new(shard.clone(), n, 128, artifacts).unwrap();
        let s = bench("xla cov sweep (b=128, optimized)", 2, 10, || {
            xe128.sweep(&w, &z, &beta, 0.5, 1e-6, 0.0, &mut out).unwrap();
        });
        let (k, v) = record("xla_sweep_cov_b128", &s);
        report.insert(k, v);
    }

    section("worker sweep on a DENSE shard (epsilon-like, 128 features, n = 3000)");
    {
        let dense = synth::epsilon_like(3_000, 128, 8);
        let dpart = FeaturePartition::build(PartitionStrategy::RoundRobin, 128, 1, None);
        let dshard = shard_in_memory(&dense.x, &dpart).remove(0);
        let dmargins = vec![0f32; 3_000];
        let (dw, dz, _) = stats_native(&dmargins, &dense.y);
        let dbeta = vec![0f32; 128];
        let mut ne = NativeEngine::new(dshard.clone(), 3_000);
        let mut out = SweepResult::default();
        let s = bench("native sparse sweep (dense data)", 2, 10, || {
            ne.sweep(&dw, &dz, &dbeta, 0.5, 1e-6, 0.0, &mut out).unwrap();
        });
        let (k, v) = record("native_sweep_dense_shard", &s);
        report.insert(k, v);
        #[cfg(feature = "xla")]
        if have_artifacts {
            let mut xe = XlaEngine::new(dshard.clone(), 3_000, 64, artifacts).unwrap();
            let s = bench("xla cov sweep (dense data)", 2, 10, || {
                xe.sweep(&dw, &dz, &dbeta, 0.5, 1e-6, 0.0, &mut out).unwrap();
            });
            let (k, v) = record("xla_sweep_dense_shard", &s);
            report.insert(k, v);
        }
    }

    section("leader stats (n = 3000)");
    {
        let cfg = TrainConfig::builder().engine(EngineKind::Native).build();
        let mut leader = LeaderCompute::new(&cfg, &ds.y, artifacts).unwrap();
        let s = bench("native stats", 3, 20, || {
            let _ = leader.stats(&margins).unwrap();
        });
        let (k, v) = record("leader_stats_native", &s);
        report.insert(k, v);
    }
    #[cfg(feature = "xla")]
    if have_artifacts {
        let cfg = TrainConfig::builder().engine(EngineKind::Xla).build();
        let mut leader = LeaderCompute::new(&cfg, &ds.y, artifacts).unwrap();
        let s = bench("xla stats kernel", 3, 20, || {
            let _ = leader.stats(&margins).unwrap();
        });
        let (k, v) = record("leader_stats_xla", &s);
        report.insert(k, v);
    }

    section("line-search grid evaluation (16 alphas, n = 3000)");
    {
        let dm = vec![0.1f32; n];
        let alphas: Vec<f64> = (0..16).map(|i| i as f64 / 15.0).collect();
        let cfg = TrainConfig::builder().engine(EngineKind::Native).build();
        let mut leader = LeaderCompute::new(&cfg, &ds.y, artifacts).unwrap();
        let s = bench("native 16-alpha grid", 3, 20, || {
            let _ = leader.line_losses(&margins, &dm, &alphas).unwrap();
        });
        let (k, v) = record("line_search_grid_native", &s);
        report.insert(k, v);
        #[cfg(feature = "xla")]
        if have_artifacts {
            let cfg = TrainConfig::builder().engine(EngineKind::Xla).build();
            let mut leader = LeaderCompute::new(&cfg, &ds.y, artifacts).unwrap();
            let s = bench("xla 16-alpha grid kernel", 3, 20, || {
                let _ = leader.line_losses(&margins, &dm, &alphas).unwrap();
            });
            let (k, v) = record("line_search_grid_xla", &s);
            report.insert(k, v);
        }
    }

    section("tree allreduce, dense wire (n = 100k floats)");
    for m in [4usize, 16] {
        let contribs: Vec<Vec<f32>> = (0..m).map(|k| vec![k as f32; 100_000]).collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let s = bench(&format!("dense allreduce M = {m}"), 2, 10, || {
            let _ = ar.sum(&contribs, &ledger);
        });
        let (k, v) = record(&format!("allreduce_dense_m{m}"), &s);
        report.insert(k, v);
    }

    section("tree allreduce, sparse wire (dim = 100k, ~200 nnz/machine)");
    for m in [4usize, 16] {
        let contribs: Vec<SparseVec> = (0..m)
            .map(|k| {
                let mut v = SparseVec::new(100_000);
                // disjoint-ish strided supports, ~200 entries each
                for t in 0..200u32 {
                    v.push(t * 500 + k as u32, (k + 1) as f32);
                }
                v
            })
            .collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        let s = bench(&format!("sparse allreduce M = {m}"), 2, 10, || {
            let _ =
                ar.sum_sparse_into(contribs.iter(), 100_000, &ledger, &mut scratch, &mut out);
        });
        let (k, v) = record(&format!("allreduce_sparse_m{m}"), &s);
        report.insert(k, v);
    }

    section("full iteration via pool (M = 4, native, protocol sweep)");
    {
        let cfg = TrainConfig::builder()
            .machines(4)
            .engine(EngineKind::Native)
            .build();
        let shards = shard_in_memory(&ds.x, &part);
        let mut pool = dglmnet::solver::pool::WorkerPool::spawn(
            &cfg,
            shards,
            &ds.y,
            4_000,
            "artifacts".into(),
        )
        .unwrap();
        let mut results = Vec::new();
        let s = bench("pool.sweep_all (4 workers, worker-held state)", 2, 10, || {
            pool.sweep_all(0.5, 1e-6, 0.0, &mut results).unwrap();
        });
        let (k, v) = record("pool_sweep_all_m4", &s);
        report.insert(k, v);
    }

    // ---- solver-level sparse vs dense allreduce (the Table-3 claim) -----
    section("per-fit comm: sparse vs dense allreduce (webspam-like, M = 8)");
    {
        // p >> n and a high λ: the regime where update sparsity pays
        let ds = synth::webspam_like(1_000, 20_000, 12, 11);
        let lam = lambda_max(&ds) / 4.0;
        let mk = |dense: bool| {
            TrainConfig::builder()
                .machines(8)
                .engine(EngineKind::Native)
                .lambda(lam)
                .max_iter(25)
                .dense_allreduce(dense)
                .build()
        };
        let mut s_sparse = DGlmnetSolver::from_dataset(&ds, &mk(false)).unwrap();
        let t0 = std::time::Instant::now();
        let fit_sparse = s_sparse.fit(None).unwrap();
        let sparse_wall = t0.elapsed().as_secs_f64();
        let mut s_dense = DGlmnetSolver::from_dataset(&ds, &mk(true)).unwrap();
        let t1 = std::time::Instant::now();
        let fit_dense = s_dense.fit(None).unwrap();
        let dense_wall = t1.elapsed().as_secs_f64();
        let reduction = fit_dense.comm_bytes as f64 / fit_sparse.comm_bytes.max(1) as f64;
        println!(
            "sparse: {} bytes, {:.4}s sim-comm, obj {:.6} ({} iters, {:.3}s wall)",
            fit_sparse.comm_bytes,
            fit_sparse.sim_comm_secs,
            fit_sparse.objective,
            fit_sparse.iterations,
            sparse_wall
        );
        println!(
            "dense : {} bytes, {:.4}s sim-comm, obj {:.6} ({} iters, {:.3}s wall)",
            fit_dense.comm_bytes,
            fit_dense.sim_comm_secs,
            fit_dense.objective,
            fit_dense.iterations,
            dense_wall
        );
        println!("comm reduction: {reduction:.1}x");
        let mut m = BTreeMap::new();
        m.insert("sparse_comm_bytes".into(), Json::Num(fit_sparse.comm_bytes as f64));
        m.insert("dense_comm_bytes".into(), Json::Num(fit_dense.comm_bytes as f64));
        m.insert("comm_reduction_x".into(), Json::Num(reduction));
        m.insert("sparse_objective".into(), Json::Num(fit_sparse.objective));
        m.insert("dense_objective".into(), Json::Num(fit_dense.objective));
        m.insert("sparse_sim_comm_secs".into(), Json::Num(fit_sparse.sim_comm_secs));
        m.insert("dense_sim_comm_secs".into(), Json::Num(fit_dense.sim_comm_secs));
        m.insert(
            "sparse_wall_secs_per_iter".into(),
            Json::Num(sparse_wall / fit_sparse.iterations.max(1) as f64),
        );
        m.insert(
            "dense_wall_secs_per_iter".into(),
            Json::Num(dense_wall / fit_dense.iterations.max(1) as f64),
        );
        report.insert("fit_sparse_vs_dense_comm".into(), Json::Obj(m));
    }

    // ---- per-strategy comm: reduce-Δm vs allgather-Δβ vs the cost model -
    section("per-fit comm: exchange strategies (webspam-like, M = 8)");
    {
        let ds = synth::webspam_like(1_000, 20_000, 12, 11);
        let lam = lambda_max(&ds) / 4.0;
        let mk = |exchange: ExchangeStrategy| {
            TrainConfig::builder()
                .machines(8)
                .engine(EngineKind::Native)
                .lambda(lam)
                .max_iter(25)
                .exchange(exchange)
                .build()
        };
        let run = |cfg: &TrainConfig| {
            let mut s = DGlmnetSolver::from_dataset(&ds, cfg).unwrap();
            s.fit(None).unwrap()
        };
        let fit_reduce = run(&mk(ExchangeStrategy::ReduceDm));
        let fit_gather = run(&mk(ExchangeStrategy::AllGatherBeta));
        let fit_auto = run(&mk(ExchangeStrategy::Auto));
        // the strategy the cost model picked (majority across iterations) —
        // check_bench_regression.py gates comm growth on this one
        let gather_iters = fit_auto
            .trace
            .iter()
            .filter(|r| r.exchange == Some(ExchangeStrategy::AllGatherBeta))
            .count();
        let chosen = if 2 * gather_iters >= fit_auto.trace.len() {
            "allgather_beta"
        } else {
            "reduce_dm"
        };
        println!(
            "reduce-Δm   : {} bytes, obj {:.6} ({} iters)",
            fit_reduce.comm_bytes, fit_reduce.objective, fit_reduce.iterations
        );
        println!(
            "allgather-Δβ: {} bytes, obj {:.6} ({} iters)",
            fit_gather.comm_bytes, fit_gather.objective, fit_gather.iterations
        );
        println!(
            "auto        : {} bytes, obj {:.6} ({} iters, picked {chosen})",
            fit_auto.comm_bytes, fit_auto.objective, fit_auto.iterations
        );
        let mut m = BTreeMap::new();
        m.insert("reduce_dm_comm_bytes".into(), Json::Num(fit_reduce.comm_bytes as f64));
        m.insert(
            "allgather_beta_comm_bytes".into(),
            Json::Num(fit_gather.comm_bytes as f64),
        );
        m.insert("auto_comm_bytes".into(), Json::Num(fit_auto.comm_bytes as f64));
        m.insert("chosen_strategy".into(), Json::Str(chosen.into()));
        m.insert("auto_objective".into(), Json::Num(fit_auto.objective));
        m.insert("reduce_dm_objective".into(), Json::Num(fit_reduce.objective));
        report.insert("fit_exchange_strategies".into(), Json::Obj(m));
    }

    // ---- per-transport comm: the same fit in-process vs over sockets ----
    section("per-transport comm: in-process vs socket (webspam-like, M = 4)");
    {
        let ds = synth::webspam_like(800, 8_000, 12, 13);
        let lam = lambda_max(&ds) / 4.0;
        let cfg = TrainConfig::builder()
            .machines(4)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(15)
            .build();
        let t0 = std::time::Instant::now();
        let mut local = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
        let fit_local = local.fit(None).unwrap();
        let local_wall = t0.elapsed().as_secs_f64();

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let workers = dglmnet::solver::pool::spawn_local_socket_workers(&cfg, &ds, addr);
        let t1 = std::time::Instant::now();
        let mut remote = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
        let fit_remote = remote.fit(None).unwrap();
        let remote_wall = t1.elapsed().as_secs_f64();
        drop(remote);
        for h in workers {
            h.join().expect("worker thread panicked").unwrap();
        }

        println!(
            "in-process: {} bytes, obj {:.6} ({} iters, {:.3}s wall)",
            fit_local.comm_bytes, fit_local.objective, fit_local.iterations, local_wall
        );
        println!(
            "socket    : {} bytes, obj {:.6} ({} iters, {:.3}s wall)",
            fit_remote.comm_bytes, fit_remote.objective, fit_remote.iterations, remote_wall
        );
        assert_eq!(
            fit_local.objective.to_bits(),
            fit_remote.objective.to_bits(),
            "transports must not change the trajectory"
        );
        let mut m = BTreeMap::new();
        m.insert("in_process_comm_bytes".into(), Json::Num(fit_local.comm_bytes as f64));
        m.insert("socket_comm_bytes".into(), Json::Num(fit_remote.comm_bytes as f64));
        m.insert(
            "in_process_wall_secs_per_iter".into(),
            Json::Num(local_wall / fit_local.iterations.max(1) as f64),
        );
        m.insert(
            "socket_wall_secs_per_iter".into(),
            Json::Num(remote_wall / fit_remote.iterations.max(1) as f64),
        );
        m.insert("objective".into(), Json::Num(fit_local.objective));
        report.insert("fit_transport_comparison".into(), Json::Obj(m));
    }

    // ---- topology: measured leader vs worker bandwidth, star vs tree ----
    // The O(1)-leader-bandwidth claim, measured at the transport: under the
    // star the leader's per-iteration bytes grow linearly in M, under the
    // tree they are pinned to the root edge. check_bench_regression.py
    // gates the tree's M-ratio near 1.
    section("topology: leader bytes on the wire, star vs tree (M ∈ {4, 8})");
    {
        let ds = synth::webspam_like(800, 8_000, 12, 13);
        let lam = lambda_max(&ds) / 4.0;
        let mut m = BTreeMap::new();
        let mut leader_per_iter = BTreeMap::new();
        for (topology, tname) in
            [(TopologyKind::Star, "star"), (TopologyKind::Tree, "tree")]
        {
            for machines in [4usize, 8] {
                let cfg = TrainConfig::builder()
                    .machines(machines)
                    .engine(EngineKind::Native)
                    .lambda(lam)
                    .max_iter(15)
                    .topology(topology)
                    .build();
                let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
                let addr = listener.local_addr().unwrap();
                let (workers, counters) =
                    dglmnet::solver::pool::spawn_local_socket_workers_counted(
                        &cfg, &ds, addr,
                    );
                let mut solver =
                    DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener).unwrap();
                let fit = solver.fit(None).unwrap();
                let (sent, recv) = solver.leader_wire_bytes();
                drop(solver);
                for h in workers {
                    h.join().expect("worker thread panicked").unwrap();
                }
                let iters = fit.iterations.max(1) as f64;
                let leader = (sent + recv) as f64 / iters;
                let worker_max = counters
                    .iter()
                    .map(|c| {
                        let (s, r) = c.totals();
                        s + r
                    })
                    .max()
                    .unwrap_or(0) as f64
                    / iters;
                println!(
                    "{tname} M = {machines}: leader {leader:.0} B/iter, \
                     busiest worker {worker_max:.0} B/iter ({} iters, obj {:.6})",
                    fit.iterations, fit.objective
                );
                leader_per_iter.insert((tname, machines), leader);
                m.insert(
                    format!("{tname}_m{machines}_leader_bytes_per_iter"),
                    Json::Num(leader),
                );
                m.insert(
                    format!("{tname}_m{machines}_max_worker_bytes_per_iter"),
                    Json::Num(worker_max),
                );
            }
        }
        for tname in ["star", "tree"] {
            let ratio = leader_per_iter[&(tname, 8usize)]
                / leader_per_iter[&(tname, 4usize)].max(1.0);
            println!("{tname} leader-byte ratio M=8 / M=4: {ratio:.2}x");
            m.insert(
                format!("leader_byte_ratio_m8_over_m4_{tname}"),
                Json::Num(ratio),
            );
        }
        report.insert("fit_topology".into(), Json::Obj(m));
    }

    // ---- leader-process peak RSS ----------------------------------------
    // Self-read from /proc/self/status after all fits above: this process
    // played the leader for every solver-level section, so growth here is
    // the leader-memory regression canary the check script gates (the
    // socket_e2e CI job additionally asserts an *isolated* store-driven
    // leader process stays below the full-load watermark).
    section("leader-process peak RSS");
    {
        let rss = dglmnet::util::peak_rss_bytes().unwrap_or(0);
        println!("peak RSS: {:.1} MiB", rss as f64 / (1u64 << 20) as f64);
        let mut m = BTreeMap::new();
        m.insert("peak_rss_bytes".into(), Json::Num(rss as f64));
        report.insert("leader_process".into(), Json::Obj(m));
    }

    // ---- emit the machine-readable baseline -----------------------------
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("bench_iteration".into()));
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("results".to_string(), Json::Obj(report));
    let path = "BENCH_iteration.json";
    match std::fs::write(path, format!("{}\n", Json::Obj(top))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
