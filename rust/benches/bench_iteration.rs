//! P1 — hot-path micro benchmarks: one worker sweep (XLA vs native), leader
//! stats, batched line-search evaluation, and the simulated tree AllReduce.
//! These are the pieces the §Perf iteration log in EXPERIMENTS.md tracks.
//!
//! Run: `cargo bench --bench bench_iteration`

use std::sync::Arc;

use dglmnet::bench_harness::{bench, section};
use dglmnet::cluster::allreduce::TreeAllReduce;
use dglmnet::cluster::network::{NetworkLedger, NetworkModel};
use dglmnet::cluster::partition::{FeaturePartition, PartitionStrategy};
use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::shuffle::shard_in_memory;
use dglmnet::data::synth;
use dglmnet::engine::{NativeEngine, SubproblemEngine, XlaEngine};
use dglmnet::solver::leader::LeaderCompute;
use dglmnet::solver::quadratic::stats_native;

fn main() {
    let artifacts = std::path::Path::new("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists();
    if !have_artifacts {
        eprintln!("WARNING: artifacts missing; XLA benches skipped (run `make artifacts`)");
    }

    // A webspam-like worker shard: 1000 local features over 3000 examples.
    let ds = synth::webspam_like(3_000, 4_000, 40, 7);
    let n = ds.n_examples();
    let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 4_000, 4, None);
    let shard = shard_in_memory(&ds.x, &part).remove(0);
    let margins = vec![0f32; n];
    let (w, z, _) = stats_native(&margins, &ds.y);
    let beta = vec![0f32; shard.csc.n_cols];

    section("worker sweep (one machine, 1000 features, n = 3000)");
    {
        let mut ne = NativeEngine::new(shard.clone(), n);
        let s = bench("native sparse sweep", 2, 10, || {
            let _ = ne.sweep(&w, &z, &beta, 0.5, 1e-6).unwrap();
        });
        println!("{}", s.row());
    }
    if have_artifacts {
        let mut naive = XlaEngine::with_kernel(shard.clone(), n, 64, artifacts, true).unwrap();
        let s = bench("xla naive sweep (b=64, per-column)", 2, 10, || {
            let _ = naive.sweep(&w, &z, &beta, 0.5, 1e-6).unwrap();
        });
        println!("{}", s.row());
        let mut xe = XlaEngine::new(shard.clone(), n, 64, artifacts).unwrap();
        let s = bench("xla cov sweep (b=64, optimized)", 2, 10, || {
            let _ = xe.sweep(&w, &z, &beta, 0.5, 1e-6).unwrap();
        });
        println!("{}", s.row());
        let mut xe128 = XlaEngine::new(shard.clone(), n, 128, artifacts).unwrap();
        let s = bench("xla cov sweep (b=128, optimized)", 2, 10, || {
            let _ = xe128.sweep(&w, &z, &beta, 0.5, 1e-6).unwrap();
        });
        println!("{}", s.row());
    }

    section("worker sweep on a DENSE shard (epsilon-like, 128 features, n = 3000)");
    {
        let dense = synth::epsilon_like(3_000, 128, 8);
        let dpart = FeaturePartition::build(PartitionStrategy::RoundRobin, 128, 1, None);
        let dshard = shard_in_memory(&dense.x, &dpart).remove(0);
        let dmargins = vec![0f32; 3_000];
        let (dw, dz, _) = stats_native(&dmargins, &dense.y);
        let dbeta = vec![0f32; 128];
        let mut ne = NativeEngine::new(dshard.clone(), 3_000);
        let s = bench("native sparse sweep (dense data)", 2, 10, || {
            let _ = ne.sweep(&dw, &dz, &dbeta, 0.5, 1e-6).unwrap();
        });
        println!("{}", s.row());
        if have_artifacts {
            let mut xe = XlaEngine::new(dshard.clone(), 3_000, 64, artifacts).unwrap();
            let s = bench("xla cov sweep (dense data)", 2, 10, || {
                let _ = xe.sweep(&dw, &dz, &dbeta, 0.5, 1e-6).unwrap();
            });
            println!("{}", s.row());
        }
    }

    section("leader stats (n = 3000)");
    {
        let cfg = TrainConfig::builder().engine(EngineKind::Native).build();
        let mut leader = LeaderCompute::new(&cfg, &ds.y, artifacts).unwrap();
        let s = bench("native stats", 3, 20, || {
            let _ = leader.stats(&margins).unwrap();
        });
        println!("{}", s.row());
    }
    if have_artifacts {
        let cfg = TrainConfig::builder().engine(EngineKind::Xla).build();
        let mut leader = LeaderCompute::new(&cfg, &ds.y, artifacts).unwrap();
        let s = bench("xla stats kernel", 3, 20, || {
            let _ = leader.stats(&margins).unwrap();
        });
        println!("{}", s.row());
    }

    section("line-search grid evaluation (16 alphas, n = 3000)");
    {
        let dm = vec![0.1f32; n];
        let alphas: Vec<f64> = (0..16).map(|i| i as f64 / 15.0).collect();
        let cfg = TrainConfig::builder().engine(EngineKind::Native).build();
        let mut leader = LeaderCompute::new(&cfg, &ds.y, artifacts).unwrap();
        let s = bench("native 16-alpha grid", 3, 20, || {
            let _ = leader.line_losses(&margins, &dm, &alphas).unwrap();
        });
        println!("{}", s.row());
        if have_artifacts {
            let cfg = TrainConfig::builder().engine(EngineKind::Xla).build();
            let mut leader = LeaderCompute::new(&cfg, &ds.y, artifacts).unwrap();
            let s = bench("xla 16-alpha grid kernel", 3, 20, || {
                let _ = leader.line_losses(&margins, &dm, &alphas).unwrap();
            });
            println!("{}", s.row());
        }
    }

    section("tree allreduce (n = 100k floats)");
    for m in [4usize, 16] {
        let contribs: Vec<Vec<f32>> = (0..m).map(|k| vec![k as f32; 100_000]).collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let s = bench(&format!("allreduce M = {m}"), 2, 10, || {
            let _ = ar.sum(&contribs, &ledger);
        });
        println!("{}", s.row());
    }

    section("full iteration via pool (M = 4, native)");
    {
        let cfg = TrainConfig::builder()
            .machines(4)
            .engine(EngineKind::Native)
            .build();
        let shards = shard_in_memory(&ds.x, &part);
        let pool =
            dglmnet::solver::pool::WorkerPool::spawn(&cfg, shards, n, "artifacts".into()).unwrap();
        let (wa, za) = (Arc::new(w.clone()), Arc::new(z.clone()));
        let beta_full = vec![0f32; 4_000];
        let s = bench("pool.sweep_all (4 workers)", 2, 10, || {
            let _ = pool.sweep_all(&wa, &za, &beta_full, 0.5, 1e-6).unwrap();
        });
        println!("{}", s.row());
    }
}
