//! Figure 1 (a, b, c) — testing quality (area under Precision-Recall curve)
//! versus the number of non-zero entries in β, for d-GLMNET's
//! regularization path against the distributed truncated-gradient grid, on
//! the three Table-2 dataset analogs.
//!
//! Paper expectation: "The d-GLMNET algorithm is a clear winner: for each
//! data set, each degree of sparsity, it yields the same or better testing
//! quality." We print both series, the frontier-dominance score, and write
//! CSVs under target/figure1/.
//!
//! Run: `cargo bench --bench bench_figure1`
//! (set DGLMNET_FAST=1 for a reduced-size smoke run)

use dglmnet::baselines::grid::{grid_frontier, online_grid_search};
use dglmnet::config::{EngineKind, PathConfig, TrainConfig};
use dglmnet::data::dataset::SplitDataset;
use dglmnet::data::synth;
use dglmnet::report::{ascii_scatter, write_series_csv, Series, Table};
use dglmnet::solver::{lambda_max, RegPath};

struct FigureSpec {
    tag: &'static str,
    paper_dataset: &'static str,
    split: SplitDataset,
    machines: usize,
    path_steps: usize,
    passes: usize,
}

fn datasets(fast: bool) -> Vec<FigureSpec> {
    let f = if fast { 4 } else { 1 };
    vec![
        FigureSpec {
            tag: "fig1a",
            paper_dataset: "epsilon (dense)",
            split: synth::epsilon_like(8_000 / f, 512 / f, 11).split(0.8, 11).unwrap(),
            machines: 4,
            path_steps: if fast { 6 } else { 14 },
            passes: if fast { 3 } else { 8 },
        },
        FigureSpec {
            tag: "fig1b",
            paper_dataset: "webspam (sparse, p >> n)",
            split: synth::webspam_like(4_000 / f, 16_000 / f, 60, 12).split(0.8, 12).unwrap(),
            machines: 8,
            path_steps: if fast { 6 } else { 14 },
            passes: if fast { 3 } else { 8 },
        },
        FigureSpec {
            tag: "fig1c",
            paper_dataset: "dna (n >> p)",
            split: synth::dna_like(40_000 / f, 400, 12, 13).split(0.8, 13).unwrap(),
            machines: 4,
            path_steps: if fast { 6 } else { 14 },
            passes: if fast { 3 } else { 8 },
        },
    ]
}

fn main() -> dglmnet::Result<()> {
    let fast = std::env::var("DGLMNET_FAST").is_ok();
    let engine = EngineKind::Auto; // per-shard XLA/native routing
    let mut summary = Table::new(
        "Figure 1 reproduction summary",
        &["figure", "dataset", "best d-GLMNET AUPRC", "best baseline AUPRC", "frontier wins", "shape holds"],
    );

    for spec in datasets(fast) {
        println!("\n########## {} — {} ##########", spec.tag, spec.paper_dataset);
        let train = &spec.split.train;
        let test = &spec.split.test;
        println!(
            "n = {} train / {} test, p = {}, nnz = {}",
            train.n_examples(),
            test.n_examples(),
            train.n_features(),
            train.x.nnz()
        );

        // d-GLMNET path
        let cfg = TrainConfig::builder()
            .machines(spec.machines)
            .engine(engine)
            .max_iter(40)
            .build();
        let path_cfg = PathConfig { steps: spec.path_steps, ..Default::default() };
        let path = RegPath::run(train, test, &cfg, &path_cfg)?;

        // baseline grid (the paper's full §4.3 sweep, reduced rates in fast)
        // extended above λ_max: truncated gradient needs stronger shrinkage
        // to reach the same sparsity (the paper added extra λ ranges too)
        let lam_max = lambda_max(train);
        let lambdas: Vec<f64> = (-6..=spec.path_steps.min(10) as i32)
            .map(|i| lam_max * 0.5f64.powi(i))
            .collect();
        let (rates, decays): (&[f64], &[f64]) = if fast {
            (&[0.1, 0.5], &[0.5])
        } else {
            (&[0.1, 0.2, 0.3, 0.4, 0.5], &[0.5, 0.7, 0.9])
        };
        let grid = online_grid_search(
            train, test, spec.machines, rates, decays, &lambdas, spec.passes, 5,
        );

        // series + plot
        let mut dg = Series::new("d-glmnet");
        for p in &path.points {
            if p.nnz > 0 {
                dg.push(p.nnz as f64, p.auprc);
            }
        }
        let mut vw = Series::new("trunc-grad");
        for g in &grid {
            if g.nnz > 0 {
                vw.push(g.nnz as f64, g.auprc);
            }
        }
        print!("{}", ascii_scatter(&[dg.clone(), vw.clone()], 70, 16));
        write_series_csv(
            format!("target/figure1/{}.csv", spec.tag),
            &[dg.clone(), vw.clone()],
        )?;

        // dominance score
        let dg_front = path.frontier();
        let vw_front = grid_frontier(&grid);
        let mut wins = 0usize;
        let mut total = 0usize;
        for &(nnz, auprc) in &dg_front {
            let vw_best = vw_front
                .iter()
                .filter(|&&(v, _)| v <= nnz)
                .map(|&(_, a)| a)
                .fold(f64::NEG_INFINITY, f64::max);
            if vw_best.is_finite() {
                total += 1;
                if auprc >= vw_best - 1e-3 {
                    wins += 1;
                }
            }
        }
        let best_dg = path.points.iter().map(|p| p.auprc).fold(0.0, f64::max);
        let best_vw = grid.iter().map(|g| g.auprc).fold(0.0, f64::max);
        let holds = best_dg >= best_vw - 5e-3 && total > 0 && wins * 10 >= total * 8;
        summary.add_row(vec![
            spec.tag.to_string(),
            spec.paper_dataset.to_string(),
            format!("{best_dg:.4}"),
            format!("{best_vw:.4}"),
            format!("{wins}/{total}"),
            if holds { "YES".into() } else { "CHECK".into() },
        ]);
    }
    println!();
    summary.print();
    println!("CSVs under target/figure1/");
    Ok(())
}
