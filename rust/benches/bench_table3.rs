//! Table 3 — execution times for the whole regularization path on each
//! Table-2 dataset analog: total iterations, total time, % time in the
//! line search, average time per d-GLMNET iteration, and the baseline's
//! average time per pass (one pass over the data = one d-GLMNET iteration
//! in complexity, both O(nnz) — the paper's comparability argument).
//!
//! Run: `cargo bench --bench bench_table3`
//! (DGLMNET_FAST=1 for a reduced run)

use dglmnet::baselines::distributed_online::DistributedOnlineLearner;
use dglmnet::config::{EngineKind, PathConfig, TrainConfig};
use dglmnet::data::dataset::SplitDataset;
use dglmnet::data::synth;
use dglmnet::report::Table;
use dglmnet::solver::RegPath;

fn main() -> dglmnet::Result<()> {
    let fast = std::env::var("DGLMNET_FAST").is_ok();
    let f = if fast { 4 } else { 1 };
    let engine = EngineKind::Auto; // per-shard XLA/native routing

    let specs: Vec<(&str, SplitDataset, usize)> = vec![
        ("epsilon_like", synth::epsilon_like(8_000 / f, 512 / f, 21).split(0.8, 21).unwrap(), 4),
        ("webspam_like", synth::webspam_like(4_000 / f, 16_000 / f, 60, 22).split(0.8, 22).unwrap(), 8),
        ("dna_like", synth::dna_like(40_000 / f, 400, 12, 23).split(0.8, 23).unwrap(), 4),
    ];

    let mut t2 = Table::new(
        "Table 2 analog — datasets",
        &["dataset", "#examples (train/test)", "#features", "nnz", "avg nonzeros"],
    );
    let mut t3 = Table::new(
        "Table 3 analog — execution times (whole regularization path)",
        &["dataset", "#iter", "time, sec", "line search %", "avg time/iter, sec", "baseline avg time/pass, sec"],
    );

    for (name, split, machines) in specs {
        let s = split.train.summary();
        t2.add_row(vec![
            name.to_string(),
            format!("{}/{}", split.train.n_examples(), split.test.n_examples()),
            s.n_features.to_string(),
            s.nnz.to_string(),
            format!("{:.0}", s.avg_nonzeros),
        ]);

        println!("[{name}] d-GLMNET path ({machines} machines, {engine:?} engine)...");
        let cfg = TrainConfig::builder()
            .machines(machines)
            .engine(engine)
            .max_iter(40)
            .build();
        let steps = if fast { 6 } else { 14 };
        let path_cfg = PathConfig { steps, ..Default::default() };
        let t0 = std::time::Instant::now();
        let path = RegPath::run(&split.train, &split.test, &cfg, &path_cfg)?;
        let total = t0.elapsed().as_secs_f64();

        println!("[{name}] baseline passes...");
        let passes = if fast { 2 } else { 5 };
        let learner = DistributedOnlineLearner::new(machines, 0.1, 0.5, 1e-7, 9);
        let t1 = std::time::Instant::now();
        let _ = learner.train(&split.train, passes);
        let per_pass = t1.elapsed().as_secs_f64() / passes as f64;

        t3.add_row(vec![
            name.to_string(),
            path.total_iterations.to_string(),
            format!("{total:.1}"),
            format!("{:.0}%", path.line_search_frac * 100.0),
            format!("{:.3}", total / path.total_iterations.max(1) as f64),
            format!("{per_pass:.3}"),
        ]);
    }
    println!();
    t2.print();
    println!();
    t3.print();
    println!(
        "\npaper shape check: line search should be a minor fraction (5-25% in the\n\
         paper); avg d-GLMNET iteration and baseline pass are both O(nnz)."
    );
    Ok(())
}
