//! Ablations of the design choices DESIGN.md calls out:
//!
//! * `shotgun`    (A1) — parallel stochastic CD conflicts vs d-GLMNET's
//!                 combine-then-line-search (the §1 motivation).
//! * `blocks`     (A2) — block-diagonal Hessian coarseness: iterations and
//!                 objective trajectory vs M ∈ {1, 2, 4, 8, 16}.
//! * `linesearch` (A3) — Alg 3's α_init scan vs plain Armijo backtracking.
//! * `comm`       (A4) — measured AllReduce bytes/time vs the O((n+p)·ln M)
//!                 model, plus the shuffle preprocessing share (§3).
//! * `partition`  — round-robin vs contiguous vs nnz-balanced shards.
//! * `kernels`    — naive vs covariance-update vs threaded sweep kernels on
//!                 one worker shard; emits `BENCH_ablation.json` with
//!                 per-sweep ns and speedup ratios so the CI regression
//!                 gate can watch the kernel win across PRs.
//! * `families`   — per-sweep cost of the GLM families (working stats +
//!                 sweep) at elastic-net α ∈ {1.0, 0.5}; same shard
//!                 geometry as `kernels`, merged into `BENCH_ablation.json`
//!                 for the same regression gate.
//!
//! Run: `cargo bench --bench bench_ablation [-- <name>]` (default: all)

use std::collections::BTreeMap;

use dglmnet::baselines::shotgun::shotgun;
use dglmnet::bench_harness::{bench, section};
use dglmnet::cluster::partition::{FeaturePartition, PartitionStrategy};
use dglmnet::config::{EngineKind, LineSearchConfig, TrainConfig};
use dglmnet::data::shuffle::{shard_in_memory, shuffle_to_feature_shards};
use dglmnet::data::synth;
use dglmnet::engine::{NativeEngine, SubproblemEngine, SweepKernel, SweepResult};
use dglmnet::family::FamilyKind;
use dglmnet::report::Table;
use dglmnet::solver::quadratic::stats_native;
use dglmnet::solver::{lambda_max, DGlmnetSolver};
use dglmnet::util::json::Json;

/// Merge `results` in as one named section of `BENCH_ablation.json`,
/// preserving every other section a previous bench invocation wrote (the
/// kernels and families ablations run independently — a plain overwrite
/// would drop whichever ran first).
fn write_bench_section(section_name: &str, results: BTreeMap<String, Json>) {
    let path = "BENCH_ablation.json";
    let mut sections = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| dglmnet::util::json::parse(&text).ok())
        .and_then(|doc| match doc {
            Json::Obj(mut top) => match top.remove("results") {
                Some(Json::Obj(s)) => Some(s),
                _ => None,
            },
            _ => None,
        })
        .unwrap_or_default();
    sections.insert(section_name.to_string(), Json::Obj(results));
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("bench_ablation".into()));
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("results".to_string(), Json::Obj(sections));
    match std::fs::write(path, format!("{}\n", Json::Obj(top))) {
        Ok(()) => println!("\nwrote {path} ({section_name} section)"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn ablation_shotgun() {
    section("A1: shotgun update conflicts (correlated features)");
    // near-duplicate columns: the worst case for uncoordinated parallel CD
    let base = synth::epsilon_like(400, 8, 31);
    let p = 64usize;
    let mut x = dglmnet::data::sparse::CsrMatrix::new(p);
    for i in 0..400 {
        let (_, vals) = base.x.row(i);
        let entries: Vec<(u32, f32)> = (0..p)
            .map(|j| (j as u32, vals[j % vals.len()] * (1.0 + 0.01 * j as f32)))
            .collect();
        x.push_row(&entries);
    }
    let ds = dglmnet::data::dataset::Dataset::new("correlated", x, base.y.clone());
    let csc = ds.x.to_csc();
    let mut t = Table::new("", &["parallel updates P", "final objective", "diverged"]);
    for par in [1usize, 4, 16, 64] {
        let r = shotgun(&ds, &csc, 0.1, par, 64, 7);
        t.add_row(vec![
            par.to_string(),
            format!("{:.2}", r.objective_trace.last().unwrap()),
            r.diverged.to_string(),
        ]);
    }
    t.print();
    // d-GLMNET on the same data: the line search absorbs the conflicts
    let cfg = TrainConfig::builder()
        .machines(8)
        .engine(EngineKind::Native)
        .lambda(0.1)
        .max_iter(64)
        .build();
    let mut s = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
    let fit = s.fit(None).unwrap();
    println!(
        "d-GLMNET (M = 8, same correlated data): objective {:.2} in {} iters, no divergence\n",
        fit.objective, fit.iterations
    );
}

fn ablation_blocks() {
    section("A2: block-diagonal Hessian coarseness (iterations vs M)");
    let split = synth::webspam_like(3_000, 3_000, 30, 32).split(0.8, 32).unwrap();
    let lam = lambda_max(&split.train) / 32.0;
    let mut t = Table::new("", &["M", "iterations", "objective", "nnz"]);
    for m in [1usize, 2, 4, 8, 16] {
        let cfg = TrainConfig::builder()
            .machines(m)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(80)
            .build();
        let mut s = DGlmnetSolver::from_dataset(&split.train, &cfg).unwrap();
        let fit = s.fit(None).unwrap();
        t.add_row(vec![
            m.to_string(),
            fit.iterations.to_string(),
            format!("{:.4}", fit.objective),
            fit.nnz().to_string(),
        ]);
    }
    t.print();
    println!("expected: same objective for all M; iterations grow mildly with M.\n");
}

fn ablation_linesearch() {
    section("A3: alpha_init scan (Alg 3 step 2) vs plain Armijo");
    let split = synth::dna_like(8_000, 300, 10, 33).split(0.8, 33).unwrap();
    let lam = lambda_max(&split.train) / 64.0;
    let mut t = Table::new("", &["variant", "iterations", "objective", "nnz", "wall s"]);
    for (name, skip) in [("alpha_init scan (paper)", false), ("plain Armijo from 1", true)] {
        let ls = LineSearchConfig { skip_alpha_init: skip, ..Default::default() };
        let cfg = TrainConfig::builder()
            .machines(4)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(80)
            .line_search(ls)
            .build();
        let t0 = std::time::Instant::now();
        let mut s = DGlmnetSolver::from_dataset(&split.train, &cfg).unwrap();
        let fit = s.fit(None).unwrap();
        t.add_row(vec![
            name.to_string(),
            fit.iterations.to_string(),
            format!("{:.4}", fit.objective),
            fit.nnz().to_string(),
            format!("{:.2}", t0.elapsed().as_secs_f64()),
        ]);
    }
    t.print();
    println!("paper: selecting alpha_init by minimizing f speeds up convergence.\n");
}

fn ablation_comm() {
    section("A4: communication vs the O((n+p)·ln M) model + shuffle share");
    let split = synth::webspam_like(3_000, 6_000, 40, 34).split(0.8, 34).unwrap();
    let lam = lambda_max(&split.train) / 16.0;
    let mut t = Table::new(
        "",
        &["M", "iters", "bytes moved", "bytes/iter", "sim comm s", "pred ratio vs M=2"],
    );
    let mut base: Option<f64> = None;
    for m in [2usize, 4, 8, 16] {
        let cfg = TrainConfig::builder()
            .machines(m)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(30)
            .build();
        let mut s = DGlmnetSolver::from_dataset(&split.train, &cfg).unwrap();
        let fit = s.fit(None).unwrap();
        let per_iter = fit.comm_bytes as f64 / fit.iterations.max(1) as f64;
        let b = *base.get_or_insert(per_iter);
        // model: bytes/iter ∝ (reduce+broadcast rounds) = 2·ceil(log2 M)… the
        // reduce tree sends M-1 vectors + log M broadcast: predict vs M=2.
        let pred = |m: usize| (m - 1) as f64 + (m as f64).log2().ceil();
        t.add_row(vec![
            m.to_string(),
            fit.iterations.to_string(),
            fit.comm_bytes.to_string(),
            format!("{per_iter:.0}"),
            format!("{:.5}", fit.sim_comm_secs),
            format!("{:.2} (measured {:.2})", pred(m) / pred(2), per_iter / b),
        ]);
    }
    t.print();

    // shuffle share of total path time (§3: paper reports 1–5%)
    let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 6_000, 8, None);
    let dir = std::env::temp_dir().join(format!("dglmnet_bench_shuffle_{}", std::process::id()));
    let t0 = std::time::Instant::now();
    let (_, stats) = shuffle_to_feature_shards(&split.train.x, &part, &dir).unwrap();
    let shuffle_secs = t0.elapsed().as_secs_f64();
    std::fs::remove_dir_all(&dir).ok();
    println!(
        "by-feature shuffle: {:.2}s ({} triplets, {} spill bytes) — compare to path wall time\n",
        shuffle_secs, stats.triplets, stats.spill_bytes
    );
}

fn ablation_partition() {
    section("partition strategy on a skewed dataset");
    let split = synth::webspam_like(2_000, 4_000, 40, 35).split(0.8, 35).unwrap();
    let lam = lambda_max(&split.train) / 16.0;
    let mut t = Table::new("", &["strategy", "iters", "objective", "max/min shard nnz"]);
    for (name, strat) in [
        ("round-robin", PartitionStrategy::RoundRobin),
        ("contiguous", PartitionStrategy::Contiguous),
        ("nnz-balanced", PartitionStrategy::NnzBalanced),
    ] {
        let cfg = TrainConfig::builder()
            .machines(8)
            .engine(EngineKind::Native)
            .lambda(lam)
            .partition(strat)
            .max_iter(40)
            .build();
        let mut s = DGlmnetSolver::from_dataset(&split.train, &cfg).unwrap();
        // shard balance
        let csc = split.train.x.to_csc();
        let loads: Vec<usize> = (0..8)
            .map(|k| {
                s.partition()
                    .features_of(k)
                    .iter()
                    .map(|&j| csc.col_nnz(j as usize))
                    .sum()
            })
            .collect();
        let fit = s.fit(None).unwrap();
        t.add_row(vec![
            name.to_string(),
            fit.iterations.to_string(),
            format!("{:.4}", fit.objective),
            format!(
                "{:.2}",
                *loads.iter().max().unwrap() as f64 / (*loads.iter().min().unwrap()).max(1) as f64
            ),
        ]);
    }
    t.print();
    println!();
}

fn ablation_kernels() {
    section("kernels: naive vs covariance-update vs threaded sweep");
    // one worker shard of the bench_iteration geometry, swept at the λ the
    // acceptance pin uses: λ_max / 4 on webspam-like data
    let ds = synth::webspam_like(3_000, 4_000, 40, 7);
    let n = ds.n_examples();
    let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 4_000, 4, None);
    let shard = shard_in_memory(&ds.x, &part).remove(0);
    let lam = (lambda_max(&ds) / 4.0) as f32;
    let margins = vec![0f32; n];
    let (w, z, _) = stats_native(&margins, &ds.y);
    let beta = vec![0f32; shard.csc.n_cols];

    let kernel = |naive: bool, threads: usize| SweepKernel { naive, threads, ..Default::default() };
    let variants = [
        ("naive_t1", "naive, 1 thread", kernel(true, 1)),
        ("cov_t1", "cov, 1 thread", kernel(false, 1)),
        ("naive_t4", "naive, 4 threads", kernel(true, 4)),
        ("cov_t4", "cov, 4 threads", kernel(false, 4)),
    ];
    let mut t = Table::new("", &["kernel", "per-sweep ms", "speedup vs naive_t1"]);
    let mut results: BTreeMap<String, Json> = BTreeMap::new();
    let mut naive_median = 0f64;
    for (key, label, kernel) in variants {
        let mut ne = NativeEngine::with_kernel(shard.clone(), n, kernel);
        let mut out = SweepResult::default();
        let s = bench(label, 2, 12, || {
            ne.sweep(&w, &z, &beta, lam, 1e-6, 0.0, &mut out).unwrap();
        });
        if key == "naive_t1" {
            naive_median = s.median;
        }
        let speedup = naive_median / s.median;
        t.add_row(vec![
            label.to_string(),
            format!("{:.3}", s.median * 1e3),
            format!("{speedup:.2}x"),
        ]);
        results.insert(format!("{key}_per_sweep_ns"), Json::Num(s.median * 1e9));
        if key != "naive_t1" {
            // gated by check_bench_regression.py: a kernel win must not
            // quietly erode across PRs
            results.insert(format!("{key}_speedup_x"), Json::Num(speedup));
        }
    }
    t.print();
    write_bench_section("kernels", results);
}

fn ablation_families() {
    section("families: per-sweep cost of the GLM working stats + elastic net");
    // the kernels-ablation shard geometry so the numbers are comparable;
    // labels remapped per family (poisson wants non-negative counts)
    let ds = synth::webspam_like(3_000, 4_000, 40, 7);
    let n = ds.n_examples();
    let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 4_000, 4, None);
    let shard = shard_in_memory(&ds.x, &part).remove(0);
    let lam = lambda_max(&ds) / 4.0;
    let margins = vec![0f32; n];
    let beta = vec![0f32; shard.csc.n_cols];

    let mut t = Table::new("", &["family", "alpha", "per-sweep ms (stats + sweep)"]);
    let mut results: BTreeMap<String, Json> = BTreeMap::new();
    for fam_kind in [FamilyKind::Logistic, FamilyKind::Gaussian, FamilyKind::Poisson] {
        let fam = fam_kind.family();
        let y: Vec<f32> = match fam_kind {
            FamilyKind::Poisson => ds.y.iter().map(|&v| (v + 1.0) / 2.0).collect(),
            _ => ds.y.clone(),
        };
        let (mut w, mut z) = (Vec::new(), Vec::new());
        for alpha in [1.0f64, 0.5] {
            let lam1 = (lam * alpha) as f32;
            let l2 = (lam * (1.0 - alpha)) as f32;
            let mut ne = NativeEngine::new(shard.clone(), n);
            let mut out = SweepResult::default();
            let s = bench(&format!("{} alpha={alpha}", fam_kind.name()), 2, 12, || {
                fam.working_stats_into(&margins, &y, &mut w, &mut z);
                ne.sweep(&w, &z, &beta, lam1, 1e-6, l2, &mut out).unwrap();
            });
            t.add_row(vec![
                fam_kind.name().to_string(),
                format!("{alpha}"),
                format!("{:.3}", s.median * 1e3),
            ]);
            let mut entry = BTreeMap::new();
            entry.insert("median_secs".to_string(), Json::Num(s.median));
            results.insert(
                format!("{}_a{:03}", fam_kind.name(), (alpha * 100.0) as u32),
                Json::Obj(entry),
            );
        }
    }
    t.print();
    write_bench_section("families", results);
}

fn main() {
    // cargo bench (harness = false) passes a `--bench` flag — ignore flags.
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a == name);
    if want("shotgun") {
        ablation_shotgun();
    }
    if want("blocks") {
        ablation_blocks();
    }
    if want("linesearch") {
        ablation_linesearch();
    }
    if want("comm") {
        ablation_comm();
    }
    if want("partition") {
        ablation_partition();
    }
    if want("kernels") {
        ablation_kernels();
    }
    if want("families") {
        ablation_families();
    }
}
