//! P7 — serving benchmarks: single-request latency (p50/p99), concurrent
//! throughput, streamed batch scoring, and hot-swap detection time against
//! a live `serve` subsystem on a loopback socket. Emits `BENCH_serve.json`
//! (same shape as `BENCH_iteration.json`); `check_bench_regression.py`
//! gates the `median_secs`/`p99_secs` entries in CI.
//!
//! Run: `cargo bench --bench bench_serve`
//!
//! The latency stats here are computed manually (not through
//! `bench_harness::bench`) because samples are collected across client
//! threads and we additionally need tail percentiles.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use dglmnet::bench_harness::{fmt_secs, section};
use dglmnet::config::ServeConfig;
use dglmnet::serve::Server;
use dglmnet::solver::SparseModel;
use dglmnet::util::json::Json;

/// Deterministic sparse model: `nnz` non-zeros strided over `p` features.
fn make_model(p: usize, nnz: usize, salt: u64) -> SparseModel {
    let mut beta = vec![0f32; p];
    let mut state = salt.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
    let stride = p / nnz;
    for k in 0..nnz {
        state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let w = ((state >> 40) as f32 / (1u64 << 24) as f32) - 0.5;
        beta[k * stride] = w;
    }
    SparseModel::from_dense(&beta, 0.5).with_meta(100_000, "bench")
}

/// A deterministic ~`k`-feature example body for `/predict`.
fn example_body(p: usize, k: usize, seed: usize) -> String {
    let stride = p / k;
    let idx: Vec<String> = (0..k).map(|t| (t * stride + seed % stride).to_string()).collect();
    let vals: Vec<String> =
        (0..k).map(|t| (if t % 2 == 0 { "1" } else { "2" }).to_string()).collect();
    format!("{{\"indices\":[{}],\"values\":[{}]}}", idx.join(","), vals.join(","))
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to serve");
        stream.set_nodelay(true).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn post(&mut self, path: &str, body: &str) -> (u16, Vec<u8>) {
        let req = format!(
            "POST {path} HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.stream.write_all(req.as_bytes()).unwrap();
        self.read_response()
    }

    fn get(&mut self, path: &str) -> (u16, Vec<u8>) {
        let req = format!("GET {path} HTTP/1.1\r\nHost: bench\r\n\r\n");
        self.stream.write_all(req.as_bytes()).unwrap();
        self.read_response()
    }

    fn read_response(&mut self) -> (u16, Vec<u8>) {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("bad status line {line:?}"));
        let mut content_length = 0usize;
        let mut chunked = false;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).unwrap();
            let h = h.trim().to_ascii_lowercase();
            if h.is_empty() {
                break;
            }
            if let Some(v) = h.strip_prefix("content-length:") {
                content_length = v.trim().parse().unwrap();
            }
            if h.starts_with("transfer-encoding:") && h.contains("chunked") {
                chunked = true;
            }
        }
        let mut body = Vec::new();
        if chunked {
            loop {
                let mut sz = String::new();
                self.reader.read_line(&mut sz).unwrap();
                let n = usize::from_str_radix(sz.trim(), 16).unwrap();
                let mut buf = vec![0u8; n + 2]; // chunk + trailing CRLF
                self.reader.read_exact(&mut buf).unwrap();
                if n == 0 {
                    break;
                }
                body.extend_from_slice(&buf[..n]);
            }
        } else {
            body.resize(content_length, 0);
            self.reader.read_exact(&mut body).unwrap();
        }
        (status, body)
    }
}

/// median / p99 / mean / min / max over raw latency samples.
fn latency_entry(mut samples: Vec<f64>) -> (Json, f64, f64) {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |q: f64| samples[((samples.len() - 1) as f64 * q).round() as usize];
    let median = pick(0.5);
    let p99 = pick(0.99);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let mut m = BTreeMap::new();
    m.insert("median_secs".to_string(), Json::Num(median));
    m.insert("p99_secs".to_string(), Json::Num(p99));
    m.insert("mean_secs".to_string(), Json::Num(mean));
    m.insert("min_secs".to_string(), Json::Num(samples[0]));
    m.insert("max_secs".to_string(), Json::Num(samples[samples.len() - 1]));
    m.insert("samples".to_string(), Json::Num(samples.len() as f64));
    (Json::Obj(m), median, p99)
}

fn main() {
    const P: usize = 200_000;
    let dir = std::env::temp_dir().join(format!("dglmnet_bench_serve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let artifact = dir.join("model.artifact");
    make_model(P, 5_000, 1).save(&artifact).unwrap();

    let cfg = ServeConfig {
        listen: "127.0.0.1:0".into(),
        threads: 4,
        max_batch: 1024,
        watch: true,
        poll_interval_secs: 0.05,
    };
    let handle = Server::start(&artifact, &cfg).expect("start serve");
    let addr = handle.addr;
    println!("serving {} (p = {P}) at {addr}", artifact.display());
    let mut report: BTreeMap<String, Json> = BTreeMap::new();

    section("single-request latency (keep-alive, ~50-feature examples)");
    {
        let mut c = Client::connect(addr);
        let bodies: Vec<String> = (0..64).map(|i| example_body(P, 50, i)).collect();
        for b in &bodies {
            let (status, _) = c.post("/predict", b);
            assert_eq!(status, 200);
        }
        let mut samples = Vec::with_capacity(2_000);
        for i in 0..2_000 {
            let b = &bodies[i % bodies.len()];
            let t0 = Instant::now();
            let (status, _) = c.post("/predict", b);
            samples.push(t0.elapsed().as_secs_f64());
            assert_eq!(status, 200);
        }
        let (entry, median, p99) = latency_entry(samples);
        println!("p50 {}  p99 {}", fmt_secs(median), fmt_secs(p99));
        report.insert("predict_single_latency".into(), entry);
    }

    section("concurrent throughput (4 client threads x 500 requests)");
    {
        let t0 = Instant::now();
        let threads: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr);
                    let bodies: Vec<String> =
                        (0..16).map(|i| example_body(P, 50, t * 100 + i)).collect();
                    let mut samples = Vec::with_capacity(500);
                    for i in 0..500 {
                        let b = &bodies[i % bodies.len()];
                        let s0 = Instant::now();
                        let (status, _) = c.post("/predict", b);
                        samples.push(s0.elapsed().as_secs_f64());
                        assert_eq!(status, 200);
                    }
                    samples
                })
            })
            .collect();
        let mut all = Vec::new();
        for t in threads {
            all.extend(t.join().expect("client thread"));
        }
        let wall = t0.elapsed().as_secs_f64();
        let rps = all.len() as f64 / wall;
        let (entry, median, p99) = latency_entry(all);
        println!("throughput {rps:.0} req/s  p50 {}  p99 {}", fmt_secs(median), fmt_secs(p99));
        report.insert("predict_concurrent_latency".into(), entry);
        let mut m = BTreeMap::new();
        m.insert("requests_per_sec".into(), Json::Num(rps));
        m.insert("wall_secs".into(), Json::Num(wall));
        report.insert("predict_throughput".into(), Json::Obj(m));
    }

    section("streamed batch scoring (512 examples per request)");
    {
        let examples: Vec<String> = (0..512).map(|i| example_body(P, 50, i)).collect();
        let body = format!("{{\"examples\":[{}]}}", examples.join(","));
        let mut c = Client::connect(addr);
        let (status, bytes) = c.post("/predict_batch", &body);
        assert_eq!(status, 200);
        assert_eq!(bytes.iter().filter(|&&b| b == b'\n').count(), 512);
        let mut samples = Vec::with_capacity(20);
        for _ in 0..20 {
            let t0 = Instant::now();
            let (status, _) = c.post("/predict_batch", &body);
            // per-example cost is the comparable number across runs
            samples.push(t0.elapsed().as_secs_f64() / 512.0);
            assert_eq!(status, 200);
        }
        let (entry, median, p99) = latency_entry(samples);
        println!("per-example p50 {}  p99 {}", fmt_secs(median), fmt_secs(p99));
        report.insert("predict_batch_per_example".into(), entry);
    }

    section("hot-swap detection (artifact rewrite -> new version served)");
    {
        let mut c = Client::connect(addr);
        let (_, body) = c.get("/healthz");
        let before = String::from_utf8(body).unwrap();
        make_model(P, 5_000, 2).save(&artifact).unwrap();
        let t0 = Instant::now();
        let detect_secs = loop {
            let (_, body) = c.get("/healthz");
            if String::from_utf8(body).unwrap() != before {
                break t0.elapsed().as_secs_f64();
            }
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "hot-swap was never detected"
            );
            std::thread::sleep(Duration::from_millis(5));
        };
        // informational (poll-cadence noise dominates): no median_secs key,
        // so the regression gate ignores it
        println!("detected in {}", fmt_secs(detect_secs));
        let mut m = BTreeMap::new();
        m.insert("detect_secs".into(), Json::Num(detect_secs));
        m.insert("poll_interval_secs".into(), Json::Num(cfg.poll_interval_secs));
        report.insert("hot_swap_detection".into(), Json::Obj(m));
    }

    handle.stop();
    std::fs::remove_dir_all(&dir).ok();

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("bench_serve".into()));
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("results".to_string(), Json::Obj(report));
    let path = "BENCH_serve.json";
    match std::fs::write(path, format!("{}\n", Json::Obj(top))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
