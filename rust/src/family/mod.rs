//! GLM families: the loss-specific seam of d-GLMNET.
//!
//! The paper's derivation (§2) touches the loss only through three scalar
//! functions of one example's label `y` and margin `m = βᵀx`:
//!
//! * the loss value ℓ(y, m) (objective, line search),
//! * its margin derivative ℓ′(y, m) (the directional-derivative term D and
//!   the gradient at β = 0 behind λ_max),
//! * the per-example **working statistics** (w, z) of the GLMNET quadratic
//!   approximation — `w = ℓ″(y, m)` (possibly clamped) and
//!   `z = −ℓ′(y, m) / w`, so the subproblem minimized by every engine sweep
//!   is `Σᵢ wᵢ (zᵢ − Δβᵀxᵢ)² / 2 + penalty` regardless of family.
//!
//! Everything else in the stack — partitioning, sweeps, Δ-exchange, line
//! search, checkpoints, failover — is family-agnostic, which is exactly the
//! observation the authors' follow-up (arXiv 1611.02101) builds on.
//! [`GlmFamily`] packages those three functions plus the λ_max gradient
//! scale, the inverse link (`mean`) used by predict/serve, and the family's
//! wire/artifact identity.
//!
//! ## The (w, z) contract
//!
//! `working_stats(y, m)` must return `w ≥ 0` finite and `z` finite for every
//! finite `(y, m)` — engines divide by `Σ w x² + ν` and multiply by `w·z`,
//! so infinities or NaNs here poison the whole sweep. Families enforce this
//! with explicit stability clamps:
//!
//! * **Logistic** (`y ∈ {−1, +1}`): `w = p(1−p)` underflows to 0 on
//!   saturated examples, so the division in `z = (ỹ − p)/w` guards with
//!   `w.max(W_EPS)` (`W_EPS = 1e-10`) — the seed's exact formula, kept
//!   bit-for-bit.
//! * **Gaussian**: `w ≡ 1`, `z = y − m` — no clamps needed; the quadratic
//!   model is exact and a batch fast path skips the per-example dispatch.
//! * **Poisson** (log link, `y ≥ 0`): `w = exp(m)` is clamped to
//!   `[POISSON_W_MIN, POISSON_W_MAX]` and the margin entering `exp` to
//!   `± POISSON_MARGIN_CLAMP`, the standard guard against early-iteration
//!   margin overshoot blowing up the working weights.
//!
//! The default family is [`Logistic`]; the logistic code paths throughout
//! the crate are pinned bit-identical to the pre-family hardcoded ones
//! (`tests/estimator_api.rs` seed-exactness pins).

use crate::error::{DlrError, Result};
use crate::util::math::{log1pexp, sigmoid, working_stats, W_EPS};

/// Poisson working-weight clamp floor/ceiling: `w = exp(m)` outside this
/// range makes the quadratic model useless (and its reciprocal in `z`
/// inf-prone), so it is clamped like glmnet's `fmin`/`fmax` guards.
pub const POISSON_W_MIN: f64 = 1e-6;
pub const POISSON_W_MAX: f64 = 1e6;
/// Margin magnitude cap inside Poisson `exp(m)` evaluations (exp(±30) spans
/// the clamped weight range with headroom; keeps loss/means finite).
pub const POISSON_MARGIN_CLAMP: f64 = 30.0;

/// Which GLM family a fit runs — the config/wire/artifact identity. The
/// trait object behind it comes from [`FamilyKind::family`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FamilyKind {
    /// L1/elastic-net logistic regression on `y ∈ {−1, +1}` — the paper's
    /// problem and the default (bit-identical to the pre-family code).
    #[default]
    Logistic,
    /// Least squares (identity link): `ℓ = (y − m)²/2`, `w ≡ 1`.
    Gaussian,
    /// Poisson regression with log link on counts `y ≥ 0`:
    /// `ℓ = exp(m) − y·m`.
    Poisson,
}

impl FamilyKind {
    /// Parse a config/CLI/wire family name. Accepts the canonical names
    /// plus common aliases; returns `None` for anything else.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "logistic" | "binomial" | "logit" => Some(Self::Logistic),
            "gaussian" | "linear" | "least-squares" | "squared" => Some(Self::Gaussian),
            "poisson" => Some(Self::Poisson),
            _ => None,
        }
    }

    /// Canonical name — what artifacts, checkpoints and the handshake carry.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Logistic => "logistic",
            Self::Gaussian => "gaussian",
            Self::Poisson => "poisson",
        }
    }

    /// The static family implementation behind this id.
    pub fn family(&self) -> &'static dyn GlmFamily {
        match self {
            Self::Logistic => &Logistic,
            Self::Gaussian => &Gaussian,
            Self::Poisson => &Poisson,
        }
    }

    /// Parse with an actionable error naming the offender and the options.
    pub fn parse_or_err(s: &str) -> Result<Self> {
        Self::parse(s).ok_or_else(|| {
            DlrError::Config(format!(
                "unknown GLM family '{s}' — expected one of logistic (default), \
                 gaussian, poisson"
            ))
        })
    }
}

/// A GLM loss family. See the module docs for the (w, z) contract; all
/// implementations are stateless unit structs, shared as `&'static dyn`.
pub trait GlmFamily: Sync {
    fn kind(&self) -> FamilyKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Per-example loss ℓ(y, m) (up to a y-only constant).
    fn loss(&self, y: f64, margin: f64) -> f64;

    /// ∂ℓ/∂m — the margin derivative driving the smooth part of D.
    fn dloss(&self, y: f64, margin: f64) -> f64;

    /// GLMNET working statistics (w, z) for one example.
    fn working_stats(&self, y: f64, margin: f64) -> (f64, f64);

    /// Mean prediction μ = g⁻¹(m): probability (logistic), identity
    /// (gaussian), exp (poisson). What predict/serve report.
    fn mean(&self, margin: f64) -> f64;

    /// Scale applied to `max_j |Σ_i x_ij t_i|` to get λ_max, where `t` is
    /// [`lambda_max_targets`](GlmFamily::lambda_max_targets): the gradient
    /// of the loss at β = 0 is `−scale⁻¹`-proportional to `Σ x t`.
    fn lambda_max_scale(&self) -> f64 {
        1.0
    }

    /// Per-example gradient-at-zero targets `t` for λ_max. For families
    /// whose target *is* the label vector (logistic, gaussian) this returns
    /// `y` itself — zero copies, keeping the default path's buffers and
    /// bits untouched; Poisson fills `scratch` with `y − 1`.
    fn lambda_max_targets<'a>(&self, y: &'a [f32], _scratch: &'a mut Vec<f32>) -> &'a [f32] {
        y
    }

    /// Validate the label vector at fit setup. The logistic default is
    /// deliberately permissive (the seed never validated), non-default
    /// families reject labels their loss cannot handle.
    fn validate_labels(&self, _y: &[f32]) -> Result<()> {
        Ok(())
    }

    /// Batch (w, z) into caller-reused buffers (cleared and refilled;
    /// capacities persist) plus the loss sum — the per-iteration stats
    /// computation on leader and workers.
    fn working_stats_into(
        &self,
        margins: &[f32],
        y: &[f32],
        w: &mut Vec<f32>,
        z: &mut Vec<f32>,
    ) -> f64 {
        debug_assert_eq!(margins.len(), y.len());
        w.clear();
        z.clear();
        w.reserve(margins.len());
        z.reserve(margins.len());
        let mut loss = 0f64;
        for (&m, &yy) in margins.iter().zip(y) {
            let (wi, zi) = self.working_stats(yy as f64, m as f64);
            w.push(wi as f32);
            z.push(zi as f32);
            loss += self.loss(yy as f64, m as f64);
        }
        loss
    }

    /// Loss sum over all examples at the given margins.
    fn loss_sum(&self, margins: &[f32], y: &[f32]) -> f64 {
        debug_assert_eq!(margins.len(), y.len());
        margins.iter().zip(y).map(|(&m, &yy)| self.loss(yy as f64, m as f64)).sum()
    }

    /// Loss sum at margins `m + α·Δm` (the line-search evaluations).
    fn line_loss_sum(&self, margins: &[f32], dmargins: &[f32], alpha: f64, y: &[f32]) -> f64 {
        margins
            .iter()
            .zip(dmargins)
            .zip(y)
            .map(|((&m, &dm), &yy)| self.loss(yy as f64, m as f64 + alpha * dm as f64))
            .sum()
    }

    /// ∇L(β)ᵀΔβ = Σ_i ℓ′(y_i, m_i)·Δm_i — the smooth part of D (Alg 3).
    fn grad_dot_delta(&self, margins: &[f32], dmargins: &[f32], y: &[f32]) -> f64 {
        debug_assert_eq!(margins.len(), dmargins.len());
        let mut acc = 0f64;
        for i in 0..margins.len() {
            acc += self.dloss(y[i] as f64, margins[i] as f64) * dmargins[i] as f64;
        }
        acc
    }

    /// Per-example (unit) deviance d(y, μ) — includes the conventional
    /// factor 2, so a total deviance is just Σᵢ d(yᵢ, μᵢ).
    fn unit_deviance(&self, y: f64, mu: f64) -> f64;

    /// Intercept-only model mean μ̄ (mean response for every family here).
    fn null_mean(&self, y: &[f32]) -> f64 {
        if y.is_empty() {
            return 0.0;
        }
        let s: f64 = y.iter().map(|&v| self.mean_response(v as f64)).sum();
        s / y.len() as f64
    }

    /// The response on the mean scale — identity except for logistic, where
    /// labels are ±1 but means are probabilities in [0, 1].
    fn mean_response(&self, y: f64) -> f64 {
        y
    }
}

/// The paper's family: `ℓ(y, m) = log(1 + exp(−y·m))`, `y ∈ {−1, +1}`.
pub struct Logistic;

impl GlmFamily for Logistic {
    fn kind(&self) -> FamilyKind {
        FamilyKind::Logistic
    }

    fn loss(&self, y: f64, margin: f64) -> f64 {
        log1pexp(-y * margin)
    }

    fn dloss(&self, y: f64, margin: f64) -> f64 {
        sigmoid(margin) - (y + 1.0) / 2.0
    }

    fn working_stats(&self, y: f64, margin: f64) -> (f64, f64) {
        // the seed's exact formula (w = p(1−p), z = (ỹ − p)/max(w, W_EPS))
        working_stats(y, margin)
    }

    fn mean(&self, margin: f64) -> f64 {
        sigmoid(margin)
    }

    fn lambda_max_scale(&self) -> f64 {
        // ∂ℓ/∂β_j at β = 0 is −Σ x_ij y_i / 2: scale the |Σ x y| max by ½.
        // (×0.5 ≡ the historical ÷2.0 bit-for-bit.)
        0.5
    }

    fn unit_deviance(&self, y: f64, mu: f64) -> f64 {
        let p = mu.clamp(1e-15, 1.0 - 1e-15);
        if y > 0.0 {
            -2.0 * p.ln()
        } else {
            -2.0 * (1.0 - p).ln()
        }
    }

    fn mean_response(&self, y: f64) -> f64 {
        (y + 1.0) / 2.0
    }
}

/// Least squares: `ℓ(y, m) = (y − m)²/2`, identity link, exact quadratic.
pub struct Gaussian;

impl GlmFamily for Gaussian {
    fn kind(&self) -> FamilyKind {
        FamilyKind::Gaussian
    }

    fn loss(&self, y: f64, margin: f64) -> f64 {
        let r = y - margin;
        0.5 * r * r
    }

    fn dloss(&self, y: f64, margin: f64) -> f64 {
        margin - y
    }

    fn working_stats(&self, y: f64, margin: f64) -> (f64, f64) {
        (1.0, y - margin)
    }

    fn working_stats_into(
        &self,
        margins: &[f32],
        y: &[f32],
        w: &mut Vec<f32>,
        z: &mut Vec<f32>,
    ) -> f64 {
        // w ≡ 1 fast path: skip the per-example (w, z) dispatch entirely
        debug_assert_eq!(margins.len(), y.len());
        w.clear();
        z.clear();
        w.resize(margins.len(), 1.0);
        z.reserve(margins.len());
        let mut loss = 0f64;
        for (&m, &yy) in margins.iter().zip(y) {
            let r = yy as f64 - m as f64;
            z.push(r as f32);
            loss += 0.5 * r * r;
        }
        loss
    }

    fn mean(&self, margin: f64) -> f64 {
        margin
    }

    fn validate_labels(&self, y: &[f32]) -> Result<()> {
        if let Some(i) = y.iter().position(|v| !v.is_finite()) {
            return Err(DlrError::Config(format!(
                "gaussian family needs finite labels, but y[{i}] = {}",
                y[i]
            )));
        }
        Ok(())
    }

    fn unit_deviance(&self, y: f64, mu: f64) -> f64 {
        let r = y - mu;
        r * r
    }
}

/// Poisson regression with log link on counts: `ℓ(y, m) = exp(m) − y·m`
/// (the log(y!) term is constant in β and dropped).
pub struct Poisson;

impl Poisson {
    #[inline]
    fn mu(margin: f64) -> f64 {
        margin.clamp(-POISSON_MARGIN_CLAMP, POISSON_MARGIN_CLAMP).exp()
    }
}

impl GlmFamily for Poisson {
    fn kind(&self) -> FamilyKind {
        FamilyKind::Poisson
    }

    fn loss(&self, y: f64, margin: f64) -> f64 {
        Self::mu(margin) - y * margin
    }

    fn dloss(&self, y: f64, margin: f64) -> f64 {
        Self::mu(margin) - y
    }

    fn working_stats(&self, y: f64, margin: f64) -> (f64, f64) {
        let mu = Self::mu(margin);
        let w = mu.clamp(POISSON_W_MIN, POISSON_W_MAX);
        let z = (y - mu) / w.max(W_EPS);
        (w, z)
    }

    fn mean(&self, margin: f64) -> f64 {
        Self::mu(margin)
    }

    fn lambda_max_targets<'a>(&self, y: &'a [f32], scratch: &'a mut Vec<f32>) -> &'a [f32] {
        // ∂ℓ/∂m at β = 0 is exp(0) − y = 1 − y, so the per-feature gradient
        // magnitude is |Σ x (y − 1)|.
        scratch.clear();
        scratch.extend(y.iter().map(|&v| v - 1.0));
        scratch
    }

    fn validate_labels(&self, y: &[f32]) -> Result<()> {
        if let Some(i) = y.iter().position(|v| !v.is_finite() || *v < 0.0) {
            return Err(DlrError::Config(format!(
                "poisson family needs non-negative count labels, but y[{i}] = {} — \
                 did you mean family = \"logistic\" (labels in {{-1, +1}})?",
                y[i]
            )));
        }
        Ok(())
    }

    fn unit_deviance(&self, y: f64, mu: f64) -> f64 {
        let mu = mu.max(1e-15);
        if y > 0.0 {
            2.0 * (y * (y / mu).ln() - (y - mu))
        } else {
            2.0 * mu
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_round_trips_names_and_aliases() {
        for k in [FamilyKind::Logistic, FamilyKind::Gaussian, FamilyKind::Poisson] {
            assert_eq!(FamilyKind::parse(k.name()), Some(k));
            assert_eq!(k.family().kind(), k);
        }
        assert_eq!(FamilyKind::parse("binomial"), Some(FamilyKind::Logistic));
        assert_eq!(FamilyKind::parse("linear"), Some(FamilyKind::Gaussian));
        assert_eq!(FamilyKind::parse("least-squares"), Some(FamilyKind::Gaussian));
        assert_eq!(FamilyKind::parse("gamma"), None);
        let err = FamilyKind::parse_or_err("tweedie").unwrap_err().to_string();
        assert!(err.contains("tweedie") && err.contains("poisson"), "{err}");
        assert_eq!(FamilyKind::default(), FamilyKind::Logistic);
    }

    #[test]
    fn logistic_matches_seed_formulas_bitwise() {
        let fam = FamilyKind::Logistic.family();
        for &(y, m) in &[(1.0, 0.0), (-1.0, 0.3), (1.0, -40.0), (-1.0, 100.0)] {
            let (w_old, z_old) = working_stats(y, m);
            let (w, z) = fam.working_stats(y, m);
            assert_eq!(w.to_bits(), w_old.to_bits());
            assert_eq!(z.to_bits(), z_old.to_bits());
            assert_eq!(fam.loss(y, m).to_bits(), log1pexp(-y * m).to_bits());
            let d_old = sigmoid(m) - (y + 1.0) / 2.0;
            assert_eq!(fam.dloss(y, m).to_bits(), d_old.to_bits());
        }
        // ×0.5 must equal the historical ÷2.0 exactly
        for &g in &[3.0f64, 1e-12, 7.25e8, f64::MIN_POSITIVE] {
            assert_eq!((g * fam.lambda_max_scale()).to_bits(), (g / 2.0).to_bits());
        }
    }

    #[test]
    fn batch_stats_match_per_example_dispatch() {
        let margins = [0.0f32, 0.5, -1.5, 3.0];
        for kind in [FamilyKind::Logistic, FamilyKind::Gaussian, FamilyKind::Poisson] {
            let fam = kind.family();
            let y: Vec<f32> = match kind {
                FamilyKind::Poisson => vec![0.0, 1.0, 3.0, 2.0],
                _ => vec![1.0, -1.0, 1.0, -1.0],
            };
            let (mut w, mut z) = (Vec::new(), Vec::new());
            let loss = fam.working_stats_into(&margins, &y, &mut w, &mut z);
            let mut want_loss = 0f64;
            for i in 0..4 {
                let (wi, zi) = fam.working_stats(y[i] as f64, margins[i] as f64);
                assert_eq!(w[i].to_bits(), (wi as f32).to_bits(), "{kind:?} w[{i}]");
                assert_eq!(z[i].to_bits(), (zi as f32).to_bits(), "{kind:?} z[{i}]");
                want_loss += fam.loss(y[i] as f64, margins[i] as f64);
            }
            assert!((loss - want_loss).abs() < 1e-12, "{kind:?}");
            assert!((fam.loss_sum(&margins, &y) - want_loss).abs() < 1e-12);
        }
    }

    #[test]
    fn gaussian_is_exact_quadratic() {
        let fam = FamilyKind::Gaussian.family();
        let (w, z) = fam.working_stats(3.0, 1.0);
        assert_eq!(w, 1.0);
        assert_eq!(z, 2.0);
        assert_eq!(fam.loss(3.0, 1.0), 2.0);
        assert_eq!(fam.dloss(3.0, 1.0), -2.0);
        assert_eq!(fam.mean(0.7), 0.7);
        assert_eq!(fam.unit_deviance(3.0, 1.0), 4.0);
        assert!(fam.validate_labels(&[1.0, -2.5]).is_ok());
        assert!(fam.validate_labels(&[1.0, f32::NAN]).is_err());
    }

    #[test]
    fn poisson_clamps_keep_stats_finite() {
        let fam = FamilyKind::Poisson.family();
        for &(y, m) in &[(0.0, -200.0), (5.0, 200.0), (3.0, 0.0), (0.0, 29.0)] {
            let (w, z) = fam.working_stats(y, m);
            assert!(w.is_finite() && (POISSON_W_MIN..=POISSON_W_MAX).contains(&w), "w = {w}");
            assert!(z.is_finite(), "z = {z}");
            assert!(fam.loss(y, m).is_finite());
            assert!(fam.dloss(y, m).is_finite());
        }
        // λ_max targets are y − 1 (gradient at β = 0)
        let mut scratch = Vec::new();
        let t = fam.lambda_max_targets(&[0.0, 1.0, 4.0], &mut scratch);
        assert_eq!(t, &[-1.0, 0.0, 3.0]);
        // counts only
        assert!(fam.validate_labels(&[0.0, 2.0, 7.0]).is_ok());
        let err = fam.validate_labels(&[1.0, -1.0]).unwrap_err().to_string();
        assert!(err.contains("non-negative"), "{err}");
    }

    #[test]
    fn default_lambda_max_targets_borrow_y_unchanged() {
        let y = [1.0f32, -1.0, 1.0];
        let mut scratch = Vec::new();
        for kind in [FamilyKind::Logistic, FamilyKind::Gaussian] {
            let t = kind.family().lambda_max_targets(&y, &mut scratch);
            assert_eq!(t.as_ptr(), y.as_ptr(), "{kind:?} must not copy");
        }
    }

    #[test]
    fn deviance_is_zero_at_perfect_fit_and_positive_off_it() {
        let log = FamilyKind::Logistic.family();
        assert!(log.unit_deviance(1.0, 1.0 - 1e-15) < 1e-9);
        assert!(log.unit_deviance(1.0, 0.5) > 0.0);
        let poi = FamilyKind::Poisson.family();
        assert!(poi.unit_deviance(3.0, 3.0).abs() < 1e-12);
        assert!(poi.unit_deviance(3.0, 1.0) > 0.0);
        assert!(poi.unit_deviance(0.0, 0.5) > 0.0);
        // null means live on the mean scale (probability for logistic)
        assert!((log.null_mean(&[1.0, 1.0, -1.0, -1.0]) - 0.5).abs() < 1e-12);
        assert!((poi.null_mean(&[0.0, 2.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn grad_dot_matches_hardcoded_logistic() {
        let margins = [0.1f32, -0.4, 0.0];
        let dm = [0.3f32, 0.2, -0.1];
        let y = [1.0f32, -1.0, 1.0];
        let fam = FamilyKind::Logistic.family();
        let mut want = 0f64;
        for i in 0..3 {
            let p = sigmoid(margins[i] as f64);
            want += (p - (y[i] as f64 + 1.0) / 2.0) * dm[i] as f64;
        }
        assert_eq!(fam.grad_dot_delta(&margins, &dm, &y).to_bits(), want.to_bits());
    }
}
