//! From-scratch CLI argument parser (no `clap` in the vendored set):
//! subcommands, `--key value` options, `--flag` switches, typed getters,
//! and generated `--help`.

use std::collections::BTreeMap;

use crate::error::{DlrError, Result};

/// Specification of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Specification of one subcommand.
#[derive(Debug, Clone, Default)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl CommandSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: vec![] }
    }
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec { name, help, default, is_flag: false });
        self
    }
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }
}

/// Parsed arguments of a subcommand.
#[derive(Debug, Clone, Default)]
pub struct ParsedArgs {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl ParsedArgs {
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.values
            .get(key)
            .map(|s| {
                s.parse::<f64>()
                    .map_err(|_| DlrError::Cli(format!("--{key}: expected number, got '{s}'")))
            })
            .transpose()
    }
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.values
            .get(key)
            .map(|s| {
                s.parse::<usize>()
                    .map_err(|_| DlrError::Cli(format!("--{key}: expected integer, got '{s}'")))
            })
            .transpose()
    }
    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.values
            .get(key)
            .map(|s| {
                s.parse::<u64>()
                    .map_err(|_| DlrError::Cli(format!("--{key}: expected integer, got '{s}'")))
            })
            .transpose()
    }
    pub fn get_flag(&self, key: &str) -> bool {
        self.flags.get(key).copied().unwrap_or(false)
    }
}

/// The application: a set of subcommands.
#[derive(Debug, Default)]
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, commands: vec![] }
    }

    pub fn command(mut self, spec: CommandSpec) -> Self {
        self.commands.push(spec);
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE: {} <command> [options]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<16} {}\n", c.name, c.about));
        }
        s.push_str("\nRun '<command> --help' for per-command options.\n");
        s
    }

    pub fn command_usage(&self, cmd: &CommandSpec) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.name, cmd.name, cmd.about);
        for o in &cmd.opts {
            let dflt = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let kind = if o.is_flag { "" } else { " <value>" };
            s.push_str(&format!("  --{:<18}{} {}{}\n", o.name, kind, o.help, dflt));
        }
        s
    }

    /// Parse `args` (without argv[0]). Returns Err with a usage string on
    /// unknown commands/options; `--help` yields `Ok` with command "help".
    pub fn parse(&self, args: &[String]) -> Result<ParsedArgs> {
        let Some(cmd_name) = args.first() else {
            return Err(DlrError::Cli(self.usage()));
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Ok(ParsedArgs { command: "help".into(), ..Default::default() });
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| {
                DlrError::Cli(format!("unknown command '{cmd_name}'\n\n{}", self.usage()))
            })?;
        let mut parsed = ParsedArgs { command: cmd.name.to_string(), ..Default::default() };
        for o in &cmd.opts {
            if let Some(d) = o.default {
                parsed.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(DlrError::Cli(self.command_usage(cmd)));
            }
            if let Some(name) = a.strip_prefix("--") {
                let spec = cmd.opts.iter().find(|o| o.name == name).ok_or_else(|| {
                    DlrError::Cli(format!(
                        "unknown option '--{name}' for '{}'\n\n{}",
                        cmd.name,
                        self.command_usage(cmd)
                    ))
                })?;
                if spec.is_flag {
                    parsed.flags.insert(name.to_string(), true);
                } else {
                    let v = args.get(i + 1).ok_or_else(|| {
                        DlrError::Cli(format!("option '--{name}' needs a value"))
                    })?;
                    parsed.values.insert(name.to_string(), v.clone());
                    i += 1;
                }
            } else {
                parsed.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(parsed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("dglmnet", "test app").command(
            CommandSpec::new("train", "train a model")
                .opt("lambda", "L1 strength", Some("1.0"))
                .opt("machines", "cluster size", Some("4"))
                .flag("verbose", "chatty"),
        )
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let p = app()
            .parse(&sv(&["train", "--lambda", "0.5", "--verbose", "file.svm"]))
            .unwrap();
        assert_eq!(p.command, "train");
        assert_eq!(p.get_f64("lambda").unwrap(), Some(0.5));
        assert_eq!(p.get_usize("machines").unwrap(), Some(4)); // default
        assert!(p.get_flag("verbose"));
        assert_eq!(p.positionals, vec!["file.svm"]);
    }

    #[test]
    fn unknown_command_and_option_error() {
        assert!(app().parse(&sv(&["nope"])).is_err());
        assert!(app().parse(&sv(&["train", "--bogus", "1"])).is_err());
        assert!(app().parse(&sv(&["train", "--lambda"])).is_err());
    }

    #[test]
    fn typed_getter_errors() {
        let p = app().parse(&sv(&["train", "--lambda", "abc"])).unwrap();
        assert!(p.get_f64("lambda").is_err());
    }

    #[test]
    fn help_paths() {
        let p = app().parse(&sv(&["--help"])).unwrap();
        assert_eq!(p.command, "help");
        let e = app().parse(&sv(&["train", "--help"])).unwrap_err();
        assert!(e.to_string().contains("--lambda"));
        let u = app().usage();
        assert!(u.contains("train"));
    }
}
