//! Distributed online learning via truncated gradient — the paper's §4.3
//! comparison system: Agarwal et al. (2011) Algorithm 2, *first part only*
//! (the L-BFGS second part is inapplicable under L1, as the paper notes).
//!
//! Examples are split across M shards; each shard trains an independent
//! truncated-gradient learner for one pass; shard weights are averaged
//! (weighted by shard size) and re-broadcast as the warmstart for the next
//! pass. Communication is one p-vector allreduce per pass — charged to the
//! simulated network through the scratch-holding
//! [`TreeAllReduce::sum_dense_into`] path (no sparse conversion, reusable
//! buffers) so Table 3's per-iteration comparison is honest.
//!
//! [`DistributedOnlineEstimator`] adapts the learner to the crate-wide
//! [`Estimator`] interface: one fit = `passes` averaged passes, one
//! [`FitObserver`] callback per pass (the §4.3 protocol's save-β-per-pass).

use crate::baselines::truncated_gradient::TruncatedGradientLearner;
use crate::cluster::allreduce::{AllReduceScratch, TreeAllReduce};
use crate::cluster::network::{NetworkLedger, NetworkModel};
use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::solver::dglmnet::{FitResult, IterationRecord};
use crate::solver::estimator::{Estimator, FitControl, FitObserver, FitStep};
use crate::solver::model::SparseModel;
use crate::util::math::{l1_norm, logloss_sum};
use crate::util::rng::Xoshiro256;
use crate::util::timer::PhaseTimer;

/// Per-pass snapshot (the paper evaluates every pass's averaged model).
#[derive(Debug, Clone)]
pub struct PassSnapshot {
    pub pass: usize,
    pub weights: Vec<f32>,
    pub wall_secs: f64,
    pub sim_comm_secs: f64,
    /// bytes this pass's weight allreduce moved.
    pub comm_bytes: u64,
}

/// Driver for the sharded + averaged training.
pub struct DistributedOnlineLearner {
    pub machines: usize,
    pub learning_rate: f64,
    pub decay: f64,
    pub l1: f64,
    pub seed: u64,
    pub network: NetworkModel,
}

impl DistributedOnlineLearner {
    pub fn new(machines: usize, learning_rate: f64, decay: f64, l1: f64, seed: u64) -> Self {
        Self {
            machines,
            learning_rate,
            decay,
            l1,
            seed,
            network: NetworkModel::gigabit(),
        }
    }

    /// Split example indices across shards (round-robin after shuffle).
    fn shard_indices(&self, n: usize) -> Vec<Vec<usize>> {
        let mut idx: Vec<usize> = (0..n).collect();
        Xoshiro256::new(self.seed ^ 0xA5A5).shuffle(&mut idx);
        let mut shards = vec![Vec::with_capacity(n / self.machines + 1); self.machines];
        for (i, &e) in idx.iter().enumerate() {
            shards[i % self.machines].push(e);
        }
        shards
    }

    /// Train for `passes` passes, returning a snapshot of the averaged
    /// weights after every pass (the §4.3 protocol saves β per pass).
    pub fn train(&self, ds: &Dataset, passes: usize) -> Vec<PassSnapshot> {
        self.run_passes(ds, passes, |_| FitControl::Continue)
    }

    /// [`DistributedOnlineLearner::train`] with a per-pass callback that
    /// can stop early — the hook the [`Estimator`] adapter builds on. The
    /// per-pass weight averaging runs through one reusable
    /// [`AllReduceScratch`] + staging buffers, so steady-state passes only
    /// allocate the snapshot itself.
    pub fn run_passes(
        &self,
        ds: &Dataset,
        passes: usize,
        mut on_pass: impl FnMut(&PassSnapshot) -> FitControl,
    ) -> Vec<PassSnapshot> {
        let p = ds.n_features();
        let shards = self.shard_indices(ds.n_examples());
        let total: f64 = shards.iter().map(|s| s.len() as f64).sum();
        let allreduce = TreeAllReduce::new(self.network);
        let ledger = NetworkLedger::new();
        let mut ar_scratch = AllReduceScratch::default();
        let mut weighted: Vec<Vec<f32>> = vec![Vec::new(); self.machines];
        let mut avg: Vec<f32> = Vec::new();

        let mut learners: Vec<TruncatedGradientLearner> = (0..self.machines)
            .map(|_| TruncatedGradientLearner::new(p, self.learning_rate, self.decay, self.l1))
            .collect();
        let mut snapshots = Vec::with_capacity(passes);

        for pass in 0..passes {
            let t0 = std::time::Instant::now();
            // shard-parallel pass (threads: learners are plain data)
            let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
                let handles: Vec<_> = learners
                    .iter_mut()
                    .zip(&shards)
                    .enumerate()
                    .map(|(k, (learner, shard))| {
                        let seed = self.seed.wrapping_add((pass * 1000 + k) as u64);
                        scope.spawn(move || {
                            let mut order = shard.clone();
                            Xoshiro256::new(seed).shuffle(&mut order);
                            learner.run_pass(ds, &order);
                            learner.settled_weights()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            // weighted average (shard sizes are near-equal but be exact)
            // into the reusable staging buffers — no per-pass Vec-of-Vecs
            for ((dst, w), s) in weighted.iter_mut().zip(&results).zip(&shards) {
                let scale = s.len() as f64 / total;
                dst.clear();
                dst.extend(w.iter().map(|&x| (x as f64 * scale) as f32));
            }
            let sim_before = ledger.simulated_secs();
            let outcome =
                allreduce.sum_dense_into(&weighted, &ledger, &mut ar_scratch, &mut avg);
            let sim_comm = ledger.simulated_secs() - sim_before;
            // rebroadcast as warmstart
            for learner in &mut learners {
                learner.set_weights(&avg);
            }
            let snap = PassSnapshot {
                pass: pass + 1,
                weights: avg.clone(),
                wall_secs: t0.elapsed().as_secs_f64(),
                sim_comm_secs: sim_comm,
                comm_bytes: outcome.bytes_moved,
            };
            let control = on_pass(&snap);
            snapshots.push(snap);
            if control == FitControl::Stop {
                break;
            }
        }
        snapshots
    }
}

/// [`Estimator`] adapter: sharded truncated-gradient training with weighted
/// per-pass averaging, one observer callback per pass. `lambda` is on the
/// objective scale — it becomes VW's per-example `--l1` (λ/n, paper
/// footnote 4) at fit time. Fits are cold-start: online passes begin at
/// β = 0 regardless of warmstart state (the averaging protocol has no
/// warmstart notion), so `reset` only clears the stored model.
///
/// Each pass's [`IterationRecord::objective`] costs one extra O(nnz) scan
/// of the train set on top of the pass itself — the price of a trace that
/// early-stop observers can act on uniformly across solvers.
pub struct DistributedOnlineEstimator {
    pub machines: usize,
    pub learning_rate: f64,
    pub decay: f64,
    pub lambda: f64,
    pub passes: usize,
    pub seed: u64,
    pub network: NetworkModel,
    weights: Vec<f32>,
}

impl DistributedOnlineEstimator {
    pub fn new(
        machines: usize,
        learning_rate: f64,
        decay: f64,
        lambda: f64,
        passes: usize,
        seed: u64,
    ) -> Self {
        Self {
            machines,
            learning_rate,
            decay,
            lambda,
            passes,
            seed,
            network: NetworkModel::gigabit(),
            weights: Vec::new(),
        }
    }
}

impl Estimator for DistributedOnlineEstimator {
    fn name(&self) -> &'static str {
        "distributed-online"
    }

    fn fit(&mut self, ds: &Dataset, observer: &mut dyn FitObserver) -> Result<FitResult> {
        let n = ds.n_examples() as f64;
        let lambda = self.lambda;
        let learner = DistributedOnlineLearner {
            machines: self.machines,
            learning_rate: self.learning_rate,
            decay: self.decay,
            l1: lambda / n.max(1.0),
            seed: self.seed,
            network: self.network,
        };
        let mut trace: Vec<IterationRecord> = Vec::new();
        let mut stopped = false;
        let total_passes = self.passes;
        let snapshots = learner.run_passes(ds, total_passes, |snap| {
            let margins = ds.x.margins(&snap.weights);
            let objective = logloss_sum(&margins, &ds.y) + lambda * l1_norm(&snap.weights);
            let record = IterationRecord {
                iter: snap.pass,
                objective,
                alpha: 1.0,
                fast_path: false,
                max_worker_secs: snap.wall_secs,
                sim_comm_secs: snap.sim_comm_secs,
                comm_bytes: snap.comm_bytes,
                exchange: None,
                wall_secs: snap.wall_secs,
            };
            trace.push(record.clone());
            let model_fn = || SparseModel::from_dense(&snap.weights, lambda);
            let control = observer.on_iteration(&FitStep::new(&record, &model_fn));
            if control == FitControl::Stop && snap.pass < total_passes {
                // a Stop on the final scheduled pass changes nothing: the
                // fit completed its budget (the FitDriver contract)
                stopped = true;
            }
            control
        });
        self.weights = snapshots
            .last()
            .map(|s| s.weights.clone())
            .unwrap_or_default();
        Ok(FitResult {
            lambda,
            objective: trace.last().map_or(f64::INFINITY, |r| r.objective),
            iterations: trace.len(),
            // "converged" for an online baseline = it completed its pass
            // budget without an observer stop
            converged: !stopped && !trace.is_empty(),
            model: SparseModel::from_dense(&self.weights, lambda),
            sim_compute_secs: trace.iter().map(|r| r.max_worker_secs).sum(),
            sim_comm_secs: trace.iter().map(|r| r.sim_comm_secs).sum(),
            comm_bytes: trace.iter().map(|r| r.comm_bytes).sum(),
            trace,
            timers: PhaseTimer::new(),
        })
    }

    fn model(&self) -> SparseModel {
        SparseModel::from_dense(&self.weights, self.lambda)
    }

    fn reset(&mut self) {
        self.weights.clear();
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn set_lambda(&mut self, lambda: f64) {
        self.lambda = lambda;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics;

    #[test]
    fn averaging_learns_and_improves_over_passes() {
        let split = synth::epsilon_like(2_000, 40, 61).split(0.8, 2).unwrap();
        let d = DistributedOnlineLearner::new(4, 0.3, 0.8, 1e-7, 3);
        let snaps = d.train(&split.train, 4);
        assert_eq!(snaps.len(), 4);
        let auc_at = |w: &[f32]| {
            let m = split.test.x.margins(w);
            metrics::roc_auc(&m, &split.test.y)
        };
        let first = auc_at(&snaps[0].weights);
        let last = auc_at(&snaps.last().unwrap().weights);
        assert!(last > 0.75, "last auc = {last}");
        assert!(last >= first - 0.05, "first {first} last {last}");
    }

    #[test]
    fn shards_cover_all_examples() {
        let d = DistributedOnlineLearner::new(3, 0.1, 0.5, 0.0, 1);
        let shards = d.shard_indices(100);
        let mut all: Vec<usize> = shards.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn single_machine_matches_plain_online_shape() {
        // M = 1 distributed == plain single-machine training modulo shuffle
        let ds = synth::dna_like(400, 30, 5, 62);
        let d = DistributedOnlineLearner::new(1, 0.2, 0.6, 1e-6, 4);
        let snaps = d.train(&ds, 3);
        let margins = ds.x.margins(&snaps.last().unwrap().weights);
        assert!(metrics::roc_auc(&margins, &ds.y) > 0.7);
    }

    #[test]
    fn comm_cost_recorded() {
        let ds = synth::dna_like(200, 20, 4, 63);
        let d = DistributedOnlineLearner::new(4, 0.1, 0.5, 0.0, 5);
        let snaps = d.train(&ds, 2);
        assert!(snaps.iter().all(|s| s.sim_comm_secs > 0.0));
        assert!(snaps.iter().all(|s| s.comm_bytes > 0));
    }

    #[test]
    fn estimator_adapter_matches_raw_learner() {
        // the trait path must produce the same weights as train()
        let ds = synth::dna_like(300, 25, 4, 64);
        let passes = 3;
        let lambda = 0.03;
        // same λ/n computation as the estimator performs, so l1 bit-matches
        let l1 = lambda / ds.n_examples() as f64;
        let raw = DistributedOnlineLearner::new(2, 0.2, 0.7, l1, 9).train(&ds, passes);
        let mut est = DistributedOnlineEstimator::new(2, 0.2, 0.7, lambda, passes, 9);
        let fit = est
            .fit(&ds, &mut crate::solver::estimator::NoopObserver)
            .unwrap();
        assert_eq!(fit.iterations, passes);
        assert!(fit.converged);
        assert_eq!(raw.last().unwrap().weights, est.model().to_dense());
        assert_eq!(fit.comm_bytes, raw.iter().map(|s| s.comm_bytes).sum::<u64>());
    }

    #[test]
    fn observer_stop_ends_after_that_pass() {
        struct StopAfter(usize);
        impl FitObserver for StopAfter {
            fn on_iteration(&mut self, step: &FitStep<'_>) -> FitControl {
                if step.record.iter >= self.0 {
                    FitControl::Stop
                } else {
                    FitControl::Continue
                }
            }
        }
        let ds = synth::dna_like(200, 20, 4, 65);
        let mut est = DistributedOnlineEstimator::new(2, 0.2, 0.7, 0.5, 10, 3);
        let fit = est.fit(&ds, &mut StopAfter(2)).unwrap();
        assert_eq!(fit.iterations, 2);
        assert!(!fit.converged);
    }
}
