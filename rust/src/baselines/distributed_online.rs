//! Distributed online learning via truncated gradient — the paper's §4.3
//! comparison system: Agarwal et al. (2011) Algorithm 2, *first part only*
//! (the L-BFGS second part is inapplicable under L1, as the paper notes).
//!
//! Examples are split across M shards; each shard trains an independent
//! truncated-gradient learner for one pass; shard weights are averaged
//! (weighted by shard size) and re-broadcast as the warmstart for the next
//! pass. Communication is one p-vector allreduce per pass — also charged to
//! the simulated network so Table 3's per-iteration comparison is honest.

use crate::baselines::truncated_gradient::TruncatedGradientLearner;
use crate::cluster::allreduce::TreeAllReduce;
use crate::cluster::network::{NetworkLedger, NetworkModel};
use crate::data::dataset::Dataset;
use crate::util::rng::Xoshiro256;

/// Per-pass snapshot (the paper evaluates every pass's averaged model).
#[derive(Debug, Clone)]
pub struct PassSnapshot {
    pub pass: usize,
    pub weights: Vec<f32>,
    pub wall_secs: f64,
    pub sim_comm_secs: f64,
}

/// Driver for the sharded + averaged training.
pub struct DistributedOnlineLearner {
    pub machines: usize,
    pub learning_rate: f64,
    pub decay: f64,
    pub l1: f64,
    pub seed: u64,
    pub network: NetworkModel,
}

impl DistributedOnlineLearner {
    pub fn new(machines: usize, learning_rate: f64, decay: f64, l1: f64, seed: u64) -> Self {
        Self {
            machines,
            learning_rate,
            decay,
            l1,
            seed,
            network: NetworkModel::gigabit(),
        }
    }

    /// Split example indices across shards (round-robin after shuffle).
    fn shard_indices(&self, n: usize) -> Vec<Vec<usize>> {
        let mut idx: Vec<usize> = (0..n).collect();
        Xoshiro256::new(self.seed ^ 0xA5A5).shuffle(&mut idx);
        let mut shards = vec![Vec::with_capacity(n / self.machines + 1); self.machines];
        for (i, &e) in idx.iter().enumerate() {
            shards[i % self.machines].push(e);
        }
        shards
    }

    /// Train for `passes` passes, returning a snapshot of the averaged
    /// weights after every pass (the §4.3 protocol saves β per pass).
    pub fn train(&self, ds: &Dataset, passes: usize) -> Vec<PassSnapshot> {
        let p = ds.n_features();
        let shards = self.shard_indices(ds.n_examples());
        let total: f64 = shards.iter().map(|s| s.len() as f64).sum();
        let allreduce = TreeAllReduce::new(self.network);
        let ledger = NetworkLedger::new();

        let mut learners: Vec<TruncatedGradientLearner> = (0..self.machines)
            .map(|_| TruncatedGradientLearner::new(p, self.learning_rate, self.decay, self.l1))
            .collect();
        let mut snapshots = Vec::with_capacity(passes);

        for pass in 0..passes {
            let t0 = std::time::Instant::now();
            // shard-parallel pass (threads: learners are plain data)
            let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
                let handles: Vec<_> = learners
                    .iter_mut()
                    .zip(&shards)
                    .enumerate()
                    .map(|(k, (learner, shard))| {
                        let seed = self.seed.wrapping_add((pass * 1000 + k) as u64);
                        scope.spawn(move || {
                            let mut order = shard.clone();
                            Xoshiro256::new(seed).shuffle(&mut order);
                            learner.run_pass(ds, &order);
                            learner.settled_weights()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            // weighted average (shard sizes are near-equal but be exact)
            let sim_before = ledger.simulated_secs();
            let weighted: Vec<Vec<f32>> = results
                .iter()
                .zip(&shards)
                .map(|(w, s)| {
                    let scale = s.len() as f64 / total;
                    w.iter().map(|&x| (x as f64 * scale) as f32).collect()
                })
                .collect();
            let (avg, _) = allreduce.sum(&weighted, &ledger);
            let sim_comm = ledger.simulated_secs() - sim_before;
            // rebroadcast as warmstart
            for learner in &mut learners {
                learner.set_weights(&avg);
            }
            snapshots.push(PassSnapshot {
                pass: pass + 1,
                weights: avg,
                wall_secs: t0.elapsed().as_secs_f64(),
                sim_comm_secs: sim_comm,
            });
        }
        snapshots
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics;

    #[test]
    fn averaging_learns_and_improves_over_passes() {
        let split = synth::epsilon_like(2_000, 40, 61).split(0.8, 2);
        let d = DistributedOnlineLearner::new(4, 0.3, 0.8, 1e-7, 3);
        let snaps = d.train(&split.train, 4);
        assert_eq!(snaps.len(), 4);
        let auc_at = |w: &[f32]| {
            let m = split.test.x.margins(w);
            metrics::roc_auc(&m, &split.test.y)
        };
        let first = auc_at(&snaps[0].weights);
        let last = auc_at(&snaps.last().unwrap().weights);
        assert!(last > 0.75, "last auc = {last}");
        assert!(last >= first - 0.05, "first {first} last {last}");
    }

    #[test]
    fn shards_cover_all_examples() {
        let d = DistributedOnlineLearner::new(3, 0.1, 0.5, 0.0, 1);
        let shards = d.shard_indices(100);
        let mut all: Vec<usize> = shards.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn single_machine_matches_plain_online_shape() {
        // M = 1 distributed == plain single-machine training modulo shuffle
        let ds = synth::dna_like(400, 30, 5, 62);
        let d = DistributedOnlineLearner::new(1, 0.2, 0.6, 1e-6, 4);
        let snaps = d.train(&ds, 3);
        let margins = ds.x.margins(&snaps.last().unwrap().weights);
        assert!(metrics::roc_auc(&margins, &ds.y) > 0.7);
    }

    #[test]
    fn comm_cost_recorded() {
        let ds = synth::dna_like(200, 20, 4, 63);
        let d = DistributedOnlineLearner::new(4, 0.1, 0.5, 0.0, 5);
        let snaps = d.train(&ds, 2);
        assert!(snaps.iter().all(|s| s.sim_comm_secs > 0.0));
    }
}
