//! Baselines the paper compares against (or motivates with):
//!
//! * [`truncated_gradient`] — sparse online learning via truncated gradient
//!   (Langford, Li & Zhang 2009), the algorithm behind Vowpal Wabbit's
//!   `--l1`.
//! * [`distributed_online`] — the distributed variant of §4.3: per-shard
//!   online training + weighted parameter averaging (Agarwal et al. 2011,
//!   Algorithm 2 first part), with the paper's learning-rate/decay grid.
//! * [`shotgun`] — parallel *stochastic* coordinate descent (Bradley et al.
//!   2011), used by the A1 ablation to demonstrate the update-conflict
//!   problem that motivates d-GLMNET's line-search design.

//! Every baseline also implements the crate-wide
//! [`Estimator`](crate::solver::Estimator) trait
//! ([`ShotgunEstimator`], [`TruncatedGradientEstimator`],
//! [`DistributedOnlineEstimator`]), so the regularization path, the grid,
//! the bench harness and the CLI can run them head-to-head against
//! d-GLMNET through `&mut dyn Estimator`.

pub mod distributed_online;
pub mod grid;
pub mod shotgun;
pub mod truncated_gradient;

pub use distributed_online::{DistributedOnlineEstimator, DistributedOnlineLearner};
pub use grid::{fit_scored, online_grid_search, GridPoint, PassEval};
pub use shotgun::ShotgunEstimator;
pub use truncated_gradient::{TruncatedGradientEstimator, TruncatedGradientLearner};
