//! Baselines the paper compares against (or motivates with):
//!
//! * [`truncated_gradient`] — sparse online learning via truncated gradient
//!   (Langford, Li & Zhang 2009), the algorithm behind Vowpal Wabbit's
//!   `--l1`.
//! * [`distributed_online`] — the distributed variant of §4.3: per-shard
//!   online training + weighted parameter averaging (Agarwal et al. 2011,
//!   Algorithm 2 first part), with the paper's learning-rate/decay grid.
//! * [`shotgun`] — parallel *stochastic* coordinate descent (Bradley et al.
//!   2011), used by the A1 ablation to demonstrate the update-conflict
//!   problem that motivates d-GLMNET's line-search design.

pub mod distributed_online;
pub mod grid;
pub mod shotgun;
pub mod truncated_gradient;

pub use distributed_online::DistributedOnlineLearner;
pub use grid::{online_grid_search, GridPoint};
pub use truncated_gradient::TruncatedGradientLearner;
