//! Shotgun — parallel *stochastic* coordinate descent (Bradley et al. 2011).
//!
//! Each round draws P coordinates uniformly at random and updates them in
//! parallel **from the same β** (no line search, no conflict resolution).
//! With correlated features, large P causes update conflicts and can
//! diverge — the exact phenomenon (§1) that motivates d-GLMNET's combine-
//! then-line-search design. Used by ablation A1, and exposed as a
//! head-to-head competitor through [`ShotgunEstimator`] (which, being the
//! one stochastic [`Estimator`] in the crate, also demonstrates the RNG
//! half of the [`Checkpoint`] contract: its checkpoints carry the
//! xoshiro256++ state, so a resumed run draws the same coordinate sequence
//! the uninterrupted run would have).

use crate::data::dataset::Dataset;
use crate::data::sparse::CscMatrix;
use crate::error::{DlrError, Result};
use crate::solver::dglmnet::{FitResult, IterationRecord};
use crate::solver::driver::Checkpoint;
use crate::solver::estimator::{Estimator, FitControl, FitObserver, FitStep};
use crate::solver::model::SparseModel;
use crate::util::math::{soft_threshold, working_stats};
use crate::util::rng::Xoshiro256;
use crate::util::timer::{PhaseTimer, Stopwatch};

/// Outcome of a shotgun run.
#[derive(Debug, Clone)]
pub struct ShotgunResult {
    pub beta: Vec<f32>,
    pub objective_trace: Vec<f64>,
    pub diverged: bool,
}

/// Full objective f(β) = L(margins) + λ‖β‖₁ at the current state.
fn shotgun_objective(margins: &[f32], y: &[f32], beta: &[f32], lambda: f64) -> f64 {
    crate::util::math::logloss_sum(margins, y) + lambda * crate::util::math::l1_norm(beta)
}

/// One shotgun round: draw `par` coordinates, compute their Newton updates
/// from the *shared* β, apply them all simultaneously (the conflicting
/// part). Returns the objective after the round.
fn shotgun_round(
    ds: &Dataset,
    csc: &CscMatrix,
    lambda: f64,
    par: usize,
    rng: &mut Xoshiro256,
    beta: &mut [f32],
    margins: &mut [f32],
) -> f64 {
    let p = beta.len();
    // P coordinates drawn without replacement, updated from the SAME β
    let coords = rng.sample_indices(p, par.min(p));
    // second-order info at the shared point
    let (w, z): (Vec<f64>, Vec<f64>) = margins
        .iter()
        .zip(&ds.y)
        .map(|(&m, &y)| working_stats(y as f64, m as f64))
        .unzip();
    let mut updates = Vec::with_capacity(coords.len());
    for &j in &coords {
        let (rows, vals) = csc.col(j);
        let mut a = 1e-6;
        let mut c = 0f64;
        for (&i, &v) in rows.iter().zip(vals) {
            let i = i as usize;
            let x = v as f64;
            a += w[i] * x * x;
            // residual at the shared β: r_i = z_i (delta = 0 locally)
            c += w[i] * z[i] * x;
        }
        c += beta[j] as f64 * a;
        let s = soft_threshold(c, lambda) / a;
        updates.push((j, (s - beta[j] as f64) as f32));
    }
    // apply all updates simultaneously (the conflicting part)
    for &(j, d) in &updates {
        if d != 0.0 {
            beta[j] += d;
            let (rows, vals) = csc.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                margins[i as usize] += d * v;
            }
        }
    }
    shotgun_objective(margins, &ds.y, beta, lambda)
}

/// Run shotgun with parallelism `par` for `rounds` rounds.
pub fn shotgun(
    ds: &Dataset,
    csc: &CscMatrix,
    lambda: f64,
    par: usize,
    rounds: usize,
    seed: u64,
) -> ShotgunResult {
    let n = ds.n_examples();
    let p = ds.n_features();
    let mut beta = vec![0f32; p];
    let mut margins = vec![0f32; n];
    let mut rng = Xoshiro256::new(seed);
    let mut trace = Vec::with_capacity(rounds);
    let f0 = shotgun_objective(&margins, &ds.y, &beta, lambda);
    trace.push(f0);
    let mut diverged = false;

    for _round in 0..rounds {
        let f = shotgun_round(ds, csc, lambda, par, &mut rng, &mut beta, &mut margins);
        trace.push(f);
        if !f.is_finite() || f > 10.0 * f0 {
            diverged = true;
            break;
        }
    }
    ShotgunResult { beta, objective_trace: trace, diverged }
}

/// [`Estimator`] adapter for shotgun: one fit = up to `rounds` rounds from
/// the current state (warmstart; [`Estimator::reset`] re-seeds the RNG and
/// zeroes β), one observer callback per round. Warmstarted fits must pass
/// the same dataset the current state was trained on — the same contract as
/// `DGlmnetSolver`'s trait fit; call `reset` before switching datasets.
/// Divergence (non-finite objective, or growth past 10× the fit's starting
/// objective — the same guard as [`shotgun`]) ends the fit with
/// `converged = false`.
///
/// [`ShotgunEstimator::checkpoint`] / [`ShotgunEstimator::resume`]
/// round-trip (β, margins, round counter, RNG state) through the same
/// [`Checkpoint`] JSON the d-GLMNET driver uses — resuming reproduces the
/// uninterrupted coordinate sequence exactly.
pub struct ShotgunEstimator {
    pub lambda: f64,
    pub parallelism: usize,
    /// Rounds per `fit` call.
    pub rounds: usize,
    pub seed: u64,
    beta: Vec<f32>,
    margins: Vec<f32>,
    rng: Xoshiro256,
    completed_rounds: usize,
    last_objective: Option<f64>,
    /// Cached by-feature transpose of the fitted dataset (rebuilt after
    /// `reset` or when the dataset's nnz changes, shared across the
    /// warmstarted fits of a λ ladder). Warmstarted `fit` calls must reuse
    /// the dataset the state was trained on — see [`Estimator::fit`] docs.
    csc: Option<CscMatrix>,
    csc_nnz: usize,
}

impl ShotgunEstimator {
    pub fn new(lambda: f64, parallelism: usize, rounds: usize, seed: u64) -> Self {
        Self {
            lambda,
            parallelism,
            rounds,
            seed,
            beta: Vec::new(),
            margins: Vec::new(),
            rng: Xoshiro256::new(seed),
            completed_rounds: 0,
            last_objective: None,
            csc: None,
            csc_nnz: 0,
        }
    }

    /// Resumable state after the last completed round (RNG included).
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            lambda: self.lambda,
            // the shotgun baseline is logistic pure-L1 only
            family: crate::family::FamilyKind::Logistic,
            enet_alpha: 1.0,
            n: self.margins.len(),
            p: self.beta.len(),
            iter: self.completed_rounds,
            f_prev: self.last_objective,
            sim_compute_secs: 0.0,
            sim_comm_secs: 0.0,
            comm_bytes: 0,
            wall_secs: 0.0,
            beta: self.beta.clone(),
            margins: self.margins.clone(),
            rng: Some(self.rng.state()),
            // no distributed cluster: no worker-held shards, no comm
            // estimator state
            shards: Vec::new(),
            est_shrink: None,
        }
    }

    /// Restore a [`ShotgunEstimator::checkpoint`]: β, margins and the RNG
    /// stream continue bit-exactly where the checkpoint left off.
    pub fn resume(&mut self, ck: &Checkpoint) -> Result<()> {
        let state = ck.rng.ok_or_else(|| {
            DlrError::Solver("checkpoint carries no RNG state (not a shotgun checkpoint?)".into())
        })?;
        self.lambda = ck.lambda;
        self.beta = ck.beta.clone();
        self.margins = ck.margins.clone();
        self.rng = Xoshiro256::from_state(state);
        self.completed_rounds = ck.iter;
        self.last_objective = ck.f_prev;
        self.csc = None; // the next fit re-derives it from its dataset
        Ok(())
    }
}

impl Estimator for ShotgunEstimator {
    fn name(&self) -> &'static str {
        "shotgun"
    }

    fn fit(&mut self, ds: &Dataset, observer: &mut dyn FitObserver) -> Result<FitResult> {
        let (n, p) = (ds.n_examples(), ds.n_features());
        if self.beta.len() != p || self.margins.len() != n {
            if !self.beta.is_empty() || !self.margins.is_empty() {
                return Err(DlrError::Solver(format!(
                    "dataset shape ({n} x {p}) does not match shotgun state ({} x {})",
                    self.margins.len(),
                    self.beta.len()
                )));
            }
            self.beta = vec![0f32; p];
            self.margins = vec![0f32; n];
        }
        if self.csc.is_none() || self.csc_nnz != ds.x.nnz() {
            self.csc = Some(ds.x.to_csc());
            self.csc_nnz = ds.x.nnz();
        }
        let csc = self.csc.take().expect("csc cached above");
        let lambda = self.lambda;
        // divergence reference (same guard as `shotgun()`): the objective at
        // this fit's starting state
        let f0 = shotgun_objective(&self.margins, &ds.y, &self.beta, lambda);
        let mut trace: Vec<IterationRecord> = Vec::new();
        let mut stopped = false;
        let mut diverged = false;
        for k in 1..=self.rounds {
            let sw = Stopwatch::start();
            let f = shotgun_round(
                ds,
                &csc,
                lambda,
                self.parallelism,
                &mut self.rng,
                &mut self.beta,
                &mut self.margins,
            );
            self.completed_rounds += 1;
            self.last_objective = Some(f);
            let wall = sw.elapsed_secs();
            let record = IterationRecord {
                iter: self.completed_rounds,
                objective: f,
                alpha: 1.0,
                fast_path: false,
                max_worker_secs: wall,
                sim_comm_secs: 0.0,
                comm_bytes: 0,
                exchange: None,
                wall_secs: wall,
            };
            trace.push(record.clone());
            if !f.is_finite() || f > 10.0 * f0 {
                diverged = true;
            }
            // every round is reported, the diverged/final round included;
            // a Stop on the final scheduled round changes nothing (the fit
            // completed its budget — matching the FitDriver contract)
            let beta_ref = &self.beta;
            let model_fn = move || SparseModel::from_dense(beta_ref, lambda);
            let control = observer.on_iteration(&FitStep::new(&record, &model_fn));
            if diverged {
                break;
            }
            if control == FitControl::Stop {
                if k < self.rounds {
                    stopped = true;
                }
                break;
            }
        }
        self.csc = Some(csc);
        Ok(FitResult {
            lambda,
            objective: self.last_objective.unwrap_or(f64::INFINITY),
            iterations: trace.len(),
            converged: !stopped && !diverged && !trace.is_empty(),
            model: SparseModel::from_dense(&self.beta, lambda),
            sim_compute_secs: trace.iter().map(|r| r.max_worker_secs).sum(),
            sim_comm_secs: 0.0,
            comm_bytes: 0,
            trace,
            timers: PhaseTimer::new(),
        })
    }

    fn model(&self) -> SparseModel {
        SparseModel::from_dense(&self.beta, self.lambda)
    }

    fn reset(&mut self) {
        self.beta.clear();
        self.margins.clear();
        self.rng = Xoshiro256::new(self.seed);
        self.completed_rounds = 0;
        self.last_objective = None;
        self.csc = None;
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn set_lambda(&mut self, lambda: f64) {
        self.lambda = lambda;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn correlated_dataset(n: usize, p: usize, seed: u64) -> Dataset {
        // near-duplicate columns => maximal update conflicts
        let base = synth::epsilon_like(n, 4, seed);
        let mut x = crate::data::sparse::CsrMatrix::new(p);
        for i in 0..n {
            let (_, vals) = base.x.row(i);
            let entries: Vec<(u32, f32)> = (0..p)
                .map(|j| (j as u32, vals[j % vals.len()] * (1.0 + 0.01 * (j as f32))))
                .collect();
            x.push_row(&entries);
        }
        Dataset::new("correlated", x, base.y.clone())
    }

    #[test]
    fn serial_shotgun_descends() {
        let ds = synth::dna_like(400, 30, 5, 81);
        let csc = ds.x.to_csc();
        let r = shotgun(&ds, &csc, 0.5, 1, 200, 1);
        assert!(!r.diverged);
        let first = r.objective_trace[0];
        let last = *r.objective_trace.last().unwrap();
        assert!(last < first, "{last} !< {first}");
    }

    #[test]
    fn high_parallelism_on_correlated_features_hurts() {
        let ds = correlated_dataset(300, 64, 82);
        let csc = ds.x.to_csc();
        let serial = shotgun(&ds, &csc, 0.1, 1, 64, 2);
        let wild = shotgun(&ds, &csc, 0.1, 64, 64, 2);
        let s_last = *serial.objective_trace.last().unwrap();
        let w_last = *wild.objective_trace.last().unwrap();
        // conflicts: the fully-parallel run must end worse (or diverge)
        assert!(
            wild.diverged || w_last > s_last,
            "serial {s_last} vs wild {w_last} (diverged = {})",
            wild.diverged
        );
    }

    #[test]
    fn estimator_matches_raw_shotgun() {
        // the trait path draws the same coordinate stream as shotgun()
        let ds = synth::dna_like(300, 24, 4, 83);
        let csc = ds.x.to_csc();
        let raw = shotgun(&ds, &csc, 0.3, 4, 30, 5);
        let mut est = ShotgunEstimator::new(0.3, 4, 30, 5);
        let fit = est
            .fit(&ds, &mut crate::solver::estimator::NoopObserver)
            .unwrap();
        assert_eq!(fit.iterations, 30);
        assert_eq!(raw.beta, est.model().to_dense());
        assert_eq!(
            raw.objective_trace.last().unwrap().to_bits(),
            fit.objective.to_bits()
        );
    }

    #[test]
    fn checkpoint_resume_continues_the_rng_stream() {
        // 4 + 6 rounds through a checkpoint == 10 uninterrupted rounds
        let ds = synth::dna_like(250, 20, 4, 84);
        let mut whole = ShotgunEstimator::new(0.2, 3, 10, 11);
        let fit_whole = whole
            .fit(&ds, &mut crate::solver::estimator::NoopObserver)
            .unwrap();
        let mut head = ShotgunEstimator::new(0.2, 3, 4, 11);
        head.fit(&ds, &mut crate::solver::estimator::NoopObserver).unwrap();
        let ck = head.checkpoint();
        // fresh estimator, as a fresh process would build it
        let mut tail = ShotgunEstimator::new(0.2, 3, 6, 11);
        tail.resume(&ck).unwrap();
        let fit_tail = tail
            .fit(&ds, &mut crate::solver::estimator::NoopObserver)
            .unwrap();
        assert_eq!(whole.model().to_dense(), tail.model().to_dense());
        assert_eq!(fit_whole.objective.to_bits(), fit_tail.objective.to_bits());
        assert_eq!(fit_tail.trace.last().unwrap().iter, 10);
    }
}
