//! Shotgun — parallel *stochastic* coordinate descent (Bradley et al. 2011).
//!
//! Each round draws P coordinates uniformly at random and updates them in
//! parallel **from the same β** (no line search, no conflict resolution).
//! With correlated features, large P causes update conflicts and can
//! diverge — the exact phenomenon (§1) that motivates d-GLMNET's combine-
//! then-line-search design. Used by ablation A1.

use crate::data::dataset::Dataset;
use crate::data::sparse::CscMatrix;
use crate::util::math::{soft_threshold, working_stats};
use crate::util::rng::Xoshiro256;

/// Outcome of a shotgun run.
#[derive(Debug, Clone)]
pub struct ShotgunResult {
    pub beta: Vec<f32>,
    pub objective_trace: Vec<f64>,
    pub diverged: bool,
}

/// Run shotgun with parallelism `par` for `rounds` rounds.
pub fn shotgun(
    ds: &Dataset,
    csc: &CscMatrix,
    lambda: f64,
    par: usize,
    rounds: usize,
    seed: u64,
) -> ShotgunResult {
    let n = ds.n_examples();
    let p = ds.n_features();
    let mut beta = vec![0f32; p];
    let mut margins = vec![0f32; n];
    let mut rng = Xoshiro256::new(seed);
    let mut trace = Vec::with_capacity(rounds);
    let f_at = |margins: &[f32], beta: &[f32]| {
        crate::util::math::logloss_sum(margins, &ds.y)
            + lambda * crate::util::math::l1_norm(beta)
    };
    let f0 = f_at(&margins, &beta);
    trace.push(f0);
    let mut diverged = false;

    for _round in 0..rounds {
        // P coordinates drawn without replacement, updated from the SAME β
        let coords = rng.sample_indices(p, par.min(p));
        // second-order info at the shared point
        let (w, z): (Vec<f64>, Vec<f64>) = margins
            .iter()
            .zip(&ds.y)
            .map(|(&m, &y)| working_stats(y as f64, m as f64))
            .unzip();
        let mut updates = Vec::with_capacity(coords.len());
        for &j in &coords {
            let (rows, vals) = csc.col(j);
            let mut a = 1e-6;
            let mut c = 0f64;
            for (&i, &v) in rows.iter().zip(vals) {
                let i = i as usize;
                let x = v as f64;
                a += w[i] * x * x;
                // residual at the shared β: r_i = z_i (delta = 0 locally)
                c += w[i] * z[i] * x;
            }
            c += beta[j] as f64 * a;
            let s = soft_threshold(c, lambda) / a;
            updates.push((j, (s - beta[j] as f64) as f32));
        }
        // apply all updates simultaneously (the conflicting part)
        for &(j, d) in &updates {
            if d != 0.0 {
                beta[j] += d;
                let (rows, vals) = csc.col(j);
                for (&i, &v) in rows.iter().zip(vals) {
                    margins[i as usize] += d * v;
                }
            }
        }
        let f = f_at(&margins, &beta);
        trace.push(f);
        if !f.is_finite() || f > 10.0 * f0 {
            diverged = true;
            break;
        }
    }
    ShotgunResult { beta, objective_trace: trace, diverged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn correlated_dataset(n: usize, p: usize, seed: u64) -> Dataset {
        // near-duplicate columns => maximal update conflicts
        let base = synth::epsilon_like(n, 4, seed);
        let mut x = crate::data::sparse::CsrMatrix::new(p);
        for i in 0..n {
            let (_, vals) = base.x.row(i);
            let entries: Vec<(u32, f32)> = (0..p)
                .map(|j| (j as u32, vals[j % vals.len()] * (1.0 + 0.01 * (j as f32))))
                .collect();
            x.push_row(&entries);
        }
        Dataset::new("correlated", x, base.y.clone())
    }

    #[test]
    fn serial_shotgun_descends() {
        let ds = synth::dna_like(400, 30, 5, 81);
        let csc = ds.x.to_csc();
        let r = shotgun(&ds, &csc, 0.5, 1, 200, 1);
        assert!(!r.diverged);
        let first = r.objective_trace[0];
        let last = *r.objective_trace.last().unwrap();
        assert!(last < first, "{last} !< {first}");
    }

    #[test]
    fn high_parallelism_on_correlated_features_hurts() {
        let ds = correlated_dataset(300, 64, 82);
        let csc = ds.x.to_csc();
        let serial = shotgun(&ds, &csc, 0.1, 1, 64, 2);
        let wild = shotgun(&ds, &csc, 0.1, 64, 64, 2);
        let s_last = *serial.objective_trace.last().unwrap();
        let w_last = *wild.objective_trace.last().unwrap();
        // conflicts: the fully-parallel run must end worse (or diverge)
        assert!(
            wild.diverged || w_last > s_last,
            "serial {s_last} vs wild {w_last} (diverged = {})",
            wild.diverged
        );
    }
}
