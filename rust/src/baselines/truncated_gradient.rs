//! Online learning via truncated gradient (Langford, Li & Zhang, JMLR 2009)
//! — the single-machine learner inside the paper's Vowpal Wabbit baseline.
//!
//! SGD on the logistic loss with lazy L1 truncation: every K steps, weights
//! are pulled toward zero by `K·η·g` and clamped at zero (the T1 operator).
//! We apply the truncation lazily per-feature at touch time (the standard
//! sparse implementation), with learning rate `η_t = lr · decay^pass`
//! matching the §4.3 protocol of one rate per pass.

use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::solver::dglmnet::{FitResult, IterationRecord};
use crate::solver::estimator::{Estimator, FitControl, FitObserver, FitStep};
use crate::solver::model::SparseModel;
use crate::util::math::{l1_norm, logloss_sum, sigmoid};
use crate::util::rng::Xoshiro256;
use crate::util::timer::{PhaseTimer, Stopwatch};

/// Truncated-gradient online learner state.
#[derive(Debug, Clone)]
pub struct TruncatedGradientLearner {
    pub weights: Vec<f32>,
    /// gravity accumulated per step; `pending[j]` tracks the truncation debt
    /// applied lazily when feature j is next touched.
    cumulative_gravity: f64,
    applied_gravity: Vec<f64>,
    pub learning_rate: f64,
    pub decay: f64,
    /// per-example L1 strength (VW's --l1; paper footnote: arg = λ/n).
    pub l1: f64,
    pass: usize,
}

impl TruncatedGradientLearner {
    pub fn new(p: usize, learning_rate: f64, decay: f64, l1: f64) -> Self {
        Self {
            weights: vec![0f32; p],
            cumulative_gravity: 0.0,
            applied_gravity: vec![0f64; p],
            learning_rate,
            decay,
            l1,
            pass: 0,
        }
    }

    fn eta(&self) -> f64 {
        self.learning_rate * self.decay.powi(self.pass as i32)
    }

    /// T1 truncation toward zero by `amount >= 0`.
    #[inline]
    fn truncate(w: f64, amount: f64) -> f64 {
        if w > 0.0 {
            (w - amount).max(0.0)
        } else if w < 0.0 {
            (w + amount).min(0.0)
        } else {
            0.0
        }
    }

    /// Bring feature j up to date with the accumulated gravity.
    #[inline]
    fn settle(&mut self, j: usize) {
        let owed = self.cumulative_gravity - self.applied_gravity[j];
        if owed > 0.0 {
            self.weights[j] = Self::truncate(self.weights[j] as f64, owed) as f32;
            self.applied_gravity[j] = self.cumulative_gravity;
        }
    }

    /// One SGD step on example (cols, vals, y). Returns the pre-update margin.
    pub fn step(&mut self, cols: &[u32], vals: &[f32], y: f32) -> f64 {
        let eta = self.eta();
        // settle touched features, compute margin
        let mut margin = 0f64;
        for (&c, &v) in cols.iter().zip(vals) {
            self.settle(c as usize);
            margin += self.weights[c as usize] as f64 * v as f64;
        }
        // logistic gradient: dL/dm = p - (y+1)/2
        let g = sigmoid(margin) - (y as f64 + 1.0) / 2.0;
        for (&c, &v) in cols.iter().zip(vals) {
            let j = c as usize;
            self.weights[j] -= (eta * g * v as f64) as f32;
        }
        // accumulate gravity for the lazy truncation
        self.cumulative_gravity += eta * self.l1;
        margin
    }

    /// One full pass over `ds` in the order given by `order` (shuffled by
    /// the caller / the distributed driver). Advances the per-pass decay.
    pub fn run_pass(&mut self, ds: &Dataset, order: &[usize]) {
        for &i in order {
            let (cols, vals) = ds.x.row(i);
            self.step(cols, vals, ds.y[i]);
        }
        self.pass += 1;
    }

    /// Settle all features and return the final weights.
    pub fn finish(mut self) -> Vec<f32> {
        for j in 0..self.weights.len() {
            self.settle(j);
        }
        self.weights
    }

    /// Settle all features in place (for inspection between passes).
    pub fn settled_weights(&mut self) -> Vec<f32> {
        for j in 0..self.weights.len() {
            self.settle(j);
        }
        self.weights.clone()
    }

    /// Install averaged weights as the warmstart for the next pass
    /// (gravity bookkeeping resets — the debt is already realized).
    pub fn set_weights(&mut self, w: &[f32]) {
        self.weights.copy_from_slice(w);
        self.cumulative_gravity = 0.0;
        self.applied_gravity.fill(0.0);
    }
}

/// Train one learner for `passes` passes over the dataset with per-pass
/// reshuffling — the single-machine baseline.
pub fn train_single(
    ds: &Dataset,
    learning_rate: f64,
    decay: f64,
    l1: f64,
    passes: usize,
    seed: u64,
) -> Vec<f32> {
    let mut learner = TruncatedGradientLearner::new(ds.n_features(), learning_rate, decay, l1);
    let mut rng = Xoshiro256::new(seed);
    let mut order: Vec<usize> = (0..ds.n_examples()).collect();
    for _ in 0..passes {
        rng.shuffle(&mut order);
        learner.run_pass(ds, &order);
    }
    learner.finish()
}

/// [`Estimator`] adapter for the single-machine truncated-gradient learner:
/// one fit = `passes` passes with per-pass reshuffling, one observer
/// callback per pass. `lambda` is on the objective scale (per-example
/// `--l1` = λ/n at fit time). Fits are cold-start — SGD passes begin at
/// β = 0 — so `reset` only clears the stored model. Each pass's
/// [`IterationRecord::objective`] costs one extra O(nnz) train-set scan —
/// the price of a trace that early-stop observers can act on uniformly.
pub struct TruncatedGradientEstimator {
    pub learning_rate: f64,
    pub decay: f64,
    pub lambda: f64,
    pub passes: usize,
    pub seed: u64,
    weights: Vec<f32>,
}

impl TruncatedGradientEstimator {
    pub fn new(learning_rate: f64, decay: f64, lambda: f64, passes: usize, seed: u64) -> Self {
        Self { learning_rate, decay, lambda, passes, seed, weights: Vec::new() }
    }
}

impl Estimator for TruncatedGradientEstimator {
    fn name(&self) -> &'static str {
        "truncated-gradient"
    }

    fn fit(&mut self, ds: &Dataset, observer: &mut dyn FitObserver) -> Result<FitResult> {
        let lambda = self.lambda;
        let l1 = lambda / (ds.n_examples() as f64).max(1.0);
        let mut learner =
            TruncatedGradientLearner::new(ds.n_features(), self.learning_rate, self.decay, l1);
        let mut rng = Xoshiro256::new(self.seed);
        let mut order: Vec<usize> = (0..ds.n_examples()).collect();
        let mut trace: Vec<IterationRecord> = Vec::new();
        let mut stopped = false;
        for pass in 1..=self.passes {
            let sw = Stopwatch::start();
            rng.shuffle(&mut order);
            learner.run_pass(ds, &order);
            let weights = learner.settled_weights();
            let wall = sw.elapsed_secs();
            let margins = ds.x.margins(&weights);
            let objective = logloss_sum(&margins, &ds.y) + lambda * l1_norm(&weights);
            let record = IterationRecord {
                iter: pass,
                objective,
                alpha: 1.0,
                fast_path: false,
                max_worker_secs: wall,
                sim_comm_secs: 0.0,
                comm_bytes: 0,
                exchange: None,
                wall_secs: wall,
            };
            trace.push(record.clone());
            self.weights = weights;
            let model_fn = || SparseModel::from_dense(&self.weights, lambda);
            if observer.on_iteration(&FitStep::new(&record, &model_fn)) == FitControl::Stop {
                // a Stop on the final scheduled pass changes nothing: the
                // fit completed its budget (the FitDriver contract)
                if pass < self.passes {
                    stopped = true;
                }
                break;
            }
        }
        Ok(FitResult {
            lambda,
            objective: trace.last().map_or(f64::INFINITY, |r| r.objective),
            iterations: trace.len(),
            converged: !stopped && !trace.is_empty(),
            model: SparseModel::from_dense(&self.weights, lambda),
            sim_compute_secs: trace.iter().map(|r| r.max_worker_secs).sum(),
            sim_comm_secs: 0.0,
            comm_bytes: 0,
            trace,
            timers: PhaseTimer::new(),
        })
    }

    fn model(&self) -> SparseModel {
        SparseModel::from_dense(&self.weights, self.lambda)
    }

    fn reset(&mut self) {
        self.weights.clear();
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn set_lambda(&mut self, lambda: f64) {
        self.lambda = lambda;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics;
    use crate::util::math::nnz;

    #[test]
    fn learns_a_separable_problem() {
        let ds = synth::epsilon_like(1_500, 30, 51);
        let w = train_single(&ds, 0.3, 0.8, 1e-7, 5, 1);
        let margins = ds.x.margins(&w);
        let auc = metrics::roc_auc(&margins, &ds.y);
        assert!(auc > 0.8, "auc = {auc}");
    }

    #[test]
    fn stronger_l1_gives_sparser_weights() {
        let ds = synth::webspam_like(800, 2_000, 20, 52);
        let w_weak = train_single(&ds, 0.2, 0.7, 1e-8, 3, 2);
        let w_strong = train_single(&ds, 0.2, 0.7, 5e-4, 3, 2);
        assert!(
            nnz(&w_strong) < nnz(&w_weak),
            "strong {} !< weak {}",
            nnz(&w_strong),
            nnz(&w_weak)
        );
    }

    #[test]
    fn huge_l1_kills_all_weights() {
        let ds = synth::dna_like(300, 20, 4, 53);
        let w = train_single(&ds, 0.1, 0.5, 10.0, 2, 3);
        assert_eq!(nnz(&w), 0);
    }

    #[test]
    fn truncation_is_lazy_but_exact() {
        // two learners, one settling every step, one lazily: same result
        let ds = synth::dna_like(200, 15, 3, 54);
        let mut lazy = TruncatedGradientLearner::new(15, 0.2, 1.0, 1e-3);
        let mut eager = TruncatedGradientLearner::new(15, 0.2, 1.0, 1e-3);
        let order: Vec<usize> = (0..ds.n_examples()).collect();
        lazy.run_pass(&ds, &order);
        for &i in &order {
            let (cols, vals) = ds.x.row(i);
            eager.step(cols, vals, ds.y[i]);
            let _ = eager.settled_weights();
        }
        let a = lazy.finish();
        let b = eager.finish();
        for j in 0..15 {
            assert!((a[j] - b[j]).abs() < 1e-5, "w[{j}]: {} vs {}", a[j], b[j]);
        }
    }

    #[test]
    fn decay_reduces_step_size_across_passes() {
        let mut l = TruncatedGradientLearner::new(2, 0.4, 0.5, 0.0);
        assert!((l.eta() - 0.4).abs() < 1e-12);
        l.pass = 2;
        assert!((l.eta() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn estimator_adapter_matches_train_single() {
        // same seed, same shuffles, per-pass settling is lazy-exact
        let ds = synth::dna_like(300, 20, 4, 55);
        let lambda = 0.03;
        // same λ/n computation as the estimator performs, so l1 bit-matches
        let l1 = lambda / ds.n_examples() as f64;
        let want = train_single(&ds, 0.2, 0.7, l1, 3, 7);
        let mut est = TruncatedGradientEstimator::new(0.2, 0.7, lambda, 3, 7);
        let fit = est
            .fit(&ds, &mut crate::solver::estimator::NoopObserver)
            .unwrap();
        assert_eq!(fit.iterations, 3);
        assert!(fit.converged);
        assert!(fit.objective.is_finite());
        let got = est.model().to_dense();
        for j in 0..got.len() {
            assert!((got[j] - want[j]).abs() < 1e-5, "w[{j}]: {} vs {}", got[j], want[j]);
        }
    }
}
