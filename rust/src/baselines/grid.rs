//! The §4.3 parameter grid for the online baseline: learning rates
//! 0.1–0.5 × decays 0.5–0.9 × the λ ladder, evaluating every pass of every
//! combination — exactly the scatter of Vowpal Wabbit points in Figure 1.

use crate::baselines::distributed_online::DistributedOnlineLearner;
use crate::data::dataset::Dataset;
use crate::metrics;
use crate::util::math::nnz;

/// One evaluated grid point (one VW marker in Figure 1).
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub learning_rate: f64,
    pub decay: f64,
    pub l1_per_example: f64,
    pub pass: usize,
    pub nnz: usize,
    pub auprc: f64,
    pub auc: f64,
    pub wall_secs: f64,
    /// avg wall seconds per pass (Table 3's VW "avg time per iter").
    pub secs_per_pass: f64,
}

/// Full §4.3 protocol. `lambdas` are objective-scale λ values (the same
/// ladder d-GLMNET uses); VW's per-example arg is λ/n (paper footnote 4).
#[allow(clippy::too_many_arguments)]
pub fn online_grid_search(
    train: &Dataset,
    test: &Dataset,
    machines: usize,
    learning_rates: &[f64],
    decays: &[f64],
    lambdas: &[f64],
    passes: usize,
    seed: u64,
) -> Vec<GridPoint> {
    let n = train.n_examples() as f64;
    let mut out = Vec::new();
    for &lr in learning_rates {
        for &decay in decays {
            for &lam in lambdas {
                let t0 = std::time::Instant::now();
                let learner =
                    DistributedOnlineLearner::new(machines, lr, decay, lam / n, seed);
                let snaps = learner.train(train, passes);
                let wall = t0.elapsed().as_secs_f64();
                for s in &snaps {
                    let margins = test.x.margins(&s.weights);
                    out.push(GridPoint {
                        learning_rate: lr,
                        decay,
                        l1_per_example: lam / n,
                        pass: s.pass,
                        nnz: nnz(&s.weights),
                        auprc: metrics::auprc(&margins, &test.y),
                        auc: metrics::roc_auc(&margins, &test.y),
                        wall_secs: wall,
                        secs_per_pass: wall / passes as f64,
                    });
                }
            }
        }
    }
    out
}

/// The best quality achievable at each sparsity level across the whole grid
/// (the envelope Figure 1 visually compares d-GLMNET against).
pub fn grid_frontier(points: &[GridPoint]) -> Vec<(usize, f64)> {
    let mut pts: Vec<(usize, f64)> = points.iter().map(|g| (g.nnz, g.auprc)).collect();
    pts.sort_by_key(|p| p.0);
    let mut out: Vec<(usize, f64)> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for (x, y) in pts {
        if y > best {
            best = y;
            out.push((x, y));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn grid_produces_point_per_combo_per_pass() {
        let split = synth::dna_like(400, 30, 5, 71).split(0.8, 3);
        let pts = online_grid_search(
            &split.train,
            &split.test,
            2,
            &[0.1, 0.3],
            &[0.5],
            &[1.0, 4.0],
            2,
            1,
        );
        assert_eq!(pts.len(), 2 * 1 * 2 * 2);
        assert!(pts.iter().all(|p| p.auprc >= 0.0 && p.auprc <= 1.0));
    }

    #[test]
    fn frontier_is_monotone() {
        let split = synth::dna_like(300, 25, 4, 72).split(0.8, 4);
        let pts = online_grid_search(
            &split.train, &split.test, 2, &[0.2], &[0.7], &[0.5, 8.0], 2, 2,
        );
        let f = grid_frontier(&pts);
        assert!(!f.is_empty());
        let ys: Vec<f64> = f.iter().map(|p| p.1).collect();
        assert!(ys.windows(2).all(|w| w[1] >= w[0]));
    }
}
