//! The §4.3 parameter grid for the online baseline: learning rates
//! 0.1–0.5 × decays 0.5–0.9 × the λ ladder, evaluating every pass of every
//! combination — exactly the scatter of Vowpal Wabbit points in Figure 1.
//!
//! The evaluation machinery is estimator-generic: [`fit_scored`] fits any
//! `&mut dyn Estimator` and scores the model on the test set after every
//! iteration through a [`FitObserver`] (the observer materializes each
//! iteration's model lazily via [`FitStep::model`]). The grid itself only
//! decides *which* estimators to construct.

use crate::baselines::distributed_online::DistributedOnlineEstimator;
use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::metrics;
use crate::solver::dglmnet::FitResult;
use crate::solver::estimator::{fit_cold, Estimator, FitControl, FitObserver, FitStep};

/// One evaluated grid point (one VW marker in Figure 1).
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub learning_rate: f64,
    pub decay: f64,
    pub l1_per_example: f64,
    pub pass: usize,
    pub nnz: usize,
    pub auprc: f64,
    pub auc: f64,
    pub wall_secs: f64,
    /// avg wall seconds per pass (Table 3's VW "avg time per iter").
    pub secs_per_pass: f64,
}

/// Test-set quality of one fit iteration (one pass/round of any estimator).
#[derive(Debug, Clone)]
pub struct PassEval {
    pub pass: usize,
    pub nnz: usize,
    pub auprc: f64,
    pub auc: f64,
}

struct ScoreObserver<'a> {
    test: &'a Dataset,
    evals: Vec<PassEval>,
}

impl FitObserver for ScoreObserver<'_> {
    fn on_iteration(&mut self, step: &FitStep<'_>) -> FitControl {
        let model = step.model();
        let margins = model.predict_margins(&self.test.x);
        self.evals.push(PassEval {
            pass: step.record.iter,
            nnz: model.nnz(),
            auprc: metrics::auprc(&margins, &self.test.y),
            auc: metrics::roc_auc(&margins, &self.test.y),
        });
        FitControl::Continue
    }
}

/// Cold-fit `est` on `train`, scoring the model on `test` after every
/// iteration — the generic per-pass evaluation every grid search and
/// tournament builds on (no solver-specific branches).
pub fn fit_scored(
    est: &mut dyn Estimator,
    train: &Dataset,
    test: &Dataset,
) -> Result<(FitResult, Vec<PassEval>)> {
    let mut observer = ScoreObserver { test, evals: Vec::new() };
    let fit = fit_cold(est, train, &mut observer)?;
    Ok((fit, observer.evals))
}

/// Full §4.3 protocol. `lambdas` are objective-scale λ values (the same
/// ladder d-GLMNET uses); VW's per-example arg is λ/n (paper footnote 4).
#[allow(clippy::too_many_arguments)]
pub fn online_grid_search(
    train: &Dataset,
    test: &Dataset,
    machines: usize,
    learning_rates: &[f64],
    decays: &[f64],
    lambdas: &[f64],
    passes: usize,
    seed: u64,
) -> Vec<GridPoint> {
    let n = train.n_examples() as f64;
    let mut out = Vec::new();
    for &lr in learning_rates {
        for &decay in decays {
            for &lam in lambdas {
                let mut est =
                    DistributedOnlineEstimator::new(machines, lr, decay, lam, passes, seed);
                let (fit, evals) = match fit_scored(&mut est, train, test) {
                    Ok(out) => out,
                    Err(e) => {
                        // never drop a grid combo silently: the Figure-1
                        // scatter must not read as complete when it isn't
                        eprintln!(
                            "[grid] skipping lr={lr} decay={decay} lambda={lam:.5}: {e}"
                        );
                        continue;
                    }
                };
                // total training wall of this combo (excludes scoring time)
                let wall: f64 = fit.trace.iter().map(|r| r.wall_secs).sum();
                for e in &evals {
                    out.push(GridPoint {
                        learning_rate: lr,
                        decay,
                        l1_per_example: lam / n,
                        pass: e.pass,
                        nnz: e.nnz,
                        auprc: e.auprc,
                        auc: e.auc,
                        wall_secs: wall,
                        secs_per_pass: wall / passes.max(1) as f64,
                    });
                }
            }
        }
    }
    out
}

/// The best quality achievable at each sparsity level across the whole grid
/// (the envelope Figure 1 visually compares d-GLMNET against).
pub fn grid_frontier(points: &[GridPoint]) -> Vec<(usize, f64)> {
    let mut pts: Vec<(usize, f64)> = points.iter().map(|g| (g.nnz, g.auprc)).collect();
    pts.sort_by_key(|p| p.0);
    let mut out: Vec<(usize, f64)> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for (x, y) in pts {
        if y > best {
            best = y;
            out.push((x, y));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn grid_produces_point_per_combo_per_pass() {
        let split = synth::dna_like(400, 30, 5, 71).split(0.8, 3).unwrap();
        let pts = online_grid_search(
            &split.train,
            &split.test,
            2,
            &[0.1, 0.3],
            &[0.5],
            &[1.0, 4.0],
            2,
            1,
        );
        assert_eq!(pts.len(), 2 * 1 * 2 * 2);
        assert!(pts.iter().all(|p| p.auprc >= 0.0 && p.auprc <= 1.0));
    }

    #[test]
    fn frontier_is_monotone() {
        let split = synth::dna_like(300, 25, 4, 72).split(0.8, 4).unwrap();
        let pts = online_grid_search(
            &split.train, &split.test, 2, &[0.2], &[0.7], &[0.5, 8.0], 2, 2,
        );
        let f = grid_frontier(&pts);
        assert!(!f.is_empty());
        let ys: Vec<f64> = f.iter().map(|p| p.1).collect();
        assert!(ys.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn fit_scored_works_for_any_estimator() {
        use crate::baselines::shotgun::ShotgunEstimator;
        let split = synth::dna_like(300, 24, 4, 73).split(0.8, 5).unwrap();
        let mut est = ShotgunEstimator::new(0.5, 2, 8, 3);
        let (fit, evals) = fit_scored(&mut est, &split.train, &split.test).unwrap();
        assert_eq!(fit.iterations, 8);
        assert_eq!(evals.len(), 8);
        assert!(evals.iter().all(|e| (0.0..=1.0).contains(&e.auprc)));
        assert_eq!(evals.last().unwrap().pass, 8);
    }
}
