//! Typed configuration for the solver, cluster, regularization path and
//! baselines, with a builder API and a TOML-subset file loader.

pub mod toml;

use std::path::Path;

use crate::cluster::network::NetworkModel;
use crate::cluster::partition::PartitionStrategy;
use crate::error::{DlrError, Result};
use crate::family::FamilyKind;
use toml::TomlDoc;

/// Which subproblem engine workers run (DESIGN.md §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Per shard: XLA when the dense-tile formulation pays off (artifacts
    /// present, n fits a compiled tile, density/memory within budget),
    /// otherwise the native sparse path. The production default.
    Auto,
    /// AOT Pallas kernels through PJRT on densified (N, B) tiles.
    Xla,
    /// Pure-rust sparse coordinate descent (paper's CPU formulation).
    Native,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(Self::Auto),
            "xla" | "pjrt" => Some(Self::Xla),
            "native" | "sparse" => Some(Self::Native),
            _ => None,
        }
    }
}

/// How Δ-state crosses the simulated wire each iteration (Alg 4 step 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeStrategy {
    /// Per-iteration byte-cost model: allgather-Δβ when shipping the Δβ
    /// shards is estimated cheaper than reducing the example-space Δm.
    Auto,
    /// Classic d-GLMNET: tree-AllReduce both Δm (dim n) and Δβ (dim p).
    ReduceDm,
    /// AllGather the machines' sparse Δβ shards and recompute Δm from the
    /// locally-owned feature shards — kills the `O(n)` wire term.
    AllGatherBeta,
}

impl ExchangeStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(Self::Auto),
            "reduce" | "reduce-dm" => Some(Self::ReduceDm),
            "allgather" | "allgather-beta" => Some(Self::AllGatherBeta),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::ReduceDm => "reduce-dm",
            Self::AllGatherBeta => "allgather-beta",
        }
    }
}

/// How the leader drives its worker nodes (the node protocol runs
/// unchanged over both — trajectories are bit-identical, pinned by
/// `tests/node_protocol.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Worker threads inside the leader process, protocol messages over
    /// in-process channels (the default).
    InProcess,
    /// Remote worker processes over TCP byte streams: the leader listens
    /// on [`TrainConfig::listen`] and admits one `dglmnet worker` process
    /// per partition block.
    Socket,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "in-process" | "inprocess" | "channel" | "threads" => Some(Self::InProcess),
            "socket" | "tcp" => Some(Self::Socket),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::InProcess => "in-process",
            Self::Socket => "socket",
        }
    }
}

/// Physical routing of the collective traffic (`[cluster] topology` /
/// `--topology`). Trajectories, β and the comm ledger are bit-identical
/// under both — the ledger always charged tree edges; the knob decides
/// whether the wire makes them physical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    /// Every worker talks only to the leader; the leader stages all M
    /// sweep payloads and runs the tree merges itself (the default).
    /// Leader bytes-on-wire grow O(M) per iteration.
    Star,
    /// Workers dial each other from the topology handed out in `Welcome`
    /// and relay sweep/apply traffic on the physical merge tree; the
    /// leader touches only its O(1) root edge. Socket transport only —
    /// the in-process pool has no wire, so the setting is accepted and
    /// routing stays leader-staged (bit-identical by the pins above).
    Tree,
}

impl TopologyKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "star" | "leader-star" => Some(Self::Star),
            "tree" | "p2p" | "peer-to-peer" => Some(Self::Tree),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Star => "star",
            Self::Tree => "tree",
        }
    }
}

/// Line-search constants of Alg 3. Paper: b = 0.5, sigma = 0.01, gamma = 0.
#[derive(Debug, Clone, Copy)]
pub struct LineSearchConfig {
    pub backtrack: f64,
    pub sigma: f64,
    pub gamma: f64,
    /// Lower bound delta for the alpha_init scan (Alg 3 step 2).
    pub alpha_min: f64,
    /// Grid size for the alpha_init scan — matches the AOT K.
    pub grid: usize,
    /// Step 1 shortcut: accept alpha = 1 outright when the relative
    /// objective decrease is at least this (the sparsity precaution).
    pub sufficient_decrease: f64,
    /// Disable the alpha_init scan (plain Armijo from 1) — ablation A3.
    pub skip_alpha_init: bool,
}

impl Default for LineSearchConfig {
    fn default() -> Self {
        Self {
            backtrack: 0.5,
            sigma: 0.01,
            gamma: 0.0,
            alpha_min: 1e-3,
            grid: 16,
            sufficient_decrease: 1e-4,
            skip_alpha_init: false,
        }
    }
}

/// Hard resource budgets for one fit, enforced *between* iterations by the
/// stepwise `FitDriver` (a budget never interrupts a running iteration).
/// `None` means unlimited. Hitting any budget ends the fit with
/// `converged = false` and the matching `StopReason`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FitBudget {
    /// Wall-clock cap in seconds (includes resumed-over time).
    pub wall_secs: Option<f64>,
    /// Simulated-network byte cap (includes resumed-over traffic).
    pub comm_bytes: Option<u64>,
    /// Total-iteration cap across checkpoint/resume boundaries. Unlike
    /// `max_iter` (which forces the α = 1 convergence retry at the cap),
    /// this simply stops.
    pub iterations: Option<usize>,
}

impl FitBudget {
    pub fn unlimited() -> Self {
        Self::default()
    }

    pub fn is_unlimited(&self) -> bool {
        *self == Self::default()
    }
}

/// Solver configuration (Algorithms 1–4).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub lambda: f64,
    /// GLM loss family (`[train] family` / `--family`): `logistic` (the
    /// default, bit-identical to the historical hardcoded path), `gaussian`
    /// (least squares) or `poisson` (log-link counts). Flows through the
    /// worker handshake, checkpoints and model artifacts.
    pub family: FamilyKind,
    /// Elastic-net mixing `α ∈ (0, 1]` (`[train] alpha` / `--alpha`): the
    /// penalty is `λ(α‖β‖₁ + (1−α)/2·‖β‖₂²)`. `1.0` (the default) is pure
    /// L1 — the paper's problem, bit-identical to the pre-knob code. Named
    /// `enet_alpha` in code because `alpha` already names the line-search
    /// step size.
    pub enet_alpha: f64,
    /// Ridge term nu added to the block-diagonal Hessian (paper: 1e-6).
    pub nu: f64,
    pub max_iter: usize,
    /// Convergence: relative objective decrease threshold.
    pub tol: f64,
    /// Number of simulated machines M.
    pub machines: usize,
    /// Dense tile width B for the XLA engine.
    pub block: usize,
    pub engine: EngineKind,
    /// Use the naive per-column sweep kernel instead of the optimized
    /// covariance-update kernel (perf ablation; see EXPERIMENTS.md §Perf).
    /// With the native engine, `naive_sweep = true` + `sweep_threads = 1`
    /// is the exact-ablation escape hatch: it reproduces the historical
    /// single-threaded trajectories bit-for-bit.
    pub naive_sweep: bool,
    /// Threads each worker's CD sweep runs on (`[engine] sweep_threads` /
    /// `--sweep-threads`). `0` = auto from available parallelism. A
    /// T-threaded worker partitions its columns into T sub-blocks
    /// (same strategy as the machine partition) and is bit-identical to
    /// running those sub-blocks as T separate machines (T a power of two).
    pub sweep_threads: usize,
    pub partition: PartitionStrategy,
    pub network: NetworkModel,
    /// Force the dense AllReduce wire format *and* the reduce-Δm exchange
    /// (the pre-sparsity baseline; benchmarks and the sparse-vs-dense
    /// regression tests use this — production leaves it off and lets the
    /// per-message byte-cost model decide).
    pub dense_allreduce: bool,
    /// Which Δ-exchange the solver runs each iteration (default: the
    /// byte-cost model picks per iteration). `dense_allreduce` overrides
    /// this to [`ExchangeStrategy::ReduceDm`].
    pub exchange: ExchangeStrategy,
    /// Allow the lossy delta-varint + f16 wire codec for Δ-margin
    /// messages (reduce-Δm strategy only; changes trajectories within a
    /// small tolerance — see `tests/wire_codec.rs`). Off by default.
    pub wire_f16_margins: bool,
    /// Allow the lossy f16 codec for β-carrying (Δβ) messages. Off by
    /// default and discouraged: it quantizes the model update itself.
    pub wire_f16_beta: bool,
    /// Sharded on-disk store directory (`[data] store` / `--store`): train
    /// out-of-core — workers self-load their shard files and the leader
    /// stays O(n + p). `None` trains from an in-memory dataset (which the
    /// in-process constructors route through a temp store anyway, so the
    /// two paths are bit-identical).
    pub store: Option<String>,
    /// How workers are driven: in-process threads (default) or remote
    /// `dglmnet worker` processes over TCP (`[cluster] transport`).
    pub transport: TransportKind,
    /// Leader bind address for `transport = socket` (`[cluster] listen`).
    pub listen: String,
    /// Physical routing of collective traffic (`[cluster] topology` /
    /// `--topology`): `star` (leader-staged, default) or `tree` (workers
    /// relay sweep/apply traffic peer-to-peer on the merge bracket; the
    /// leader keeps O(1) bytes-on-wire per iteration). Bit-identical
    /// trajectories and ledgers either way; `tree` requires the default
    /// lossless wire policy.
    pub topology: TopologyKind,
    /// PR-3-compat accounting ablation: charge the broadcast phase of the
    /// Δβ exchange as if workers still received the merged Δβ. Under
    /// worker-held β shards that broadcast no longer exists, so the
    /// default charges the Δβ flow as the gather it is; turning this on
    /// reproduces the old ledger for regression comparisons.
    pub charge_beta_broadcast: bool,
    /// Leader-side supervision (`[cluster] supervise` / `--supervise`):
    /// detect a dead or wedged worker mid-fit, roll back to the last
    /// recovery checkpoint, re-admit a replacement, and resume — instead
    /// of the fail-fast default where the first worker fault ends the fit
    /// with a clean error. Recovery is bit-exact: the completed fit
    /// reproduces the undisturbed run's β, objective trajectory, and comm
    /// ledger (supervision traffic is accounted separately).
    pub supervise: bool,
    /// Recv deadline for the supervision heartbeat (`Ping`/`Pong`) probe,
    /// in seconds: a worker that doesn't answer within this is declared
    /// dead and replaced (`[cluster] heartbeat_timeout_secs`).
    pub heartbeat_timeout_secs: f64,
    /// Per-link recv deadline during normal fit phases, in seconds — turns
    /// a wedged (alive but silent) worker into a prompt "timed out" error
    /// the supervisor can act on. `0` (the default) blocks indefinitely;
    /// peer *death* is always detected promptly regardless
    /// (`[cluster] recv_timeout_secs`).
    pub recv_timeout_secs: f64,
    /// Iterations between automatic recovery checkpoints while supervising
    /// (`[cluster] recovery_checkpoint_every`). Recovery checkpoints are
    /// leader-local (no worker pull, no wire traffic), so the default of 1
    /// re-runs at most the failed iteration after a rollback.
    pub recovery_checkpoint_every: usize,
    pub line_search: LineSearchConfig,
    /// Tolerated relative objective increase when retrying alpha = 1 at
    /// convergence (the second sparsity precaution of §2).
    pub alpha_one_slack: f64,
    /// Wall-clock / comm-bytes / iteration caps (default: unlimited).
    pub budget: FitBudget,
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            lambda: 1.0,
            family: FamilyKind::Logistic,
            enet_alpha: 1.0,
            nu: 1e-6,
            max_iter: 100,
            tol: 1e-5,
            machines: 4,
            block: 64,
            engine: EngineKind::Auto,
            naive_sweep: false,
            sweep_threads: 1,
            partition: PartitionStrategy::RoundRobin,
            network: NetworkModel::gigabit(),
            dense_allreduce: false,
            exchange: ExchangeStrategy::Auto,
            wire_f16_margins: false,
            wire_f16_beta: false,
            store: None,
            transport: TransportKind::InProcess,
            listen: "127.0.0.1:4801".into(),
            topology: TopologyKind::Star,
            charge_beta_broadcast: false,
            supervise: false,
            heartbeat_timeout_secs: 5.0,
            recv_timeout_secs: 0.0,
            recovery_checkpoint_every: 1,
            line_search: LineSearchConfig::default(),
            alpha_one_slack: 1e-4,
            budget: FitBudget::default(),
            verbose: false,
        }
    }
}

impl TrainConfig {
    pub fn builder() -> TrainConfigBuilder {
        TrainConfigBuilder(Self::default())
    }

    pub fn validate(&self) -> Result<()> {
        if self.lambda < 0.0 {
            return Err(DlrError::Config("lambda must be >= 0".into()));
        }
        if !self.enet_alpha.is_finite() || self.enet_alpha <= 0.0 || self.enet_alpha > 1.0 {
            return Err(DlrError::Config(format!(
                "[train] alpha = {} is outside (0, 1]: alpha mixes the elastic-net \
                 penalty λ(α‖β‖₁ + (1−α)/2·‖β‖₂²) — use 1.0 for pure L1 (the default) \
                 or a smaller positive value to blend in ridge (pure ridge α = 0 is \
                 not supported: λ_max = λ_max(L1)/α diverges)",
                self.enet_alpha
            )));
        }
        if self.engine == EngineKind::Xla
            && (self.family != FamilyKind::Logistic || self.enet_alpha < 1.0)
        {
            return Err(DlrError::Config(format!(
                "engine = xla compiles logistic-only pure-L1 AOT kernels, but family = {} \
                 with alpha = {} was requested — use engine = native (or auto, which \
                 resolves to native for non-default families)",
                self.family.name(),
                self.enet_alpha
            )));
        }
        if self.nu <= 0.0 {
            return Err(DlrError::Config(
                "nu must be > 0 (positive definiteness, §2.1)".into(),
            ));
        }
        if self.machines == 0 {
            return Err(DlrError::Config("machines must be >= 1".into()));
        }
        if !(0.0 < self.line_search.backtrack && self.line_search.backtrack < 1.0) {
            return Err(DlrError::Config("backtrack b must be in (0,1)".into()));
        }
        if !(0.0 < self.line_search.sigma && self.line_search.sigma < 1.0) {
            return Err(DlrError::Config("sigma must be in (0,1)".into()));
        }
        if !(0.0..1.0).contains(&self.line_search.gamma) {
            return Err(DlrError::Config("gamma must be in [0,1)".into()));
        }
        if self.block == 0 || self.block % 8 != 0 {
            return Err(DlrError::Config("block must be a positive multiple of 8".into()));
        }
        if self.dense_allreduce && self.exchange == ExchangeStrategy::AllGatherBeta {
            return Err(DlrError::Config(
                "dense_allreduce forces the reduce-dm exchange; \
                 do not combine it with exchange = allgather-beta"
                    .into(),
            ));
        }
        if self.wire_f16_beta && self.exchange == ExchangeStrategy::AllGatherBeta {
            return Err(DlrError::Config(
                "wire_f16_beta cannot be combined with exchange = allgather-beta: \
                 the allgather path recombines Δm from the workers' exact Δβᵀx \
                 products, which a cluster applying f16-quantized Δβ could not \
                 reproduce (use reduce-dm, where the drift is physical)"
                    .into(),
            ));
        }
        if let Some(w) = self.budget.wall_secs {
            if w.is_nan() || w < 0.0 {
                return Err(DlrError::Config("budget.wall_secs must be >= 0".into()));
            }
        }
        if self.transport == TransportKind::Socket && self.listen.is_empty() {
            return Err(DlrError::Config(
                "transport = socket needs a [cluster] listen = \"host:port\" address".into(),
            ));
        }
        if self.topology == TopologyKind::Tree
            && (self.wire_f16_margins || self.wire_f16_beta)
        {
            return Err(DlrError::Config(
                "topology = tree requires the default lossless wire policy: peer-relayed \
                 merges ship exact payloads, and the lossy wire_f16_* charging model \
                 quantizes inside the leader-staged collective — use topology = star \
                 for the f16 ablations"
                    .into(),
            ));
        }
        if !self.heartbeat_timeout_secs.is_finite() || self.heartbeat_timeout_secs <= 0.0 {
            return Err(DlrError::Config(
                "heartbeat_timeout_secs must be a positive number of seconds".into(),
            ));
        }
        if !self.recv_timeout_secs.is_finite() || self.recv_timeout_secs < 0.0 {
            return Err(DlrError::Config(
                "recv_timeout_secs must be >= 0 (0 disables the recv deadline)".into(),
            ));
        }
        if self.recovery_checkpoint_every == 0 {
            return Err(DlrError::Config(
                "recovery_checkpoint_every must be >= 1 iteration".into(),
            ));
        }
        Ok(())
    }

    /// The satellite bugfix for worker-count validation: reject worker
    /// counts the feature space cannot cover *before* any partition or
    /// shard work runs, with an actionable message — the old path
    /// surfaced as a failure deep inside `partition.rs`/shard
    /// construction. Called by every `DGlmnetSolver` constructor once the
    /// dataset shape is known.
    pub fn validate_machines_for(&self, n_features: usize) -> Result<()> {
        if self.machines == 0 {
            return Err(DlrError::Config(
                "the cluster needs at least one worker ([cluster] workers / --workers >= 1)"
                    .into(),
            ));
        }
        if self.machines > n_features {
            return Err(DlrError::Config(format!(
                "the cluster has {} workers but the dataset has only {} features; every \
                 worker must own at least one feature block — lower [cluster] workers / \
                 --workers (or --machines) to at most {}",
                self.machines, n_features, n_features
            )));
        }
        Ok(())
    }

    /// The sweep-thread analog of [`validate_machines_for`]: an explicit
    /// `sweep_threads` larger than a worker's column count would leave
    /// threads with no features to sweep. Called with the smallest shard
    /// width once the partition is known (`0` = auto always resolves to a
    /// clamped, valid count).
    ///
    /// [`validate_machines_for`]: TrainConfig::validate_machines_for
    pub fn validate_sweep_threads_for(&self, min_shard_cols: usize) -> Result<()> {
        if self.sweep_threads > min_shard_cols.max(1) {
            return Err(DlrError::Config(format!(
                "[engine] sweep_threads = {} but the narrowest worker shard has only {} \
                 feature column(s); every sweep thread must own at least one column — \
                 lower --sweep-threads to at most {} (or use 0 = auto, which clamps \
                 to the shard width)",
                self.sweep_threads,
                min_shard_cols,
                min_shard_cols.max(1)
            )));
        }
        Ok(())
    }

    /// Load from a TOML file (`[solver]`, `[cluster]`, `[line_search]`).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&toml::parse(&text)?)
    }

    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = Self::default();
        let num = |sec: &str, key: &str| doc.get(sec, key).and_then(|v| v.as_f64());
        let int = |sec: &str, key: &str| doc.get(sec, key).and_then(|v| v.as_usize());
        if let Some(v) = num("solver", "lambda") {
            cfg.lambda = v;
        }
        if let Some(v) = num("solver", "nu") {
            cfg.nu = v;
        }
        if let Some(v) = int("solver", "max_iter") {
            cfg.max_iter = v;
        }
        if let Some(v) = num("solver", "tol") {
            cfg.tol = v;
        }
        if let Some(v) = int("solver", "machines") {
            cfg.machines = v;
        }
        if let Some(v) = int("solver", "block") {
            cfg.block = v;
        }
        if let Some(s) = doc.get("solver", "engine").and_then(|v| v.as_str()) {
            cfg.engine = EngineKind::parse(s)
                .ok_or_else(|| DlrError::Config(format!("unknown engine '{s}'")))?;
        }
        if let Some(s) = doc.get("train", "family").and_then(|v| v.as_str()) {
            cfg.family = FamilyKind::parse_or_err(s)?;
        }
        if let Some(v) = doc.get("train", "alpha") {
            cfg.enet_alpha = v.as_f64().ok_or_else(|| {
                DlrError::Config("train.alpha must be a number in (0, 1]".into())
            })?;
        }
        if let Some(v) = doc.get("engine", "sweep_threads") {
            cfg.sweep_threads = v.as_usize().ok_or_else(|| {
                DlrError::Config(
                    "engine.sweep_threads must be a non-negative integer (0 = auto)".into(),
                )
            })?;
        }
        if let Some(v) = doc.get("engine", "naive_sweep").and_then(|v| v.as_bool()) {
            cfg.naive_sweep = v;
        }
        if let Some(s) = doc.get("solver", "partition").and_then(|v| v.as_str()) {
            cfg.partition = PartitionStrategy::parse(s)
                .ok_or_else(|| DlrError::Config(format!("unknown partition '{s}'")))?;
        }
        if let Some(v) = num("cluster", "bandwidth_gbps") {
            cfg.network.bandwidth_bytes_per_sec = v * 125e6;
        }
        if let Some(v) = num("cluster", "latency_us") {
            cfg.network.latency_sec = v * 1e-6;
        }
        if let Some(v) = doc.get("cluster", "dense_allreduce").and_then(|v| v.as_bool()) {
            cfg.dense_allreduce = v;
        }
        if let Some(s) = doc.get("cluster", "exchange").and_then(|v| v.as_str()) {
            cfg.exchange = ExchangeStrategy::parse(s)
                .ok_or_else(|| DlrError::Config(format!("unknown exchange strategy '{s}'")))?;
        }
        if let Some(v) = doc.get("cluster", "wire_f16_margins").and_then(|v| v.as_bool()) {
            cfg.wire_f16_margins = v;
        }
        if let Some(v) = doc.get("cluster", "wire_f16_beta").and_then(|v| v.as_bool()) {
            cfg.wire_f16_beta = v;
        }
        if let Some(v) = doc.get("cluster", "workers") {
            // alias for [solver] machines; reject garbage (negative,
            // fractional) instead of silently ignoring it
            cfg.machines = v.as_usize().ok_or_else(|| {
                DlrError::Config("cluster.workers must be a non-negative integer".into())
            })?;
        }
        if let Some(s) = doc.get("data", "store").and_then(|v| v.as_str()) {
            cfg.store = Some(s.to_string());
        }
        if let Some(s) = doc.get("cluster", "transport").and_then(|v| v.as_str()) {
            cfg.transport = TransportKind::parse(s)
                .ok_or_else(|| DlrError::Config(format!("unknown transport '{s}'")))?;
        }
        if let Some(s) = doc.get("cluster", "listen").and_then(|v| v.as_str()) {
            cfg.listen = s.to_string();
        }
        if let Some(s) = doc.get("cluster", "topology").and_then(|v| v.as_str()) {
            cfg.topology = TopologyKind::parse(s)
                .ok_or_else(|| DlrError::Config(format!("unknown topology '{s}'")))?;
        }
        if let Some(v) = doc.get("cluster", "charge_beta_broadcast").and_then(|v| v.as_bool())
        {
            cfg.charge_beta_broadcast = v;
        }
        if let Some(v) = doc.get("cluster", "supervise").and_then(|v| v.as_bool()) {
            cfg.supervise = v;
        }
        if let Some(v) = num("cluster", "heartbeat_timeout_secs") {
            cfg.heartbeat_timeout_secs = v;
        }
        if let Some(v) = num("cluster", "recv_timeout_secs") {
            cfg.recv_timeout_secs = v;
        }
        if let Some(v) = doc.get("cluster", "recovery_checkpoint_every") {
            cfg.recovery_checkpoint_every = v.as_usize().ok_or_else(|| {
                DlrError::Config(
                    "cluster.recovery_checkpoint_every must be a positive integer".into(),
                )
            })?;
        }
        if let Some(v) = num("line_search", "backtrack") {
            cfg.line_search.backtrack = v;
        }
        if let Some(v) = num("line_search", "sigma") {
            cfg.line_search.sigma = v;
        }
        if let Some(v) = num("line_search", "gamma") {
            cfg.line_search.gamma = v;
        }
        if let Some(v) = doc.get("line_search", "skip_alpha_init").and_then(|v| v.as_bool()) {
            cfg.line_search.skip_alpha_init = v;
        }
        if let Some(v) = num("budget", "wall_secs") {
            cfg.budget.wall_secs = Some(v);
        }
        if let Some(v) = num("budget", "comm_bytes") {
            if v < 0.0 {
                return Err(DlrError::Config("budget.comm_bytes must be >= 0".into()));
            }
            cfg.budget.comm_bytes = Some(v as u64);
        }
        if let Some(v) = num("budget", "iterations") {
            if v < 0.0 {
                return Err(DlrError::Config("budget.iterations must be >= 0".into()));
            }
            cfg.budget.iterations = Some(v as usize);
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Builder for [`TrainConfig`].
pub struct TrainConfigBuilder(TrainConfig);

impl TrainConfigBuilder {
    pub fn lambda(mut self, v: f64) -> Self {
        self.0.lambda = v;
        self
    }
    pub fn family(mut self, v: FamilyKind) -> Self {
        self.0.family = v;
        self
    }
    pub fn enet_alpha(mut self, v: f64) -> Self {
        self.0.enet_alpha = v;
        self
    }
    pub fn nu(mut self, v: f64) -> Self {
        self.0.nu = v;
        self
    }
    pub fn max_iter(mut self, v: usize) -> Self {
        self.0.max_iter = v;
        self
    }
    pub fn tol(mut self, v: f64) -> Self {
        self.0.tol = v;
        self
    }
    pub fn machines(mut self, v: usize) -> Self {
        self.0.machines = v;
        self
    }
    pub fn block(mut self, v: usize) -> Self {
        self.0.block = v;
        self
    }
    pub fn engine(mut self, v: EngineKind) -> Self {
        self.0.engine = v;
        self
    }
    pub fn naive_sweep(mut self, v: bool) -> Self {
        self.0.naive_sweep = v;
        self
    }
    pub fn sweep_threads(mut self, v: usize) -> Self {
        self.0.sweep_threads = v;
        self
    }
    pub fn partition(mut self, v: PartitionStrategy) -> Self {
        self.0.partition = v;
        self
    }
    pub fn network(mut self, v: NetworkModel) -> Self {
        self.0.network = v;
        self
    }
    pub fn dense_allreduce(mut self, v: bool) -> Self {
        self.0.dense_allreduce = v;
        self
    }
    pub fn exchange(mut self, v: ExchangeStrategy) -> Self {
        self.0.exchange = v;
        self
    }
    pub fn wire_f16_margins(mut self, v: bool) -> Self {
        self.0.wire_f16_margins = v;
        self
    }
    pub fn wire_f16_beta(mut self, v: bool) -> Self {
        self.0.wire_f16_beta = v;
        self
    }
    pub fn store(mut self, v: impl Into<String>) -> Self {
        self.0.store = Some(v.into());
        self
    }
    pub fn transport(mut self, v: TransportKind) -> Self {
        self.0.transport = v;
        self
    }
    pub fn topology(mut self, v: TopologyKind) -> Self {
        self.0.topology = v;
        self
    }

    pub fn listen(mut self, v: impl Into<String>) -> Self {
        self.0.listen = v.into();
        self
    }
    pub fn charge_beta_broadcast(mut self, v: bool) -> Self {
        self.0.charge_beta_broadcast = v;
        self
    }
    pub fn supervise(mut self, v: bool) -> Self {
        self.0.supervise = v;
        self
    }
    pub fn heartbeat_timeout_secs(mut self, v: f64) -> Self {
        self.0.heartbeat_timeout_secs = v;
        self
    }
    pub fn recv_timeout_secs(mut self, v: f64) -> Self {
        self.0.recv_timeout_secs = v;
        self
    }
    pub fn recovery_checkpoint_every(mut self, v: usize) -> Self {
        self.0.recovery_checkpoint_every = v;
        self
    }
    pub fn line_search(mut self, v: LineSearchConfig) -> Self {
        self.0.line_search = v;
        self
    }
    pub fn budget(mut self, v: FitBudget) -> Self {
        self.0.budget = v;
        self
    }
    pub fn verbose(mut self, v: bool) -> Self {
        self.0.verbose = v;
        self
    }
    pub fn build(self) -> TrainConfig {
        self.0.validate().expect("invalid TrainConfig");
        self.0
    }
}

/// Regularization-path configuration (Alg 5).
#[derive(Debug, Clone)]
pub struct PathConfig {
    /// Number of halvings of lambda_max (paper: 20).
    pub steps: usize,
    /// Extra lambda values inserted (the paper adds 4 for dna).
    pub extra_lambdas: Vec<f64>,
    /// Per-lambda iteration cap (warmstarted fits converge fast).
    pub max_iter_per_lambda: usize,
}

impl Default for PathConfig {
    fn default() -> Self {
        Self { steps: 20, extra_lambdas: vec![], max_iter_per_lambda: 50 }
    }
}

/// Truncated-gradient online-learning baseline configuration (§4.3).
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    pub learning_rate: f64,
    pub decay: f64,
    pub passes: usize,
    /// L1 strength per example (VW's --l1; paper footnote 4: arg = lambda/n).
    pub l1_per_example: f64,
    /// Machines (example shards) for distributed averaging.
    pub machines: usize,
    pub seed: u64,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        Self {
            learning_rate: 0.1,
            decay: 0.5,
            passes: 10,
            l1_per_example: 1e-6,
            machines: 4,
            seed: 1,
        }
    }
}

/// `dglmnet serve` configuration (`[serve]` TOML section).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Bind address (`[serve] listen`). Port 0 picks an ephemeral port
    /// (the server prints the resolved address on its ready line).
    pub listen: String,
    /// Accept/worker threads handling connections (`[serve] threads`).
    pub threads: usize,
    /// Per-request example cap for `POST /predict_batch`
    /// (`[serve] max_batch`); larger batches get 413.
    pub max_batch: usize,
    /// Watch the model artifact and hot-swap on change (`[serve] watch`).
    pub watch: bool,
    /// Artifact poll cadence for the watcher thread, in seconds
    /// (`[serve] poll_interval_secs`).
    pub poll_interval_secs: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:4890".into(),
            threads: 4,
            max_batch: 1024,
            watch: true,
            poll_interval_secs: 0.5,
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.listen.is_empty() {
            return Err(DlrError::Config(
                "serve needs a [serve] listen = \"host:port\" address".into(),
            ));
        }
        if self.threads == 0 {
            return Err(DlrError::Config("serve.threads must be >= 1".into()));
        }
        if self.max_batch == 0 {
            return Err(DlrError::Config("serve.max_batch must be >= 1".into()));
        }
        if !self.poll_interval_secs.is_finite() || self.poll_interval_secs <= 0.0 {
            return Err(DlrError::Config(
                "serve.poll_interval_secs must be a positive number of seconds".into(),
            ));
        }
        Ok(())
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&toml::parse(&text)?)
    }

    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(s) = doc.get("serve", "listen").and_then(|v| v.as_str()) {
            cfg.listen = s.to_string();
        }
        if let Some(v) = doc.get("serve", "threads") {
            cfg.threads = v.as_usize().ok_or_else(|| {
                DlrError::Config("serve.threads must be a positive integer".into())
            })?;
        }
        if let Some(v) = doc.get("serve", "max_batch") {
            cfg.max_batch = v.as_usize().ok_or_else(|| {
                DlrError::Config("serve.max_batch must be a positive integer".into())
            })?;
        }
        if let Some(v) = doc.get("serve", "watch").and_then(|v| v.as_bool()) {
            cfg.watch = v;
        }
        if let Some(v) = doc.get("serve", "poll_interval_secs").and_then(|v| v.as_f64()) {
            cfg.poll_interval_secs = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_are_paper_constants() {
        let c = TrainConfig::builder().build();
        assert_eq!(c.line_search.backtrack, 0.5);
        assert_eq!(c.line_search.sigma, 0.01);
        assert_eq!(c.line_search.gamma, 0.0);
        assert_eq!(c.nu, 1e-6);
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let mut c = TrainConfig::default();
        c.lambda = -1.0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.nu = 0.0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.machines = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.block = 65;
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_toml_reads_all_sections() {
        let doc = toml::parse(
            r#"
[solver]
lambda = 0.25
machines = 8
engine = "native"
partition = "nnz"
[cluster]
bandwidth_gbps = 10.0
latency_us = 50.0
[line_search]
sigma = 0.05
skip_alpha_init = true
"#,
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.lambda, 0.25);
        assert_eq!(c.machines, 8);
        assert_eq!(c.engine, EngineKind::Native);
        assert_eq!(c.partition, PartitionStrategy::NnzBalanced);
        assert!((c.network.bandwidth_bytes_per_sec - 1.25e9).abs() < 1.0);
        assert_eq!(c.line_search.sigma, 0.05);
        assert!(c.line_search.skip_alpha_init);
    }

    #[test]
    fn from_toml_rejects_unknown_engine() {
        let doc = toml::parse("[solver]\nengine = \"gpu\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn exchange_and_wire_knobs_load_from_toml() {
        let c = TrainConfig::default();
        assert_eq!(c.exchange, ExchangeStrategy::Auto);
        assert!(!c.wire_f16_margins && !c.wire_f16_beta);
        let doc = toml::parse(
            "[cluster]\nexchange = \"allgather-beta\"\nwire_f16_margins = true\n",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.exchange, ExchangeStrategy::AllGatherBeta);
        assert!(c.wire_f16_margins);
        assert!(!c.wire_f16_beta);
        // short aliases parse too
        assert_eq!(ExchangeStrategy::parse("reduce"), Some(ExchangeStrategy::ReduceDm));
        assert_eq!(ExchangeStrategy::parse("allgather"), Some(ExchangeStrategy::AllGatherBeta));
        assert_eq!(ExchangeStrategy::parse("bogus"), None);
        // unknown strategy errors
        let doc = toml::parse("[cluster]\nexchange = \"ring\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        // dense_allreduce + allgather-beta is contradictory
        let mut c = TrainConfig::default();
        c.dense_allreduce = true;
        c.exchange = ExchangeStrategy::AllGatherBeta;
        assert!(c.validate().is_err());
        // so is a quantized Δβ wire + the exact local Δm recombination
        let mut c = TrainConfig::default();
        c.wire_f16_beta = true;
        c.exchange = ExchangeStrategy::AllGatherBeta;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.wire_f16_beta = true;
        c.exchange = ExchangeStrategy::ReduceDm;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn data_store_loads_from_toml() {
        assert!(TrainConfig::default().store.is_none());
        let doc = toml::parse("[data]\nstore = \"/var/shards/webspam\"\n").unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.store.as_deref(), Some("/var/shards/webspam"));
    }

    #[test]
    fn transport_and_workers_load_from_toml() {
        let c = TrainConfig::default();
        assert_eq!(c.transport, TransportKind::InProcess);
        assert!(!c.charge_beta_broadcast);
        let doc = toml::parse(
            "[cluster]\ntransport = \"socket\"\nlisten = \"127.0.0.1:9099\"\nworkers = 6\n",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.transport, TransportKind::Socket);
        assert_eq!(c.listen, "127.0.0.1:9099");
        assert_eq!(c.machines, 6);
        // aliases parse; unknown transports error
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Socket));
        assert_eq!(TransportKind::parse("threads"), Some(TransportKind::InProcess));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
        // topology: default star, tree loads from toml, aliases parse
        assert_eq!(TrainConfig::default().topology, TopologyKind::Star);
        let c = TrainConfig::from_toml(
            &toml::parse("[cluster]\ntransport = \"socket\"\ntopology = \"tree\"\n").unwrap(),
        )
        .unwrap();
        assert_eq!(c.topology, TopologyKind::Tree);
        assert_eq!(TopologyKind::parse("p2p"), Some(TopologyKind::Tree));
        assert_eq!(TopologyKind::parse("leader-star"), Some(TopologyKind::Star));
        assert_eq!(TopologyKind::parse("ring"), None);
        assert!(TrainConfig::from_toml(
            &toml::parse("[cluster]\ntopology = \"ring\"\n").unwrap()
        )
        .is_err());
        // the tree topology requires the lossless wire policy
        let mut bad = TrainConfig::default();
        bad.topology = TopologyKind::Tree;
        bad.wire_f16_margins = true;
        assert!(bad.validate().is_err());
        bad.wire_f16_margins = false;
        assert!(bad.validate().is_ok());
        let doc = toml::parse("[cluster]\ntransport = \"udp\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        // socket transport with an empty listen address is rejected
        let mut c = TrainConfig::default();
        c.transport = TransportKind::Socket;
        c.listen = String::new();
        assert!(c.validate().is_err());
    }

    #[test]
    fn worker_count_is_validated_against_the_feature_count() {
        // satellite bugfix: 0 and > feature-block-count worker counts fail
        // at config load / solver construction with a clear message
        let doc = toml::parse("[cluster]\nworkers = 0\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = toml::parse("[cluster]\nworkers = -2\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = toml::parse("[cluster]\nworkers = 3\n").unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert!(c.validate_machines_for(3).is_ok());
        let err = c.validate_machines_for(2).unwrap_err().to_string();
        assert!(err.contains("3 workers"), "{err}");
        assert!(err.contains("2 features"), "{err}");
    }

    #[test]
    fn supervision_knobs_load_from_toml_and_are_validated() {
        // fail-fast is the default: supervision is opt-in
        let c = TrainConfig::default();
        assert!(!c.supervise);
        assert_eq!(c.heartbeat_timeout_secs, 5.0);
        assert_eq!(c.recv_timeout_secs, 0.0);
        assert_eq!(c.recovery_checkpoint_every, 1);
        let doc = toml::parse(
            "[cluster]\nsupervise = true\nheartbeat_timeout_secs = 2.5\n\
             recv_timeout_secs = 10.0\nrecovery_checkpoint_every = 4\n",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert!(c.supervise);
        assert_eq!(c.heartbeat_timeout_secs, 2.5);
        assert_eq!(c.recv_timeout_secs, 10.0);
        assert_eq!(c.recovery_checkpoint_every, 4);
        // garbage knobs are rejected with clear messages
        let bad =
            TrainConfig { heartbeat_timeout_secs: 0.0, ..TrainConfig::default() };
        assert!(bad.validate().is_err());
        let bad = TrainConfig { recv_timeout_secs: -1.0, ..TrainConfig::default() };
        assert!(bad.validate().is_err());
        let bad =
            TrainConfig { recovery_checkpoint_every: 0, ..TrainConfig::default() };
        assert!(bad.validate().is_err());
        let doc = toml::parse("[cluster]\nrecovery_checkpoint_every = -2\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn serve_config_loads_from_toml_and_is_validated() {
        let c = ServeConfig::default();
        assert_eq!(c.listen, "127.0.0.1:4890");
        assert_eq!(c.threads, 4);
        assert_eq!(c.max_batch, 1024);
        assert!(c.watch);
        assert_eq!(c.poll_interval_secs, 0.5);
        let doc = toml::parse(
            "[serve]\nlisten = \"0.0.0.0:8080\"\nthreads = 8\nmax_batch = 64\n\
             watch = false\npoll_interval_secs = 0.1\n",
        )
        .unwrap();
        let c = ServeConfig::from_toml(&doc).unwrap();
        assert_eq!(c.listen, "0.0.0.0:8080");
        assert_eq!(c.threads, 8);
        assert_eq!(c.max_batch, 64);
        assert!(!c.watch);
        assert_eq!(c.poll_interval_secs, 0.1);
        // garbage knobs are rejected with clear messages
        let bad = ServeConfig { threads: 0, ..ServeConfig::default() };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { max_batch: 0, ..ServeConfig::default() };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { poll_interval_secs: 0.0, ..ServeConfig::default() };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { listen: String::new(), ..ServeConfig::default() };
        assert!(bad.validate().is_err());
        let doc = toml::parse("[serve]\nthreads = -1\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn sweep_kernel_knobs_load_from_toml_and_are_validated() {
        // defaults: cov kernel (naive_sweep = false), single-threaded sweep
        let c = TrainConfig::default();
        assert!(!c.naive_sweep);
        assert_eq!(c.sweep_threads, 1);
        let doc = toml::parse("[engine]\nsweep_threads = 4\nnaive_sweep = true\n").unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.sweep_threads, 4);
        assert!(c.naive_sweep);
        // 0 = auto is a valid setting
        let doc = toml::parse("[engine]\nsweep_threads = 0\n").unwrap();
        assert_eq!(TrainConfig::from_toml(&doc).unwrap().sweep_threads, 0);
        // garbage thread counts error, not saturate
        let doc = toml::parse("[engine]\nsweep_threads = -2\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        // explicit thread counts are validated against the narrowest shard
        let c = TrainConfig::builder().sweep_threads(4).build();
        assert!(c.validate_sweep_threads_for(4).is_ok());
        let err = c.validate_sweep_threads_for(3).unwrap_err().to_string();
        assert!(err.contains("sweep_threads = 4"), "{err}");
        assert!(err.contains("3 feature column(s)"), "{err}");
        assert!(err.contains("0 = auto"), "{err}");
        // auto never fails validation — it clamps at resolution time
        let c = TrainConfig::builder().sweep_threads(0).build();
        assert!(c.validate_sweep_threads_for(1).is_ok());
    }

    #[test]
    fn family_and_alpha_load_from_toml_and_are_validated() {
        // defaults: the paper's problem, untouched
        let c = TrainConfig::default();
        assert_eq!(c.family, FamilyKind::Logistic);
        assert_eq!(c.enet_alpha, 1.0);
        let doc = toml::parse("[train]\nfamily = \"poisson\"\nalpha = 0.5\n").unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.family, FamilyKind::Poisson);
        assert_eq!(c.enet_alpha, 0.5);
        // unknown family strings fail at load with an actionable message
        let doc = toml::parse("[train]\nfamily = \"tweedie\"\n").unwrap();
        let err = TrainConfig::from_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("tweedie") && err.contains("logistic"), "{err}");
        // alpha outside (0, 1] is rejected: 0, negative, > 1, NaN
        for bad in ["0.0", "-0.2", "1.5", "nan"] {
            let doc = toml::parse(&format!("[train]\nalpha = {bad}\n"));
            let Ok(doc) = doc else { continue };
            let err = TrainConfig::from_toml(&doc);
            assert!(err.is_err(), "alpha = {bad} should be rejected");
        }
        let err = TrainConfig { enet_alpha: 0.0, ..TrainConfig::default() }
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("(0, 1]"), "{err}");
        // the XLA kernels are logistic-only pure-L1: explicit combinations fail
        let bad = TrainConfig {
            engine: EngineKind::Xla,
            family: FamilyKind::Gaussian,
            ..TrainConfig::default()
        };
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("native"), "{err}");
        let bad = TrainConfig {
            engine: EngineKind::Xla,
            enet_alpha: 0.5,
            ..TrainConfig::default()
        };
        assert!(bad.validate().is_err());
        // auto is always fine — it resolves to native for new families
        let ok = TrainConfig {
            family: FamilyKind::Poisson,
            enet_alpha: 0.25,
            ..TrainConfig::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn budget_defaults_unlimited_and_loads_from_toml() {
        assert!(TrainConfig::default().budget.is_unlimited());
        let doc = toml::parse(
            "[budget]\nwall_secs = 1.5\ncomm_bytes = 1000000\niterations = 25\n",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.budget.wall_secs, Some(1.5));
        assert_eq!(c.budget.comm_bytes, Some(1_000_000));
        assert_eq!(c.budget.iterations, Some(25));
        let mut bad = TrainConfig::default();
        bad.budget.wall_secs = Some(-1.0);
        assert!(bad.validate().is_err());
        // negative TOML budgets must error, not saturate to 0
        let neg = toml::parse("[budget]\ncomm_bytes = -1\n").unwrap();
        assert!(TrainConfig::from_toml(&neg).is_err());
        let neg = toml::parse("[budget]\niterations = -3\n").unwrap();
        assert!(TrainConfig::from_toml(&neg).is_err());
    }
}
