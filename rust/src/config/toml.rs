//! Minimal TOML-subset parser (no `serde`/`toml` in the vendored set).
//! Supports what our config files use: `[section]` headers, `key = value`
//! with string / integer / float / bool / flat-array values, `#` comments.

use std::collections::BTreeMap;

use crate::error::{DlrError, Result};

/// A flat TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// `section -> key -> value`; keys before any `[section]` land in "".
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }
}

pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut current = String::new();
    doc.sections.entry(current.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let ctx = || format!("toml line {}", lineno + 1);
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .ok_or_else(|| DlrError::parse(ctx(), "unterminated section header"))?;
            current = name.trim().to_string();
            doc.sections.entry(current.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| DlrError::parse(ctx(), "expected key = value"))?;
        let v = parse_value(value.trim(), &ctx())?;
        doc.sections
            .get_mut(&current)
            .unwrap()
            .insert(key.trim().to_string(), v);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, ctx: &str) -> Result<TomlValue> {
    if s.is_empty() {
        return Err(DlrError::parse(ctx, "empty value"));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| DlrError::parse(ctx, "unterminated string"))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| DlrError::parse(ctx, "unterminated array"))?;
        let mut out = Vec::new();
        for item in split_top_level(inner) {
            let item = item.trim();
            if !item.is_empty() {
                out.push(parse_value(item, ctx)?);
            }
        }
        return Ok(TomlValue::Array(out));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(DlrError::parse(ctx, format!("cannot parse value '{s}'")))
}

fn split_top_level(s: &str) -> Vec<String> {
    // arrays are flat (no nesting needed), so a simple comma split with
    // string awareness suffices
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# top comment
name = "run1"
[solver]
lambda = 0.5        # inline comment
machines = 8
use_xla = true
alphas = [0.25, 0.5, 1.0]
[data]
path = "data/webspam.svm"   # has # inside? no
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = parse(DOC).unwrap();
        assert_eq!(d.get("", "name").unwrap().as_str(), Some("run1"));
        assert_eq!(d.get("solver", "lambda").unwrap().as_f64(), Some(0.5));
        assert_eq!(d.get("solver", "machines").unwrap().as_usize(), Some(8));
        assert_eq!(d.get("solver", "use_xla").unwrap().as_bool(), Some(true));
        let arr = match d.get("solver", "alphas").unwrap() {
            TomlValue::Array(a) => a,
            _ => panic!(),
        };
        assert_eq!(arr.len(), 3);
        assert_eq!(d.get("data", "path").unwrap().as_str(), Some("data/webspam.svm"));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let d = parse("k = \"a#b\"\n").unwrap();
        assert_eq!(d.get("", "k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("novalue\n").is_err());
        assert!(parse("k = \n").is_err());
        assert!(parse("k = \"open\n").is_err());
        assert!(parse("k = what\n").is_err());
    }

    #[test]
    fn negative_and_float_forms() {
        let d = parse("a = -3\nb = 1e-6\nc = -0.5\n").unwrap();
        assert_eq!(d.get("", "a").unwrap().as_f64(), Some(-3.0));
        assert_eq!(d.get("", "b").unwrap().as_f64(), Some(1e-6));
        assert_eq!(d.get("", "c").unwrap().as_f64(), Some(-0.5));
    }
}
