//! Deterministic pseudo-random generation: splitmix64 seeding +
//! xoshiro256++ core, with the distribution helpers the data generators and
//! baselines need. From scratch — the vendor set has no `rand`.

/// xoshiro256++ generator (Blackman & Vigna), seeded via splitmix64 so any
/// u64 seed yields a well-mixed state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
    /// Cached second normal from the last Box-Muller draw.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// The raw xoshiro256++ state — what a fit checkpoint persists so a
    /// resumed run continues the exact stream. Note: a cached Box-Muller
    /// spare from [`Xoshiro256::normal`] is *not* part of the state;
    /// checkpoint between paired normal draws and the resumed stream
    /// diverges by one normal (the integer/uniform stream is unaffected).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from [`Xoshiro256::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s, spare_normal: None }
    }

    /// Independent child stream `i` (for per-worker / per-shard RNGs).
    pub fn fork(&self, i: u64) -> Self {
        // Mix the child index through splitmix so forks don't correlate.
        let mut sm = self.s[0] ^ self.s[2] ^ i.wrapping_mul(0x9E3779B97F4A7C15);
        Self::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> exactly representable uniform double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// ±1 label with P(+1) = p.
    pub fn label(&mut self, p_pos: f64) -> f32 {
        if self.bernoulli(p_pos) {
            1.0
        } else {
            -1.0
        }
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Zipf-ish popularity rank in [0, n): P(rank) ∝ 1/(rank+1)^s, via
    /// inverse-CDF of the continuous approximation ∫ t^-s dt over [1, n+1]
    /// (valid for any s ≥ 0; good enough for data synthesis).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let u = self.uniform();
        let x = if (s - 1.0).abs() < 1e-9 {
            // F(x) = ln(x)/ln(n+1)  =>  x = (n+1)^u
            ((n + 1) as f64).powf(u)
        } else {
            // F(x) = (x^(1-s) - 1)/((n+1)^(1-s) - 1)
            let e = 1.0 - s;
            let top = ((n + 1) as f64).powf(e);
            (1.0 + u * (top - 1.0)).powf(1.0 / e)
        };
        ((x as usize).saturating_sub(1)).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut a = Xoshiro256::new(123);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Xoshiro256::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_do_not_collide() {
        let root = Xoshiro256::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Xoshiro256::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts = {counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.02, "var = {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::new(6);
        let idx = r.sample_indices(50, 20);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Xoshiro256::new(8);
        let mut head = 0;
        for _ in 0..10_000 {
            let z = r.zipf(1000, 1.1);
            assert!(z < 1000);
            if z < 100 {
                head += 1;
            }
        }
        // a zipf(1.1) should put well over half its mass in the first decile
        assert!(head > 5_000, "head = {head}");
    }
}
