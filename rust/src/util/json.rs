//! Minimal recursive-descent JSON parser — enough for `artifacts/manifest.json`
//! (and model/report round-trips). From scratch: the vendor set has no
//! `serde_json`.

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{DlrError, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `obj["k"]` access that threads Option.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write!(f, "\"{}\"", escape(s)),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "\"{}\":{}", escape(k), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> DlrError {
        DlrError::parse(format!("json offset {}", self.pos), msg)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number '{s}'")))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
          "version": 1,
          "units": [
            {"name": "cd_sweep_n1024_b64", "file": "x.hlo.txt",
             "inputs": [[1024, 64], [1024]], "outputs": [[64], [1024]]}
          ],
          "n_sizes": [1024, 4096]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let units = v.get("units").unwrap().as_arr().unwrap();
        assert_eq!(units.len(), 1);
        assert_eq!(
            units[0].get("name").unwrap().as_str(),
            Some("cd_sweep_n1024_b64")
        );
        let ins = units[0].get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parses_scalars_and_escapes() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn display_roundtrip() {
        let doc = r#"{"a":[1,2.5,"s"],"b":{"c":null,"d":false}}"#;
        let v = parse(doc).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v, Json::Str("héllo ☃".into()));
    }
}
