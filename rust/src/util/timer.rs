//! Lightweight wall-clock timers and a per-phase accumulator used for the
//! Table-3 timing breakdown (total time, time per iteration, % line search).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One-shot stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulates named phase durations (thread-compatible; the solver owns one
/// per fit and merges worker-side phases after joins).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    totals: BTreeMap<&'static str, Duration>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `phase`.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn add(&mut self, phase: &'static str, d: Duration) {
        *self.totals.entry(phase).or_default() += d;
    }

    pub fn get(&self, phase: &str) -> Duration {
        self.totals.get(phase).copied().unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.totals.values().sum()
    }

    /// Fraction of total time spent in `phase` (0 when nothing recorded).
    pub fn fraction(&self, phase: &str) -> f64 {
        let total = self.total().as_secs_f64();
        if total == 0.0 {
            0.0
        } else {
            self.get(phase).as_secs_f64() / total
        }
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.totals {
            self.add(k, *v);
        }
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, Duration)> + '_ {
        self.totals.iter().map(|(k, v)| (*k, *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate_and_merge() {
        let mut t = PhaseTimer::new();
        t.add("sweep", Duration::from_millis(30));
        t.add("sweep", Duration::from_millis(20));
        t.add("line_search", Duration::from_millis(50));
        assert_eq!(t.get("sweep"), Duration::from_millis(50));
        assert!((t.fraction("line_search") - 0.5).abs() < 1e-9);

        let mut u = PhaseTimer::new();
        u.add("sweep", Duration::from_millis(10));
        t.merge(&u);
        assert_eq!(t.get("sweep"), Duration::from_millis(60));
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = PhaseTimer::new();
        let x = t.time("work", || 21 * 2);
        assert_eq!(x, 42);
        assert!(t.get("work") > Duration::ZERO);
    }
}
