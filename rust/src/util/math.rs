//! Numerically careful scalar/vector math shared by the solver, engines and
//! baselines. Mirrors the formulas in `python/compile/kernels/ref.py` so the
//! native engine and the XLA engine agree bit-for-tolerance.

/// Guard used when dividing by w = p(1-p) on saturated examples.
pub const W_EPS: f64 = 1e-10;

/// sigmoid(x) without overflow on either tail.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// log(1 + exp(x)) without overflow.
#[inline]
pub fn log1pexp(x: f64) -> f64 {
    if x > 0.0 {
        x + (-x).exp().ln_1p()
    } else {
        x.exp().ln_1p()
    }
}

/// Soft-thresholding operator T(x, a) = sign(x) max(|x| - a, 0)  (eq. (6)).
#[inline]
pub fn soft_threshold(x: f64, a: f64) -> f64 {
    if x > a {
        x - a
    } else if x < -a {
        x + a
    } else {
        0.0
    }
}

/// Per-example logistic loss log(1 + exp(-y m)).
#[inline]
pub fn logistic_loss(y: f64, margin: f64) -> f64 {
    log1pexp(-y * margin)
}

/// Masked logistic loss sum over example margins.
pub fn logloss_sum(margins: &[f32], y: &[f32]) -> f64 {
    margins
        .iter()
        .zip(y)
        .map(|(&m, &yy)| logistic_loss(yy as f64, m as f64))
        .sum()
}

/// L1 norm of a sparse-ish dense vector.
pub fn l1_norm(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64).abs()).sum()
}

/// Squared L2 norm ‖v‖₂² in f64 accumulation (the elastic-net ridge term).
pub fn sq_norm(v: &[f32]) -> f64 {
    v.iter().map(|&x| x as f64 * x as f64).sum()
}

/// Number of non-zeros (exact zero; the solver produces exact zeros via
/// soft-thresholding, so no epsilon is needed).
pub fn nnz(v: &[f32]) -> usize {
    v.iter().filter(|&&x| x != 0.0).count()
}

/// dot in f64 accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// GLMNET working stats for one example (paper eq. (4)):
/// returns (w, z) given margin m and label y.
#[inline]
pub fn working_stats(y: f64, margin: f64) -> (f64, f64) {
    let p = sigmoid(margin);
    let w = p * (1.0 - p);
    let z = ((y + 1.0) / 2.0 - p) / w.max(W_EPS);
    (w, z)
}

/// Σ_k vals[k] · dense[rows[k]] with four independent f64 accumulators (the
/// SIMD-shaped gather-dot on a sparse column). The combine order
/// `(s0 + s1) + (s2 + s3)` plus a sequential tail is FIXED: `lambda_max_local`
/// on every engine and the leader-side `regpath::lambda_max` both call this
/// helper, and their per-feature results are pinned bit-identical.
#[inline]
pub fn gather_dot4(rows: &[u32], vals: &[f32], dense: &[f32]) -> f64 {
    let (mut s0, mut s1, mut s2, mut s3) = (0f64, 0f64, 0f64, 0f64);
    let chunks = rows.len() / 4;
    for k in 0..chunks {
        let b = 4 * k;
        s0 += vals[b] as f64 * dense[rows[b] as usize] as f64;
        s1 += vals[b + 1] as f64 * dense[rows[b + 1] as usize] as f64;
        s2 += vals[b + 2] as f64 * dense[rows[b + 2] as usize] as f64;
        s3 += vals[b + 3] as f64 * dense[rows[b + 3] as usize] as f64;
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for k in 4 * chunks..rows.len() {
        acc += vals[k] as f64 * dense[rows[k] as usize] as f64;
    }
    acc
}

/// `gather_dot4` against an f64 gather source (the covariance kernel's
/// precomputed `w·z` products). Same fixed combine order.
#[inline]
pub fn gather_dot4_f64(rows: &[u32], vals: &[f32], dense: &[f64]) -> f64 {
    let (mut s0, mut s1, mut s2, mut s3) = (0f64, 0f64, 0f64, 0f64);
    let chunks = rows.len() / 4;
    for k in 0..chunks {
        let b = 4 * k;
        s0 += vals[b] as f64 * dense[rows[b] as usize];
        s1 += vals[b + 1] as f64 * dense[rows[b + 1] as usize];
        s2 += vals[b + 2] as f64 * dense[rows[b + 2] as usize];
        s3 += vals[b + 3] as f64 * dense[rows[b + 3] as usize];
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for k in 4 * chunks..rows.len() {
        acc += vals[k] as f64 * dense[rows[k] as usize];
    }
    acc
}

/// Σ_k w[rows[k]] · vals[k]² — the weighted squared column norm `Σ w x²`
/// behind every CD denominator, 4-way unrolled like [`gather_dot4`].
#[inline]
pub fn weighted_sq_norm4(rows: &[u32], vals: &[f32], w: &[f32]) -> f64 {
    let (mut s0, mut s1, mut s2, mut s3) = (0f64, 0f64, 0f64, 0f64);
    let chunks = rows.len() / 4;
    for k in 0..chunks {
        let b = 4 * k;
        let (x0, x1) = (vals[b] as f64, vals[b + 1] as f64);
        let (x2, x3) = (vals[b + 2] as f64, vals[b + 3] as f64);
        s0 += w[rows[b] as usize] as f64 * x0 * x0;
        s1 += w[rows[b + 1] as usize] as f64 * x1 * x1;
        s2 += w[rows[b + 2] as usize] as f64 * x2 * x2;
        s3 += w[rows[b + 3] as usize] as f64 * x3 * x3;
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for k in 4 * chunks..rows.len() {
        let x = vals[k] as f64;
        acc += w[rows[k] as usize] as f64 * x * x;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_tails_and_center() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(40.0) > 1.0 - 1e-12);
        assert!(sigmoid(-40.0) < 1e-12);
        assert!(sigmoid(800.0).is_finite());
        assert!(sigmoid(-800.0).is_finite());
    }

    #[test]
    fn log1pexp_matches_naive_in_safe_range() {
        for &x in &[-30.0, -1.0, 0.0, 1.0, 30.0] {
            let naive = (1.0f64 + f64::exp(x)).ln();
            assert!((log1pexp(x) - naive).abs() < 1e-9, "x = {x}");
        }
        assert!((log1pexp(1000.0) - 1000.0).abs() < 1e-9);
        assert!(log1pexp(-1000.0).abs() < 1e-12);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn working_stats_at_zero_margin() {
        let (w, z) = working_stats(1.0, 0.0);
        assert!((w - 0.25).abs() < 1e-12);
        assert!((z - 2.0).abs() < 1e-12);
        let (w, z) = working_stats(-1.0, 0.0);
        assert!((w - 0.25).abs() < 1e-12);
        assert!((z + 2.0).abs() < 1e-12);
    }

    #[test]
    fn working_stats_saturated_is_finite() {
        let (w, z) = working_stats(1.0, 100.0);
        assert!(w >= 0.0 && w.is_finite());
        assert!(z.is_finite());
    }

    #[test]
    fn unrolled_gather_dots_match_serial_to_fp_tolerance() {
        // deterministic pseudo-random column (no external RNG in the crate)
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        let n = 257usize; // odd tail exercises the remainder loop
        let dense: Vec<f32> = (0..n).map(|_| (next() - 0.5) as f32).collect();
        let dense64: Vec<f64> = dense.iter().map(|&v| v as f64).collect();
        let rows: Vec<u32> = (0..n as u32).step_by(2).collect();
        let vals: Vec<f32> = rows.iter().map(|_| (next() * 2.0 - 1.0) as f32).collect();
        let serial: f64 =
            rows.iter().zip(&vals).map(|(&i, &v)| v as f64 * dense[i as usize] as f64).sum();
        assert!((gather_dot4(&rows, &vals, &dense) - serial).abs() < 1e-10);
        assert!((gather_dot4_f64(&rows, &vals, &dense64) - serial).abs() < 1e-10);
        let w: Vec<f32> = (0..n).map(|_| next() as f32 * 0.25).collect();
        let serial_sq: f64 = rows
            .iter()
            .zip(&vals)
            .map(|(&i, &v)| w[i as usize] as f64 * v as f64 * v as f64)
            .sum();
        assert!((weighted_sq_norm4(&rows, &vals, &w) - serial_sq).abs() < 1e-10);
        // empty and sub-unroll-width inputs hit only the tail path
        assert_eq!(gather_dot4(&[], &[], &dense), 0.0);
        assert_eq!(
            gather_dot4(&rows[..3], &vals[..3], &dense),
            (0..3).map(|k| vals[k] as f64 * dense[rows[k] as usize] as f64).sum::<f64>()
        );
    }

    #[test]
    fn vector_helpers() {
        let a = [1.0f32, 2.0, -3.0];
        let b = [4.0f32, -5.0, 6.0];
        assert!((dot(&a, &b) + 24.0).abs() < 1e-9);
        assert!((l1_norm(&a) - 6.0).abs() < 1e-9);
        assert_eq!(nnz(&[0.0, 1.0, 0.0, -2.0]), 2);
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }
}
