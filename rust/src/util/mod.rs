//! Substrate utilities built from scratch (the vendored dependency set has
//! no `rand`, `serde`, `serde_json` or `criterion`): a counter-based PRNG,
//! numerically careful math helpers, a JSON parser for the artifact
//! manifest, and lightweight timers.

pub mod json;
pub mod math;
pub mod rng;
pub mod timer;

/// Peak resident-set size of this process in bytes, self-read from
/// `/proc/self/status` (`VmHWM`). Returns `None` on platforms without
/// procfs — callers treat the measurement as best-effort. This is the
/// number the out-of-core leader gates on: a leader driving a fit from a
/// sharded store must stay far below the full-dataset watermark
/// (`scripts/check_bench_regression.py` + the socket_e2e CI job).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Round `n` up to the next multiple of `k` (tile padding).
pub fn round_up(n: usize, k: usize) -> usize {
    debug_assert!(k > 0);
    n.div_ceil(k) * k
}

/// Smallest element of `candidates` that is `>= n`; falls back to the
/// largest candidate when none fits (caller then tiles the data).
pub fn pick_padded(n: usize, candidates: &[usize]) -> usize {
    let mut best: Option<usize> = None;
    for &c in candidates {
        if c >= n && best.is_none_or(|b| c < b) {
            best = Some(c);
        }
    }
    best.unwrap_or_else(|| candidates.iter().copied().max().unwrap_or(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(1, 64), 64);
        assert_eq!(round_up(64, 64), 64);
        assert_eq!(round_up(65, 64), 128);
    }

    #[test]
    fn peak_rss_reads_a_sane_value_on_linux() {
        if std::path::Path::new("/proc/self/status").exists() {
            let rss = peak_rss_bytes().expect("procfs present but VmHWM unreadable");
            // a running test binary occupies at least a few pages and less
            // than a terabyte
            assert!(rss > 64 * 1024, "{rss}");
            assert!(rss < (1u64 << 40), "{rss}");
        }
    }

    #[test]
    fn pick_padded_prefers_smallest_fit() {
        let c = [1024, 4096, 16384];
        assert_eq!(pick_padded(10, &c), 1024);
        assert_eq!(pick_padded(1024, &c), 1024);
        assert_eq!(pick_padded(1025, &c), 4096);
        assert_eq!(pick_padded(100_000, &c), 16384); // caller must tile
    }
}
