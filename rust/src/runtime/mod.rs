//! PJRT runtime: load AOT HLO-text artifacts, compile them once per
//! process-simulated machine, execute them from the rust hot path.
//!
//! Gotchas encoded here (see /opt/xla-example/README.md):
//! * interchange is HLO **text** — `HloModuleProto::from_text_file`
//!   reassigns instruction ids; serialized protos from jax >= 0.5 would be
//!   rejected by xla_extension 0.5.1.
//! * modules are lowered with `return_tuple=True`, so every execution
//!   returns a 1-tuple/выше literal that we untuple here.
//! * `PjRtClient` is not `Send`: each simulated machine (worker thread)
//!   owns its own client, which also mirrors the paper's per-machine
//!   processes.

pub mod artifacts;

#[cfg(feature = "xla")]
use std::collections::HashMap;

pub use artifacts::{default_artifacts_dir, Manifest, UnitMeta};

#[cfg(feature = "xla")]
use crate::error::{DlrError, Result};

/// A per-thread PJRT context: client + compiled-executable cache.
/// Only available with the `xla` feature (vendored PJRT bindings).
#[cfg(feature = "xla")]
pub struct XlaContext {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "xla")]
impl XlaContext {
    /// Build a CPU PJRT client and attach the manifest at `dir`.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the unit named `name`.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let unit = self
            .manifest
            .units
            .iter()
            .find(|u| u.name == name)
            .ok_or_else(|| DlrError::Artifact(format!("unknown unit '{name}'")))?;
        let path = self.manifest.hlo_path(unit);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute unit `name` on `inputs`; returns the untupled output literals.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &mut self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute::<L>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Convenience: run and convert every output to `Vec<f32>`.
    pub fn run_f32<L: std::borrow::Borrow<xla::Literal>>(
        &mut self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<Vec<f32>>> {
        self.run(name, inputs)?
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }

    pub fn compiled_units(&self) -> usize {
        self.cache.len()
    }
}

/// f32 vector literal.
#[cfg(feature = "xla")]
pub fn lit_vec(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Row-major (rows × cols) f32 matrix literal.
#[cfg(feature = "xla")]
pub fn lit_mat(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(Into::into)
}

/// Copy `src` into a zero-padded buffer of length `n_pad`.
pub fn pad_to(src: &[f32], n_pad: usize) -> Vec<f32> {
    debug_assert!(src.len() <= n_pad);
    let mut out = vec![0f32; n_pad];
    out[..src.len()].copy_from_slice(src);
    out
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;

    fn ctx() -> Option<XlaContext> {
        XlaContext::new(default_artifacts_dir()).ok()
    }

    #[test]
    fn stats_unit_executes_and_matches_native() {
        let Some(mut ctx) = ctx() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let n_pad = 1024usize;
        let n = 100usize;
        let mut margins = vec![0f32; n_pad];
        let mut y = vec![0f32; n_pad];
        let mut mask = vec![0f32; n_pad];
        for i in 0..n {
            margins[i] = (i as f32 / 25.0) - 2.0;
            y[i] = if i % 3 == 0 { 1.0 } else { -1.0 };
            mask[i] = 1.0;
        }
        let out = ctx
            .run_f32("stats_n1024", &[lit_vec(&margins), lit_vec(&y), lit_vec(&mask)])
            .unwrap();
        assert_eq!(out.len(), 3);
        let (w, z, loss) = (&out[0], &out[1], &out[2]);
        assert_eq!(w.len(), n_pad);
        assert_eq!(loss.len(), 1);
        // native comparison
        let mut loss_want = 0f64;
        for i in 0..n {
            let (ww, zz) = crate::util::math::working_stats(y[i] as f64, margins[i] as f64);
            assert!((w[i] as f64 - ww).abs() < 1e-4, "w[{i}]");
            assert!((z[i] as f64 - zz).abs() < 2e-3 * (1.0 + zz.abs()), "z[{i}]");
            loss_want += crate::util::math::logistic_loss(y[i] as f64, margins[i] as f64);
        }
        assert!((loss[0] as f64 - loss_want).abs() / loss_want < 1e-4);
        // padded region inert
        assert!(w[n..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn cd_sweep_unit_matches_native_math() {
        let Some(mut ctx) = ctx() else {
            return;
        };
        let (n_pad, b) = (1024usize, 64usize);
        let n = 50usize;
        let mut rngstate = 0x12345u64;
        let mut next = move || {
            rngstate = rngstate.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((rngstate >> 33) as f32 / (1u64 << 31) as f32) - 1.0
        };
        let mut xt = vec![0f32; n_pad * b];
        for i in 0..n {
            for j in 0..8 {
                // only first 8 columns non-zero
                xt[i * b + j] = next();
            }
        }
        let mut w = vec![0f32; n_pad];
        let mut r = vec![0f32; n_pad];
        for i in 0..n {
            w[i] = 0.25;
            r[i] = 2.0 * next();
        }
        let beta = vec![0f32; b];
        let delta = vec![0f32; b];
        let (lam, nu) = (0.05f32, 1e-6f32);
        let out = ctx
            .run_f32(
                "cd_sweep_n1024_b64",
                &[
                    lit_mat(&xt, n_pad, b).unwrap(),
                    lit_vec(&w),
                    lit_vec(&r),
                    lit_vec(&beta),
                    lit_vec(&delta),
                    lit_vec(&[lam]),
                    lit_vec(&[nu]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        let (delta_out, r_out) = (&out[0], &out[1]);
        assert_eq!(delta_out.len(), b);
        assert_eq!(r_out.len(), n_pad);
        // columns 8.. are all-zero => exactly zero updates
        assert!(delta_out[8..].iter().all(|&v| v == 0.0));
        // native single-sweep reference
        let mut r_ref: Vec<f64> = r.iter().map(|&x| x as f64).collect();
        let mut delta_ref = vec![0f64; b];
        for j in 0..8 {
            let col: Vec<f64> = (0..n).map(|i| xt[i * b + j] as f64).collect();
            let a: f64 =
                col.iter().enumerate().map(|(i, &x)| w[i] as f64 * x * x).sum::<f64>() + nu as f64;
            let c: f64 = col
                .iter()
                .enumerate()
                .map(|(i, &x)| w[i] as f64 * r_ref[i] * x)
                .sum::<f64>()
                + delta_ref[j] * (a - nu as f64);
            let s = crate::util::math::soft_threshold(c, lam as f64) / a;
            let step = s - delta_ref[j];
            delta_ref[j] = s;
            for (i, &x) in col.iter().enumerate() {
                r_ref[i] -= step * x;
            }
        }
        for j in 0..8 {
            assert!(
                (delta_out[j] as f64 - delta_ref[j]).abs() < 5e-4 * (1.0 + delta_ref[j].abs()),
                "delta[{j}] = {} vs {}",
                delta_out[j],
                delta_ref[j]
            );
        }
    }

    #[test]
    fn line_search_unit_evaluates_grid() {
        let Some(mut ctx) = ctx() else {
            return;
        };
        let n_pad = 1024usize;
        let n = 200usize;
        let mut m = vec![0f32; n_pad];
        let mut dm = vec![0f32; n_pad];
        let mut y = vec![0f32; n_pad];
        let mut mask = vec![0f32; n_pad];
        for i in 0..n {
            m[i] = -0.5 + (i as f32) / 200.0;
            dm[i] = 0.3;
            y[i] = if i % 2 == 0 { 1.0 } else { -1.0 };
            mask[i] = 1.0;
        }
        let alphas: Vec<f32> = (0..16).map(|k| k as f32 / 15.0).collect();
        let out = ctx
            .run_f32(
                "line_search_n1024_k16",
                &[lit_vec(&m), lit_vec(&dm), lit_vec(&y), lit_vec(&mask), lit_vec(&alphas)],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let losses = &out[0];
        assert_eq!(losses.len(), 16);
        // alpha = 0 must equal the plain masked logloss
        let want0: f64 = (0..n)
            .map(|i| crate::util::math::logistic_loss(y[i] as f64, m[i] as f64))
            .sum();
        assert!((losses[0] as f64 - want0).abs() / want0 < 1e-4);
        // all finite and positive
        assert!(losses.iter().all(|&l| l.is_finite() && l > 0.0));
    }

    #[test]
    fn executable_cache_hits() {
        let Some(mut ctx) = ctx() else {
            return;
        };
        assert_eq!(ctx.compiled_units(), 0);
        ctx.ensure_compiled("stats_n1024").unwrap();
        ctx.ensure_compiled("stats_n1024").unwrap();
        assert_eq!(ctx.compiled_units(), 1);
    }
}
