//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. The manifest records every AOT unit (function, padded
//! shapes, file) so shape selection is data-driven, never hardcoded.

use std::path::{Path, PathBuf};

use crate::error::{DlrError, Result};
use crate::util::json::{self, Json};

/// One AOT-compiled HLO module.
#[derive(Debug, Clone)]
pub struct UnitMeta {
    pub name: String,
    pub file: String,
    /// Logical function: "stats" | "cd_sweep" | "line_search" | "matvec".
    pub fn_name: String,
    pub n: usize,
    pub b: Option<usize>,
    pub k: Option<usize>,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub n_sizes: Vec<usize>,
    pub b_sizes: Vec<usize>,
    pub k_alphas: usize,
    pub units: Vec<UnitMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            DlrError::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let doc = json::parse(&text)?;
        let version = doc.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            return Err(DlrError::Artifact(format!("unsupported manifest version {version}")));
        }
        let usizes = |key: &str| -> Vec<usize> {
            doc.get(key)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default()
        };
        let mut units = Vec::new();
        for u in doc
            .get("units")
            .and_then(Json::as_arr)
            .ok_or_else(|| DlrError::Artifact("manifest missing units".into()))?
        {
            let get_str = |k: &str| -> Result<String> {
                u.get(k)
                    .and_then(Json::as_str)
                    .map(String::from)
                    .ok_or_else(|| DlrError::Artifact(format!("unit missing '{k}'")))
            };
            let shapes = |k: &str| -> Vec<Vec<usize>> {
                u.get(k)
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(Json::as_arr)
                            .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
                            .collect()
                    })
                    .unwrap_or_default()
            };
            units.push(UnitMeta {
                name: get_str("name")?,
                file: get_str("file")?,
                fn_name: get_str("fn")?,
                n: u
                    .get("n")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| DlrError::Artifact("unit missing 'n'".into()))?,
                b: u.get("b").and_then(Json::as_usize),
                k: u.get("k").and_then(Json::as_usize),
                inputs: shapes("inputs"),
                outputs: shapes("outputs"),
            });
        }
        Ok(Self { dir, n_sizes: usizes("n_sizes"), b_sizes: usizes("b_sizes"), k_alphas: doc.get("k_alphas").and_then(Json::as_usize).unwrap_or(16), units })
    }

    /// Smallest compiled `n` that fits `n_needed` (error when too large).
    pub fn pick_n(&self, n_needed: usize) -> Result<usize> {
        self.n_sizes
            .iter()
            .copied()
            .filter(|&c| c >= n_needed)
            .min()
            .ok_or_else(|| {
                DlrError::Artifact(format!(
                    "no compiled n >= {n_needed} (available: {:?}); use the native engine",
                    self.n_sizes
                ))
            })
    }

    /// Smallest compiled block width >= `b_needed`.
    pub fn pick_b(&self, b_needed: usize) -> Result<usize> {
        self.b_sizes
            .iter()
            .copied()
            .filter(|&c| c >= b_needed)
            .min()
            .or_else(|| self.b_sizes.iter().copied().max())
            .ok_or_else(|| DlrError::Artifact("manifest has no block sizes".into()))
    }

    /// Find the unit for (fn, n[, b]).
    pub fn find(&self, fn_name: &str, n: usize, b: Option<usize>) -> Result<&UnitMeta> {
        self.units
            .iter()
            .find(|u| u.fn_name == fn_name && u.n == n && u.b == b)
            .ok_or_else(|| {
                DlrError::Artifact(format!("no unit for fn={fn_name} n={n} b={b:?}"))
            })
    }

    pub fn hlo_path(&self, unit: &UnitMeta) -> PathBuf {
        self.dir.join(&unit.file)
    }
}

/// Default artifacts directory: `$DGLMNET_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("DGLMNET_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_available() -> Option<Manifest> {
        Manifest::load(default_artifacts_dir()).ok()
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(m) = manifest_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(!m.units.is_empty());
        assert!(m.n_sizes.contains(&1024));
        let u = m.find("cd_sweep", 1024, Some(64)).unwrap();
        assert!(m.hlo_path(u).exists());
        assert_eq!(u.outputs.len(), 2);
        let s = m.find("stats", 4096, None).unwrap();
        assert_eq!(s.outputs.len(), 3);
    }

    #[test]
    fn pick_n_and_b() {
        let Some(m) = manifest_available() else {
            return;
        };
        assert_eq!(m.pick_n(1).unwrap(), 1024);
        assert_eq!(m.pick_n(5_000).unwrap(), 16384);
        assert!(m.pick_n(10_000_000).is_err());
        assert_eq!(m.pick_b(64).unwrap(), 64);
        assert_eq!(m.pick_b(100).unwrap(), 128);
    }

    #[test]
    fn missing_dir_is_actionable_error() {
        let e = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(e.to_string().contains("make artifacts"));
    }
}
