//! Reporting substrate: ASCII tables (the paper's Tables 2/3) and CSV
//! series writers (Figure 1 curves), shared by the CLI, examples and
//! benches.

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// Column-aligned ASCII table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep = |w: &Vec<usize>| -> String {
            let mut s = String::from("+");
            for &wi in w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..cols {
                s.push_str(&format!(" {:<width$} |", cells[i], width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep(&widths));
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep(&widths));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep(&widths));
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// A named (x, y) series — one curve of Figure 1.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), points: vec![] }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// Upper envelope: best y seen at or below each x (the paper's Figure 1
    /// compares frontiers — for VW each (nnz, auprc) point from the grid is
    /// plotted, but the comparison statement is about the envelope).
    pub fn pareto_envelope(&self) -> Series {
        let mut pts = self.points.clone();
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut out = Series::new(format!("{}-envelope", self.name));
        let mut best = f64::NEG_INFINITY;
        for (x, y) in pts {
            if y > best {
                best = y;
                out.push(x, best);
            }
        }
        out
    }
}

/// Write series as tidy CSV: `series,x,y`.
pub fn write_series_csv(path: impl AsRef<Path>, series: &[Series]) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "series,x,y")?;
    for s in series {
        for (x, y) in &s.points {
            writeln!(f, "{},{},{}", s.name, x, y)?;
        }
    }
    f.flush()?;
    Ok(())
}

/// Render series as a coarse ASCII scatter for terminal inspection.
pub fn ascii_scatter(series: &[Series], width: usize, height: usize) -> String {
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.clone()).collect();
    if all.is_empty() {
        return String::from("(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 <= x0 {
        x1 = x0 + 1.0;
    }
    if y1 <= y0 {
        y1 = y0 + 1.0;
    }
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        for &(x, y) in &s.points {
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} = {}\n", marks[si % marks.len()], s.name));
    }
    out.push_str(&format!(
        "  x: [{x0:.3}, {x1:.3}]  y: [{y0:.4}, {y1:.4}]\n"
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Datasets", &["name", "n", "p"]);
        t.add_row(vec!["epsilon_like".into(), "8000".into(), "512".into()]);
        t.add_row(vec!["dna_like".into(), "40000".into(), "400".into()]);
        let r = t.render();
        assert!(r.contains("| name         | n     | p   |"), "{r}");
        assert!(r.lines().count() >= 6);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.add_row(vec!["1".into()]);
    }

    #[test]
    fn pareto_envelope_is_monotone() {
        let mut s = Series::new("vw");
        for &(x, y) in &[(10.0, 0.5), (5.0, 0.6), (20.0, 0.55), (30.0, 0.7)] {
            s.push(x, y);
        }
        let env = s.pareto_envelope();
        let ys: Vec<f64> = env.points.iter().map(|p| p.1).collect();
        assert!(ys.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(env.points.first().unwrap().0, 5.0);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join(format!("dglmnet_csv_{}", std::process::id()));
        let p = dir.join("fig.csv");
        let mut s = Series::new("a");
        s.push(1.0, 2.0);
        write_series_csv(&p, &[s]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "series,x,y\na,1,2\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scatter_contains_marks() {
        let mut s = Series::new("a");
        s.push(0.0, 0.0);
        s.push(1.0, 1.0);
        let plot = ascii_scatter(&[s], 20, 10);
        assert!(plot.contains('*'));
    }
}
