//! The leader's handle to its M worker nodes — every interaction goes
//! through the serializable node protocol
//! ([`NodeMessage`](crate::cluster::protocol::NodeMessage)) over a
//! [`Transport`] per worker, so the same driver runs against in-process
//! worker threads and against remote worker processes:
//!
//! * [`WorkerPool::spawn`] — one thread per shard (paper Alg 4 "do in
//!   parallel over M machines"), each building its engine inside its own
//!   thread (PJRT clients are thread-bound) and wrapping a
//!   [`WorkerNode`]; messages move over in-process channels without
//!   serialization, so the [`SweepResult`] buffers round-trip through the
//!   `Sweep.recycle` slot and steady-state iterations allocate nothing.
//! * [`WorkerPool::listen_and_accept`] — remote workers (launched with the
//!   `dglmnet worker` CLI subcommand) connect over TCP; the handshake
//!   validates each node's shard identity (machine index, dataset shape,
//!   owned-column checksum) before admission.
//!
//! Workers hold their own β shard and margins (see
//! [`crate::cluster::node`]): a sweep request carries only `(λ, ν)` and an
//! apply carries only `(α, Δm)` — no `beta_local` gather, no `(w, z)`
//! broadcast. The leader's global (β, margins) stay bit-identical to the
//! union of the worker-held shards; [`WorkerPool::pull_states`] and
//! [`WorkerPool::sync_full_state`] cross-check and restore that invariant
//! at checkpoint/resume boundaries.
//!
//! The in-process pool doubles as the cluster's [`TaskExecutor`]: the
//! `cluster::comm` collectives submit their tree-node merge jobs here, so
//! AllReduce merge work runs on worker threads — the leader thread only
//! stages payloads and charges the ledger ([`WorkerPool::tasks_executed`]
//! counts the jobs, which the regression tests use to prove the off-thread
//! contract). A socket pool has no local worker threads, so merge jobs run
//! inline on the leader.

use std::net::{TcpListener, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::comm::{bracket_children, bracket_parent, Job, TaskExecutor};
use crate::cluster::network::NetworkLedger;
use crate::cluster::node::WorkerNode;
use crate::cluster::partition::FeaturePartition;
use crate::cluster::protocol::{
    crc_u32, log_lost_abort, NodeMessage, PeerInfo, Topology, TreeSwept,
};
use crate::cluster::transport::{
    Fault, FaultyTransport, PeerTable, SocketTransport, Transport, WireCounters,
};
use crate::config::{TopologyKind, TrainConfig};
use crate::data::dataset::Dataset;
use crate::data::shuffle::{shard_in_memory, FeatureShard};
use crate::data::sparse::SparseVec;
use crate::data::store::ShardStore;
use crate::engine::SweepResult;
use crate::error::{DlrError, Result};
use crate::family::FamilyKind;

/// A deferred worker-node constructor, run *inside* the worker's own thread
/// (PJRT clients are thread-bound; store-backed nodes read their own shard
/// file there, so shard I/O is per-worker and never leader-side).
type NodeBuilder = Box<dyn FnOnce() -> Result<WorkerNode> + Send + 'static>;

/// Rebuilds the [`NodeBuilder`] for any machine index — what lets a
/// store-backed pool respawn a dead worker thread mid-fit (the replacement
/// re-loads the same shard file the original did).
type NodeRespawner = Box<dyn Fn(usize) -> NodeBuilder>;

/// What travels to an in-process worker thread: a protocol message, or one
/// [`TaskExecutor`] job (a tree-node merge) — the latter never exists on a
/// real wire, it is the thread pool piggybacking on the worker threads.
enum ThreadMsg {
    Proto(NodeMessage),
    Task(Job),
}

/// Leader-side endpoint of one in-process worker: protocol messages are
/// wrapped in [`ThreadMsg`] on the way down, replies come back plain.
/// Byte counters meter the frame each message *would* occupy on a real
/// wire (encoded body + 4-byte length prefix), so per-link traffic reports
/// are comparable across transports.
struct LeaderLink {
    tx: mpsc::Sender<ThreadMsg>,
    rx: mpsc::Receiver<NodeMessage>,
    sent: u64,
    recv: u64,
}

impl LeaderLink {
    fn new(tx: mpsc::Sender<ThreadMsg>, rx: mpsc::Receiver<NodeMessage>) -> Self {
        Self { tx, rx, sent: 0, recv: 0 }
    }
}

/// The frame a message would occupy on a socket: encoded body + prefix.
fn wire_frame_len(msg: &NodeMessage) -> u64 {
    msg.encode().len() as u64 + 4
}

impl Transport for LeaderLink {
    fn send(&mut self, msg: NodeMessage) -> Result<()> {
        self.sent += wire_frame_len(&msg);
        self.tx
            .send(ThreadMsg::Proto(msg))
            .map_err(|_| DlrError::Solver("worker thread hung up".into()))
    }

    fn recv(&mut self) -> Result<NodeMessage> {
        let msg = self
            .rx
            .recv()
            .map_err(|_| DlrError::Solver("worker thread hung up".into()))?;
        self.recv += wire_frame_len(&msg);
        Ok(msg)
    }

    fn recv_poll(&mut self, wait: Duration) -> Result<Option<NodeMessage>> {
        match self.rx.recv_timeout(wait) {
            Ok(msg) => {
                self.recv += wire_frame_len(&msg);
                Ok(Some(msg))
            }
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(DlrError::Solver("worker thread hung up".into()))
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_recv(&self) -> u64 {
        self.recv
    }

    fn kind(&self) -> &'static str {
        "in-process"
    }
}

fn worker_err(k: usize, e: DlrError) -> DlrError {
    DlrError::Solver(format!("worker {k}: {e}"))
}

/// Handle to the M worker nodes.
pub struct WorkerPool {
    links: Vec<Box<dyn Transport>>,
    /// Global feature ids per machine (ascending within a machine).
    pub global_cols: Vec<Vec<u32>>,
    pub engine_names: Vec<String>,
    /// Example count — the expected `dim` of every Δm payload.
    n: usize,
    /// Global feature count — what a replacement worker's `Join` must
    /// announce.
    p: usize,
    transport: &'static str,
    handles: Vec<JoinHandle<()>>,
    /// Task-lane senders into the in-process worker threads (empty for a
    /// socket pool — merges then run inline on the leader).
    task_txs: Vec<mpsc::Sender<ThreadMsg>>,
    /// Completion acknowledgements for [`TaskExecutor`] jobs.
    task_done_rx: Option<mpsc::Receiver<()>>,
    /// Retained ack-sender so a respawned worker thread acknowledges
    /// task-lane jobs on the same channel as its siblings.
    task_done_tx: Option<mpsc::Sender<()>>,
    /// Jobs the workers have executed (observable leader-offload proof).
    tasks_done: Arc<AtomicU64>,
    /// Socket pools retain their listener so the supervisor can re-admit a
    /// replacement worker mid-fit ([`WorkerPool::replace_link`]).
    listener: Option<TcpListener>,
    /// Store-backed in-process pools can rebuild machine k's node from its
    /// shard file; `None` when the shards were consumed at spawn.
    respawner: Option<NodeRespawner>,
    /// GLM family the fit runs under — every admitted worker's `Join` must
    /// announce the same one, and the `Welcome` echoes it back.
    family: FamilyKind,
    /// Elastic-net α, echoed in the `Welcome` for worker-side sanity checks.
    enet_alpha: f64,
    /// Tree topology active: collective traffic routes over physical
    /// worker↔worker links and the leader talks to machine 0 only. Only a
    /// socket pool routes physically; an in-process pool under a tree
    /// config stays leader-staged (the staged engine already *is* the
    /// bracket, and there is no wire to relieve).
    tree: bool,
    /// Current topology epoch: bumped on every re-issue so peers can
    /// reject stale hellos. 0 = never issued.
    topo_epoch: u32,
    /// Per-hop peer recv deadline handed out in every [`Topology`].
    peer_timeout_secs: f64,
    /// Peer-listener address each worker announced in its `Join` (empty
    /// for star workers); re-learned whenever a replacement is admitted.
    listen_addrs: Vec<String>,
}

impl WorkerPool {
    /// Spawn one in-process worker per shard. Every worker builds its
    /// engine inside its own thread and announces itself with the protocol
    /// handshake; fails fast if any engine fails to build.
    pub fn spawn(
        cfg: &TrainConfig,
        shards: Vec<FeatureShard>,
        y: &[f32],
        p: usize,
        artifacts_dir: std::path::PathBuf,
    ) -> Result<Self> {
        let n = y.len();
        // one shared copy of the labels for the whole pool (read-only)
        let y = Arc::new(y.to_vec());
        let global_cols: Vec<Vec<u32>> =
            shards.iter().map(|s| s.global_cols.clone()).collect();
        let builders: Vec<NodeBuilder> = shards
            .into_iter()
            .map(|shard| {
                let cfg = cfg.clone();
                let y = Arc::clone(&y);
                let dir = artifacts_dir.clone();
                Box::new(move || WorkerNode::from_shard(&cfg, shard, y, p, &dir))
                    as NodeBuilder
            })
            .collect();
        Self::spawn_nodes(n, p, global_cols, builders, cfg.family, cfg.enet_alpha)
    }

    /// Spawn one in-process worker per machine of an on-disk [`ShardStore`]
    /// — each worker thread opens and loads **only its own** shard file
    /// (checksum-verified), so the leader never stages a shard payload.
    /// `y` is the leader's already-loaded label vector, shared read-only
    /// with every worker.
    pub fn spawn_from_store(
        cfg: &TrainConfig,
        store: &ShardStore,
        y: Arc<Vec<f32>>,
        artifacts_dir: std::path::PathBuf,
    ) -> Result<Self> {
        let m = store.machines();
        let n = store.n();
        let p = store.p();
        if y.len() != n {
            return Err(DlrError::Solver(format!(
                "{} labels but the store says n = {n}",
                y.len()
            )));
        }
        // O(p) total: shard headers only, never the CSC payloads
        let global_cols: Vec<Vec<u32>> =
            (0..m).map(|k| store.shard_cols(k)).collect::<Result<_>>()?;
        let builders: Vec<NodeBuilder> = (0..m)
            .map(|k| {
                let cfg = cfg.clone();
                let store = store.clone();
                let y = Arc::clone(&y);
                let dir = artifacts_dir.clone();
                Box::new(move || {
                    let shard = store.load_shard(k)?;
                    WorkerNode::from_shard(&cfg, shard, y, p, &dir)
                }) as NodeBuilder
            })
            .collect();
        let mut pool =
            Self::spawn_nodes(n, p, global_cols, builders, cfg.family, cfg.enet_alpha)?;
        // a store-backed worker can be rebuilt from its shard file at any
        // time, so this pool supports supervisor respawns
        let cfg = cfg.clone();
        let store = store.clone();
        let dir = artifacts_dir;
        pool.respawner = Some(Box::new(move |k| {
            let cfg = cfg.clone();
            let store = store.clone();
            let y = Arc::clone(&y);
            let dir = dir.clone();
            Box::new(move || {
                let shard = store.load_shard(k)?;
                WorkerNode::from_shard(&cfg, shard, y, p, &dir)
            }) as NodeBuilder
        }));
        Ok(pool)
    }

    /// Shared in-process spawn loop: one thread per machine, each building
    /// its node inside its own thread and serving the protocol over
    /// channels, plus the task lane for comm-layer merge jobs.
    fn spawn_nodes(
        n: usize,
        p: usize,
        global_cols: Vec<Vec<u32>>,
        builders: Vec<NodeBuilder>,
        family: FamilyKind,
        enet_alpha: f64,
    ) -> Result<Self> {
        let m = builders.len();
        debug_assert_eq!(global_cols.len(), m);
        let (task_done_tx, task_done_rx) = mpsc::channel::<()>();
        let tasks_done = Arc::new(AtomicU64::new(0));
        let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(m);
        let mut task_txs = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);

        for (machine, build) in builders.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<ThreadMsg>();
            let (reply_tx, reply_rx) = mpsc::channel::<NodeMessage>();
            task_txs.push(tx.clone());
            links.push(Box::new(LeaderLink::new(tx, reply_rx)));
            handles.push(spawn_worker_thread(
                machine,
                build,
                rx,
                reply_tx,
                task_done_tx.clone(),
                Arc::clone(&tasks_done),
            ));
        }

        let mut pool = Self {
            links,
            global_cols,
            engine_names: vec![String::new(); m],
            n,
            p,
            transport: "in-process",
            handles,
            task_txs,
            task_done_rx: Some(task_done_rx),
            task_done_tx: Some(task_done_tx),
            tasks_done,
            listener: None,
            respawner: None,
            family,
            enet_alpha,
            tree: false,
            topo_epoch: 0,
            peer_timeout_secs: 0.0,
            listen_addrs: vec![String::new(); m],
        };
        for k in 0..m {
            let expected = &pool.global_cols[k];
            let (jn, jp, features, checksum) =
                (n as u32, p as u32, expected.len() as u32, crc_u32(expected));
            let engine = handshake(
                pool.links[k].as_mut(),
                k,
                jn,
                jp,
                features,
                checksum,
                family,
                enet_alpha,
            )?;
            pool.engine_names[k] = engine;
        }
        Ok(pool)
    }

    /// Bind `addr` and admit one remote worker per partition block — the
    /// multi-process counterpart of [`WorkerPool::spawn`]. Workers are
    /// launched separately (`dglmnet worker --connect <addr> --machine k`)
    /// and may connect in any order; each is validated against the
    /// partition (and, when the leader pins a concrete engine,
    /// `expected_engine`) before admission. Stray peers — port scanners,
    /// health probes, silent or garbage-sending connections, duplicate
    /// joins from a retry race — are rejected and the leader keeps
    /// waiting; a *valid worker* announcing a mismatched shard or a
    /// startup failure is a hard error. Gives up after `timeout`.
    ///
    /// Under `TopologyKind::Tree` every worker must announce a peer
    /// listener in its `Join`; admission is *batched* — the `Welcome`s
    /// (each carrying that worker's [`Topology`]) go out only once all M
    /// workers have joined, because the tree addresses aren't known before
    /// that. `peer_timeout_secs` is the per-hop peer recv deadline handed
    /// out in every topology (0 disables it).
    #[allow(clippy::too_many_arguments)]
    pub fn listen_and_accept(
        partition: &FeaturePartition,
        n: usize,
        expected_engine: Option<&str>,
        family: FamilyKind,
        enet_alpha: f64,
        topology: TopologyKind,
        peer_timeout_secs: f64,
        addr: impl ToSocketAddrs,
        timeout: Duration,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Self::accept(
            partition,
            n,
            expected_engine,
            family,
            enet_alpha,
            topology,
            peer_timeout_secs,
            listener,
            timeout,
        )
    }

    /// Admit one remote worker per partition block on an already-bound
    /// listener (lets callers bind port 0 and hand the concrete address to
    /// the workers first).
    #[allow(clippy::too_many_arguments)]
    pub fn accept(
        partition: &FeaturePartition,
        n: usize,
        expected_engine: Option<&str>,
        family: FamilyKind,
        enet_alpha: f64,
        topology: TopologyKind,
        peer_timeout_secs: f64,
        listener: TcpListener,
        timeout: Duration,
    ) -> Result<Self> {
        let m = partition.machines();
        let p = partition.n_features();
        let tree = topology == TopologyKind::Tree;
        let global_cols: Vec<Vec<u32>> = (0..m).map(|k| partition.features_of(k)).collect();
        let mut links: Vec<Option<Box<dyn Transport>>> = (0..m).map(|_| None).collect();
        let mut raws: Vec<Option<std::net::TcpStream>> = (0..m).map(|_| None).collect();
        let mut listen_addrs = vec![String::new(); m];
        let mut engine_names = vec![String::new(); m];
        listener.set_nonblocking(true)?;
        let deadline = Instant::now() + timeout;
        let mut admitted = 0usize;
        while admitted < m {
            let stream = match listener.accept() {
                Ok((stream, _)) => stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(DlrError::Solver(format!(
                            "only {admitted} of {m} workers connected within {:.0}s",
                            timeout.as_secs_f64()
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(25));
                    continue;
                }
                Err(e) => return Err(e.into()),
            };
            stream.set_nonblocking(false)?;
            // a peer that connects but never announces itself must not
            // wedge admission past the deadline: bound the handshake read
            let remaining = deadline
                .saturating_duration_since(Instant::now())
                .max(Duration::from_millis(100));
            stream.set_read_timeout(Some(remaining))?;
            let raw = stream.try_clone()?;
            let mut link: Box<dyn Transport> = Box::new(SocketTransport::from_stream(stream)?);
            // stray peers (scanners, probes, garbage, handshake races) are
            // rejected without killing the accept loop — the deadline
            // still bounds the total wait
            let first = match link.recv() {
                Ok(msg) => msg,
                Err(e) => {
                    eprintln!("[accept] rejected a peer that sent no valid join: {e}");
                    continue;
                }
            };
            match first {
                NodeMessage::Join {
                    machine,
                    n: jn,
                    p: jp,
                    local_features,
                    cols_checksum,
                    engine,
                    family: jfam,
                    listen_addr,
                } => {
                    let k = machine as usize;
                    if k >= m {
                        let msg = format!("machine {k} out of range (M = {m})");
                        eprintln!("[accept] rejected a peer: {msg}");
                        if let Err(e) = link.send(NodeMessage::Abort { message: msg }) {
                            log_lost_abort(k, "admission", &e);
                        }
                        continue;
                    }
                    if links[k].is_some() {
                        // a worker whose connect_retry raced can open two
                        // connections; keep the admitted one
                        let msg = format!("machine {k} already connected");
                        eprintln!("[accept] rejected a duplicate join: {msg}");
                        if let Err(e) = link.send(NodeMessage::Abort { message: msg }) {
                            log_lost_abort(k, "admission", &e);
                        }
                        continue;
                    }
                    // a *matching-machine* worker with the wrong shard or
                    // engine is a real misconfiguration: fail loudly
                    // instead of waiting out the deadline
                    let expected = &global_cols[k];
                    if jn as usize != n
                        || jp as usize != p
                        || local_features as usize != expected.len()
                        || cols_checksum != crc_u32(expected)
                    {
                        let msg = format!(
                            "worker {k} announced shard (n = {jn}, p = {jp}, features = \
                             {local_features}) but the leader expects (n = {n}, p = {p}, \
                             features = {}) — are the worker's data/partition flags \
                             identical to the leader's?",
                            expected.len()
                        );
                        if let Err(e) = link.send(NodeMessage::Abort { message: msg.clone() })
                        {
                            log_lost_abort(k, "admission", &e);
                        }
                        return Err(DlrError::Solver(msg));
                    }
                    if let Some(want) = expected_engine {
                        if engine != want {
                            let msg = format!(
                                "worker {k} runs the '{engine}' engine but the leader \
                                 pins '{want}' — mixed engines would break the \
                                 bit-identical trajectory contract"
                            );
                            if let Err(e) =
                                link.send(NodeMessage::Abort { message: msg.clone() })
                            {
                                log_lost_abort(k, "admission", &e);
                            }
                            return Err(DlrError::Solver(msg));
                        }
                    }
                    if jfam != family.name() {
                        let msg = format!(
                            "worker {k} derives working statistics under the '{jfam}' \
                             family but the leader runs '{}' — pass the matching \
                             --family to every worker",
                            family.name()
                        );
                        if let Err(e) = link.send(NodeMessage::Abort { message: msg.clone() })
                        {
                            log_lost_abort(k, "admission", &e);
                        }
                        return Err(DlrError::Solver(msg));
                    }
                    if tree && listen_addr.is_empty() {
                        let msg = format!(
                            "worker {k} announced no peer listener but the leader runs \
                             the tree topology — start every worker with --topology tree"
                        );
                        if let Err(e) = link.send(NodeMessage::Abort { message: msg.clone() })
                        {
                            log_lost_abort(k, "admission", &e);
                        }
                        return Err(DlrError::Solver(msg));
                    }
                    // admitted; the welcome (and, under the tree topology,
                    // this worker's Topology) goes out once all M joined
                    engine_names[k] = engine;
                    listen_addrs[k] = listen_addr;
                    raws[k] = Some(raw);
                    links[k] = Some(link);
                    admitted += 1;
                }
                NodeMessage::Abort { message } => {
                    // an announced worker failure (e.g. its engine failed
                    // to build): surface it instead of timing out
                    return Err(DlrError::Solver(format!("a worker failed to start: {message}")))
                }
                other => {
                    eprintln!(
                        "[accept] rejected a peer that sent {} instead of join",
                        other.name()
                    );
                    continue;
                }
            }
        }
        let mut links: Vec<Box<dyn Transport>> =
            links.into_iter().map(|l| l.expect("all machines admitted")).collect();
        // every shard is connected: release the batched welcomes, each
        // carrying its worker's tree view when the topology asks for one
        let topo_epoch = if tree { 1 } else { 0 };
        for (k, link) in links.iter_mut().enumerate() {
            let topo = tree.then(|| {
                build_topology(k, topo_epoch, peer_timeout_secs, &listen_addrs, &global_cols)
            });
            link.send(NodeMessage::Welcome {
                family: family.name().to_string(),
                alpha: enet_alpha,
                topology: topo,
            })
            .map_err(|e| worker_err(k, e))?;
            // admitted: lift the handshake deadline for fit traffic
            raws[k]
                .as_ref()
                .expect("all machines admitted")
                .set_read_timeout(None)?;
        }
        Ok(Self {
            links,
            global_cols,
            engine_names,
            n,
            p,
            transport: "socket",
            handles: Vec::new(),
            task_txs: Vec::new(),
            task_done_rx: None,
            task_done_tx: None,
            tasks_done: Arc::new(AtomicU64::new(0)),
            // retained: the supervisor re-admits replacement workers here
            listener: Some(listener),
            respawner: None,
            family,
            enet_alpha,
            tree,
            topo_epoch,
            peer_timeout_secs,
            listen_addrs,
        })
    }

    pub fn machines(&self) -> usize {
        self.links.len()
    }

    /// `"in-process"` or `"socket"`.
    pub fn transport_kind(&self) -> &'static str {
        self.transport
    }

    /// Total [`TaskExecutor`] jobs the workers have executed — the
    /// leader-offload regression tests assert this grows during fits.
    pub fn tasks_executed(&self) -> u64 {
        self.tasks_done.load(Ordering::Relaxed)
    }

    /// Does collective traffic route over physical worker↔worker links?
    /// True only for a socket pool admitted under the tree topology — an
    /// in-process pool under a tree config stays leader-staged.
    pub fn is_physical_tree(&self) -> bool {
        self.tree && self.transport == "socket"
    }

    /// Current topology epoch (0 = no topology ever issued).
    pub fn topology_epoch(&self) -> u32 {
        self.topo_epoch
    }

    /// Total frame bytes the leader has moved over all of its worker links
    /// `(sent, received)` — measured at the transport, so under the tree
    /// topology this is the leader's whole bandwidth bill.
    pub fn wire_bytes(&self) -> (u64, u64) {
        let mut sent = 0u64;
        let mut recv = 0u64;
        for link in &self.links {
            sent += link.bytes_sent();
            recv += link.bytes_recv();
        }
        (sent, recv)
    }

    /// Re-issue the tree topology to every worker under a bumped epoch —
    /// the supervisor calls this after any recovery so all peer links are
    /// torn down (discarding stale in-flight payloads) and rebuilt against
    /// the current listener addresses (replacements bind fresh ones).
    /// Charged to the ledger's recovery bucket. No-op for star pools.
    pub fn reissue_topology(&mut self, ledger: &NetworkLedger) -> Result<()> {
        if !self.is_physical_tree() {
            return Ok(());
        }
        self.topo_epoch += 1;
        for k in 0..self.links.len() {
            let msg = NodeMessage::Topology(build_topology(
                k,
                self.topo_epoch,
                self.peer_timeout_secs,
                &self.listen_addrs,
                &self.global_cols,
            ));
            ledger.record_recovery(msg.encode().len() as u64);
            self.links[k].send(msg).map_err(|e| worker_err(k, e))?;
        }
        Ok(())
    }

    /// One tree-collective sweep: the leader sends a single `Sweep` down
    /// its machine-0 link and receives the bracket root's merged
    /// [`TreeSwept`] back — O(1) leader traffic per iteration, regardless
    /// of M. The payload's origin/edge metadata is validated to cover
    /// every machine (so strategy picks and ledger replays see the same
    /// facts the staged engine would).
    pub fn sweep_all_tree(&mut self, lam: f32, nu: f32, l2: f32) -> Result<TreeSwept> {
        let m = self.machines();
        self.links[0]
            .send(NodeMessage::Sweep { lam, nu, l2, recycle: SweepResult::default() })
            .map_err(|e| worker_err(0, e))?;
        let swept = match self.links[0].recv().map_err(|e| worker_err(0, e))? {
            NodeMessage::TreeSwept(swept) => swept,
            NodeMessage::Abort { message } => {
                return Err(DlrError::Solver(format!(
                    "tree sweep failed: {message}"
                )))
            }
            other => {
                return Err(DlrError::Solver(format!(
                    "expected tree-swept from machine 0, got {}",
                    other.name()
                )))
            }
        };
        if swept.db.dim as usize != self.p || swept.dm.dim as usize != self.n {
            return Err(DlrError::Solver(format!(
                "tree sweep returned payload dims ({}, {}) but the problem is ({}, {})",
                swept.db.dim, swept.dm.dim, self.p, self.n
            )));
        }
        let mut seen = vec![false; m];
        for o in &swept.origins {
            let k = o.machine as usize;
            if k >= m || seen[k] {
                return Err(DlrError::Solver(format!(
                    "tree sweep origin metadata names machine {k} twice (or out of \
                     range for M = {m})"
                )));
            }
            seen[k] = true;
        }
        if swept.origins.len() != m {
            return Err(DlrError::Solver(format!(
                "tree sweep covered {} of {m} machines",
                swept.origins.len()
            )));
        }
        if swept.edges.len() != m - 1 {
            return Err(DlrError::Solver(format!(
                "tree sweep reported {} merge edges but an M = {m} bracket has {}",
                swept.edges.len(),
                m - 1
            )));
        }
        Ok(swept)
    }

    /// The tree apply: one `Apply` down the machine-0 link, relayed along
    /// the tree, answered by a single aggregated `Ack`.
    pub fn apply_all_tree(
        &mut self,
        alpha: f32,
        dmargins: &Arc<SparseVec>,
        delta: Option<&Arc<SparseVec>>,
    ) -> Result<()> {
        self.links[0]
            .send(NodeMessage::Apply {
                alpha,
                dmargins: Arc::clone(dmargins),
                delta: delta.cloned(),
            })
            .map_err(|e| worker_err(0, e))?;
        match self.links[0].recv().map_err(|e| worker_err(0, e))? {
            NodeMessage::Ack => Ok(()),
            NodeMessage::Abort { message } => Err(DlrError::Solver(format!(
                "tree apply failed: {message}"
            ))),
            other => Err(DlrError::Solver(format!(
                "expected the aggregated tree ack, got {}",
                other.name()
            ))),
        }
    }

    /// One parallel sweep across all machines (Alg 4 steps 1–2): a send
    /// phase (`Sweep { λ, ν, l2 }` to every node — the workers derive
    /// their own `(w, z)` from their margins) followed by a recv phase.
    /// `lam` is the L1 soft-threshold strength (λ·α under the elastic net)
    /// and `l2` the ridge strength λ·(1−α); 0 under the default pure-L1
    /// configuration. Results land in `out`, indexed by machine; the
    /// caller owns (and should reuse) `out` — its sparse buffers
    /// round-trip through the in-process workers via the `recycle` slot,
    /// so steady-state sweeps don't allocate.
    pub fn sweep_all(
        &mut self,
        lam: f32,
        nu: f32,
        l2: f32,
        out: &mut Vec<SweepResult>,
    ) -> Result<()> {
        let m = self.machines();
        out.resize_with(m, SweepResult::default);
        for (k, link) in self.links.iter_mut().enumerate() {
            link.send(NodeMessage::Sweep {
                lam,
                nu,
                l2,
                recycle: std::mem::take(&mut out[k]),
            })
            .map_err(|e| worker_err(k, e))?;
        }
        for (k, link) in self.links.iter_mut().enumerate() {
            match link.recv().map_err(|e| worker_err(k, e))? {
                NodeMessage::Swept { result } => {
                    // a rogue or version-skewed peer must error cleanly,
                    // never flow malformed dims into the merge (the codec
                    // only guarantees indices < the frame's own dim)
                    if result.delta_local.dim != self.global_cols[k].len()
                        || result.dmargins.dim != self.n
                    {
                        return Err(DlrError::Solver(format!(
                            "worker {k} returned a sweep of shape (Δβ dim {}, Δm dim {}) \
                             but owns {} features over {} examples",
                            result.delta_local.dim,
                            result.dmargins.dim,
                            self.global_cols[k].len(),
                            self.n
                        )));
                    }
                    out[k] = result
                }
                NodeMessage::Abort { message } => {
                    return Err(DlrError::Solver(format!("worker {k} failed mid-sweep: {message}")))
                }
                other => {
                    return Err(DlrError::Solver(format!(
                        "worker {k}: expected swept, got {}",
                        other.name()
                    )))
                }
            }
        }
        Ok(())
    }

    /// The apply phase (Alg 4 step 5): every node applies `α·Δβ_local` to
    /// its own β shard and `α·Δm` to its margins. `delta` (the merged
    /// global Δβ) travels only when a lossy β wire is active — see
    /// [`NodeMessage::Apply`].
    pub fn apply_all(
        &mut self,
        alpha: f32,
        dmargins: &Arc<SparseVec>,
        delta: Option<&Arc<SparseVec>>,
    ) -> Result<()> {
        for (k, link) in self.links.iter_mut().enumerate() {
            link.send(NodeMessage::Apply {
                alpha,
                dmargins: Arc::clone(dmargins),
                delta: delta.cloned(),
            })
            .map_err(|e| worker_err(k, e))?;
        }
        self.expect_acks("apply")
    }

    /// Distributed λ_max gradient max: every node reports its shard's
    /// `max_j |Σ_i x_ij t_i| · scale` with its family's gradient targets
    /// `t` (logistic: t = y, scale = ½) and the leader max-reduces over
    /// machines. Exact — each per-feature f64 sum is computed in the same
    /// ascending-example order as the in-memory scan, the partition is
    /// disjoint, and max is order-independent — so the result is
    /// **bit-identical** to [`lambda_max`](crate::solver::regpath::lambda_max)
    /// on the assembled dataset, for any machine count and either
    /// transport (pinned in `tests/store.rs`). This is what lets an
    /// out-of-core leader anchor the regularization path without ever
    /// holding X.
    pub fn lambda_max(&mut self) -> Result<f64> {
        for (k, link) in self.links.iter_mut().enumerate() {
            link.send(NodeMessage::LambdaMax).map_err(|e| worker_err(k, e))?;
        }
        let mut best = 0f64;
        for (k, link) in self.links.iter_mut().enumerate() {
            match link.recv().map_err(|e| worker_err(k, e))? {
                NodeMessage::LambdaMaxed { value } => best = best.max(value),
                NodeMessage::Abort { message } => {
                    return Err(DlrError::Solver(format!("worker {k} failed: {message}")))
                }
                other => {
                    return Err(DlrError::Solver(format!(
                        "worker {k}: expected lambda-maxed, got {}",
                        other.name()
                    )))
                }
            }
        }
        Ok(best)
    }

    /// Distributed margins rebuild `margins_i = Σ_j β_j x_ij` for a
    /// warmstart install: each node computes its shard's product from its
    /// locally-held feature block, and the leader sums the disjoint
    /// contributions in machine order (f64 accumulation — deterministic
    /// across transports). `out` is overwritten with the n margins.
    pub fn margins_for(&mut self, beta: &[f32], out: &mut Vec<f32>) -> Result<()> {
        for k in 0..self.links.len() {
            let beta_local: Vec<f32> =
                self.global_cols[k].iter().map(|&g| beta[g as usize]).collect();
            self.links[k]
                .send(NodeMessage::Margins { beta_local })
                .map_err(|e| worker_err(k, e))?;
        }
        let mut acc = vec![0f64; self.n];
        for (k, link) in self.links.iter_mut().enumerate() {
            match link.recv().map_err(|e| worker_err(k, e))? {
                NodeMessage::MarginsPart { part } => {
                    if part.dim != self.n {
                        return Err(DlrError::Solver(format!(
                            "worker {k} returned a margins part of dim {} but n = {}",
                            part.dim, self.n
                        )));
                    }
                    for (i, v) in part.iter() {
                        acc[i as usize] += v as f64;
                    }
                }
                NodeMessage::Abort { message } => {
                    return Err(DlrError::Solver(format!("worker {k} failed: {message}")))
                }
                other => {
                    return Err(DlrError::Solver(format!(
                        "worker {k}: expected margins-part, got {}",
                        other.name()
                    )))
                }
            }
        }
        out.clear();
        out.extend(acc.iter().map(|&v| v as f32));
        Ok(())
    }

    /// Push the full (β, margins) state: each node receives its shard's
    /// slice of `beta` and the complete margins, bit-for-bit (warmstart
    /// installs, resets, legacy-checkpoint resumes).
    pub fn sync_full_state(&mut self, beta: &[f32], margins: &[f32]) -> Result<()> {
        let margins = Arc::new(margins.to_vec());
        for k in 0..self.links.len() {
            let beta_local: Vec<f32> =
                self.global_cols[k].iter().map(|&g| beta[g as usize]).collect();
            self.links[k]
                .send(NodeMessage::SetState { beta_local, margins: Arc::clone(&margins) })
                .map_err(|e| worker_err(k, e))?;
        }
        self.expect_acks("set-state")
    }

    /// Push checkpointed per-machine shard states verbatim (the resume
    /// path that restores exactly what [`WorkerPool::pull_states`]
    /// captured).
    pub fn push_shard_states(&mut self, shards: &[Vec<f32>], margins: &[f32]) -> Result<()> {
        if shards.len() != self.links.len() {
            return Err(DlrError::Solver(format!(
                "checkpoint has {} shard states but the cluster has {} workers",
                shards.len(),
                self.links.len()
            )));
        }
        let margins = Arc::new(margins.to_vec());
        for (k, shard) in shards.iter().enumerate() {
            if shard.len() != self.global_cols[k].len() {
                return Err(DlrError::Solver(format!(
                    "shard state {k} has {} coefficients but machine {k} owns {} features",
                    shard.len(),
                    self.global_cols[k].len()
                )));
            }
            self.links[k]
                .send(NodeMessage::SetState {
                    beta_local: shard.clone(),
                    margins: Arc::clone(&margins),
                })
                .map_err(|e| worker_err(k, e))?;
        }
        self.expect_acks("set-state")
    }

    /// Pull every node's shard state: its β shard in full plus a checksum
    /// of its margins (checkpoint capture + sync verification).
    pub fn pull_states(&mut self) -> Result<Vec<(Vec<f32>, u64)>> {
        for (k, link) in self.links.iter_mut().enumerate() {
            link.send(NodeMessage::GetState).map_err(|e| worker_err(k, e))?;
        }
        let mut states = Vec::with_capacity(self.links.len());
        for (k, link) in self.links.iter_mut().enumerate() {
            match link.recv().map_err(|e| worker_err(k, e))? {
                NodeMessage::State { beta_local, margins_crc } => {
                    states.push((beta_local, margins_crc))
                }
                NodeMessage::Abort { message } => {
                    return Err(DlrError::Solver(format!("worker {k} failed: {message}")))
                }
                other => {
                    return Err(DlrError::Solver(format!(
                        "worker {k}: expected state, got {}",
                        other.name()
                    )))
                }
            }
        }
        Ok(states)
    }

    fn expect_acks(&mut self, what: &str) -> Result<()> {
        for (k, link) in self.links.iter_mut().enumerate() {
            match link.recv().map_err(|e| worker_err(k, e))? {
                NodeMessage::Ack => {}
                NodeMessage::Abort { message } => {
                    return Err(DlrError::Solver(format!(
                        "worker {k} failed during {what}: {message}"
                    )))
                }
                other => {
                    return Err(DlrError::Solver(format!(
                        "worker {k}: expected ack for {what}, got {}",
                        other.name()
                    )))
                }
            }
        }
        Ok(())
    }

    /// Remap a shard-local sparse Δβ to global feature ids (the gather
    /// contribution of Alg 4 step 3/4) — O(nnz). `out` is reused by the
    /// caller.
    pub fn delta_to_global(
        &self,
        machine: usize,
        delta_local: &SparseVec,
        p: usize,
        out: &mut SparseVec,
    ) {
        out.clear(p);
        let cols = &self.global_cols[machine];
        debug_assert_eq!(delta_local.dim, cols.len());
        for (local, v) in delta_local.iter() {
            // global ids ascend with local ids inside a machine, so pushes
            // stay sorted
            out.push(cols[local as usize], v);
        }
    }

    /// Apply one recv deadline to every link. Sockets turn a wedged (alive
    /// but silent) peer into a clean "timed out" error the supervisor can
    /// act on; in-process channels ignore the deadline — a dead worker
    /// thread already fails `recv` immediately.
    pub fn set_recv_deadline(&mut self, deadline: Option<Duration>) -> Result<()> {
        for (k, link) in self.links.iter_mut().enumerate() {
            link.set_recv_deadline(deadline).map_err(|e| worker_err(k, e))?;
        }
        Ok(())
    }

    /// Liveness probe: ping every worker and report the machines that did
    /// not answer within `timeout`. The protocol is strictly
    /// request/reply, so at most one stale (un-consumed) reply from the
    /// failed phase can sit ahead of the pong — the probe drains it, which
    /// is exactly what a rollback needs: every surviving link is left
    /// idle. Probe traffic is charged to the ledger's recovery bucket,
    /// never the algorithmic one.
    pub fn probe_links(&mut self, timeout: Duration, ledger: &NetworkLedger) -> Vec<usize> {
        let mut dead = Vec::new();
        for (k, link) in self.links.iter_mut().enumerate() {
            let _ = link.set_recv_deadline(Some(timeout));
            let alive = probe_one(link.as_mut(), ledger);
            let _ = link.set_recv_deadline(None);
            if !alive {
                dead.push(k);
            }
        }
        dead
    }

    /// Re-admit a replacement for machine `k` after
    /// [`WorkerPool::probe_links`] declared it dead. A socket pool waits up
    /// to `window` for a fresh `dglmnet worker` process to connect on the
    /// retained listener and validates it exactly like the original
    /// admission (machine index, shard shape, owned-column checksum, and
    /// the engine the fit started on). A store-backed in-process pool
    /// respawns the worker thread, which re-loads its shard file. Either
    /// way the replacement starts cold — the caller restores state (the
    /// driver's rollback re-syncs every worker from its recovery
    /// checkpoint).
    pub fn replace_link(
        &mut self,
        k: usize,
        window: Duration,
        ledger: &NetworkLedger,
    ) -> Result<()> {
        if k >= self.links.len() {
            return Err(DlrError::Solver(format!(
                "no machine {k} in a {}-worker pool",
                self.links.len()
            )));
        }
        if self.transport == "socket" {
            let listener = self.listener.take().ok_or_else(|| {
                DlrError::Solver(
                    "cannot re-admit a replacement: this socket pool did not retain \
                     its listener"
                        .into(),
                )
            })?;
            let admitted = self.admit_replacement(&listener, k, window, ledger);
            self.listener = Some(listener);
            let (link, engine, listen_addr) = admitted?;
            self.links[k] = link;
            self.engine_names[k] = engine;
            // a replacement binds a fresh peer listener; the next
            // topology re-issue points its peers at it
            self.listen_addrs[k] = listen_addr;
            Ok(())
        } else {
            self.respawn_in_process(k)
        }
    }

    /// The socket re-admission loop: like [`WorkerPool::accept`], but for
    /// exactly one known machine. Stray peers are rejected and the wait
    /// continues; a machine-`k` worker announcing the wrong shard or
    /// engine is a hard, actionable error.
    fn admit_replacement(
        &self,
        listener: &TcpListener,
        k: usize,
        window: Duration,
        ledger: &NetworkLedger,
    ) -> Result<(Box<dyn Transport>, String, String)> {
        let expected = &self.global_cols[k];
        let (n, p) = (self.n, self.p);
        let deadline = Instant::now() + window;
        loop {
            let stream = match listener.accept() {
                Ok((stream, _)) => stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(DlrError::Solver(format!(
                            "no replacement for worker {k} connected within {:.0}s",
                            window.as_secs_f64()
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(25));
                    continue;
                }
                Err(e) => return Err(e.into()),
            };
            stream.set_nonblocking(false)?;
            let remaining = deadline
                .saturating_duration_since(Instant::now())
                .max(Duration::from_millis(100));
            stream.set_read_timeout(Some(remaining))?;
            let raw = stream.try_clone()?;
            let mut link: Box<dyn Transport> =
                Box::new(SocketTransport::from_stream(stream)?);
            let first = match link.recv() {
                Ok(msg) => msg,
                Err(e) => {
                    eprintln!("[recover] rejected a peer that sent no valid join: {e}");
                    continue;
                }
            };
            ledger.record_recovery(first.encode().len() as u64);
            match first {
                NodeMessage::Join {
                    machine,
                    n: jn,
                    p: jp,
                    local_features,
                    cols_checksum,
                    engine,
                    family: jfam,
                    listen_addr,
                } => {
                    let jm = machine as usize;
                    if jm != k {
                        let msg = format!(
                            "the supervisor is re-admitting machine {k}, not machine {jm}"
                        );
                        eprintln!("[recover] rejected a peer: {msg}");
                        if let Err(e) = link.send(NodeMessage::Abort { message: msg }) {
                            log_lost_abort(jm, "re-admission", &e);
                        }
                        continue;
                    }
                    if jn as usize != n
                        || jp as usize != p
                        || local_features as usize != expected.len()
                        || cols_checksum != crc_u32(expected)
                    {
                        let msg = format!(
                            "worker {k} announced shard (n = {jn}, p = {jp}, features = \
                             {local_features}) but the leader expects (n = {n}, p = {p}, \
                             features = {}) — are the worker's data/partition flags \
                             identical to the leader's?",
                            expected.len()
                        );
                        if let Err(e) =
                            link.send(NodeMessage::Abort { message: msg.clone() })
                        {
                            log_lost_abort(k, "re-admission", &e);
                        }
                        return Err(DlrError::Solver(msg));
                    }
                    let want = &self.engine_names[k];
                    if !want.is_empty() && engine != *want {
                        let msg = format!(
                            "replacement worker {k} runs the '{engine}' engine but the \
                             fit started on '{want}' — mixed engines would break the \
                             bit-identical trajectory contract"
                        );
                        if let Err(e) =
                            link.send(NodeMessage::Abort { message: msg.clone() })
                        {
                            log_lost_abort(k, "re-admission", &e);
                        }
                        return Err(DlrError::Solver(msg));
                    }
                    if jfam != self.family.name() {
                        let msg = format!(
                            "replacement worker {k} derives working statistics under the \
                             '{jfam}' family but the fit runs '{}' — pass the matching \
                             --family to the replacement",
                            self.family.name()
                        );
                        if let Err(e) =
                            link.send(NodeMessage::Abort { message: msg.clone() })
                        {
                            log_lost_abort(k, "re-admission", &e);
                        }
                        return Err(DlrError::Solver(msg));
                    }
                    if self.tree && listen_addr.is_empty() {
                        let msg = format!(
                            "replacement worker {k} announced no peer listener but the \
                             fit runs the tree topology — start it with --topology tree"
                        );
                        if let Err(e) =
                            link.send(NodeMessage::Abort { message: msg.clone() })
                        {
                            log_lost_abort(k, "re-admission", &e);
                        }
                        return Err(DlrError::Solver(msg));
                    }
                    // the replacement's welcome never carries a topology:
                    // a worker with a peer table idles (answering control
                    // traffic star-style) until the supervisor re-issues
                    // the tree to *every* worker under a fresh epoch
                    let welcome = NodeMessage::Welcome {
                        family: self.family.name().to_string(),
                        alpha: self.enet_alpha,
                        topology: None,
                    };
                    ledger.record_recovery(welcome.encode().len() as u64);
                    link.send(welcome).map_err(|e| worker_err(k, e))?;
                    // admitted: lift the handshake deadline for fit traffic
                    raw.set_read_timeout(None)?;
                    return Ok((link, engine, listen_addr));
                }
                NodeMessage::Abort { message } => {
                    return Err(DlrError::Solver(format!(
                        "the replacement worker failed to start: {message}"
                    )))
                }
                other => {
                    eprintln!(
                        "[recover] rejected a peer that sent {} instead of join",
                        other.name()
                    );
                    continue;
                }
            }
        }
    }

    /// Respawn an in-process worker thread for machine `k` from the
    /// retained shard-store respawner.
    fn respawn_in_process(&mut self, k: usize) -> Result<()> {
        let respawner = self.respawner.as_ref().ok_or_else(|| {
            DlrError::Solver(format!(
                "cannot respawn in-process worker {k}: only a store-backed pool \
                 (spawn_from_store) can re-load a shard after its thread died"
            ))
        })?;
        let build = respawner(k);
        let task_done_tx = self
            .task_done_tx
            .clone()
            .expect("in-process pool keeps its task-ack sender");
        let (tx, rx) = mpsc::channel::<ThreadMsg>();
        let (reply_tx, reply_rx) = mpsc::channel::<NodeMessage>();
        self.handles.push(spawn_worker_thread(
            k,
            build,
            rx,
            reply_tx,
            task_done_tx,
            Arc::clone(&self.tasks_done),
        ));
        let mut link: Box<dyn Transport> =
            Box::new(LeaderLink::new(tx.clone(), reply_rx));
        let expected = &self.global_cols[k];
        let engine = handshake(
            link.as_mut(),
            k,
            self.n as u32,
            self.p as u32,
            expected.len() as u32,
            crc_u32(expected),
            self.family,
            self.enet_alpha,
        )?;
        self.engine_names[k] = engine;
        self.links[k] = link;
        self.task_txs[k] = tx;
        Ok(())
    }

    /// Test hook for the fault-injection harness: wrap machine `k`'s live
    /// link in a [`FaultyTransport`] that injures the `at`-th recv.
    #[doc(hidden)]
    pub fn wrap_link(&mut self, k: usize, fault: Fault, at: usize) {
        let inner = self.links.remove(k);
        self.links.insert(k, Box::new(FaultyTransport::new(inner, fault, at)));
    }
}

/// Build machine `k`'s view of the collective tree: its bracket parent and
/// children (from the deterministic pairwise merge bracket — see
/// [`bracket_children`]) resolved to the peer addresses and shard
/// checksums the workers announced at admission.
fn build_topology(
    k: usize,
    epoch: u32,
    peer_timeout_secs: f64,
    listen_addrs: &[String],
    global_cols: &[Vec<u32>],
) -> Topology {
    let m = listen_addrs.len();
    let info = |j: u32| PeerInfo {
        machine: j,
        addr: listen_addrs[j as usize].clone(),
        cols_checksum: crc_u32(&global_cols[j as usize]),
    };
    Topology {
        epoch,
        parent: bracket_parent(m)[k].map(&info),
        children: bracket_children(m)[k].iter().map(|&c| info(c)).collect(),
        peer_timeout_secs,
    }
}

/// One ping/pong round on a single link; `false` means the peer is dead
/// (or wedged past the deadline).
fn probe_one(link: &mut dyn Transport, ledger: &NetworkLedger) -> bool {
    ledger.record_recovery(NodeMessage::Ping.encode().len() as u64);
    if link.send(NodeMessage::Ping).is_err() {
        return false;
    }
    for _ in 0..2 {
        match link.recv() {
            Ok(msg) => {
                ledger.record_recovery(msg.encode().len() as u64);
                if matches!(msg, NodeMessage::Pong) {
                    return true;
                }
                // anything else is the one stale reply — drain and retry
            }
            Err(_) => return false,
        }
    }
    false
}

/// The in-process worker thread body: build the node, announce it, then
/// serve protocol messages and task-lane jobs until the leader hangs up.
/// Shared by the initial spawn and by supervisor respawns
/// ([`WorkerPool::replace_link`]), so a replacement behaves exactly like
/// the worker it stands in for.
fn spawn_worker_thread(
    machine: usize,
    build: NodeBuilder,
    rx: mpsc::Receiver<ThreadMsg>,
    reply_tx: mpsc::Sender<NodeMessage>,
    task_done_tx: mpsc::Sender<()>,
    tasks_done: Arc<AtomicU64>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let mut node = match build() {
            Ok(node) => node,
            Err(e) => {
                if let Err(lost) =
                    reply_tx.send(NodeMessage::Abort { message: e.to_string() })
                {
                    log_lost_abort(machine, "node construction", &lost);
                }
                return;
            }
        };
        if reply_tx.send(node.join_message("")).is_err() {
            return;
        }
        while let Ok(req) = rx.recv() {
            match req {
                ThreadMsg::Task(job) => {
                    job();
                    tasks_done.fetch_add(1, Ordering::Relaxed);
                    if task_done_tx.send(()).is_err() {
                        return; // leader gone
                    }
                }
                // the admission reply of the handshake — the
                // in-process join can only succeed
                ThreadMsg::Proto(NodeMessage::Welcome { .. }) => {}
                ThreadMsg::Proto(msg) => match node.handle(msg) {
                    Ok(Some(reply)) => {
                        if reply_tx.send(reply).is_err() {
                            return; // leader gone
                        }
                    }
                    Ok(None) => return, // clean shutdown
                    Err(e) => {
                        if let Err(lost) =
                            reply_tx.send(NodeMessage::Abort { message: e.to_string() })
                        {
                            log_lost_abort(machine, "request handling", &lost);
                        }
                        return;
                    }
                },
            }
        }
    })
}

/// Validate one node's `Join` announcement and admit it. Shared by the
/// in-process spawn; the socket accept inlines the same checks because it
/// must first learn *which* machine connected.
#[allow(clippy::too_many_arguments)]
fn handshake(
    link: &mut dyn Transport,
    machine: usize,
    n: u32,
    p: u32,
    local_features: u32,
    cols_checksum: u64,
    family: FamilyKind,
    enet_alpha: f64,
) -> Result<String> {
    match link.recv().map_err(|e| worker_err(machine, e))? {
        NodeMessage::Join {
            machine: jm,
            n: jn,
            p: jp,
            local_features: jf,
            cols_checksum: jc,
            engine,
            family: jfam,
            listen_addr: _,
        } => {
            let ok = jm as usize == machine
                && jn == n
                && jp == p
                && jf == local_features
                && jc == cols_checksum
                && jfam == family.name();
            if !ok {
                let msg = format!(
                    "worker {jm} announced shard (n = {jn}, p = {jp}, features = {jf}, \
                     family = {jfam}) but the leader expects machine {machine} with \
                     (n = {n}, p = {p}, features = {local_features}, family = {}) — \
                     are the worker's data/partition/family flags identical to the \
                     leader's?",
                    family.name()
                );
                if let Err(e) = link.send(NodeMessage::Abort { message: msg.clone() }) {
                    log_lost_abort(machine, "admission", &e);
                }
                return Err(DlrError::Solver(msg));
            }
            link.send(NodeMessage::Welcome {
                family: family.name().to_string(),
                alpha: enet_alpha,
                topology: None,
            })
            .map_err(|e| worker_err(machine, e))?;
            Ok(engine)
        }
        NodeMessage::Abort { message } => Err(DlrError::Solver(format!(
            "worker {machine} failed to start: {message}"
        ))),
        other => Err(DlrError::Solver(format!(
            "worker {machine}: expected join, got {}",
            other.name()
        ))),
    }
}

impl TaskExecutor for WorkerPool {
    /// Distribute the jobs round-robin over the in-process worker threads
    /// and block until every one has been acknowledged. A worker that died
    /// gets its share run inline rather than losing the merge; a socket
    /// pool (no local threads) runs everything inline.
    fn run_all(&self, jobs: Vec<Job>) {
        if self.task_txs.is_empty() {
            for job in jobs {
                job();
            }
            return;
        }
        let m = self.task_txs.len();
        let mut pending = 0usize;
        for (j, job) in jobs.into_iter().enumerate() {
            match self.task_txs[j % m].send(ThreadMsg::Task(job)) {
                Ok(()) => pending += 1,
                Err(mpsc::SendError(ThreadMsg::Task(job))) => job(),
                Err(_) => unreachable!("send error returns the message we sent"),
            }
        }
        let done = self
            .task_done_rx
            .as_ref()
            .expect("in-process pool keeps its task-ack channel");
        for _ in 0..pending {
            done.recv().expect("worker pool dropped a task acknowledgement");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for link in &mut self.links {
            let _ = link.send(NodeMessage::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Launch one socket worker *thread* per partition block of `ds`, each
/// serving a [`WorkerNode`] over a real TCP connection to `addr` — the
/// single-binary harness the transport equivalence tests, benches, and
/// examples use. Real deployments run `dglmnet worker` processes instead;
/// the bytes on the wire are identical.
pub fn spawn_local_socket_workers(
    cfg: &TrainConfig,
    ds: &Dataset,
    addr: std::net::SocketAddr,
) -> Vec<JoinHandle<Result<()>>> {
    spawn_local_socket_workers_counted(cfg, ds, addr).0
}

/// [`spawn_local_socket_workers`], additionally returning each worker's
/// shared [`WireCounters`] (indexed by machine) — every byte the worker
/// moves, over its leader link *and* its peer links, lands in its counter.
/// The topology bench reads these to compare leader vs worker bandwidth.
pub fn spawn_local_socket_workers_counted(
    cfg: &TrainConfig,
    ds: &Dataset,
    addr: std::net::SocketAddr,
) -> (Vec<JoinHandle<Result<()>>>, Vec<Arc<WireCounters>>) {
    let partition = crate::solver::dglmnet::DGlmnetSolver::partition_for(ds, cfg);
    let shards = shard_in_memory(&ds.x, &partition);
    let p = ds.n_features();
    let y = Arc::new(ds.y.clone());
    let counters: Vec<Arc<WireCounters>> =
        (0..shards.len()).map(|_| Arc::new(WireCounters::default())).collect();
    let handles = shards
        .into_iter()
        .map(|shard| {
            let cfg = cfg.clone();
            let y = Arc::clone(&y);
            let counters = Arc::clone(&counters[shard.machine]);
            std::thread::spawn(move || {
                let artifacts = crate::runtime::default_artifacts_dir();
                let mut node = WorkerNode::from_shard(&cfg, shard, y, p, &artifacts)?;
                let mut t = SocketTransport::connect_retry(addr, Duration::from_secs(30))?;
                t.share_counters(Arc::clone(&counters));
                let mut peers = if cfg.topology == TopologyKind::Tree {
                    let mut table = PeerTable::bind(t.local_ip()?)?;
                    table.share_counters(Arc::clone(&counters));
                    Some(table)
                } else {
                    None
                };
                node.serve(&mut t, peers.as_mut())
            })
        })
        .collect();
    (handles, counters)
}

/// Launch one socket worker *thread* per machine of an on-disk store, each
/// self-loading its shard file and serving a [`WorkerNode`] over TCP — the
/// store-driven counterpart of [`spawn_local_socket_workers`], used by the
/// out-of-core acceptance tests and the socket example. Real deployments
/// run `dglmnet worker --store <dir> --machine k` processes; the bytes on
/// the wire are identical.
pub fn spawn_local_socket_workers_from_store(
    cfg: &TrainConfig,
    store: &ShardStore,
    addr: std::net::SocketAddr,
) -> Vec<JoinHandle<Result<()>>> {
    (0..store.machines())
        .map(|k| {
            let cfg = cfg.clone();
            let store = store.clone();
            std::thread::spawn(move || {
                let artifacts = crate::runtime::default_artifacts_dir();
                let mut node = WorkerNode::from_store(&cfg, &store, k, &artifacts)?;
                let mut t = SocketTransport::connect_retry(addr, Duration::from_secs(30))?;
                let mut peers = if cfg.topology == TopologyKind::Tree {
                    Some(PeerTable::bind(t.local_ip()?)?)
                } else {
                    None
                };
                node.serve(&mut t, peers.as_mut())
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::{FeaturePartition, PartitionStrategy};
    use crate::config::{EngineKind, TrainConfig};
    use crate::data::synth;

    #[test]
    fn pool_sweeps_match_single_engine() {
        let ds = synth::dna_like(300, 40, 5, 21);
        let cfg = TrainConfig::builder()
            .machines(3)
            .engine(EngineKind::Native)
            .build();
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 40, 3, None);
        let shards = shard_in_memory(&ds.x, &part);
        let mut pool =
            WorkerPool::spawn(&cfg, shards, &ds.y, 40, "artifacts".into()).unwrap();
        assert_eq!(pool.machines(), 3);
        assert_eq!(pool.engine_names, vec!["native"; 3]);
        assert_eq!(pool.transport_kind(), "in-process");

        // cold state: workers derive (w, z) from their own zero margins
        let mut results = Vec::new();
        pool.sweep_all(0.2, 1e-6, 0.0, &mut results).unwrap();
        assert_eq!(results.len(), 3);
        // sum of dmargins across machines must equal the full delta margin
        let n = ds.n_examples();
        let mut dm_sum = vec![0f64; n];
        for r in &results {
            for (i, d) in r.dmargins.iter() {
                dm_sum[i as usize] += d as f64;
            }
        }
        // remap deltas to global ids and recompute margins delta from scratch
        let mut delta = vec![0f32; 40];
        let mut global = SparseVec::new(0);
        for (k, r) in results.iter().enumerate() {
            pool.delta_to_global(k, &r.delta_local, 40, &mut global);
            global.add_scaled_into(&mut delta, 1.0);
        }
        let want = ds.x.margins(&delta);
        for i in 0..n {
            assert!((dm_sum[i] - want[i] as f64).abs() < 1e-3, "i = {i}");
        }
    }

    #[test]
    fn tasks_run_on_worker_threads_not_the_caller() {
        // the leader-offload contract behind the comm subsystem: every job
        // submitted through the TaskExecutor runs on a worker thread
        let ds = synth::dna_like(60, 10, 3, 23);
        let cfg = TrainConfig::builder()
            .machines(2)
            .engine(EngineKind::Native)
            .build();
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 10, 2, None);
        let pool = WorkerPool::spawn(
            &cfg,
            shard_in_memory(&ds.x, &part),
            &ds.y,
            10,
            "artifacts".into(),
        )
        .unwrap();
        let caller = std::thread::current().id();
        let (tx, rx) = std::sync::mpsc::channel();
        let jobs: Vec<crate::cluster::comm::Job> = (0..6)
            .map(|_| {
                let tx = tx.clone();
                Box::new(move || {
                    let _ = tx.send(std::thread::current().id());
                }) as crate::cluster::comm::Job
            })
            .collect();
        pool.run_all(jobs);
        drop(tx);
        let ids: Vec<_> = rx.iter().collect();
        assert_eq!(ids.len(), 6, "run_all must wait for every job");
        for id in ids {
            assert_ne!(id, caller, "merge work must not run on the calling thread");
        }
        assert_eq!(pool.tasks_executed(), 6);
    }

    #[test]
    fn pool_survives_multiple_rounds_reusing_buffers() {
        let ds = synth::dna_like(100, 20, 4, 22);
        let cfg = TrainConfig::builder()
            .machines(2)
            .engine(EngineKind::Native)
            .build();
        let part = FeaturePartition::build(PartitionStrategy::Contiguous, 20, 2, None);
        let mut pool = WorkerPool::spawn(
            &cfg,
            shard_in_memory(&ds.x, &part),
            &ds.y,
            20,
            "artifacts".into(),
        )
        .unwrap();
        let mut results = Vec::new();
        let mut first: Option<Vec<SweepResult>> = None;
        for _ in 0..5 {
            // no Apply between sweeps: worker state is unchanged, so the
            // recycled buffers must reproduce identical results
            pool.sweep_all(0.1, 1e-6, 0.0, &mut results).unwrap();
            assert_eq!(results.len(), 2);
            match &first {
                None => first = Some(results.clone()),
                Some(f) => {
                    for (a, b) in f.iter().zip(&results) {
                        assert_eq!(a.delta_local, b.delta_local);
                        assert_eq!(a.dmargins, b.dmargins);
                    }
                }
            }
        }
    }

    #[test]
    fn pool_lambda_max_and_margins_match_leader_side_math() {
        let ds = synth::dna_like(200, 30, 4, 25);
        let cfg = TrainConfig::builder()
            .machines(3)
            .engine(EngineKind::Native)
            .build();
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 30, 3, None);
        let mut pool = WorkerPool::spawn(
            &cfg,
            shard_in_memory(&ds.x, &part),
            &ds.y,
            30,
            "artifacts".into(),
        )
        .unwrap();
        // distributed λ_max is bit-identical to the full-dataset scan
        let lm = pool.lambda_max().unwrap();
        assert_eq!(lm.to_bits(), crate::solver::regpath::lambda_max(&ds).to_bits());
        // distributed margins rebuild agrees with the by-example SpMV
        let beta: Vec<f32> = (0..30)
            .map(|j| if j % 3 == 0 { 0.1 * (j as f32 + 1.0) } else { 0.0 })
            .collect();
        let mut margins = Vec::new();
        pool.margins_for(&beta, &mut margins).unwrap();
        let want = ds.x.margins(&beta);
        assert_eq!(margins.len(), want.len());
        for i in 0..200 {
            assert!(
                (margins[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                "margins[{i}]: {} vs {}",
                margins[i],
                want[i]
            );
        }
    }

    #[test]
    fn dead_worker_is_probed_out_and_respawned_from_the_store() {
        let ds = synth::dna_like(90, 18, 3, 26);
        let cfg =
            TrainConfig::builder().machines(2).engine(EngineKind::Native).build();
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 18, 2, None);
        let dir = std::env::temp_dir()
            .join(format!("dglmnet_pool_respawn_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ShardStore::create(&dir, &ds, &part, "round-robin").unwrap();
        let y = Arc::new(store.load_y().unwrap());
        let mut pool =
            WorkerPool::spawn_from_store(&cfg, &store, y, "artifacts".into()).unwrap();
        let ledger = NetworkLedger::new();
        // everyone answers the heartbeat on a healthy pool, and probe
        // traffic lands only in the recovery bucket
        assert!(pool.probe_links(Duration::from_secs(2), &ledger).is_empty());
        assert!(ledger.recovery_bytes() > 0);
        assert_eq!(ledger.total_bytes(), 0, "probes never touch the algo ledger");
        // kill worker 1 (its thread exits) and detect it
        pool.links[1].send(NodeMessage::Shutdown).unwrap();
        let dead = pool.probe_links(Duration::from_secs(2), &ledger);
        assert_eq!(dead, vec![1]);
        // respawn from the store, restore state: the pool works again
        pool.replace_link(1, Duration::from_secs(2), &ledger).unwrap();
        let beta: Vec<f32> = (0..18).map(|j| j as f32 * 0.1 - 0.5).collect();
        let margins: Vec<f32> = (0..90).map(|i| (i as f32 * 0.3).sin()).collect();
        pool.sync_full_state(&beta, &margins).unwrap();
        let states = pool.pull_states().unwrap();
        let crc = crate::cluster::protocol::crc_f32(&margins);
        for (k, (beta_local, margins_crc)) in states.iter().enumerate() {
            assert_eq!(*margins_crc, crc, "machine {k}");
            for (l, &g) in pool.global_cols[k].iter().enumerate() {
                assert_eq!(beta_local[l].to_bits(), beta[g as usize].to_bits());
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn state_round_trip_through_the_protocol() {
        let ds = synth::dna_like(80, 12, 3, 24);
        let cfg = TrainConfig::builder()
            .machines(3)
            .engine(EngineKind::Native)
            .build();
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 12, 3, None);
        let mut pool = WorkerPool::spawn(
            &cfg,
            shard_in_memory(&ds.x, &part),
            &ds.y,
            12,
            "artifacts".into(),
        )
        .unwrap();
        let beta: Vec<f32> = (0..12).map(|j| j as f32 * 0.5 - 2.0).collect();
        let margins: Vec<f32> = (0..80).map(|i| (i as f32).cos()).collect();
        pool.sync_full_state(&beta, &margins).unwrap();
        let states = pool.pull_states().unwrap();
        assert_eq!(states.len(), 3);
        let crc = crate::cluster::protocol::crc_f32(&margins);
        for (k, (beta_local, margins_crc)) in states.iter().enumerate() {
            assert_eq!(*margins_crc, crc, "machine {k}");
            for (l, &g) in pool.global_cols[k].iter().enumerate() {
                assert_eq!(beta_local[l].to_bits(), beta[g as usize].to_bits());
            }
        }
    }
}
