//! Long-lived worker threads, one per simulated machine (paper Alg 4 "do in
//! parallel over M machines"). Each worker owns its feature shard and its
//! engine — for the XLA engine that includes a private PJRT client, exactly
//! like the paper's one-process-per-machine deployment. The leader talks to
//! workers over channels; all Δ-state flows back through the (simulated)
//! AllReduce in the driver.
//!
//! The hot path is allocation-free at steady state: the shard-local β
//! gather buffers and the sparse [`SweepResult`] output buffers round-trip
//! through the request/reply channels, so every iteration reuses the same
//! heap blocks instead of allocating `O(M·(n + p))` per sweep.
//!
//! The pool doubles as the cluster's [`TaskExecutor`]: the `cluster::comm`
//! collectives submit their tree-node merge jobs here, so AllReduce merge
//! work runs on worker threads — the leader thread only stages payloads
//! and charges the ledger ([`WorkerPool::tasks_executed`] counts the jobs,
//! which the regression tests use to prove the off-thread contract).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::cluster::comm::{Job, TaskExecutor};
use crate::config::TrainConfig;
use crate::data::shuffle::FeatureShard;
use crate::data::sparse::SparseVec;
use crate::engine::{build_engine, SweepResult};
use crate::error::{DlrError, Result};

enum Request {
    Sweep {
        w: Arc<Vec<f32>>,
        z: Arc<Vec<f32>>,
        /// reusable shard-local β gather (round-trips back in the reply)
        beta_local: Vec<f32>,
        /// reusable sparse output buffers (round-trip back in the reply)
        out: SweepResult,
        lam: f32,
        nu: f32,
    },
    /// One [`TaskExecutor`] job (a tree-node merge); acknowledged on the
    /// task channel when done.
    Task(Job),
    Shutdown,
}

struct Reply {
    machine: usize,
    /// the gather buffer, returned for reuse
    beta_local: Vec<f32>,
    result: Result<SweepResult>,
}

/// Handle to the M worker threads.
pub struct WorkerPool {
    txs: Vec<mpsc::Sender<Request>>,
    rx: mpsc::Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
    /// Global feature ids per machine (ascending within a machine).
    pub global_cols: Vec<Vec<u32>>,
    pub engine_names: Vec<String>,
    /// Reusable per-machine β gather buffers.
    beta_bufs: Vec<Vec<f32>>,
    /// Completion acknowledgements for [`TaskExecutor`] jobs.
    task_done_rx: mpsc::Receiver<()>,
    /// Jobs the workers have executed (observable leader-offload proof).
    tasks_done: Arc<AtomicU64>,
}

impl WorkerPool {
    /// Spawn one worker per shard; every worker builds its engine inside its
    /// own thread (PJRT clients are thread-bound). Fails fast if any engine
    /// fails to build.
    pub fn spawn(
        cfg: &TrainConfig,
        shards: Vec<FeatureShard>,
        n: usize,
        artifacts_dir: std::path::PathBuf,
    ) -> Result<Self> {
        let m = shards.len();
        let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
        let (ready_tx, ready_rx) = mpsc::channel::<(usize, Result<String>)>();
        let (task_done_tx, task_done_rx) = mpsc::channel::<()>();
        let tasks_done = Arc::new(AtomicU64::new(0));
        let mut txs = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        let mut global_cols = Vec::with_capacity(m);

        for shard in shards {
            let machine = shard.machine;
            global_cols.push(shard.global_cols.clone());
            let (tx, rx) = mpsc::channel::<Request>();
            txs.push(tx);
            let reply_tx = reply_tx.clone();
            let ready_tx = ready_tx.clone();
            let task_done_tx = task_done_tx.clone();
            let tasks_done = Arc::clone(&tasks_done);
            let cfg = cfg.clone();
            let dir = artifacts_dir.clone();
            handles.push(std::thread::spawn(move || {
                let mut engine = match build_engine(&cfg, shard, n, &dir) {
                    Ok(e) => {
                        let _ = ready_tx.send((machine, Ok(e.name().to_string())));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send((machine, Err(e)));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Sweep { w, z, beta_local, mut out, lam, nu } => {
                            let result = engine
                                .sweep(&w, &z, &beta_local, lam, nu, &mut out)
                                .map(|()| out);
                            if reply_tx.send(Reply { machine, beta_local, result }).is_err() {
                                return; // leader gone
                            }
                        }
                        Request::Task(job) => {
                            job();
                            tasks_done.fetch_add(1, Ordering::Relaxed);
                            if task_done_tx.send(()).is_err() {
                                return; // leader gone
                            }
                        }
                        Request::Shutdown => return,
                    }
                }
            }));
        }
        drop(ready_tx);
        drop(task_done_tx);

        let mut engine_names = vec![String::new(); m];
        for _ in 0..m {
            let (machine, res) = ready_rx
                .recv()
                .map_err(|_| DlrError::Solver("worker died during startup".into()))?;
            engine_names[machine] = res?;
        }
        Ok(Self {
            txs,
            rx: reply_rx,
            handles,
            global_cols,
            engine_names,
            beta_bufs: vec![Vec::new(); m],
            task_done_rx,
            tasks_done,
        })
    }

    pub fn machines(&self) -> usize {
        self.txs.len()
    }

    /// Total [`TaskExecutor`] jobs the workers have executed — the
    /// leader-offload regression tests assert this grows during fits.
    pub fn tasks_executed(&self) -> u64 {
        self.tasks_done.load(Ordering::Relaxed)
    }

    /// One parallel sweep across all machines (Alg 4 steps 1–2). `beta` is
    /// the global coefficient vector; each worker receives its shard-local
    /// gather. Results land in `out`, indexed by machine; the caller owns
    /// (and should reuse) `out` — its sparse buffers round-trip through the
    /// workers, so steady-state sweeps don't allocate.
    pub fn sweep_all(
        &mut self,
        w: &Arc<Vec<f32>>,
        z: &Arc<Vec<f32>>,
        beta: &[f32],
        lam: f32,
        nu: f32,
        out: &mut Vec<SweepResult>,
    ) -> Result<()> {
        let m = self.machines();
        out.resize_with(m, SweepResult::default);
        for (k, tx) in self.txs.iter().enumerate() {
            let mut beta_local = std::mem::take(&mut self.beta_bufs[k]);
            beta_local.clear();
            beta_local.extend(self.global_cols[k].iter().map(|&g| beta[g as usize]));
            tx.send(Request::Sweep {
                w: Arc::clone(w),
                z: Arc::clone(z),
                beta_local,
                out: std::mem::take(&mut out[k]),
                lam,
                nu,
            })
            .map_err(|_| DlrError::Solver(format!("worker {k} hung up")))?;
        }
        let mut first_err = None;
        for _ in 0..m {
            let reply = self
                .rx
                .recv()
                .map_err(|_| DlrError::Solver("all workers hung up".into()))?;
            self.beta_bufs[reply.machine] = reply.beta_local;
            match reply.result {
                Ok(res) => out[reply.machine] = res,
                Err(e) => first_err = Some(first_err.unwrap_or(e)),
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Remap a shard-local sparse Δβ to global feature ids (the allreduce
    /// contribution of Alg 4 step 3/4) — O(nnz), replacing the old
    /// `scatter_delta`'s O(p) densification. `out` is reused by the caller.
    pub fn delta_to_global(
        &self,
        machine: usize,
        delta_local: &SparseVec,
        p: usize,
        out: &mut SparseVec,
    ) {
        out.clear(p);
        let cols = &self.global_cols[machine];
        debug_assert_eq!(delta_local.dim, cols.len());
        for (local, v) in delta_local.iter() {
            // global ids ascend with local ids inside a machine, so pushes
            // stay sorted
            out.push(cols[local as usize], v);
        }
    }
}

impl TaskExecutor for WorkerPool {
    /// Distribute the jobs round-robin over the worker threads and block
    /// until every one has been acknowledged. A worker that died during
    /// startup gets its share run inline rather than losing the merge.
    fn run_all(&self, jobs: Vec<Job>) {
        let m = self.txs.len();
        let mut pending = 0usize;
        for (j, job) in jobs.into_iter().enumerate() {
            match self.txs[j % m].send(Request::Task(job)) {
                Ok(()) => pending += 1,
                Err(mpsc::SendError(Request::Task(job))) => job(),
                Err(_) => unreachable!("send error returns the request we sent"),
            }
        }
        for _ in 0..pending {
            self.task_done_rx
                .recv()
                .expect("worker pool dropped a task acknowledgement");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::{FeaturePartition, PartitionStrategy};
    use crate::config::{EngineKind, TrainConfig};
    use crate::data::shuffle::shard_in_memory;
    use crate::data::synth;
    use crate::solver::quadratic::stats_native;

    #[test]
    fn pool_sweeps_match_single_engine() {
        let ds = synth::dna_like(300, 40, 5, 21);
        let n = ds.n_examples();
        let cfg = TrainConfig::builder()
            .machines(3)
            .engine(EngineKind::Native)
            .build();
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 40, 3, None);
        let shards = shard_in_memory(&ds.x, &part);
        let mut pool = WorkerPool::spawn(&cfg, shards, n, "artifacts".into()).unwrap();
        assert_eq!(pool.machines(), 3);
        assert_eq!(pool.engine_names, vec!["native"; 3]);

        let margins = vec![0f32; n];
        let (w, z, _) = stats_native(&margins, &ds.y);
        let (w, z) = (Arc::new(w), Arc::new(z));
        let beta = vec![0f32; 40];
        let mut results = Vec::new();
        pool.sweep_all(&w, &z, &beta, 0.2, 1e-6, &mut results).unwrap();
        assert_eq!(results.len(), 3);
        // sum of dmargins across machines must equal the full delta margin
        let mut dm_sum = vec![0f64; n];
        for r in &results {
            for (i, d) in r.dmargins.iter() {
                dm_sum[i as usize] += d as f64;
            }
        }
        // remap deltas to global ids and recompute margins delta from scratch
        let mut delta = vec![0f32; 40];
        let mut global = SparseVec::new(0);
        for (k, r) in results.iter().enumerate() {
            pool.delta_to_global(k, &r.delta_local, 40, &mut global);
            global.add_scaled_into(&mut delta, 1.0);
        }
        let want = ds.x.margins(&delta);
        for i in 0..n {
            assert!((dm_sum[i] - want[i] as f64).abs() < 1e-3, "i = {i}");
        }
    }

    #[test]
    fn tasks_run_on_worker_threads_not_the_caller() {
        // the leader-offload contract behind the comm subsystem: every job
        // submitted through the TaskExecutor runs on a worker thread
        let ds = synth::dna_like(60, 10, 3, 23);
        let cfg = TrainConfig::builder()
            .machines(2)
            .engine(EngineKind::Native)
            .build();
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 10, 2, None);
        let pool =
            WorkerPool::spawn(&cfg, shard_in_memory(&ds.x, &part), 60, "artifacts".into())
                .unwrap();
        let caller = std::thread::current().id();
        let (tx, rx) = std::sync::mpsc::channel();
        let jobs: Vec<crate::cluster::comm::Job> = (0..6)
            .map(|_| {
                let tx = tx.clone();
                Box::new(move || {
                    let _ = tx.send(std::thread::current().id());
                }) as crate::cluster::comm::Job
            })
            .collect();
        pool.run_all(jobs);
        drop(tx);
        let ids: Vec<_> = rx.iter().collect();
        assert_eq!(ids.len(), 6, "run_all must wait for every job");
        for id in ids {
            assert_ne!(id, caller, "merge work must not run on the calling thread");
        }
        assert_eq!(pool.tasks_executed(), 6);
    }

    #[test]
    fn pool_survives_multiple_rounds_reusing_buffers() {
        let ds = synth::dna_like(100, 20, 4, 22);
        let cfg = TrainConfig::builder()
            .machines(2)
            .engine(EngineKind::Native)
            .build();
        let part = FeaturePartition::build(PartitionStrategy::Contiguous, 20, 2, None);
        let mut pool =
            WorkerPool::spawn(&cfg, shard_in_memory(&ds.x, &part), 100, "artifacts".into())
                .unwrap();
        let margins = vec![0f32; 100];
        let (w, z, _) = stats_native(&margins, &ds.y);
        let (w, z) = (Arc::new(w), Arc::new(z));
        let beta = vec![0f32; 20];
        let mut results = Vec::new();
        let mut first: Option<Vec<SweepResult>> = None;
        for _ in 0..5 {
            pool.sweep_all(&w, &z, &beta, 0.1, 1e-6, &mut results).unwrap();
            assert_eq!(results.len(), 2);
            match &first {
                None => first = Some(results.clone()),
                Some(f) => {
                    // same inputs through recycled buffers => same outputs
                    for (a, b) in f.iter().zip(&results) {
                        assert_eq!(a.delta_local, b.delta_local);
                        assert_eq!(a.dmargins, b.dmargins);
                    }
                }
            }
        }
    }
}
