//! Long-lived worker threads, one per simulated machine (paper Alg 4 "do in
//! parallel over M machines"). Each worker owns its feature shard and its
//! engine — for the XLA engine that includes a private PJRT client, exactly
//! like the paper's one-process-per-machine deployment. The leader talks to
//! workers over channels; all Δ-state flows back through the (simulated)
//! AllReduce in the driver.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::config::TrainConfig;
use crate::data::shuffle::FeatureShard;
use crate::engine::{build_engine, SweepResult};
use crate::error::{DlrError, Result};

enum Request {
    Sweep {
        w: Arc<Vec<f32>>,
        z: Arc<Vec<f32>>,
        beta_local: Vec<f32>,
        lam: f32,
        nu: f32,
    },
    Shutdown,
}

struct Reply {
    machine: usize,
    result: Result<SweepResult>,
}

/// Handle to the M worker threads.
pub struct WorkerPool {
    txs: Vec<mpsc::Sender<Request>>,
    rx: mpsc::Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
    /// Global feature ids per machine (ascending within a machine).
    pub global_cols: Vec<Vec<u32>>,
    pub engine_names: Vec<String>,
}

impl WorkerPool {
    /// Spawn one worker per shard; every worker builds its engine inside its
    /// own thread (PJRT clients are thread-bound). Fails fast if any engine
    /// fails to build.
    pub fn spawn(
        cfg: &TrainConfig,
        shards: Vec<FeatureShard>,
        n: usize,
        artifacts_dir: std::path::PathBuf,
    ) -> Result<Self> {
        let m = shards.len();
        let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
        let (ready_tx, ready_rx) = mpsc::channel::<(usize, Result<String>)>();
        let mut txs = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        let mut global_cols = Vec::with_capacity(m);

        for shard in shards {
            let machine = shard.machine;
            global_cols.push(shard.global_cols.clone());
            let (tx, rx) = mpsc::channel::<Request>();
            txs.push(tx);
            let reply_tx = reply_tx.clone();
            let ready_tx = ready_tx.clone();
            let cfg = cfg.clone();
            let dir = artifacts_dir.clone();
            handles.push(std::thread::spawn(move || {
                let mut engine = match build_engine(&cfg, shard, n, &dir) {
                    Ok(e) => {
                        let _ = ready_tx.send((machine, Ok(e.name().to_string())));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send((machine, Err(e)));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Sweep { w, z, beta_local, lam, nu } => {
                            let result = engine.sweep(&w, &z, &beta_local, lam, nu);
                            if reply_tx.send(Reply { machine, result }).is_err() {
                                return; // leader gone
                            }
                        }
                        Request::Shutdown => return,
                    }
                }
            }));
        }
        drop(ready_tx);

        let mut engine_names = vec![String::new(); m];
        for _ in 0..m {
            let (machine, res) = ready_rx
                .recv()
                .map_err(|_| DlrError::Solver("worker died during startup".into()))?;
            engine_names[machine] = res?;
        }
        Ok(Self { txs, rx: reply_rx, handles, global_cols, engine_names })
    }

    pub fn machines(&self) -> usize {
        self.txs.len()
    }

    /// One parallel sweep across all machines (Alg 4 steps 1–2). `beta` is
    /// the global coefficient vector; each worker receives its shard-local
    /// gather. Returns results indexed by machine.
    pub fn sweep_all(
        &self,
        w: &Arc<Vec<f32>>,
        z: &Arc<Vec<f32>>,
        beta: &[f32],
        lam: f32,
        nu: f32,
    ) -> Result<Vec<SweepResult>> {
        let m = self.machines();
        for (k, tx) in self.txs.iter().enumerate() {
            let beta_local: Vec<f32> = self.global_cols[k]
                .iter()
                .map(|&g| beta[g as usize])
                .collect();
            tx.send(Request::Sweep {
                w: Arc::clone(w),
                z: Arc::clone(z),
                beta_local,
                lam,
                nu,
            })
            .map_err(|_| DlrError::Solver(format!("worker {k} hung up")))?;
        }
        let mut out: Vec<Option<SweepResult>> = (0..m).map(|_| None).collect();
        for _ in 0..m {
            let reply = self
                .rx
                .recv()
                .map_err(|_| DlrError::Solver("all workers hung up".into()))?;
            out[reply.machine] = Some(reply.result?);
        }
        Ok(out.into_iter().map(Option::unwrap).collect())
    }

    /// Scatter shard-local deltas into a dense global vector per machine
    /// (the allreduce contribution of Alg 4 step 3/4).
    pub fn scatter_delta(&self, machine: usize, delta_local: &[f32], p: usize) -> Vec<f32> {
        let mut out = vec![0f32; p];
        for (&g, &d) in self.global_cols[machine].iter().zip(delta_local) {
            out[g as usize] = d;
        }
        out
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::{FeaturePartition, PartitionStrategy};
    use crate::config::{EngineKind, TrainConfig};
    use crate::data::shuffle::shard_in_memory;
    use crate::data::synth;
    use crate::solver::quadratic::stats_native;

    #[test]
    fn pool_sweeps_match_single_engine() {
        let ds = synth::dna_like(300, 40, 5, 21);
        let n = ds.n_examples();
        let cfg = TrainConfig::builder()
            .machines(3)
            .engine(EngineKind::Native)
            .build();
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 40, 3, None);
        let shards = shard_in_memory(&ds.x, &part);
        let pool = WorkerPool::spawn(&cfg, shards, n, "artifacts".into()).unwrap();
        assert_eq!(pool.machines(), 3);
        assert_eq!(pool.engine_names, vec!["native"; 3]);

        let margins = vec![0f32; n];
        let (w, z, _) = stats_native(&margins, &ds.y);
        let (w, z) = (Arc::new(w), Arc::new(z));
        let beta = vec![0f32; 40];
        let results = pool.sweep_all(&w, &z, &beta, 0.2, 1e-6).unwrap();
        assert_eq!(results.len(), 3);
        // sum of dmargins across machines must equal the full delta margin
        let mut dm_sum = vec![0f64; n];
        for r in &results {
            for (i, &d) in r.dmargins.iter().enumerate() {
                dm_sum[i] += d as f64;
            }
        }
        // scatter deltas and recompute margins delta from scratch
        let mut delta = vec![0f32; 40];
        for (k, r) in results.iter().enumerate() {
            let dg = pool.scatter_delta(k, &r.delta_local, 40);
            for j in 0..40 {
                delta[j] += dg[j];
            }
        }
        let want = ds.x.margins(&delta);
        for i in 0..n {
            assert!((dm_sum[i] - want[i] as f64).abs() < 1e-3, "i = {i}");
        }
    }

    #[test]
    fn pool_survives_multiple_rounds() {
        let ds = synth::dna_like(100, 20, 4, 22);
        let cfg = TrainConfig::builder()
            .machines(2)
            .engine(EngineKind::Native)
            .build();
        let part = FeaturePartition::build(PartitionStrategy::Contiguous, 20, 2, None);
        let pool = WorkerPool::spawn(&cfg, shard_in_memory(&ds.x, &part), 100, "artifacts".into())
            .unwrap();
        let margins = vec![0f32; 100];
        let (w, z, _) = stats_native(&margins, &ds.y);
        let (w, z) = (Arc::new(w), Arc::new(z));
        for _ in 0..5 {
            let r = pool.sweep_all(&w, &z, &vec![0f32; 20], 0.1, 1e-6).unwrap();
            assert_eq!(r.len(), 2);
        }
    }
}
