//! Regularization path — paper Algorithm 5 and §4.2 protocol:
//! find λ_max (whole β = 0), then solve at λ_max·2⁻¹ … λ_max·2⁻²⁰ with
//! warmstarts, recording test quality (AUPRC) vs model sparsity for each λ —
//! the points of Figure 1 — plus per-λ timing for Table 3.

use crate::config::{PathConfig, TrainConfig};
use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::family::FamilyKind;
use crate::metrics;
use crate::solver::dglmnet::DGlmnetSolver;
use crate::solver::estimator::{Estimator, NoopObserver};
use crate::solver::model::SparseModel;
use crate::util::timer::Stopwatch;

/// λ_max for logistic pure-L1 (the paper's setting): at β = 0, p_i = ½,
/// w_i = ¼, z_i = 2y_i, so the per-feature screening value is
/// |Σ_i w_i x_ij z_i| = |Σ_i x_ij y_i| / 2. The family/elastic-net
/// generalization is [`lambda_max_family`]; this is its logistic α = 1
/// case (bit-identical — ×½ and ÷1 are exact).
pub fn lambda_max(ds: &Dataset) -> f64 {
    lambda_max_family(ds, FamilyKind::Logistic, 1.0)
}

/// λ_max for any family and elastic-net mix: the smallest λ at which the
/// zero-gradient `max_j |Σ_i x_ij t_i| · scale` is dominated by the L1
/// share λ·α, i.e. that max divided by α. The targets `t` and `scale` come
/// from the family (logistic: t = y, scale = ½; gaussian: t = y; poisson:
/// t = y − 1).
///
/// Computed by-feature over a CSC view with the same unrolled
/// [`gather_dot4`](crate::util::math::gather_dot4) reduction every engine's
/// `lambda_max_local` uses, so the distributed max-reduce is bit-identical
/// to this leader-side scan (a CSC column holds exactly a shard column's
/// ascending example contributions).
pub fn lambda_max_family(ds: &Dataset, family: FamilyKind, enet_alpha: f64) -> f64 {
    let fam = family.family();
    let mut scratch = Vec::new();
    let targets = fam.lambda_max_targets(&ds.y, &mut scratch);
    let scale = fam.lambda_max_scale();
    let csc = ds.x.to_csc();
    let mut best = 0f64;
    for j in 0..csc.n_cols {
        let (rows, vals) = csc.col(j);
        best = best.max(crate::util::math::gather_dot4(rows, vals, targets).abs() * scale);
    }
    best / enet_alpha
}

/// One Figure-1 point.
#[derive(Debug, Clone)]
pub struct PathPoint {
    pub lambda: f64,
    pub nnz: usize,
    pub auprc: f64,
    pub auc: f64,
    pub test_logloss: f64,
    pub objective: f64,
    pub iterations: usize,
    pub wall_secs: f64,
    pub sim_compute_secs: f64,
    pub sim_comm_secs: f64,
    pub line_search_frac: f64,
    pub model: SparseModel,
}

/// Aggregate of a full path run (one Table-3 row).
#[derive(Debug)]
pub struct RegPath {
    pub points: Vec<PathPoint>,
    pub total_iterations: usize,
    pub total_wall_secs: f64,
    pub total_sim_comm_secs: f64,
    pub total_comm_bytes: u64,
    /// Fraction of solver wall time spent in the line search (Table 3's
    /// "linear search" column).
    pub line_search_frac: f64,
}

impl RegPath {
    /// Run the full path on `train`, scoring each λ's model on `test`.
    pub fn run(
        train: &Dataset,
        test: &Dataset,
        cfg: &TrainConfig,
        path_cfg: &PathConfig,
    ) -> Result<RegPath> {
        let mut solver = DGlmnetSolver::from_dataset(train, cfg)?;
        Self::run_with_solver(&mut solver, train, test, cfg, path_cfg)
    }

    /// Same, reusing an existing solver (keeps the worker pool warm across
    /// experiment sweeps). Builds the λ_max·2⁻ⁱ ladder, then hands off to
    /// the estimator-generic [`RegPath::run_estimator`].
    pub fn run_with_solver(
        solver: &mut DGlmnetSolver,
        train: &Dataset,
        test: &Dataset,
        cfg: &TrainConfig,
        path_cfg: &PathConfig,
    ) -> Result<RegPath> {
        // distributed reduce over the worker shards — the leader holds no
        // X (bit-identical to `lambda_max(train)`, pinned in tests/store.rs)
        let lam_max = solver.lambda_max_distributed()?;
        let mut lambdas: Vec<f64> =
            (1..=path_cfg.steps).map(|i| lam_max * 0.5f64.powi(i as i32)).collect();
        lambdas.extend(path_cfg.extra_lambdas.iter().copied());
        lambdas.sort_by(|a, b| b.partial_cmp(a).unwrap()); // descending

        solver.cfg.max_iter = path_cfg.max_iter_per_lambda.min(cfg.max_iter.max(1));
        Self::run_estimator(solver, train, test, &lambdas)
    }

    /// The generic path runner: cold-start the estimator, then fit every λ
    /// in the given (descending) ladder with warmstarts, scoring each
    /// fitted model on `test`. Works for **any** [`Estimator`] — d-GLMNET
    /// and the baselines run the identical protocol, no solver-specific
    /// branches.
    pub fn run_estimator(
        est: &mut dyn Estimator,
        train: &Dataset,
        test: &Dataset,
        lambdas: &[f64],
    ) -> Result<RegPath> {
        est.reset();

        let mut points = Vec::with_capacity(lambdas.len());
        let mut total_iters = 0usize;
        let mut total_wall = 0f64;
        let mut total_sim_comm = 0f64;
        let mut total_bytes = 0u64;
        let mut ls_secs = 0f64;
        let mut all_secs = 0f64;

        for &lam in lambdas {
            let sw = Stopwatch::start();
            est.set_lambda(lam);
            let fit = est.fit(train, &mut NoopObserver)?;
            let wall = sw.elapsed_secs();
            let margins = fit.model.predict_margins(&test.x);
            let auprc = metrics::auprc(&margins, &test.y);
            let auc = metrics::roc_auc(&margins, &test.y);
            let test_logloss = metrics::mean_logloss(&margins, &test.y);
            total_iters += fit.iterations;
            total_wall += wall;
            total_sim_comm += fit.sim_comm_secs;
            total_bytes += fit.comm_bytes;
            ls_secs += fit.timers.get("line_search").as_secs_f64();
            all_secs += fit.timers.total().as_secs_f64();
            points.push(PathPoint {
                lambda: lam,
                nnz: fit.nnz(),
                auprc,
                auc,
                test_logloss,
                objective: fit.objective,
                iterations: fit.iterations,
                wall_secs: wall,
                sim_compute_secs: fit.sim_compute_secs,
                sim_comm_secs: fit.sim_comm_secs,
                line_search_frac: if fit.timers.total().as_secs_f64() > 0.0 {
                    fit.timers.fraction("line_search")
                } else {
                    0.0
                },
                model: fit.model,
            });
        }
        Ok(RegPath {
            points,
            total_iterations: total_iters,
            total_wall_secs: total_wall,
            total_sim_comm_secs: total_sim_comm,
            total_comm_bytes: total_bytes,
            line_search_frac: if all_secs > 0.0 { ls_secs / all_secs } else { 0.0 },
        })
    }

    /// The best test AUPRC at each sparsity level (Figure 1 frontier).
    pub fn frontier(&self) -> Vec<(usize, f64)> {
        let mut pts: Vec<(usize, f64)> =
            self.points.iter().map(|p| (p.nnz, p.auprc)).collect();
        pts.sort_by_key(|p| p.0);
        let mut out: Vec<(usize, f64)> = Vec::new();
        let mut best = f64::NEG_INFINITY;
        for (nnz, auprc) in pts {
            if auprc > best {
                best = auprc;
                out.push((nnz, auprc));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, PathConfig, TrainConfig};
    use crate::data::synth;

    fn cfg(m: usize) -> TrainConfig {
        TrainConfig::builder()
            .machines(m)
            .engine(EngineKind::Native)
            .max_iter(30)
            .build()
    }

    #[test]
    fn lambda_max_zeroes_the_path_head() {
        let ds = synth::dna_like(500, 40, 5, 41);
        let lm = lambda_max(&ds);
        assert!(lm > 0.0);
        // λ slightly above λ_max keeps β = 0 (checked in dglmnet tests);
        // here: λ_max/2 (the first path step) must activate something.
        let mut s = DGlmnetSolver::from_dataset(&ds, &cfg(2)).unwrap();
        let fit = s.fit_lambda(lm / 2.0).unwrap();
        assert!(fit.nnz() > 0);
    }

    #[test]
    fn lambda_max_matches_distributed_reduce_bitwise() {
        let ds = synth::webspam_like(200, 800, 12, 42);
        let mut s = DGlmnetSolver::from_dataset(&ds, &cfg(2)).unwrap();
        assert_eq!(
            lambda_max(&ds).to_bits(),
            s.lambda_max_distributed().unwrap().to_bits()
        );
    }

    #[test]
    fn generic_path_runs_a_baseline_estimator() {
        // the same ladder protocol, driven through `&mut dyn Estimator`
        // with no solver-specific branches
        use crate::baselines::truncated_gradient::TruncatedGradientEstimator;
        let split = synth::dna_like(500, 30, 5, 44).split(0.8, 2).unwrap();
        let lam_max = lambda_max(&split.train);
        let lambdas: Vec<f64> = (1..=4).map(|i| lam_max * 0.5f64.powi(i)).collect();
        let mut est = TruncatedGradientEstimator::new(0.2, 0.7, 1.0, 3, 5);
        let path =
            RegPath::run_estimator(&mut est, &split.train, &split.test, &lambdas).unwrap();
        assert_eq!(path.points.len(), 4);
        assert!(path.points.iter().all(|p| p.objective.is_finite()));
        assert!(path.points.iter().all(|p| (0.0..=1.0).contains(&p.auprc)));
        // λ descends through the trait: the last fit used the smallest λ
        assert!((est.lambda() - lambdas[3]).abs() < 1e-12);
    }

    #[test]
    fn short_path_runs_and_nnz_grows() {
        let split = synth::dna_like(900, 50, 6, 43).split(0.8, 1).unwrap();
        let path_cfg = PathConfig { steps: 6, extra_lambdas: vec![], max_iter_per_lambda: 25 };
        let path = RegPath::run(&split.train, &split.test, &cfg(3), &path_cfg).unwrap();
        assert_eq!(path.points.len(), 6);
        // λ descends => nnz non-decreasing (up to small solver noise)
        let nnz: Vec<usize> = path.points.iter().map(|p| p.nnz).collect();
        assert!(nnz.last().unwrap() >= nnz.first().unwrap(), "{nnz:?}");
        // quality sane
        let best = path.points.iter().map(|p| p.auprc).fold(0.0, f64::max);
        assert!(best > 0.3, "best auprc = {best}");
        assert!(path.total_iterations >= 6);
        let frontier = path.frontier();
        assert!(!frontier.is_empty());
        let ys: Vec<f64> = frontier.iter().map(|p| p.1).collect();
        assert!(ys.windows(2).all(|w| w[1] >= w[0]));
    }
}
