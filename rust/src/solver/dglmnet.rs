//! The d-GLMNET solver — paper Algorithm 1 (overall procedure) fused with
//! Algorithm 4 (the distributed implementation):
//!
//! ```text
//! repeat until convergence:
//!   1. leader: loss from its margins                       [stats kernel]
//!   2. workers (M nodes): (w, z) from their own margins,
//!      one CD sweep over their β shard                     [cd_sweep kernel]
//!   3. gather Δβ, exchange/recombine Δm                    [cluster::comm]
//!   4. leader: line search over α                          [line_search kernel]
//!   5. leader and every node: β += αΔβ ; margins += αΔm    [apply phase]
//! ```
//!
//! The iteration body itself lives in [`FitDriver::step`] — this type owns
//! the cluster handle (a [`WorkerPool`] driving worker *nodes* through the
//! serializable node protocol, in-process or over sockets), the leader's
//! global warmstart state (β, margins), the EWMA comm estimators, and the
//! reusable `FitScratch` buffers, and exposes three ways to train:
//!
//! * [`DGlmnetSolver::driver`] — the stepwise API: callers own the loop
//!   (observers, checkpoint/resume, budgets).
//! * [`Estimator::fit`] — the uniform trait interface shared with the
//!   baselines (one fit at `cfg.lambda` from the current state).
//! * [`DGlmnetSolver::fit`] / [`DGlmnetSolver::fit_lambda`] — the original
//!   one-shot entry points, kept as thin wrappers over the driver.
//!
//! Workers hold their own β shard and margins (see [`crate::cluster::node`]);
//! the leader's global copies stay bit-identical to the union of the
//! worker-held shards. Convergence carries the paper's two sparsity
//! precautions: the line search's full-step shortcut, and the final α = 1
//! retry before stopping. Every large per-iteration buffer lives in
//! `FitScratch` (the leader computes only the O(n) loss now — the w/z
//! working vectors moved into the nodes), so the steady-state hot path
//! allocates only the O(M) bookkeeping of the comm layer.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::cluster::allreduce::{AllReduceScratch, TreeAllReduce};
use crate::cluster::codec::CodecPolicy;
use crate::cluster::comm::{AllGather, TreeByteEstimator};
use crate::cluster::network::NetworkLedger;
use crate::cluster::partition::FeaturePartition;
use crate::cluster::protocol::crc_f32;
use crate::cluster::transport::Fault;
use crate::config::{ExchangeStrategy, TrainConfig, TransportKind};
use crate::data::dataset::Dataset;
use crate::data::shuffle::FeatureShard;
use crate::data::sparse::SparseVec;
use crate::data::store::ShardStore;
use crate::engine::SweepResult;
use crate::error::{DlrError, Result};
use crate::runtime::default_artifacts_dir;
use crate::solver::driver::{Checkpoint, FitDriver};
use crate::solver::estimator::{Estimator, FitObserver, NoopObserver};
use crate::solver::leader::LeaderCompute;
use crate::solver::model::SparseModel;
use crate::solver::pool::WorkerPool;
use crate::util::timer::PhaseTimer;

/// How long a socket leader waits for all workers to connect.
const ACCEPT_TIMEOUT: Duration = Duration::from_secs(120);

/// How long the supervisor waits for a replacement worker to connect.
const REPLACE_TIMEOUT: Duration = Duration::from_secs(120);

/// `cfg.recv_timeout_secs` as the per-link deadline (0 disables it).
fn recv_deadline(cfg: &TrainConfig) -> Option<Duration> {
    (cfg.recv_timeout_secs > 0.0).then(|| Duration::from_secs_f64(cfg.recv_timeout_secs))
}

/// Uniquifier for the in-memory adapter's temp stores (several solvers may
/// coexist in one process — tests, benches, tournaments).
static TEMP_STORE_SEQ: AtomicU64 = AtomicU64::new(0);

fn fresh_temp_store_dir() -> PathBuf {
    std::env::temp_dir().join(format!(
        "dglmnet_tmp_store_{}_{}",
        std::process::id(),
        TEMP_STORE_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// The engine name remote workers must announce when the leader pins a
/// concrete engine kind (`Auto` resolves per shard on each host, so it
/// cannot be validated centrally).
fn pinned_engine(cfg: &TrainConfig) -> Option<&'static str> {
    match cfg.engine {
        crate::config::EngineKind::Native => Some("native"),
        crate::config::EngineKind::Xla => Some("xla"),
        crate::config::EngineKind::Auto => None,
    }
}

/// Per-iteration record (feeds Table 3, the ablation benches, and every
/// [`FitObserver`] callback).
#[derive(Debug, Clone)]
pub struct IterationRecord {
    pub iter: usize,
    pub objective: f64,
    pub alpha: f64,
    pub fast_path: bool,
    /// max over machines of the local sweep time (including the node's own
    /// (w, z) derivation) — the simulated parallel compute time of this
    /// iteration.
    pub max_worker_secs: f64,
    /// simulated AllReduce seconds (network model).
    pub sim_comm_secs: f64,
    /// bytes this iteration's Δ-exchange moved (per-iteration delta, *not*
    /// cumulative since fit start).
    pub comm_bytes: u64,
    /// Which Δ-exchange strategy this iteration ran (`None` for estimators
    /// without a distributed Δ-exchange — the §4.3 baselines). Never
    /// [`ExchangeStrategy::Auto`]: the cost model's choice is recorded.
    pub exchange: Option<ExchangeStrategy>,
    pub wall_secs: f64,
}

/// Result of one fit (any [`Estimator`], not just d-GLMNET).
#[derive(Debug)]
pub struct FitResult {
    pub lambda: f64,
    pub objective: f64,
    pub iterations: usize,
    pub converged: bool,
    pub model: SparseModel,
    pub trace: Vec<IterationRecord>,
    pub timers: PhaseTimer,
    /// Sum over iterations of max-worker + leader time (simulated parallel
    /// wall-clock) and of simulated network time.
    pub sim_compute_secs: f64,
    pub sim_comm_secs: f64,
    pub comm_bytes: u64,
}

impl FitResult {
    pub fn nnz(&self) -> usize {
        self.model.nnz()
    }
}

/// Reusable per-solver buffers for the iteration hot path. Everything here
/// is cleared-and-refilled each iteration; capacities persist, so after the
/// first iteration the loop's O(n + p) buffers allocate nothing — the only
/// steady-state allocations left are the comm layer's O(M) bookkeeping
/// (boxed merge jobs, their ack channel, and the contribution ref lists),
/// the price of running tree merges on the worker pool.
#[derive(Debug, Default)]
pub(crate) struct FitScratch {
    /// per-machine sweep outputs (sparse buffers round-trip via the pool's
    /// `Sweep.recycle` slot)
    pub(crate) results: Vec<SweepResult>,
    /// per-machine Δβ contributions remapped to global feature ids
    pub(crate) db_contribs: Vec<SparseVec>,
    /// tree-allreduce intermediate state
    pub(crate) ar: AllReduceScratch,
    /// per-machine nnz counts for the exchange-strategy cost estimate
    pub(crate) est_nnz: Vec<usize>,
    /// merged sparse Δβ / Δm — `Arc` so the apply phase can hand the same
    /// buffers to every in-process worker without copying; `Arc::make_mut`
    /// reclaims them once the workers drop their clones, so steady state
    /// stops allocating
    pub(crate) delta_sp: Arc<SparseVec>,
    pub(crate) dmargins_sp: Arc<SparseVec>,
    /// dense views for the line search / apply step
    pub(crate) delta: Vec<f32>,
    pub(crate) dmargins: Vec<f32>,
    /// support union of β and Δβ
    pub(crate) support: Vec<u32>,
}

/// The distributed solver: owns the cluster handle and the leader-side
/// warmstart state (β, margins) across `fit_lambda` calls — exactly what
/// Alg 5 needs.
pub struct DGlmnetSolver {
    pub cfg: TrainConfig,
    pub(crate) n: usize,
    pub(crate) p: usize,
    pub(crate) y: Vec<f32>,
    pub(crate) partition: FeaturePartition,
    pub(crate) pool: WorkerPool,
    pub(crate) leader: LeaderCompute,
    pub(crate) allreduce: TreeAllReduce,
    pub(crate) allgather: AllGather,
    pub(crate) policy: CodecPolicy,
    pub(crate) ledger: NetworkLedger,
    pub(crate) scratch: FitScratch,
    /// EWMA byte estimator for the Δm allreduce (full reduce + broadcast).
    pub(crate) est_dm: TreeByteEstimator,
    /// EWMA byte estimator for the Δβ gather (no broadcast — workers hold
    /// their own shards).
    pub(crate) est_db: TreeByteEstimator,
    /// Worker-held state is stale (a reset / warmstart install / legacy
    /// resume touched the leader copies); the next step or checkpoint
    /// pushes it before using it.
    pub(crate) workers_dirty: bool,
    /// Temp store directory backing the in-memory adapter constructors
    /// (removed on drop). `None` when the caller owns the store.
    temp_store: Option<PathBuf>,
    /// Current coefficients (warmstart state).
    pub beta: Vec<f32>,
    /// Current margins βᵀx_i, kept consistent with `beta`.
    pub margins: Vec<f32>,
}

impl Drop for DGlmnetSolver {
    fn drop(&mut self) {
        if let Some(dir) = self.temp_store.take() {
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

impl DGlmnetSolver {
    /// The feature partition `cfg` implies for `ds` — deterministic, so a
    /// remote worker process given the same data and config builds the
    /// exact shard the leader expects (validated by the join handshake).
    pub fn partition_for(ds: &Dataset, cfg: &TrainConfig) -> FeaturePartition {
        let csc_counts: Vec<usize> = {
            let mut counts = vec![0usize; ds.n_features()];
            for &c in &ds.x.indices {
                counts[c as usize] += 1;
            }
            counts
        };
        FeaturePartition::build(
            cfg.partition,
            ds.n_features(),
            cfg.machines,
            Some(&csc_counts),
        )
    }

    /// The shard [`DGlmnetSolver::partition_for`] assigns to `machine` —
    /// the single construction path every remote worker uses (the
    /// `dglmnet worker` CLI, the socket examples and tests), column-exact
    /// with what `shard_in_memory` builds for the in-process pool.
    pub fn shard_for(ds: &Dataset, cfg: &TrainConfig, machine: usize) -> FeatureShard {
        let partition = Self::partition_for(ds, cfg);
        let global_cols = partition.features_of(machine);
        let cols_usize: Vec<usize> = global_cols.iter().map(|&c| c as usize).collect();
        FeatureShard { machine, global_cols, csc: ds.x.to_csc().select_cols(&cols_usize) }
    }

    /// Build the cluster from an on-disk [`ShardStore`] — the out-of-core
    /// path: workers self-load their shard files (in-process threads, or
    /// remote `dglmnet worker --store` processes validated against the
    /// manifest), and the leader holds only `y`, β and the margins — it
    /// never constructs a CSR/CSC matrix of X, so its memory is O(n + p)
    /// regardless of nnz.
    pub fn from_store(store: &ShardStore, cfg: &TrainConfig) -> Result<Self> {
        cfg.validate()?;
        Self::validate_store_for(store, cfg)?;
        let partition = store.partition()?;
        match cfg.transport {
            TransportKind::InProcess => {
                let y = Arc::new(store.load_y()?);
                let pool = WorkerPool::spawn_from_store(
                    cfg,
                    store,
                    Arc::clone(&y),
                    default_artifacts_dir(),
                )?;
                Self::assemble(y.as_slice(), cfg, partition, pool)
            }
            TransportKind::Socket => {
                let y = store.load_y()?;
                let pool = WorkerPool::listen_and_accept(
                    &partition,
                    store.n(),
                    pinned_engine(cfg),
                    cfg.family,
                    cfg.enet_alpha,
                    cfg.topology,
                    cfg.recv_timeout_secs,
                    cfg.listen.as_str(),
                    ACCEPT_TIMEOUT,
                )?;
                Self::assemble(&y, cfg, partition, pool)
            }
        }
    }

    /// Build the cluster straight from the config's `[data] store` /
    /// `--store` directory: opens the [`ShardStore`] named by
    /// [`TrainConfig::store`] and dispatches to
    /// [`DGlmnetSolver::from_store`] — the entry point for callers that
    /// route everything through configuration. (The CLI's `train --store`
    /// path opens the store itself so it can print the manifest summary,
    /// then calls `from_store` — same sequence.)
    pub fn from_config(cfg: &TrainConfig) -> Result<Self> {
        let dir = cfg.store.as_deref().ok_or_else(|| {
            DlrError::Config(
                "from_config needs [data] store / --store to name a shard-store \
                 directory (use from_dataset for in-memory training)"
                    .into(),
            )
        })?;
        let store = ShardStore::open(dir)?;
        Self::from_store(&store, cfg)
    }

    /// Store-driven socket constructor over an already-bound listener:
    /// bind port 0, hand the concrete address to `dglmnet worker --store`
    /// processes (or [`spawn_local_socket_workers_from_store`]), then
    /// accept — the out-of-core acceptance tests use this.
    ///
    /// [`spawn_local_socket_workers_from_store`]:
    /// crate::solver::pool::spawn_local_socket_workers_from_store
    pub fn from_store_socket(
        store: &ShardStore,
        cfg: &TrainConfig,
        listener: TcpListener,
    ) -> Result<Self> {
        cfg.validate()?;
        Self::validate_store_for(store, cfg)?;
        let partition = store.partition()?;
        let y = store.load_y()?;
        let pool = WorkerPool::accept(
            &partition,
            store.n(),
            pinned_engine(cfg),
            cfg.family,
            cfg.enet_alpha,
            cfg.topology,
            cfg.recv_timeout_secs,
            listener,
            ACCEPT_TIMEOUT,
        )?;
        Self::assemble(&y, cfg, partition, pool)
    }

    fn validate_store_for(store: &ShardStore, cfg: &TrainConfig) -> Result<()> {
        cfg.validate_machines_for(store.p())?;
        if cfg.machines != store.machines() {
            return Err(DlrError::Config(format!(
                "the store at {} was sharded for {} machines but the cluster is \
                 configured for {} — re-shard with `dglmnet shard --machines {}` \
                 or set [cluster] workers / --workers to {}",
                store.dir().display(),
                store.machines(),
                cfg.machines,
                cfg.machines,
                store.machines()
            )));
        }
        Ok(())
    }

    /// Build the cluster from a by-example dataset. This is a thin adapter
    /// over the store path: with the default `transport = in-process` it
    /// writes a temp [`ShardStore`] (removed when the solver drops) and the
    /// workers self-load from it — bit-identical trajectories to the
    /// store-driven run by construction, pinned in `tests/store.rs`. With
    /// `transport = socket` it listens on `cfg.listen` and admits one
    /// remote `dglmnet worker` process per partition block.
    pub fn from_dataset(ds: &Dataset, cfg: &TrainConfig) -> Result<Self> {
        cfg.validate()?;
        cfg.validate_machines_for(ds.n_features())?;
        match cfg.transport {
            TransportKind::InProcess => {
                let partition = Self::partition_for(ds, cfg);
                let dir = fresh_temp_store_dir();
                let built = ShardStore::create(&dir, ds, &partition, cfg.partition.name())
                    .and_then(|store| Self::from_store(&store, cfg));
                match built {
                    Ok(mut solver) => {
                        solver.temp_store = Some(dir);
                        Ok(solver)
                    }
                    Err(e) => {
                        let _ = std::fs::remove_dir_all(&dir);
                        Err(e)
                    }
                }
            }
            TransportKind::Socket => {
                let partition = Self::partition_for(ds, cfg);
                let pool = WorkerPool::listen_and_accept(
                    &partition,
                    ds.n_examples(),
                    pinned_engine(cfg),
                    cfg.family,
                    cfg.enet_alpha,
                    cfg.topology,
                    cfg.recv_timeout_secs,
                    cfg.listen.as_str(),
                    ACCEPT_TIMEOUT,
                )?;
                Self::assemble(&ds.y, cfg, partition, pool)
            }
        }
    }

    /// Socket-transport constructor over an already-bound listener: bind
    /// port 0, hand the concrete address to the workers, then accept —
    /// what the transport-equivalence tests and the multi-process example
    /// use.
    pub fn from_dataset_socket(
        ds: &Dataset,
        cfg: &TrainConfig,
        listener: TcpListener,
    ) -> Result<Self> {
        cfg.validate()?;
        cfg.validate_machines_for(ds.n_features())?;
        let partition = Self::partition_for(ds, cfg);
        let pool = WorkerPool::accept(
            &partition,
            ds.n_examples(),
            pinned_engine(cfg),
            cfg.family,
            cfg.enet_alpha,
            cfg.topology,
            cfg.recv_timeout_secs,
            listener,
            ACCEPT_TIMEOUT,
        )?;
        Self::assemble(&ds.y, cfg, partition, pool)
    }

    /// Build from pre-sharded by-feature data already in memory (callers
    /// that ran [`shuffle_to_feature_shards`] themselves); always
    /// in-process — remote workers load their own shards.
    ///
    /// [`shuffle_to_feature_shards`]: crate::data::shuffle::shuffle_to_feature_shards
    pub fn from_shards(
        ds: &Dataset,
        cfg: &TrainConfig,
        partition: FeaturePartition,
        shards: Vec<FeatureShard>,
    ) -> Result<Self> {
        cfg.validate()?;
        cfg.validate_machines_for(ds.n_features())?;
        if shards.len() != cfg.machines {
            return Err(DlrError::Solver(format!(
                "{} shards but {} machines",
                shards.len(),
                cfg.machines
            )));
        }
        // Every machine must own >= 1 feature (validate_machines_for
        // guarantees it for the built-in partitioners; external shards are
        // re-checked here).
        for s in &shards {
            if s.global_cols.is_empty() {
                return Err(DlrError::Solver(format!(
                    "machine {} owns no features (p = {} < machines = {}?)",
                    s.machine,
                    ds.n_features(),
                    cfg.machines
                )));
            }
        }
        let artifacts = default_artifacts_dir();
        let pool =
            WorkerPool::spawn(cfg, shards, &ds.y, ds.n_features(), artifacts)?;
        Self::assemble(&ds.y, cfg, partition, pool)
    }

    /// Final assembly: the leader's state is `y`, β and the margins — the
    /// O(n + p) footprint. X lives only in the workers (their shards).
    fn assemble(
        y: &[f32],
        cfg: &TrainConfig,
        partition: FeaturePartition,
        mut pool: WorkerPool,
    ) -> Result<Self> {
        pool.set_recv_deadline(recv_deadline(cfg))?;
        // fail fast on the leader with the actionable message rather than
        // letting the narrowest worker's engine build error surface later
        cfg.validate_sweep_threads_for(partition.sizes().iter().copied().min().unwrap_or(0))?;
        cfg.family.family().validate_labels(y)?;
        let artifacts = default_artifacts_dir();
        let n = y.len();
        let p = partition.n_features();
        let leader = LeaderCompute::new(cfg, y, &artifacts)?;
        // dense_allreduce reproduces the pre-sparsity baseline: dense
        // charging on every edge, classic reduce-Δm exchange
        let policy = CodecPolicy {
            force_dense: cfg.dense_allreduce,
            f16_margins: cfg.wire_f16_margins,
            f16_beta: cfg.wire_f16_beta,
        };
        Ok(Self {
            cfg: cfg.clone(),
            n,
            p,
            y: y.to_vec(),
            partition,
            pool,
            leader,
            allreduce: TreeAllReduce::new(cfg.network),
            allgather: AllGather::new(cfg.network),
            policy,
            ledger: NetworkLedger::new(),
            scratch: FitScratch::default(),
            est_dm: TreeByteEstimator::new(true),
            est_db: TreeByteEstimator::new(cfg.charge_beta_broadcast),
            workers_dirty: false,
            temp_store: None,
            beta: vec![0f32; p],
            margins: vec![0f32; n],
        })
    }

    /// Tree-merge jobs the `WorkerPool` has executed for the comm layer —
    /// the leader-offload regression tests assert this grows during
    /// in-process fits (a socket pool has no local worker threads).
    pub fn merge_tasks_executed(&self) -> u64 {
        self.pool.tasks_executed()
    }

    /// `"in-process"` or `"socket"`.
    pub fn transport_kind(&self) -> &'static str {
        self.pool.transport_kind()
    }

    /// Current `(Δm, Δβ)` EWMA shrink factors of the comm byte estimator
    /// (1.0 until the auto strategy pick has observed an exchange).
    pub fn comm_estimator_shrink(&self) -> (f64, f64) {
        (self.est_dm.shrink(), self.est_db.shrink())
    }

    /// `(sent, received)` frame bytes measured at the leader's worker
    /// links — the leader's whole bandwidth bill. Under `topology = tree`
    /// the data-plane share is O(1) in the worker count (one Sweep down
    /// and one merged result up per iteration, on the root edge only).
    pub fn leader_wire_bytes(&self) -> (u64, u64) {
        self.pool.wire_bytes()
    }

    /// Current tree-topology epoch (0 = star, or no topology issued yet);
    /// bumped on every supervised re-issue, so tests can assert that a
    /// recovery rebuilt the peer links.
    pub fn topology_epoch(&self) -> u32 {
        self.pool.topology_epoch()
    }

    /// Probe every worker link and replace the dead ones — the supervisor's
    /// recovery hook ([`FitDriver::step`] calls this after a failed
    /// iteration, before rolling back to the recovery checkpoint). Each
    /// link gets a Ping with a `heartbeat_timeout_secs` deadline; links
    /// that fail to answer Pong are replaced — in-process workers respawn
    /// from the shard store, socket workers are re-admitted through the
    /// original listener and validated against the shard checksums. All
    /// probe and re-admission traffic lands in the ledger's recovery
    /// bucket, so the fit's charged comm accounting stays bit-identical to
    /// an undisturbed run.
    pub(crate) fn repair_workers(&mut self) -> Result<()> {
        let timeout = Duration::from_secs_f64(self.cfg.heartbeat_timeout_secs);
        let dead = self.pool.probe_links(timeout, &self.ledger);
        for &k in &dead {
            eprintln!("[supervise] worker {k} is unresponsive; admitting a replacement");
            self.pool.replace_link(k, REPLACE_TIMEOUT, &self.ledger)?;
        }
        self.pool.set_recv_deadline(recv_deadline(&self.cfg))?;
        // Survivors may hold partially-applied state from the failed
        // iteration and replacements start cold — the rollback's next step
        // pushes the full checkpointed (β, margins) to everyone.
        self.workers_dirty = true;
        Ok(())
    }

    /// Bytes the supervisor spent on liveness probes and worker
    /// re-admission — the ledger's recovery bucket, excluded from the
    /// fit's charged comm totals (see [`NetworkLedger::record_recovery`]).
    pub fn recovery_comm_bytes(&self) -> u64 {
        self.ledger.recovery_bytes()
    }

    /// Test hook: injure worker `k`'s link so its `at`-th recv misbehaves
    /// (see [`Fault`]) — the fault-injection harness behind
    /// `tests/failover.rs` and the chaos CI job.
    #[doc(hidden)]
    pub fn wrap_worker_link(&mut self, k: usize, fault: Fault, at: usize) {
        self.pool.wrap_link(k, fault, at);
    }

    /// Elastic join/leave between λ steps: re-partition the `p` features
    /// over `machines` nodes, redistribute the shard payloads from `store`
    /// into a new store at `dir`, and continue from this solver's current
    /// β. The resharded column payloads are copied bit-for-bit and the new
    /// partition is rebuilt from the store's own per-column nnz counts
    /// (identical to what [`DGlmnetSolver::partition_for`] derives from
    /// the full dataset), so the continuation is bit-identical to a fresh
    /// fit at the new machine count warm-started from the same β — pinned
    /// in `tests/failover.rs`. With `transport = socket` the new cluster
    /// listens on `cfg.listen` and admits `machines` fresh workers.
    pub fn elastic_resize(
        &self,
        store: &ShardStore,
        machines: usize,
        dir: &Path,
    ) -> Result<DGlmnetSolver> {
        let mut cfg = self.cfg.clone();
        cfg.machines = machines;
        cfg.validate()?;
        cfg.validate_machines_for(self.p)?;
        let counts = store.col_nnz()?;
        let partition = FeaturePartition::build(cfg.partition, self.p, machines, Some(&counts));
        let resharded = store.reshard(dir, &partition, cfg.partition.name())?;
        let mut next = Self::from_store(&resharded, &cfg)?;
        next.set_beta(&self.beta)?;
        Ok(next)
    }

    pub fn n_examples(&self) -> usize {
        self.n
    }

    pub fn n_features(&self) -> usize {
        self.p
    }

    pub fn partition(&self) -> &FeaturePartition {
        &self.partition
    }

    /// λ_max over the training data this cluster was built on: at β = 0
    /// the per-feature screening value is `max_j |Σ_i x_ij t_i| · scale`
    /// with the family's gradient targets `t` (logistic: t = y,
    /// scale = 1/2), divided by the elastic-net α (the L1 share must still
    /// dominate the zero-gradient). Computed as a **distributed max-reduce
    /// of per-shard gradients** over the node protocol — the leader holds
    /// no X, so each worker scans its own feature block and reports its
    /// local max. Bit-identical to the in-memory
    /// [`lambda_max_family`](crate::solver::regpath::lambda_max_family)
    /// scan for any machine count and either transport (each per-feature
    /// f64 sum accumulates in the same ascending-example order; max over
    /// the disjoint partition is exact), pinned in `tests/store.rs`.
    pub fn lambda_max_distributed(&mut self) -> Result<f64> {
        Ok(self.pool.lambda_max()? / self.cfg.enet_alpha)
    }

    /// Reset warmstart state to β = 0. The worker-held shards are synced
    /// lazily before the next sweep or checkpoint.
    pub fn reset(&mut self) {
        self.beta.fill(0.0);
        self.margins.fill(0.0);
        self.workers_dirty = true;
    }

    /// Install a warmstart β. The margins are rebuilt distributedly: each
    /// worker computes its shard's Σ_j β_j x_ij product locally and the
    /// leader sums the disjoint contributions — no process touches the
    /// whole X. Worker-held shards are then synced lazily before the next
    /// sweep or checkpoint.
    pub fn set_beta(&mut self, beta: &[f32]) -> Result<()> {
        assert_eq!(beta.len(), self.p);
        self.beta.copy_from_slice(beta);
        self.pool.margins_for(beta, &mut self.margins)?;
        self.workers_dirty = true;
        Ok(())
    }

    /// Push (β, margins) to every worker node if the leader copies moved
    /// outside the protocol (reset / warmstart install / legacy resume).
    pub(crate) fn ensure_workers_synced(&mut self) -> Result<()> {
        if self.workers_dirty {
            self.pool.sync_full_state(&self.beta, &self.margins)?;
            self.workers_dirty = false;
        }
        Ok(())
    }

    /// Pull every node's shard state and verify it is bit-identical to the
    /// leader's global (β, margins) — the checkpoint capture path. A
    /// divergence is a hard error: checkpointing corrupt state silently
    /// would poison every resume after it.
    pub(crate) fn pull_verified_shards(&mut self) -> Result<Vec<Vec<f32>>> {
        let states = self.pool.pull_states()?;
        let margins_crc = crc_f32(&self.margins);
        for (k, (beta_local, crc)) in states.iter().enumerate() {
            if *crc != margins_crc {
                return Err(DlrError::Solver(format!(
                    "worker {k} margins diverged from the leader (checksum mismatch)"
                )));
            }
            if beta_local.len() != self.pool.global_cols[k].len() {
                return Err(DlrError::Solver(format!(
                    "worker {k} reported {} coefficients but owns {} features",
                    beta_local.len(),
                    self.pool.global_cols[k].len()
                )));
            }
            for (l, &g) in self.pool.global_cols[k].iter().enumerate() {
                if beta_local[l].to_bits() != self.beta[g as usize].to_bits() {
                    return Err(DlrError::Solver(format!(
                        "worker {k} β shard diverged from the leader at feature {g}"
                    )));
                }
            }
        }
        Ok(states.into_iter().map(|(beta_local, _)| beta_local).collect())
    }

    /// Start a stepwise fit at `lambda` from the current (β, margins) —
    /// the caller owns the loop; see [`FitDriver`].
    pub fn driver(&mut self, lambda: f64) -> FitDriver<'_> {
        FitDriver::new(self, lambda)
    }

    /// Resume a stepwise fit from a [`Checkpoint`] (possibly captured in a
    /// different process): installs (β, margins) bit-for-bit on the leader
    /// and every worker node, restores the comm estimator state, and
    /// continues the iteration count and cost ledger where the checkpoint
    /// left off.
    pub fn driver_from_checkpoint(&mut self, ck: &Checkpoint) -> Result<FitDriver<'_>> {
        FitDriver::from_checkpoint(self, ck)
    }

    #[doc = "One-shot fit at `cfg.lambda` from the given (or current) \
             warmstart. Compatibility wrapper over the stepwise API — new \
             code should use [`DGlmnetSolver::driver`] (stepwise control, \
             checkpoints) or [`Estimator::fit`] (uniform interface with \
             observers)."]
    pub fn fit(&mut self, warm: Option<&[f32]>) -> Result<FitResult> {
        if let Some(w) = warm {
            self.set_beta(w)?;
        }
        self.fit_lambda(self.cfg.lambda)
    }

    #[doc = "One full Algorithm-1 run at `lambda`, warmstarting from the \
             current (β, margins); leaves the solver state at the fitted \
             optimum. Compatibility wrapper that drives \
             [`DGlmnetSolver::driver`] to convergence — bit-identical to \
             stepping the [`FitDriver`] manually."]
    pub fn fit_lambda(&mut self, lambda: f64) -> Result<FitResult> {
        self.driver(lambda).run(&mut NoopObserver)
    }
}

impl Estimator for DGlmnetSolver {
    fn name(&self) -> &'static str {
        "d-glmnet"
    }

    /// Fit at `cfg.lambda` from the current state (warmstart — call
    /// [`Estimator::reset`] first for a cold fit). `ds` must be the dataset
    /// the cluster was built on; the workers keep their shards.
    fn fit(&mut self, ds: &Dataset, observer: &mut dyn FitObserver) -> Result<FitResult> {
        if ds.n_examples() != self.n || ds.n_features() != self.p {
            return Err(DlrError::Solver(format!(
                "dataset shape ({} x {}) does not match the sharded cluster ({} x {})",
                ds.n_examples(),
                ds.n_features(),
                self.n,
                self.p
            )));
        }
        let lambda = self.cfg.lambda;
        self.driver(lambda).run(observer)
    }

    fn model(&self) -> SparseModel {
        SparseModel::from_dense(&self.beta, self.cfg.lambda)
            .with_family(self.cfg.family, self.cfg.enet_alpha)
    }

    fn reset(&mut self) {
        DGlmnetSolver::reset(self);
    }

    fn lambda(&self) -> f64 {
        self.cfg.lambda
    }

    fn set_lambda(&mut self, lambda: f64) {
        self.cfg.lambda = lambda;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, TrainConfig};
    use crate::data::synth;

    fn native_cfg(m: usize, lambda: f64) -> TrainConfig {
        TrainConfig::builder()
            .machines(m)
            .engine(EngineKind::Native)
            .lambda(lambda)
            .max_iter(40)
            .build()
    }

    #[test]
    fn objective_decreases_monotonically() {
        let ds = synth::dna_like(800, 60, 6, 31);
        let mut s = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, 2.0)).unwrap();
        let fit = s.fit(None).unwrap();
        assert!(fit.iterations >= 2);
        let objs: Vec<f64> = fit.trace.iter().map(|r| r.objective).collect();
        for w in objs.windows(2) {
            assert!(w[1] <= w[0] + 1e-6 * w[0].abs(), "trace = {objs:?}");
        }
    }

    #[test]
    fn m1_and_m4_reach_same_objective() {
        // block-diagonal approximation changes the *path*, not the optimum
        let ds = synth::dna_like(600, 40, 5, 32);
        let mut s1 = DGlmnetSolver::from_dataset(&ds, &native_cfg(1, 1.0)).unwrap();
        let mut s4 = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, 1.0)).unwrap();
        let f1 = s1.fit(None).unwrap();
        let f4 = s4.fit(None).unwrap();
        assert!(
            (f1.objective - f4.objective).abs() / f1.objective < 5e-3,
            "M=1: {} vs M=4: {}",
            f1.objective,
            f4.objective
        );
    }

    #[test]
    fn large_lambda_keeps_beta_zero() {
        let ds = synth::dna_like(300, 30, 4, 33);
        let lam_max = crate::solver::regpath::lambda_max(&ds);
        let mut s = DGlmnetSolver::from_dataset(&ds, &native_cfg(2, lam_max * 1.01)).unwrap();
        let fit = s.fit(None).unwrap();
        assert_eq!(fit.nnz(), 0, "beta must stay empty at λ > λ_max");
        assert!(fit.converged);
    }

    #[test]
    fn smaller_lambda_gives_denser_model_and_better_fit() {
        let ds = synth::dna_like(800, 50, 6, 34);
        let lam_max = crate::solver::regpath::lambda_max(&ds);
        let mut s = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, lam_max / 4.0)).unwrap();
        let hi = s.fit(None).unwrap();
        let mut s2 = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, lam_max / 64.0)).unwrap();
        let lo = s2.fit(None).unwrap();
        assert!(lo.nnz() >= hi.nnz(), "{} < {}", lo.nnz(), hi.nnz());
        assert!(lo.objective < hi.objective);
    }

    #[test]
    fn warmstart_converges_faster_than_cold() {
        let ds = synth::dna_like(600, 40, 5, 35);
        let lam_max = crate::solver::regpath::lambda_max(&ds);
        let mut s = DGlmnetSolver::from_dataset(&ds, &native_cfg(2, lam_max / 2.0)).unwrap();
        let first = s.fit_lambda(lam_max / 2.0).unwrap();
        // warm: fit the next λ from the current β
        let warm = s.fit_lambda(lam_max / 4.0).unwrap();
        // cold: reset and fit the same λ
        s.reset();
        let cold = s.fit_lambda(lam_max / 4.0).unwrap();
        assert!(
            warm.iterations <= cold.iterations,
            "warm {} > cold {}",
            warm.iterations,
            cold.iterations
        );
        assert!((warm.objective - cold.objective).abs() / cold.objective < 1e-2);
        let _ = first;
    }

    #[test]
    fn comm_ledger_populated() {
        let ds = synth::dna_like(200, 24, 4, 36);
        let mut s = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, 0.5)).unwrap();
        let fit = s.fit(None).unwrap();
        assert!(fit.comm_bytes > 0);
        assert!(fit.sim_comm_secs > 0.0);
        assert!(fit.sim_compute_secs > 0.0);
    }

    #[test]
    fn iteration_comm_bytes_are_per_iteration_deltas() {
        // the trace records each iteration's own traffic; the per-fit total
        // is their sum (regression test for the cumulative-bytes bug)
        let ds = synth::dna_like(400, 40, 5, 37);
        let mut s = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, 0.5)).unwrap();
        let fit = s.fit(None).unwrap();
        assert!(fit.iterations >= 2, "need a multi-iteration fit");
        let sum: u64 = fit.trace.iter().map(|r| r.comm_bytes).sum();
        assert_eq!(sum, fit.comm_bytes);
        // every iteration with a non-zero update moves some bytes, and no
        // single iteration carries the whole fit's traffic
        assert!(fit.trace[0].comm_bytes > 0);
        assert!(fit.trace[0].comm_bytes < fit.comm_bytes);
    }

    #[test]
    fn sparse_and_dense_allreduce_reach_identical_objectives() {
        // the sparse wire format changes accounting, never math: merges run
        // in the same deterministic tree order as the dense path
        let ds = synth::webspam_like(500, 2_000, 10, 38);
        let lam = crate::solver::regpath::lambda_max(&ds) / 4.0;
        let mut sparse = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, lam)).unwrap();
        let mut dense_cfg = native_cfg(4, lam);
        dense_cfg.dense_allreduce = true;
        let mut dense = DGlmnetSolver::from_dataset(&ds, &dense_cfg).unwrap();
        let fs = sparse.fit(None).unwrap();
        let fd = dense.fit(None).unwrap();
        assert_eq!(fs.iterations, fd.iterations);
        assert!(
            (fs.objective - fd.objective).abs() <= 1e-9 * fd.objective.abs().max(1.0),
            "sparse {} vs dense {}",
            fs.objective,
            fd.objective
        );
        assert!(fs.comm_bytes <= fd.comm_bytes, "sparse must never cost more");
    }

    #[test]
    fn forced_exchange_strategies_match_bitwise() {
        // allgather-Δβ merges Δm leader-side in the same pairwise tree
        // order as the charged reduce: the trajectory must be bit-identical
        // and the wire strictly cheaper (Δm never shipped; Δβ is a gather
        // either way)
        let ds = synth::dna_like(500, 60, 6, 41);
        let lam = crate::solver::regpath::lambda_max(&ds) / 8.0;
        let mk = |e: ExchangeStrategy| {
            TrainConfig::builder()
                .machines(4)
                .engine(EngineKind::Native)
                .lambda(lam)
                .max_iter(30)
                .exchange(e)
                .build()
        };
        let mut red = DGlmnetSolver::from_dataset(&ds, &mk(ExchangeStrategy::ReduceDm)).unwrap();
        let mut gat =
            DGlmnetSolver::from_dataset(&ds, &mk(ExchangeStrategy::AllGatherBeta)).unwrap();
        let fr = red.fit(None).unwrap();
        let fg = gat.fit(None).unwrap();
        assert_eq!(fr.iterations, fg.iterations);
        for (a, b) in fr.trace.iter().zip(&fg.trace) {
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "iter {}", a.iter);
            assert_eq!(a.exchange, Some(ExchangeStrategy::ReduceDm));
            assert_eq!(b.exchange, Some(ExchangeStrategy::AllGatherBeta));
        }
        assert_eq!(red.beta, gat.beta);
        assert!(fg.comm_bytes < fr.comm_bytes, "allgather must skip the Δm wire");
        // the merges themselves ran inside the worker pool on both paths
        assert!(red.merge_tasks_executed() > 0);
        assert!(gat.merge_tasks_executed() > 0);
    }

    #[test]
    fn estimator_trait_fit_matches_inherent_fit() {
        let ds = synth::dna_like(400, 40, 5, 39);
        let mut a = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, 0.5)).unwrap();
        let mut b = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, 0.5)).unwrap();
        let fa = a.fit(None).unwrap();
        let fb =
            Estimator::fit(&mut b, &ds, &mut crate::solver::estimator::NoopObserver).unwrap();
        assert_eq!(fa.objective.to_bits(), fb.objective.to_bits());
        assert_eq!(fa.iterations, fb.iterations);
        assert_eq!(a.beta, b.beta);
    }

    #[test]
    fn too_many_workers_fail_at_construction_with_a_clear_error() {
        // satellite bugfix: workers > feature blocks must error up front
        // with actionable wording, not panic deep in partition/shard code
        let ds = synth::dna_like(100, 8, 3, 42);
        let err = DGlmnetSolver::from_dataset(&ds, &native_cfg(9, 0.5)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("9 workers"), "{msg}");
        assert!(msg.contains("8 features"), "{msg}");
    }

    #[test]
    fn auto_fit_evolves_the_comm_estimator() {
        // Δm contributions overlap across machines, so the observed bytes
        // run below the nnz_a + nnz_b upper bound and the EWMA learns it
        let ds = synth::dna_like(400, 40, 5, 43);
        let mut s = DGlmnetSolver::from_dataset(&ds, &native_cfg(4, 0.2)).unwrap();
        assert_eq!(s.comm_estimator_shrink(), (1.0, 1.0));
        let fit = s.fit(None).unwrap();
        assert!(fit.iterations >= 2);
        let (dm, db) = s.comm_estimator_shrink();
        assert!((0.05..=1.5).contains(&dm), "dm shrink {dm}");
        assert!((0.05..=1.5).contains(&db), "db shrink {db}");
        // at least one side must have been observed away from the prior
        assert!(dm < 1.0 || db <= 1.0);
    }
}
