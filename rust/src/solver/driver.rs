//! Stepwise fit driver: one d-GLMNET iteration per [`FitDriver::step`] call,
//! so callers own the training loop. `DGlmnetSolver::fit_lambda` is a thin
//! wrapper over this driver — driving `step()` to convergence is
//! *bit-identical* (objective, β, comm-bytes ledger) to the one-shot path,
//! which the `tests/estimator_api.rs` equivalence tests pin down.
//!
//! Since the node-protocol redesign, `step()` is a sequence of send/recv
//! phases over the workers' [`Transport`](crate::cluster::transport::Transport)
//! links (the same code path for in-process threads and remote socket
//! processes):
//!
//! 1. **leader stats** — loss at the current margins (local compute);
//! 2. **sweep phase** — send `Sweep { λ, ν }` to every node, collect the
//!    sparse `Swept` replies (workers derive `(w, z)` from their own
//!    margins; no `beta_local` or `(w, z)` ever travels);
//! 3. **Δ-exchange** — the `cluster::comm` collectives: the EWMA byte-cost
//!    model picks reduce-Δm or allgather-Δβ per iteration, codecs are
//!    chosen per message, merges run on the worker pool, and the Δβ flow
//!    is charged as a *gather* (workers hold their β shards, so the PR-3
//!    merged-Δβ broadcast no longer exists);
//! 4. **line search** — leader-local over the merged Δm;
//! 5. **apply phase** — the leader applies `α·Δ` to its global state and
//!    sends `Apply { α, Δm }`; every node applies the bit-identical update
//!    to its shard.
//!
//! What stepwise control buys:
//!
//! * **Observers** — [`FitDriver::run`] reports every iteration through a
//!   [`FitObserver`], which can stop the fit early.
//! * **Checkpoint / resume** — [`FitDriver::checkpoint`] captures (β,
//!   margins, iteration counter, accumulated cost, the worker-held shard
//!   states, and the comm estimator state) as a [`Checkpoint`];
//!   `DGlmnetSolver::driver_from_checkpoint` restores it in a fresh process
//!   and the resumed fit reproduces the uninterrupted trajectory exactly —
//!   including the `comm_bytes` ledger (margins are restored bit-for-bit,
//!   never recomputed from β).
//! * **Budgets** — wall-clock / comm-bytes / iteration caps from
//!   [`TrainConfig::budget`](crate::config::TrainConfig) are enforced
//!   between iterations.

use std::path::Path;
use std::sync::Arc;

use crate::cluster::codec::MessageClass;
use crate::cluster::comm::{replay_tree_charges, Collective, CommCtx, TaskExecutor};
use crate::config::ExchangeStrategy;
use crate::data::sparse::SparseVec;
use crate::error::{DlrError, Result};
use crate::family::FamilyKind;
use crate::solver::dglmnet::{DGlmnetSolver, FitResult, IterationRecord};
use crate::solver::estimator::{FitControl, FitObserver, FitStep};
use crate::solver::line_search::{line_search, LineSearchOutcome};
use crate::solver::model::SparseModel;
use crate::solver::quadratic::{enet_penalty, penalty_at_alpha, support_union_into};
use crate::util::json::{self, Json};
use crate::util::math::l1_norm;
use crate::util::timer::{PhaseTimer, Stopwatch};

/// Why a fit stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// Relative objective decrease fell below `cfg.tol`.
    Converged,
    /// `cfg.max_iter` reached without convergence.
    MaxIter,
    /// An observer (or an explicit [`FitDriver::stop`]) ended the fit.
    Observer,
    /// `cfg.budget.iterations` exhausted.
    IterationBudget,
    /// `cfg.budget.comm_bytes` exhausted.
    CommBudget,
    /// `cfg.budget.wall_secs` exhausted.
    WallClockBudget,
}

/// Result of one [`FitDriver::step`] call.
#[derive(Debug, Clone)]
pub enum StepOutcome {
    /// One full iteration ran; the fit has not finished.
    Progress(IterationRecord),
    /// The fit is over. `record` is the final iteration's record, or `None`
    /// when the fit ended between iterations (budget hit, or `step` called
    /// on an already-finished driver).
    Finished { record: Option<IterationRecord>, reason: StopReason },
}

/// Stepwise driver over one `fit_lambda` run. Create with
/// [`DGlmnetSolver::driver`] (fresh) or
/// [`DGlmnetSolver::driver_from_checkpoint`] (resume), call [`step`]
/// until it reports [`StepOutcome::Finished`], then [`finish`] for the
/// [`FitResult`] — or let [`run`] do the loop with an observer.
///
/// [`step`]: FitDriver::step
/// [`finish`]: FitDriver::finish
/// [`run`]: FitDriver::run
pub struct FitDriver<'a> {
    solver: &'a mut DGlmnetSolver,
    lambda: f64,
    /// 1-based index of the iteration the next `step` call will run.
    next_iter: usize,
    f_prev: Option<f64>,
    finished: bool,
    stop_reason: Option<StopReason>,
    converged: bool,
    trace: Vec<IterationRecord>,
    timers: PhaseTimer,
    sim_compute: f64,
    sim_comm: f64,
    ledger_start_bytes: u64,
    /// Accumulators carried over a checkpoint/resume boundary.
    carried_iters: usize,
    carried_comm_bytes: u64,
    carried_wall_secs: f64,
    wall: Stopwatch,
    /// Supervisor rollback point (`cfg.supervise`): a leader-only
    /// checkpoint refreshed every `cfg.recovery_checkpoint_every`
    /// iterations, restored after a worker failure.
    recovery: Option<Checkpoint>,
}

impl<'a> FitDriver<'a> {
    pub fn new(solver: &'a mut DGlmnetSolver, lambda: f64) -> Self {
        let ledger_start_bytes = solver.ledger.total_bytes();
        Self {
            solver,
            lambda,
            next_iter: 1,
            f_prev: None,
            finished: false,
            stop_reason: None,
            converged: false,
            trace: Vec::new(),
            timers: PhaseTimer::new(),
            sim_compute: 0.0,
            sim_comm: 0.0,
            ledger_start_bytes,
            carried_iters: 0,
            carried_comm_bytes: 0,
            carried_wall_secs: 0.0,
            wall: Stopwatch::start(),
            recovery: None,
        }
    }

    /// Resume from a checkpoint: installs (β, margins) bit-for-bit — on
    /// the leader *and* on every worker node (verbatim shard states when
    /// the checkpoint carries them, a re-gather otherwise) — restores the
    /// comm estimator state, and carries the iteration counter and cost
    /// accumulators forward.
    pub fn from_checkpoint(solver: &'a mut DGlmnetSolver, ck: &Checkpoint) -> Result<Self> {
        let mut d = Self::new(solver, ck.lambda);
        d.restore_from(ck)?;
        Ok(d)
    }

    /// Install a checkpoint into the live driver: (β, margins) bit-for-bit
    /// on the leader, shard states on the workers (or a staleness mark
    /// when the checkpoint carries none — the next step then re-syncs
    /// every node, which is how a cold replacement worker inherits its
    /// state), the comm estimator state, and every iteration/cost
    /// accumulator. Shared by the resume path and the supervisor's
    /// failure rollback; iterations already in `trace` past the
    /// checkpoint are discarded so the re-run reproduces them.
    fn restore_from(&mut self, ck: &Checkpoint) -> Result<()> {
        let solver = &mut *self.solver;
        if ck.p != solver.n_features() || ck.n != solver.n_examples() {
            return Err(DlrError::Solver(format!(
                "checkpoint shape (n = {}, p = {}) does not match solver (n = {}, p = {})",
                ck.n,
                ck.p,
                solver.n_examples(),
                solver.n_features()
            )));
        }
        if ck.lambda.to_bits() != self.lambda.to_bits() {
            return Err(DlrError::Solver(format!(
                "checkpoint is for λ = {} but this driver runs λ = {}",
                ck.lambda, self.lambda
            )));
        }
        if ck.family != solver.cfg.family {
            return Err(DlrError::Solver(format!(
                "checkpoint was captured with family '{}' but this solver runs '{}' — \
                 set [train] family / --family to match",
                ck.family.name(),
                solver.cfg.family.name()
            )));
        }
        if ck.enet_alpha.to_bits() != solver.cfg.enet_alpha.to_bits() {
            return Err(DlrError::Solver(format!(
                "checkpoint was captured with alpha = {} but this solver runs alpha = {} — \
                 set [train] alpha / --alpha to match",
                ck.enet_alpha, solver.cfg.enet_alpha
            )));
        }
        solver.beta.copy_from_slice(&ck.beta);
        solver.margins.copy_from_slice(&ck.margins);
        if ck.shards.is_empty() {
            // no shard states (legacy file, or a leader-only recovery
            // checkpoint): mark the workers stale and re-sync from β
            solver.workers_dirty = true;
        } else {
            // the shard states were verified against β at capture time
            // *under the capturing partition* — re-verify under THIS
            // solver's partition before installing, or a resume with a
            // different [solver] partition / machine count would silently
            // land shard values on the wrong columns
            if ck.shards.len() != solver.pool.global_cols.len() {
                return Err(DlrError::Solver(format!(
                    "checkpoint has {} worker shards but this cluster has {} — was the \
                     checkpoint taken with a different machine count?",
                    ck.shards.len(),
                    solver.pool.global_cols.len()
                )));
            }
            for (k, shard) in ck.shards.iter().enumerate() {
                let cols = &solver.pool.global_cols[k];
                let consistent = shard.len() == cols.len()
                    && cols.iter().enumerate().all(|(l, &g)| {
                        shard[l].to_bits() == ck.beta[g as usize].to_bits()
                    });
                if !consistent {
                    return Err(DlrError::Solver(format!(
                        "checkpoint shard state {k} does not match its β under this \
                         cluster's partition — was the checkpoint taken with a \
                         different [solver] partition?"
                    )));
                }
            }
            solver.pool.push_shard_states(&ck.shards, &ck.margins)?;
            solver.workers_dirty = false;
        }
        match ck.est_shrink {
            Some((dm, db)) => {
                solver.est_dm.set_shrink(dm);
                solver.est_db.set_shrink(db);
            }
            None => {
                solver.est_dm.set_shrink(1.0);
                solver.est_db.set_shrink(1.0);
            }
        }
        // roll the counters back: records past the checkpoint are dropped
        // (the re-run reproduces them bit-for-bit), resumed-over work stays
        // in the carried accumulators, and the ledger baseline moves so the
        // failed attempt's partial traffic is never double-counted
        self.trace.retain(|r| r.iter <= ck.iter);
        self.carried_iters = ck.iter - self.trace.len();
        self.next_iter = ck.iter + 1;
        self.f_prev = ck.f_prev;
        self.sim_compute = ck.sim_compute_secs;
        self.sim_comm = ck.sim_comm_secs;
        self.carried_comm_bytes = ck.comm_bytes;
        self.carried_wall_secs = ck.wall_secs;
        // restart the clock so pre-checkpoint elapsed time isn't counted
        // twice on an in-fit rollback (ck.wall_secs already carries it)
        self.wall = Stopwatch::start();
        self.ledger_start_bytes = self.solver.ledger.total_bytes();
        self.finished = false;
        self.stop_reason = None;
        self.converged = false;
        Ok(())
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Iterations completed so far (including any resumed-over iterations).
    pub fn iterations(&self) -> usize {
        self.next_iter - 1
    }

    pub fn is_finished(&self) -> bool {
        self.finished
    }

    pub fn converged(&self) -> bool {
        self.converged
    }

    /// Objective after the last completed iteration (None before the first).
    pub fn objective(&self) -> Option<f64> {
        self.f_prev
    }

    /// Records of the iterations run by *this* driver (post-resume only).
    pub fn trace(&self) -> &[IterationRecord] {
        &self.trace
    }

    /// Total bytes this fit has moved, including resumed-over traffic.
    pub fn comm_bytes_so_far(&self) -> u64 {
        self.carried_comm_bytes
            + (self.solver.ledger.total_bytes() - self.ledger_start_bytes)
    }

    /// Wall-clock seconds this fit has run, including resumed-over time.
    pub fn wall_secs_so_far(&self) -> f64 {
        self.carried_wall_secs + self.wall.elapsed_secs()
    }

    /// End the fit now (the loop owner's analog of an observer `Stop`).
    pub fn stop(&mut self) {
        if !self.finished {
            self.finished = true;
            self.stop_reason = Some(StopReason::Observer);
        }
    }

    /// Capture the resumable state after the last completed iteration.
    ///
    /// This is a protocol round-trip: the worker-held shard states are
    /// pulled (`GetState`) and cross-checked against the leader's global
    /// (β, margins) — a bit-level divergence is a hard error, not a silent
    /// checkpoint of corrupt state.
    pub fn checkpoint(&mut self) -> Result<Checkpoint> {
        self.solver.ensure_workers_synced()?;
        let shards = self.solver.pull_verified_shards()?;
        Ok(Checkpoint {
            lambda: self.lambda,
            family: self.solver.cfg.family,
            enet_alpha: self.solver.cfg.enet_alpha,
            n: self.solver.n_examples(),
            p: self.solver.n_features(),
            iter: self.iterations(),
            f_prev: self.f_prev,
            sim_compute_secs: self.sim_compute,
            sim_comm_secs: self.sim_comm,
            comm_bytes: self.comm_bytes_so_far(),
            wall_secs: self.wall_secs_so_far(),
            beta: self.solver.beta.clone(),
            margins: self.solver.margins.clone(),
            rng: None,
            shards,
            est_shrink: Some((self.solver.est_dm.shrink(), self.solver.est_db.shrink())),
        })
    }

    fn budget_exceeded(&self) -> Option<StopReason> {
        let budget = &self.solver.cfg.budget;
        if let Some(cap) = budget.iterations {
            if self.iterations() >= cap {
                return Some(StopReason::IterationBudget);
            }
        }
        if let Some(cap) = budget.comm_bytes {
            if self.comm_bytes_so_far() >= cap {
                return Some(StopReason::CommBudget);
            }
        }
        if let Some(cap) = budget.wall_secs {
            if self.wall_secs_so_far() >= cap {
                return Some(StopReason::WallClockBudget);
            }
        }
        None
    }

    /// Run one leader-stats → sweep → Δ-exchange → line-search → apply
    /// iteration (paper Algorithm 1 body) as send/recv phases over the
    /// worker transports. The Δ-exchange routes through `cluster::comm`:
    /// the EWMA byte-cost model picks reduce-Δm or allgather-Δβ per
    /// iteration (unless the config forces one), codecs are chosen per
    /// message, tree merges run on the worker pool, and the Δβ flow is a
    /// charged *gather* — workers hold their own β shards, so no merged-Δβ
    /// broadcast exists. The update is applied (leader and workers) before
    /// this returns, so `checkpoint()` right after captures it.
    ///
    /// With `cfg.supervise` on, a worker failure mid-iteration does not
    /// end the fit: the supervisor probes every link (draining stale
    /// replies), replaces dead workers (socket re-admission on the
    /// retained listener, or an in-process respawn from the shard store),
    /// rolls the fit back to its recovery checkpoint — refreshed
    /// leader-only every `cfg.recovery_checkpoint_every` iterations — and
    /// re-runs from there. The recovered trajectory is bit-identical to
    /// the undisturbed one (β, objective, and the algorithmic comm
    /// ledger); supervision traffic lands in the ledger's separate
    /// recovery bucket.
    pub fn step(&mut self) -> Result<StepOutcome> {
        if !self.solver.cfg.supervise {
            return self.step_inner();
        }
        if !self.finished {
            let due = match &self.recovery {
                None => true,
                Some(ck) => {
                    self.iterations()
                        >= ck.iter + self.solver.cfg.recovery_checkpoint_every
                }
            };
            if due {
                self.recovery = Some(self.recovery_checkpoint());
            }
        }
        // a recovery that itself fails (no replacement worker, a second
        // failure mid-rollback) retries against a fresh probe; cap the
        // attempts so a hard-down cluster still surfaces an error
        const MAX_RECOVERIES: usize = 5;
        let mut attempt = 0usize;
        loop {
            match self.step_inner() {
                Ok(outcome) => return Ok(outcome),
                Err(cause) => {
                    attempt += 1;
                    if attempt > MAX_RECOVERIES {
                        return Err(DlrError::Solver(format!(
                            "fit unrecoverable after {MAX_RECOVERIES} recovery \
                             attempts; last failure: {cause}"
                        )));
                    }
                    self.recover(&cause)?;
                }
            }
        }
    }

    /// Detect → replace → roll back: the supervisor's response to a failed
    /// iteration. Probes every link (which also drains the at-most-one
    /// stale reply a failed phase leaves behind), re-admits a replacement
    /// for each dead machine, and restores the recovery checkpoint.
    fn recover(&mut self, cause: &DlrError) -> Result<()> {
        let ck = self.recovery.clone().ok_or_else(|| {
            DlrError::Solver(format!(
                "worker failure before the first recovery checkpoint: {cause}"
            ))
        })?;
        eprintln!(
            "[supervise] iteration {} failed ({cause}); rolling back to iteration {}",
            self.next_iter, ck.iter
        );
        self.solver.repair_workers()?;
        // under a physical tree every recovery re-issues the topology to
        // all workers under a bumped epoch: peer links are torn down
        // (discarding any stale in-flight payloads) and rebuilt, and the
        // replacement — welcomed without a topology — joins the tree here
        self.solver.pool.reissue_topology(&self.solver.ledger)?;
        self.restore_from(&ck)
    }

    /// Leader-only rollback point: like [`FitDriver::checkpoint`] but
    /// built without any worker round-trip (`shards` stays empty), so the
    /// supervisor can refresh it every iteration for free. Restoring it
    /// marks the worker state stale and the next step re-syncs every node
    /// — including a cold replacement — over the uncharged control path.
    fn recovery_checkpoint(&self) -> Checkpoint {
        Checkpoint {
            lambda: self.lambda,
            family: self.solver.cfg.family,
            enet_alpha: self.solver.cfg.enet_alpha,
            n: self.solver.n_examples(),
            p: self.solver.n_features(),
            iter: self.iterations(),
            f_prev: self.f_prev,
            sim_compute_secs: self.sim_compute,
            sim_comm_secs: self.sim_comm,
            comm_bytes: self.comm_bytes_so_far(),
            wall_secs: self.wall_secs_so_far(),
            beta: self.solver.beta.clone(),
            margins: self.solver.margins.clone(),
            rng: None,
            shards: Vec::new(),
            est_shrink: Some((
                self.solver.est_dm.shrink(),
                self.solver.est_db.shrink(),
            )),
        }
    }

    /// The unsupervised iteration body — see [`FitDriver::step`].
    fn step_inner(&mut self) -> Result<StepOutcome> {
        if self.finished {
            return Ok(StepOutcome::Finished {
                record: None,
                reason: self.stop_reason.unwrap_or(StopReason::Converged),
            });
        }
        if let Some(reason) = self.budget_exceeded() {
            self.finished = true;
            self.stop_reason = Some(reason);
            return Ok(StepOutcome::Finished { record: None, reason });
        }
        // max_iter = 0, or a checkpoint already at/past the cap: nothing to run
        if self.next_iter > self.solver.cfg.max_iter {
            self.finished = true;
            self.stop_reason = Some(StopReason::MaxIter);
            return Ok(StepOutcome::Finished { record: None, reason: StopReason::MaxIter });
        }
        // a reset / warmstart install / legacy resume marked the worker
        // state stale: push (β, margins) before the first sweep reads it
        self.solver.ensure_workers_synced()?;

        let lambda = self.lambda;
        let iter = self.next_iter;
        let timers = &mut self.timers;
        let DGlmnetSolver {
            cfg,
            n,
            p,
            y,
            pool,
            leader,
            allreduce,
            allgather,
            policy,
            ledger,
            scratch,
            est_dm,
            est_db,
            beta,
            margins,
            ..
        } = &mut *self.solver;
        let (n, p) = (*n, *p);
        let policy = *policy;
        // the ledger is only ever charged through &self (atomics)
        let ledger: &crate::cluster::network::NetworkLedger = ledger;
        let enet_alpha = cfg.enet_alpha;
        let family = cfg.family.family();
        // elastic-net split of λ: the L1 share λ·α soft-thresholds, the
        // ridge share λ·(1−α) lands in the sweep's quadratic denominator
        // (α = 1 reproduces the pure-L1 scalars bit-for-bit: ×1.0 and a
        // zero l2 term)
        let (lam_f, nu_f) = ((lambda * enet_alpha) as f32, cfg.nu as f32);
        let l2_f = (lambda * (1.0 - enet_alpha)) as f32;
        let iter_sw = Stopwatch::start();
        let iter_start_bytes = ledger.total_bytes();

        // ---- phase 1: leader stats (loss at the current margins) --------
        // loss only: the (w, z) working vectors are derived worker-side
        // from each node's own margins, so the leader no longer fills them
        let loss = timers.time("stats", || leader.loss(margins))?;
        let f0 = loss + enet_penalty(beta, lambda, enet_alpha);
        let f_start = *self.f_prev.get_or_insert(f0);
        debug_assert!((f_start - f0).abs() <= 1e-6 * f0.abs().max(1.0) || iter > 1);

        // ---- phases 2–3: sweep, then exchange Δβ and Δm -----------------
        // Two physical routes, one algorithm. The staged route runs the
        // merge bracket on the leader's task pool; the physical tree
        // (`--topology tree` over sockets) ships the *same* bracket over
        // worker↔worker links — the leader receives one pre-merged result
        // from machine 0 and replays the per-edge byte charges from the
        // nnz metadata the merge carried up. β, objective, and the comm
        // ledger are bit-identical either way.
        let machines = pool.machines();
        // the Δβ broadcast no longer exists (workers apply α·Δβ_local from
        // their own state); `charge_beta_broadcast` is the PR-3-compat
        // accounting ablation that pretends it still does
        let beta_bcast = cfg.charge_beta_broadcast;
        let physical_tree = pool.is_physical_tree();
        let mut auto_pick = false;
        let mut dm_upper = 0u64;
        let mut db_upper = 0u64;
        let max_worker: f64;
        let strategy: ExchangeStrategy;
        let comm_secs: f64;
        let dm_actual: Option<u64>;
        let db_actual: u64;
        if physical_tree {
            // ---- phase 2: one Sweep down the root edge, one pre-merged
            // TreeSwept back up — the leader's per-iteration data traffic
            // no longer scales with M
            let swept = timers.time("sweep", || pool.sweep_all_tree(lam_f, nu_f, l2_f))?;
            max_worker =
                swept.origins.iter().map(|o| o.compute_secs).fold(0f64, f64::max);
            // ---- phase 3: strategy pick + charge replay from metadata.
            // The origins carry every worker's raw contribution nnz (in
            // machine order after the scatter below) — the exact inputs
            // the staged path feeds the byte estimators — and the edges
            // carry each bracket pair's accumulated nnz at send time, so
            // the replay charges the identical per-edge codec costs.
            let (s, secs, dm_b, db_b) = timers.time(
                "allreduce",
                || -> Result<(ExchangeStrategy, f64, Option<u64>, u64)> {
                    let mut dm_nnz = vec![0usize; machines];
                    let mut db_nnz = vec![0usize; machines];
                    for o in &swept.origins {
                        dm_nnz[o.machine as usize] = o.dm_nnz as usize;
                        db_nnz[o.machine as usize] = o.db_nnz as usize;
                    }
                    let strategy = if cfg.dense_allreduce || cfg.wire_f16_beta {
                        ExchangeStrategy::ReduceDm
                    } else {
                        match cfg.exchange {
                            ExchangeStrategy::Auto => {
                                auto_pick = true;
                                scratch.est_nnz.clear();
                                scratch.est_nnz.extend_from_slice(&dm_nnz);
                                let dm_est = est_dm.estimate(
                                    &mut scratch.est_nnz,
                                    n,
                                    policy.f16_margins,
                                );
                                scratch.est_nnz.clear();
                                scratch.est_nnz.extend_from_slice(&db_nnz);
                                let db_est = est_db.estimate(
                                    &mut scratch.est_nnz,
                                    p,
                                    policy.f16_beta,
                                );
                                dm_upper = dm_est.upper;
                                db_upper = db_est.upper;
                                if db_est.predicted < dm_est.predicted {
                                    ExchangeStrategy::AllGatherBeta
                                } else {
                                    ExchangeStrategy::ReduceDm
                                }
                            }
                            s => s,
                        }
                    };
                    let edge_nnz = |class: MessageClass, a: u32, b: u32| -> Result<usize> {
                        swept
                            .edges
                            .iter()
                            .find(|e| e.into == a && e.from == b)
                            .map(|e| match class {
                                MessageClass::Beta => e.db_nnz as usize,
                                _ => e.dm_nnz as usize,
                            })
                            .ok_or_else(|| {
                                DlrError::Solver(format!(
                                    "tree sweep metadata is missing the {a}←{b} merge edge"
                                ))
                            })
                    };
                    match strategy {
                        ExchangeStrategy::AllGatherBeta => {
                            let o_beta = replay_tree_charges(
                                &allgather.model,
                                machines,
                                p,
                                ledger,
                                &policy,
                                MessageClass::Beta,
                                true,
                                beta_bcast,
                                &mut |a, b| edge_nnz(MessageClass::Beta, a, b),
                                swept.db.nnz(),
                            )?;
                            // Δm is charged zero bytes on this path (the
                            // staged engine's local recombination) even
                            // though the physical tree did move it
                            Ok((strategy, o_beta.simulated_secs, None, o_beta.bytes_moved))
                        }
                        _ => {
                            let o1 = replay_tree_charges(
                                &allreduce.model,
                                machines,
                                n,
                                ledger,
                                &policy,
                                MessageClass::Margins,
                                true,
                                true,
                                &mut |a, b| edge_nnz(MessageClass::Margins, a, b),
                                swept.dm.nnz(),
                            )?;
                            let o2 = replay_tree_charges(
                                &allreduce.model,
                                machines,
                                p,
                                ledger,
                                &policy,
                                MessageClass::Beta,
                                true,
                                beta_bcast,
                                &mut |a, b| edge_nnz(MessageClass::Beta, a, b),
                                swept.db.nnz(),
                            )?;
                            Ok((
                                strategy,
                                o1.simulated_secs + o2.simulated_secs,
                                Some(o1.bytes_moved),
                                o2.bytes_moved,
                            ))
                        }
                    }
                },
            )?;
            // machine 0 already applied the bracket root's f32 rounding,
            // so these land bit-identical to the staged merge outputs
            *Arc::make_mut(&mut scratch.dmargins_sp) = swept.dm.to_sparse_f32();
            *Arc::make_mut(&mut scratch.delta_sp) = swept.db.to_sparse_f32();
            strategy = s;
            comm_secs = secs;
            dm_actual = dm_b;
            db_actual = db_b;
        } else {
            // ---- phase 2: sweep send/recv over the node protocol --------
            // workers derive (w, z) from their own margins and sweep their
            // own β shard — the request carries only (λ·α, ν, λ(1−α))
            timers.time("sweep", || pool.sweep_all(lam_f, nu_f, l2_f, &mut scratch.results))?;
            max_worker = scratch
                .results
                .iter()
                .map(|r| r.compute_secs)
                .fold(0f64, f64::max);

            // ---- phase 3: exchange Δβ and Δm (cluster::comm) ------------
            // remap shard-local Δβ to global feature ids — O(nnz) per
            // machine; both strategies gather Δβ (timed under "allreduce":
            // it's comm-path staging work)
            timers.time("allreduce", || {
                scratch
                    .db_contribs
                    .resize_with(scratch.results.len(), Default::default);
                for (k, r) in scratch.results.iter().enumerate() {
                    pool.delta_to_global(k, &r.delta_local, p, &mut scratch.db_contribs[k]);
                }
            });
            // strategy choice: allgather-Δβ when gathering the Δβ shards is
            // estimated cheaper than reducing the example-space Δm (ROADMAP's
            // "kill the O(n) wire term"). Deliberately NOT "whenever Δm is
            // non-empty": the simulation charges the allgather path's local Δm
            // recombination zero bytes, which a real cluster cannot match, so
            // the Δβ-vs-Δm comparison keeps reduce-Δm in the regime where Δm
            // is the cheaper payload anyway. Both sides go through the
            // EWMA-sharpened `TreeByteEstimator` (observed overlap + codec
            // effects), with the Δβ side modeled as the gather it now is.
            // Forced strategies and the dense ablation bypass the estimate.
            strategy = if cfg.dense_allreduce || cfg.wire_f16_beta {
                // wire_f16_beta implies reduce-Δm: the allgather path's exact
                // leader-side Δm recombination is incompatible with a
                // quantized Δβ wire (validate() rejects forcing both)
                ExchangeStrategy::ReduceDm
            } else {
                match cfg.exchange {
                    ExchangeStrategy::Auto => {
                        auto_pick = true;
                        scratch.est_nnz.clear();
                        scratch
                            .est_nnz
                            .extend(scratch.results.iter().map(|r| r.dmargins.nnz()));
                        let dm_est =
                            est_dm.estimate(&mut scratch.est_nnz, n, policy.f16_margins);
                        scratch.est_nnz.clear();
                        scratch
                            .est_nnz
                            .extend(scratch.db_contribs.iter().map(|c| c.nnz()));
                        let db_est =
                            est_db.estimate(&mut scratch.est_nnz, p, policy.f16_beta);
                        dm_upper = dm_est.upper;
                        db_upper = db_est.upper;
                        if db_est.predicted < dm_est.predicted {
                            ExchangeStrategy::AllGatherBeta
                        } else {
                            ExchangeStrategy::ReduceDm
                        }
                    }
                    s => s,
                }
            };
            let exec: &dyn TaskExecutor = &*pool;
            let (secs, dm_b, db_b) = timers.time("allreduce", || {
                let dm_refs: Vec<&SparseVec> =
                    scratch.results.iter().map(|r| &r.dmargins).collect();
                let db_refs: Vec<&SparseVec> = scratch.db_contribs.iter().collect();
                match strategy {
                    ExchangeStrategy::AllGatherBeta => {
                        let ctx_beta = CommCtx {
                            ledger,
                            policy,
                            class: MessageClass::Beta,
                            exec,
                            charge: true,
                            broadcast: beta_bcast,
                        };
                        let o_beta = allgather.exchange(
                            machines,
                            &|k| db_refs[k],
                            p,
                            &ctx_beta,
                            &mut scratch.ar,
                            Arc::make_mut(&mut scratch.delta_sp),
                        );
                        // Δm never crosses the wire: every worker already owns
                        // its shard's Δβᵀx product, and the leader combines them
                        // in the same pairwise tree order as the charged reduce
                        // — bit-identical sums, zero bytes
                        let ctx_dm = CommCtx {
                            ledger,
                            policy,
                            class: MessageClass::Margins,
                            exec,
                            charge: false,
                            broadcast: false,
                        };
                        allreduce.exchange(
                            machines,
                            &|k| dm_refs[k],
                            n,
                            &ctx_dm,
                            &mut scratch.ar,
                            Arc::make_mut(&mut scratch.dmargins_sp),
                        );
                        (o_beta.simulated_secs, None, o_beta.bytes_moved)
                    }
                    _ => {
                        let ctx_dm = CommCtx {
                            ledger,
                            policy,
                            class: MessageClass::Margins,
                            exec,
                            charge: true,
                            broadcast: true,
                        };
                        let o1 = allreduce.exchange(
                            machines,
                            &|k| dm_refs[k],
                            n,
                            &ctx_dm,
                            &mut scratch.ar,
                            Arc::make_mut(&mut scratch.dmargins_sp),
                        );
                        let ctx_beta = CommCtx {
                            ledger,
                            policy,
                            class: MessageClass::Beta,
                            exec,
                            charge: true,
                            broadcast: beta_bcast,
                        };
                        let o2 = allreduce.exchange(
                            machines,
                            &|k| db_refs[k],
                            p,
                            &ctx_beta,
                            &mut scratch.ar,
                            Arc::make_mut(&mut scratch.delta_sp),
                        );
                        (
                            o1.simulated_secs + o2.simulated_secs,
                            Some(o1.bytes_moved),
                            o2.bytes_moved,
                        )
                    }
                }
            });
            comm_secs = secs;
            dm_actual = dm_b;
            db_actual = db_b;
        }
        self.sim_compute += max_worker;
        self.sim_comm += comm_secs;
        if auto_pick {
            // sharpen the estimators with what the charged exchanges
            // actually moved (deterministic, checkpointed state)
            est_db.observe(db_upper, db_actual);
            if let Some(actual) = dm_actual {
                est_dm.observe(dm_upper, actual);
            }
        }
        let iter_comm_bytes = ledger.total_bytes() - iter_start_bytes;

        // densify the merged updates into the reusable line-search views
        scratch.dmargins.resize(n, 0.0);
        scratch.dmargins.fill(0.0);
        scratch.dmargins_sp.scatter_into(&mut scratch.dmargins);
        scratch.delta.resize(p, 0.0);
        scratch.delta.fill(0.0);
        scratch.delta_sp.scatter_into(&mut scratch.delta);

        let delta_norm = l1_norm(&scratch.delta);
        support_union_into(beta, &scratch.delta, &mut scratch.support);

        // Degenerate update (λ ≥ λ_max with zero warmstart): stop now.
        if delta_norm == 0.0 {
            let record = IterationRecord {
                iter,
                objective: f0,
                alpha: 1.0,
                fast_path: true,
                max_worker_secs: max_worker,
                sim_comm_secs: comm_secs,
                comm_bytes: iter_comm_bytes,
                exchange: Some(strategy),
                wall_secs: iter_sw.elapsed_secs(),
            };
            self.trace.push(record.clone());
            self.f_prev = Some(f0);
            self.next_iter = iter + 1;
            self.converged = true;
            self.finished = true;
            self.stop_reason = Some(StopReason::Converged);
            return Ok(StepOutcome::Finished {
                record: Some(record),
                reason: StopReason::Converged,
            });
        }

        // ---- phase 4: line search ---------------------------------------
        let grad_dot = family.grad_dot_delta(margins, &scratch.dmargins, y);
        let beta_ref: &[f32] = beta;
        let delta_ref: &[f32] = &scratch.delta;
        let dmargins_ref: &[f32] = &scratch.dmargins;
        let support_ref: &[u32] = &scratch.support;
        let l1_at = move |a: f64| {
            penalty_at_alpha(beta_ref, delta_ref, support_ref, a, lambda, enet_alpha)
        };
        let margins_ref: &[f32] = margins;
        let mut losses =
            |alphas: &[f64]| leader.line_losses(margins_ref, dmargins_ref, alphas);
        let LineSearchOutcome { alpha, f_new, fast_path, .. } = timers
            .time("line_search", || {
                line_search(&mut losses, &l1_at, f0, grad_dot, 0.0, &cfg.line_search)
            })?;

        // ---- phase 5: apply (leader + every worker node) ----------------
        // sparse on the leader: only the touched coordinates; mirrored on
        // the workers through the protocol — each node applies α·Δβ_local
        // from its own sweep output (bit-equal to the merged Δβ on its
        // disjoint coordinates) and the same merged α·Δm
        let af = alpha as f32;
        scratch.delta_sp.add_scaled_into(beta, af);
        scratch.dmargins_sp.add_scaled_into(margins, af);
        let delta_wire = if policy.f16_beta { Some(&scratch.delta_sp) } else { None };
        timers.time("apply", || {
            if physical_tree {
                pool.apply_all_tree(af, &scratch.dmargins_sp, delta_wire)
            } else {
                pool.apply_all(af, &scratch.dmargins_sp, delta_wire)
            }
        })?;

        let record = IterationRecord {
            iter,
            objective: f_new,
            alpha,
            fast_path,
            max_worker_secs: max_worker,
            sim_comm_secs: comm_secs,
            comm_bytes: iter_comm_bytes,
            exchange: Some(strategy),
            wall_secs: iter_sw.elapsed_secs(),
        };
        self.trace.push(record.clone());

        // ---- convergence with the α = 1 sparsity retry -------------------
        let rel_dec = (f0 - f_new) / f0.abs().max(1.0);
        if cfg.verbose {
            eprintln!(
                "[dglmnet] λ={lambda:.5} iter={iter} f={f_new:.6} α={alpha:.4} rel_dec={rel_dec:.2e} nnz={}",
                crate::util::math::nnz(beta)
            );
        }
        self.f_prev = Some(f_new);
        self.next_iter = iter + 1;
        if rel_dec < cfg.tol || iter >= cfg.max_iter {
            if alpha < 1.0 {
                // would α = 1 not increase the objective too much?
                let loss_full =
                    leader.line_losses(margins, &scratch.dmargins, &[1.0 - alpha])?[0];
                let f_full = loss_full
                    + penalty_at_alpha(
                        beta,
                        &scratch.delta,
                        &scratch.support,
                        1.0 - alpha,
                        lambda,
                        enet_alpha,
                    );
                if f_full <= f_new + cfg.alpha_one_slack * f_new.abs().max(1.0) {
                    let rem = (1.0 - alpha) as f32;
                    scratch.delta_sp.add_scaled_into(beta, rem);
                    scratch.dmargins_sp.add_scaled_into(margins, rem);
                    let delta_wire =
                        if policy.f16_beta { Some(&scratch.delta_sp) } else { None };
                    if physical_tree {
                        pool.apply_all_tree(rem, &scratch.dmargins_sp, delta_wire)?;
                    } else {
                        pool.apply_all(rem, &scratch.dmargins_sp, delta_wire)?;
                    }
                    self.f_prev = Some(f_full);
                }
            }
            self.converged = rel_dec < cfg.tol;
            self.finished = true;
            let reason = if self.converged {
                StopReason::Converged
            } else {
                StopReason::MaxIter
            };
            self.stop_reason = Some(reason);
            return Ok(StepOutcome::Finished { record: Some(record), reason });
        }
        Ok(StepOutcome::Progress(record))
    }

    /// Drive `step()` to the end, reporting every iteration to `observer`
    /// (the final iteration's control value is ignored — see the
    /// [`estimator`](crate::solver::estimator) module docs).
    pub fn run(mut self, observer: &mut dyn FitObserver) -> Result<FitResult> {
        loop {
            match self.step()? {
                StepOutcome::Progress(record) => {
                    let stop = {
                        let lambda = self.lambda;
                        let beta = &self.solver.beta;
                        let (family, enet_alpha) =
                            (self.solver.cfg.family, self.solver.cfg.enet_alpha);
                        let model_fn = move || {
                            SparseModel::from_dense(beta, lambda)
                                .with_family(family, enet_alpha)
                        };
                        let view = FitStep::new(&record, &model_fn);
                        observer.on_iteration(&view) == FitControl::Stop
                    };
                    if stop {
                        self.stop();
                        break;
                    }
                }
                StepOutcome::Finished { record, .. } => {
                    if let Some(record) = record {
                        let lambda = self.lambda;
                        let beta = &self.solver.beta;
                        let (family, enet_alpha) =
                            (self.solver.cfg.family, self.solver.cfg.enet_alpha);
                        let model_fn = move || {
                            SparseModel::from_dense(beta, lambda)
                                .with_family(family, enet_alpha)
                        };
                        let view = FitStep::new(&record, &model_fn);
                        let _ = observer.on_iteration(&view);
                    }
                    break;
                }
            }
        }
        Ok(self.finish())
    }

    /// Consume the driver and assemble the [`FitResult`]. `iterations` and
    /// `comm_bytes` include resumed-over work; `trace` holds only the
    /// iterations this driver ran.
    pub fn finish(self) -> FitResult {
        FitResult {
            lambda: self.lambda,
            objective: self.f_prev.unwrap_or(f64::INFINITY),
            iterations: self.carried_iters + self.trace.len(),
            converged: self.converged,
            model: SparseModel::from_dense(&self.solver.beta, self.lambda)
                .with_family(self.solver.cfg.family, self.solver.cfg.enet_alpha),
            trace: self.trace,
            timers: self.timers,
            sim_compute_secs: self.sim_compute,
            sim_comm_secs: self.sim_comm,
            comm_bytes: self.carried_comm_bytes
                + (self.solver.ledger.total_bytes() - self.ledger_start_bytes),
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpoint
// ---------------------------------------------------------------------------

/// Resumable fit state, persisted as `runtime::artifacts`-style JSON.
///
/// β and margins are stored as f32 **bit patterns** (exact by construction
/// — margins are incremental sums and must never be recomputed from β), the
/// RNG state as hex u64 words; everything else round-trips through the
/// crate's shortest-representation JSON numbers. Under the node protocol
/// the checkpoint additionally captures the **worker-held shard states**
/// (pulled over the protocol and verified against the leader's β at save
/// time) and the **comm estimator state** (two EWMA shrink factors as f64
/// bit patterns), so a resumed fit reproduces the uninterrupted run's
/// exchange-strategy picks — and therefore its `comm_bytes` ledger —
/// exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub lambda: f64,
    /// GLM family of the fit (absent in pre-family files → logistic).
    pub family: FamilyKind,
    /// Elastic-net mixing α (absent in pre-family files → 1.0, pure L1).
    pub enet_alpha: f64,
    pub n: usize,
    pub p: usize,
    /// Completed iterations at capture time.
    pub iter: usize,
    /// Objective after the last completed iteration.
    pub f_prev: Option<f64>,
    pub sim_compute_secs: f64,
    pub sim_comm_secs: f64,
    pub comm_bytes: u64,
    pub wall_secs: f64,
    pub beta: Vec<f32>,
    pub margins: Vec<f32>,
    /// xoshiro256++ state for stochastic estimators (None for d-GLMNET,
    /// whose iteration is deterministic).
    pub rng: Option<[u64; 4]>,
    /// Worker-held β shard per machine (empty for baselines and legacy
    /// checkpoints — resume then re-gathers from `beta`).
    pub shards: Vec<Vec<f32>>,
    /// `(Δm, Δβ)` EWMA shrink factors of the comm byte estimator.
    pub est_shrink: Option<(f64, f64)>,
}

const CHECKPOINT_KIND: &str = "fit-checkpoint";

fn f32_bits_json(values: &[f32]) -> Json {
    Json::Arr(values.iter().map(|&v| Json::Num(v.to_bits() as f64)).collect())
}

fn f32_bits_from_value(doc: &Json, key: &str) -> Result<Vec<f32>> {
    doc.as_arr()
        .ok_or_else(|| DlrError::parse("checkpoint", format!("'{key}' is not an array")))?
        .iter()
        .map(|v| {
            // reject corrupt entries instead of letting `as u32` saturate:
            // a bit pattern is a whole number in [0, 2³²)
            let x = v
                .as_f64()
                .filter(|x| x.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(x))
                .ok_or_else(|| {
                    DlrError::parse("checkpoint", format!("bad bit pattern in '{key}'"))
                })?;
            Ok(f32::from_bits(x as u32))
        })
        .collect()
}

fn f32_bits_from_json(doc: &Json, key: &str) -> Result<Vec<f32>> {
    let arr = doc
        .get(key)
        .ok_or_else(|| DlrError::parse("checkpoint", format!("missing '{key}'")))?;
    f32_bits_from_value(arr, key)
}

fn u64_hex(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn u64_from_hex(v: &Json) -> Result<u64> {
    let s = v
        .as_str()
        .ok_or_else(|| DlrError::parse("checkpoint", "expected hex string"))?;
    u64::from_str_radix(s, 16)
        .map_err(|_| DlrError::parse("checkpoint", format!("bad hex word '{s}'")))
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("version".into(), Json::Num(1.0));
        m.insert("kind".into(), Json::Str(CHECKPOINT_KIND.into()));
        m.insert("lambda".into(), Json::Num(self.lambda));
        // f64 bit pattern alongside the readable value: bit-exact resume
        // must not depend on decimal round-tripping
        m.insert("lambda_bits".into(), u64_hex(self.lambda.to_bits()));
        m.insert("family".into(), Json::Str(self.family.name().into()));
        m.insert("enet_alpha".into(), Json::Num(self.enet_alpha));
        m.insert("enet_alpha_bits".into(), u64_hex(self.enet_alpha.to_bits()));
        m.insert("n".into(), Json::Num(self.n as f64));
        m.insert("p".into(), Json::Num(self.p as f64));
        m.insert("iter".into(), Json::Num(self.iter as f64));
        m.insert(
            "f_prev_bits".into(),
            match self.f_prev {
                Some(f) => u64_hex(f.to_bits()),
                None => Json::Null,
            },
        );
        if let Some(f) = self.f_prev {
            m.insert("objective".into(), Json::Num(f));
        }
        m.insert("sim_compute_secs".into(), Json::Num(self.sim_compute_secs));
        m.insert("sim_comm_secs".into(), Json::Num(self.sim_comm_secs));
        m.insert("comm_bytes".into(), Json::Num(self.comm_bytes as f64));
        m.insert("wall_secs".into(), Json::Num(self.wall_secs));
        m.insert("beta_bits".into(), f32_bits_json(&self.beta));
        m.insert("margins_bits".into(), f32_bits_json(&self.margins));
        m.insert(
            "rng".into(),
            match self.rng {
                Some(state) => Json::Arr(state.iter().map(|&w| u64_hex(w)).collect()),
                None => Json::Null,
            },
        );
        m.insert(
            "shards_bits".into(),
            Json::Arr(self.shards.iter().map(|s| f32_bits_json(s)).collect()),
        );
        m.insert(
            "est_shrink".into(),
            match self.est_shrink {
                Some((dm, db)) => {
                    Json::Arr(vec![u64_hex(dm.to_bits()), u64_hex(db.to_bits())])
                }
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }

    pub fn from_json(doc: &Json) -> Result<Self> {
        let version = doc.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            return Err(DlrError::parse(
                "checkpoint",
                format!("unsupported version {version}"),
            ));
        }
        if doc.get("kind").and_then(Json::as_str) != Some(CHECKPOINT_KIND) {
            return Err(DlrError::parse("checkpoint", "not a fit-checkpoint file"));
        }
        let num = |key: &str| -> Result<f64> {
            doc.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| DlrError::parse("checkpoint", format!("missing '{key}'")))
        };
        let lambda = match doc.get("lambda_bits") {
            Some(bits) => f64::from_bits(u64_from_hex(bits)?),
            None => num("lambda")?,
        };
        // pre-family checkpoints carry neither key: logistic pure-L1
        let family = match doc.get("family").and_then(Json::as_str) {
            Some(name) => FamilyKind::parse(name).ok_or_else(|| {
                DlrError::parse("checkpoint", format!("unknown family '{name}'"))
            })?,
            None => FamilyKind::Logistic,
        };
        let enet_alpha = match doc.get("enet_alpha_bits") {
            Some(bits) => f64::from_bits(u64_from_hex(bits)?),
            None => 1.0,
        };
        let f_prev = match doc.get("f_prev_bits") {
            Some(Json::Null) | None => None,
            Some(bits) => Some(f64::from_bits(u64_from_hex(bits)?)),
        };
        let rng = match doc.get("rng") {
            Some(Json::Arr(words)) if words.len() == 4 => {
                let mut state = [0u64; 4];
                for (slot, w) in state.iter_mut().zip(words) {
                    *slot = u64_from_hex(w)?;
                }
                Some(state)
            }
            _ => None,
        };
        // optional in legacy checkpoints: resume then re-gathers the shard
        // states from β and starts the estimator fresh
        let shards = match doc.get("shards_bits") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(|item| f32_bits_from_value(item, "shards_bits"))
                .collect::<Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        let est_shrink = match doc.get("est_shrink") {
            Some(Json::Arr(words)) if words.len() == 2 => Some((
                f64::from_bits(u64_from_hex(&words[0])?),
                f64::from_bits(u64_from_hex(&words[1])?),
            )),
            _ => None,
        };
        let ck = Self {
            lambda,
            family,
            enet_alpha,
            n: num("n")? as usize,
            p: num("p")? as usize,
            iter: num("iter")? as usize,
            f_prev,
            sim_compute_secs: num("sim_compute_secs")?,
            sim_comm_secs: num("sim_comm_secs")?,
            comm_bytes: num("comm_bytes")? as u64,
            wall_secs: num("wall_secs")?,
            beta: f32_bits_from_json(doc, "beta_bits")?,
            margins: f32_bits_from_json(doc, "margins_bits")?,
            rng,
            shards,
            est_shrink,
        };
        if ck.beta.len() != ck.p || ck.margins.len() != ck.n {
            return Err(DlrError::parse(
                "checkpoint",
                "beta/margins length does not match recorded shape",
            ));
        }
        if ck.shards.iter().map(Vec::len).sum::<usize>() != 0
            && ck.shards.iter().map(Vec::len).sum::<usize>() != ck.p
        {
            return Err(DlrError::parse(
                "checkpoint",
                "shard states do not cover the feature space",
            ));
        }
        Ok(ck)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_checkpoint() -> Checkpoint {
        Checkpoint {
            lambda: 0.1 + 0.2, // deliberately non-representable decimal
            family: FamilyKind::Poisson,
            enet_alpha: 0.1 + 0.6, // non-representable again
            n: 3,
            p: 2,
            iter: 7,
            f_prev: Some(123.456789012345678),
            sim_compute_secs: 0.25,
            sim_comm_secs: 1e-9,
            comm_bytes: 123_456_789,
            wall_secs: 42.0,
            beta: vec![0.1f32, -2.5e-8],
            margins: vec![1.5f32, -0.0, 3.25e10],
            rng: Some([1, u64::MAX, 0xDEAD_BEEF, 1 << 63]),
            shards: vec![vec![0.1f32], vec![-2.5e-8f32]],
            est_shrink: Some((0.3333333333333333, 1.0)),
        }
    }

    #[test]
    fn checkpoint_json_roundtrip_is_bit_exact() {
        let ck = toy_checkpoint();
        let back = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(ck.lambda.to_bits(), back.lambda.to_bits());
        assert_eq!(ck.f_prev.unwrap().to_bits(), back.f_prev.unwrap().to_bits());
        for (a, b) in ck.beta.iter().zip(&back.beta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ck.margins.iter().zip(&back.margins) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(ck.rng, back.rng);
        for (a, b) in ck.shards.iter().zip(&back.shards) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        let (adm, adb) = ck.est_shrink.unwrap();
        let (bdm, bdb) = back.est_shrink.unwrap();
        assert_eq!(adm.to_bits(), bdm.to_bits());
        assert_eq!(adb.to_bits(), bdb.to_bits());
        assert_eq!(ck, back);
    }

    #[test]
    fn checkpoint_file_roundtrip() {
        let ck = toy_checkpoint();
        let path = std::env::temp_dir()
            .join(format!("dglmnet_ckpt_{}.json", std::process::id()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(ck, back);
    }

    #[test]
    fn legacy_checkpoint_without_shards_still_loads() {
        // PR-2-era files have no shards_bits / est_shrink keys
        let mut doc = toy_checkpoint().to_json();
        if let Json::Obj(m) = &mut doc {
            m.remove("shards_bits");
            m.remove("est_shrink");
        }
        let ck = Checkpoint::from_json(&doc).unwrap();
        assert!(ck.shards.is_empty());
        assert!(ck.est_shrink.is_none());
    }

    #[test]
    fn pre_family_checkpoint_defaults_to_logistic_pure_l1() {
        let mut doc = toy_checkpoint().to_json();
        if let Json::Obj(m) = &mut doc {
            m.remove("family");
            m.remove("enet_alpha");
            m.remove("enet_alpha_bits");
        }
        let ck = Checkpoint::from_json(&doc).unwrap();
        assert_eq!(ck.family, FamilyKind::Logistic);
        assert_eq!(ck.enet_alpha.to_bits(), 1.0f64.to_bits());
        // an unknown family name is rejected, not silently defaulted
        let mut doc = toy_checkpoint().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("family".into(), Json::Str("tweedie".into()));
        }
        assert!(Checkpoint::from_json(&doc).is_err());
    }

    #[test]
    fn checkpoint_rejects_corrupt_bit_patterns() {
        // out-of-range or fractional bit entries must fail, not saturate
        let mut doc = toy_checkpoint().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert(
                "beta_bits".into(),
                Json::Arr(vec![Json::Num(5e9), Json::Num(0.0)]),
            );
        }
        assert!(Checkpoint::from_json(&doc).is_err());
        let mut doc = toy_checkpoint().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert(
                "margins_bits".into(),
                Json::Arr(vec![Json::Num(123.7), Json::Num(0.0), Json::Num(0.0)]),
            );
        }
        assert!(Checkpoint::from_json(&doc).is_err());
        // shard states that don't cover the feature space are rejected
        let mut doc = toy_checkpoint().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert(
                "shards_bits".into(),
                Json::Arr(vec![Json::Arr(vec![Json::Num(0.0)])]),
            );
        }
        assert!(Checkpoint::from_json(&doc).is_err());
    }

    #[test]
    fn checkpoint_rejects_wrong_kind_and_version() {
        let mut doc = toy_checkpoint().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("kind".into(), Json::Str("something-else".into()));
        }
        assert!(Checkpoint::from_json(&doc).is_err());
        let mut doc = toy_checkpoint().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("version".into(), Json::Num(9.0));
        }
        assert!(Checkpoint::from_json(&doc).is_err());
    }
}
