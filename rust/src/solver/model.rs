//! Fitted sparse linear model: prediction, persistence, inspection.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::data::sparse::CsrMatrix;
use crate::error::{DlrError, Result};

/// A sparse coefficient vector β (only non-zeros stored).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseModel {
    pub n_features: usize,
    /// (feature id, weight), ascending by feature id.
    pub entries: Vec<(u32, f32)>,
    /// λ the model was fitted at (metadata).
    pub lambda: f64,
}

impl SparseModel {
    pub fn from_dense(beta: &[f32], lambda: f64) -> Self {
        Self {
            n_features: beta.len(),
            entries: beta
                .iter()
                .enumerate()
                .filter(|(_, &b)| b != 0.0)
                .map(|(j, &b)| (j as u32, b))
                .collect(),
            lambda,
        }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut beta = vec![0f32; self.n_features];
        for &(j, w) in &self.entries {
            beta[j as usize] = w;
        }
        beta
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Decision margins βᵀx over a by-example matrix.
    pub fn predict_margins(&self, x: &CsrMatrix) -> Vec<f32> {
        let beta = self.to_dense();
        let mut padded = beta;
        if x.n_cols > padded.len() {
            padded.resize(x.n_cols, 0.0);
        }
        x.margins(&padded)
    }

    /// P(y = +1 | x).
    pub fn predict_proba(&self, x: &CsrMatrix) -> Vec<f32> {
        self.predict_margins(x)
            .into_iter()
            .map(|m| crate::util::math::sigmoid(m as f64) as f32)
            .collect()
    }

    /// Text persistence: header line + `feature weight` lines.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "dglmnet-model v1 p={} lambda={}", self.n_features, self.lambda)?;
        for &(j, w) in &self.entries {
            writeln!(f, "{j} {w}")?;
        }
        f.flush()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let f = BufReader::new(std::fs::File::open(path)?);
        let mut lines = f.lines();
        let header = lines
            .next()
            .ok_or_else(|| DlrError::parse("model", "empty file"))??;
        let mut p = None;
        let mut lambda = 0f64;
        for tok in header.split_whitespace() {
            if let Some(v) = tok.strip_prefix("p=") {
                p = v.parse::<usize>().ok();
            }
            if let Some(v) = tok.strip_prefix("lambda=") {
                lambda = v.parse::<f64>().unwrap_or(0.0);
            }
        }
        let n_features =
            p.ok_or_else(|| DlrError::parse("model", "missing p= in header"))?;
        let mut entries = Vec::new();
        for line in lines {
            let line = line?;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (j, w) = line
                .split_once(' ')
                .ok_or_else(|| DlrError::parse("model", "bad entry line"))?;
            entries.push((
                j.parse::<u32>()
                    .map_err(|_| DlrError::parse("model", "bad feature id"))?,
                w.parse::<f32>()
                    .map_err(|_| DlrError::parse("model", "bad weight"))?,
            ));
        }
        Ok(Self { n_features, entries, lambda })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip_and_nnz() {
        let beta = vec![0.0f32, 1.5, 0.0, -2.0];
        let m = SparseModel::from_dense(&beta, 0.5);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.entries, vec![(1, 1.5), (3, -2.0)]);
        assert_eq!(m.to_dense(), beta);
    }

    #[test]
    fn predict_margins_on_toy() {
        let mut x = CsrMatrix::new(3);
        x.push_row(&[(0, 1.0), (2, 2.0)]);
        x.push_row(&[(1, 1.0)]);
        let m = SparseModel::from_dense(&[1.0, -1.0, 0.5], 0.0);
        assert_eq!(m.predict_margins(&x), vec![2.0, -1.0]);
        let p = m.predict_proba(&x);
        assert!(p[0] > 0.5 && p[1] < 0.5);
    }

    #[test]
    fn save_load_roundtrip() {
        let m = SparseModel::from_dense(&[0.0, 0.25, -3.5], 0.125);
        let path = std::env::temp_dir().join(format!("dglmnet_model_{}.txt", std::process::id()));
        m.save(&path).unwrap();
        let m2 = SparseModel::load(&path).unwrap();
        assert_eq!(m, m2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn predict_wider_matrix_than_model() {
        let mut x = CsrMatrix::new(5);
        x.push_row(&[(4, 1.0)]);
        let m = SparseModel::from_dense(&[1.0, 2.0], 0.0);
        assert_eq!(m.predict_margins(&x), vec![0.0]);
    }
}
