//! Fitted sparse linear model: prediction, persistence, inspection.
//!
//! The on-disk artifact (v2) is the contract between `train`, the offline
//! `dglmnet predict` scorer, and the `dglmnet serve` hot-swap loop: a
//! header embedding the model shape (`p`), the training-set size (`n`),
//! λ, the solver that produced it, the GLM family and elastic-net α when
//! they differ from the logistic pure-L1 defaults, the entry count, and
//! an FNV-1a checksum over the canonical payload bytes (same scheme as
//! `data/store.rs`), followed by one `feature weight` line per non-zero.
//! [`SparseModel::load`] verifies all of it — a truncated, bit-flipped or
//! dimension-inconsistent artifact is rejected with an actionable error
//! instead of scoring garbage. v1 headers (no metadata, no checksum) are
//! still accepted for legacy files.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::data::sparse::CsrMatrix;
use crate::error::{DlrError, Result};
use crate::family::FamilyKind;

// FNV-1a, the same constants the shard store and wire protocol use.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// A sparse coefficient vector β (only non-zeros stored).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseModel {
    pub n_features: usize,
    /// (feature id, weight), ascending by feature id.
    pub entries: Vec<(u32, f32)>,
    /// λ the model was fitted at (metadata).
    pub lambda: f64,
    /// Training-set example count (artifact metadata; 0 = unknown/legacy).
    pub n_examples: usize,
    /// Solver that produced the fit (artifact metadata; "" = unknown).
    pub solver: String,
    /// GLM family the model was fitted as. Recorded in the header (and
    /// checksummed) only when non-default, so every pre-family artifact —
    /// and every default logistic one — keeps its exact historical bytes;
    /// absent on load means logistic.
    pub family: FamilyKind,
    /// Elastic-net mix α ∈ (0, 1] the fit used (1.0 = pure L1, the
    /// default). Same non-default-only persistence rule as `family`.
    pub enet_alpha: f64,
}

impl SparseModel {
    pub fn from_dense(beta: &[f32], lambda: f64) -> Self {
        Self {
            n_features: beta.len(),
            entries: beta
                .iter()
                .enumerate()
                .filter(|(_, &b)| b != 0.0)
                .map(|(j, &b)| (j as u32, b))
                .collect(),
            lambda,
            n_examples: 0,
            solver: String::new(),
            family: FamilyKind::Logistic,
            enet_alpha: 1.0,
        }
    }

    /// Attach the artifact metadata `train` embeds at `--model-out` time.
    /// Whitespace in the solver name would corrupt the header token
    /// stream, so it is replaced with `-`.
    pub fn with_meta(mut self, n_examples: usize, solver: &str) -> Self {
        self.n_examples = n_examples;
        self.solver = solver
            .chars()
            .map(|c| if c.is_whitespace() { '-' } else { c })
            .collect();
        self
    }

    /// Record which GLM family and elastic-net mix produced the fit.
    pub fn with_family(mut self, family: FamilyKind, enet_alpha: f64) -> Self {
        self.family = family;
        self.enet_alpha = enet_alpha;
        self
    }

    /// True when the fit settings match the pre-family defaults (logistic
    /// pure L1) — the case whose artifact bytes are pinned to the seed.
    fn default_family(&self) -> bool {
        self.family == FamilyKind::Logistic && self.enet_alpha == 1.0
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut beta = vec![0f32; self.n_features];
        for &(j, w) in &self.entries {
            beta[j as usize] = w;
        }
        beta
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// FNV-1a over the canonical payload bytes: `p`, `n`, λ bits, the
    /// solver name, then every `(feature, weight-bits)` pair in order.
    /// This is both the artifact integrity check and the serve-side model
    /// version (two models answer identically iff their checksums match).
    pub fn checksum(&self) -> u64 {
        let mut h = FNV_OFFSET;
        h = fnv1a(h, &(self.n_features as u64).to_le_bytes());
        h = fnv1a(h, &(self.n_examples as u64).to_le_bytes());
        h = fnv1a(h, &self.lambda.to_bits().to_le_bytes());
        h = fnv1a(h, self.solver.as_bytes());
        if !self.default_family() {
            // folded only when non-default so default artifacts keep the
            // exact checksum (and bytes) the seed produced
            h = fnv1a(h, self.family.name().as_bytes());
            h = fnv1a(h, &self.enet_alpha.to_bits().to_le_bytes());
        }
        for &(j, w) in &self.entries {
            h = fnv1a(h, &j.to_le_bytes());
            h = fnv1a(h, &w.to_bits().to_le_bytes());
        }
        h
    }

    /// Decision margins βᵀx over a by-example matrix, through the shared
    /// `data::sparse::dot_margin` kernel — bit-identical to the training
    /// cluster's margin rebuild for the same β.
    pub fn predict_margins(&self, x: &CsrMatrix) -> Vec<f32> {
        let beta = self.to_dense();
        let mut padded = beta;
        if x.n_cols > padded.len() {
            padded.resize(x.n_cols, 0.0);
        }
        x.margins(&padded)
    }

    /// P(y = +1 | x) — the logistic inverse link, regardless of the
    /// model's family. For family-aware scoring use [`predict_mean`],
    /// which is identical for logistic models.
    ///
    /// [`predict_mean`]: SparseModel::predict_mean
    pub fn predict_proba(&self, x: &CsrMatrix) -> Vec<f32> {
        self.predict_margins(x)
            .into_iter()
            .map(|m| crate::util::math::sigmoid(m as f64) as f32)
            .collect()
    }

    /// Mean predictions μ = g⁻¹(βᵀx) under the model's family:
    /// probability for logistic (bit-identical to [`predict_proba`]),
    /// identity for gaussian, exp for poisson.
    ///
    /// [`predict_proba`]: SparseModel::predict_proba
    pub fn predict_mean(&self, x: &CsrMatrix) -> Vec<f32> {
        let fam = self.family.family();
        self.predict_margins(x)
            .into_iter()
            .map(|m| fam.mean(m as f64) as f32)
            .collect()
    }

    /// Structural validation shared by `load` and the serve reloader:
    /// entries ascending/unique and inside `[0, p)`.
    fn validate(&self) -> Result<()> {
        let mut prev: Option<u32> = None;
        for &(j, _) in &self.entries {
            if j as usize >= self.n_features {
                return Err(DlrError::Artifact(format!(
                    "model entry references feature {j} but the header says p = {}; \
                     the artifact is dimension-inconsistent (corrupt or mis-assembled) \
                     — re-export it from a fit",
                    self.n_features
                )));
            }
            if prev.is_some_and(|p| p >= j) {
                return Err(DlrError::Artifact(format!(
                    "model entries are not strictly ascending at feature {j}; \
                     the artifact is corrupt — re-export it from a fit"
                )));
            }
            prev = Some(j);
        }
        Ok(())
    }

    /// Text persistence (artifact v2): checksummed header + `feature
    /// weight` lines. Byte-deterministic for a given model, so two fits
    /// that agree bit-for-bit produce `cmp`-equal artifacts.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        // family/alpha tokens appear only on non-default fits: a default
        // logistic pure-L1 artifact stays byte-for-byte what the seed wrote
        // (pinned in tests/estimator_api.rs), and old loaders that don't
        // know the tokens never see them
        let family_meta = if self.default_family() {
            String::new()
        } else {
            format!(" family={} alpha={}", self.family.name(), self.enet_alpha)
        };
        writeln!(
            f,
            "dglmnet-model v2 p={} n={} lambda={} solver={}{} nnz={} checksum={:016x}",
            self.n_features,
            self.n_examples,
            self.lambda,
            self.solver,
            family_meta,
            self.entries.len(),
            self.checksum()
        )?;
        for &(j, w) in &self.entries {
            writeln!(f, "{j} {w}")?;
        }
        f.flush()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let f = BufReader::new(std::fs::File::open(path)?);
        let mut lines = f.lines();
        let header = lines
            .next()
            .ok_or_else(|| DlrError::parse("model", "empty file"))??;
        if !header.starts_with("dglmnet-model ") {
            return Err(DlrError::Artifact(
                "not a dglmnet model artifact (missing 'dglmnet-model' header) — \
                 was the wrong file passed as --model?"
                    .into(),
            ));
        }
        let mut p = None;
        let mut lambda = 0f64;
        let mut n_examples = 0usize;
        let mut solver = String::new();
        let mut family = FamilyKind::Logistic;
        let mut enet_alpha = 1.0f64;
        let mut nnz: Option<usize> = None;
        let mut checksum: Option<u64> = None;
        for tok in header.split_whitespace() {
            if let Some(v) = tok.strip_prefix("p=") {
                p = v.parse::<usize>().ok();
            }
            if let Some(v) = tok.strip_prefix("n=") {
                n_examples = v.parse::<usize>().unwrap_or(0);
            }
            if let Some(v) = tok.strip_prefix("lambda=") {
                lambda = v.parse::<f64>().unwrap_or(0.0);
            }
            if let Some(v) = tok.strip_prefix("solver=") {
                solver = v.to_string();
            }
            if let Some(v) = tok.strip_prefix("family=") {
                family = FamilyKind::parse(v).ok_or_else(|| {
                    DlrError::Artifact(format!(
                        "model artifact names unknown GLM family '{v}' — was it \
                         written by a newer dglmnet? Known: logistic, gaussian, \
                         poisson"
                    ))
                })?;
            }
            if let Some(v) = tok.strip_prefix("alpha=") {
                enet_alpha = v.parse::<f64>().map_err(|_| {
                    DlrError::Artifact(format!(
                        "unreadable elastic-net alpha '{v}' — the artifact header \
                         is corrupt"
                    ))
                })?;
            }
            if let Some(v) = tok.strip_prefix("nnz=") {
                nnz = v.parse::<usize>().ok();
            }
            if let Some(v) = tok.strip_prefix("checksum=") {
                checksum = Some(u64::from_str_radix(v, 16).map_err(|_| {
                    DlrError::Artifact(format!(
                        "unreadable model checksum '{v}' — the artifact header is corrupt"
                    ))
                })?);
            }
        }
        let n_features =
            p.ok_or_else(|| DlrError::parse("model", "missing p= in header"))?;
        let mut entries = Vec::new();
        for line in lines {
            let line = line?;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (j, w) = line
                .split_once(' ')
                .ok_or_else(|| DlrError::parse("model", "bad entry line"))?;
            entries.push((
                j.parse::<u32>()
                    .map_err(|_| DlrError::parse("model", "bad feature id"))?,
                w.parse::<f32>()
                    .map_err(|_| DlrError::parse("model", "bad weight"))?,
            ));
        }
        let model =
            Self { n_features, entries, lambda, n_examples, solver, family, enet_alpha };
        if let Some(want) = nnz {
            if model.entries.len() != want {
                return Err(DlrError::Artifact(format!(
                    "model artifact has {} entries but the header promises nnz = {want}; \
                     the file is truncated or was partially rewritten — retrain or \
                     re-export it",
                    model.entries.len()
                )));
            }
        }
        model.validate()?;
        if let Some(want) = checksum {
            let got = model.checksum();
            if got != want {
                return Err(DlrError::Artifact(format!(
                    "model artifact checksum mismatch (header {want:016x}, computed \
                     {got:016x}); the file is corrupt or was partially rewritten — \
                     retrain or re-export it"
                )));
            }
        }
        Ok(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip_and_nnz() {
        let beta = vec![0.0f32, 1.5, 0.0, -2.0];
        let m = SparseModel::from_dense(&beta, 0.5);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.entries, vec![(1, 1.5), (3, -2.0)]);
        assert_eq!(m.to_dense(), beta);
    }

    #[test]
    fn predict_margins_on_toy() {
        let mut x = CsrMatrix::new(3);
        x.push_row(&[(0, 1.0), (2, 2.0)]);
        x.push_row(&[(1, 1.0)]);
        let m = SparseModel::from_dense(&[1.0, -1.0, 0.5], 0.0);
        assert_eq!(m.predict_margins(&x), vec![2.0, -1.0]);
        let p = m.predict_proba(&x);
        assert!(p[0] > 0.5 && p[1] < 0.5);
    }

    #[test]
    fn save_load_roundtrip_with_metadata() {
        let m = SparseModel::from_dense(&[0.0, 0.25, -3.5], 0.125)
            .with_meta(4_000, "dglmnet");
        let path = std::env::temp_dir().join(format!("dglmnet_model_{}.txt", std::process::id()));
        m.save(&path).unwrap();
        let m2 = SparseModel::load(&path).unwrap();
        assert_eq!(m, m2);
        assert_eq!(m2.n_examples, 4_000);
        assert_eq!(m2.solver, "dglmnet");
        assert_eq!(m2.checksum(), m.checksum());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn predict_wider_matrix_than_model() {
        let mut x = CsrMatrix::new(5);
        x.push_row(&[(4, 1.0)]);
        let m = SparseModel::from_dense(&[1.0, 2.0], 0.0);
        assert_eq!(m.predict_margins(&x), vec![0.0]);
    }

    #[test]
    fn corrupted_artifacts_are_rejected_with_actionable_errors() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("dglmnet_model_corrupt_{}.txt", std::process::id()));
        let m = SparseModel::from_dense(&[1.0, 0.0, -0.5, 2.25], 0.5)
            .with_meta(100, "dglmnet");
        m.save(&path).unwrap();

        // bit-flip a weight: checksum mismatch
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("2.25", "2.26")).unwrap();
        let err = SparseModel::load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");

        // drop an entry line: nnz mismatch (truncation)
        let mut lines: Vec<&str> = text.lines().collect();
        lines.pop();
        std::fs::write(&path, lines.join("\n")).unwrap();
        let err = SparseModel::load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");

        // entry beyond p: dimension mismatch beats garbage scoring
        let bad = text.replacen("p=4", "p=2", 1);
        std::fs::write(&path, bad).unwrap();
        let err = SparseModel::load(&path).unwrap_err().to_string();
        assert!(err.contains("dimension-inconsistent"), "{err}");

        // not a model at all
        std::fs::write(&path, "BENCH results\n1 2\n").unwrap();
        let err = SparseModel::load(&path).unwrap_err().to_string();
        assert!(err.contains("not a dglmnet model artifact"), "{err}");

        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_v1_headers_still_load() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("dglmnet_model_v1_{}.txt", std::process::id()));
        std::fs::write(&path, "dglmnet-model v1 p=3 lambda=0.5\n1 1.5\n2 -2\n").unwrap();
        let m = SparseModel::load(&path).unwrap();
        assert_eq!(m.n_features, 3);
        assert_eq!(m.lambda, 0.5);
        assert_eq!(m.entries, vec![(1, 1.5), (2, -2.0)]);
        assert_eq!(m.n_examples, 0);
        assert!(m.solver.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checksum_tracks_every_metadata_field() {
        let base = SparseModel::from_dense(&[1.0, -1.0], 0.5).with_meta(10, "dglmnet");
        let mut other = base.clone();
        other.lambda = 0.25;
        assert_ne!(base.checksum(), other.checksum());
        let mut other = base.clone();
        other.n_examples = 11;
        assert_ne!(base.checksum(), other.checksum());
        let mut other = base.clone();
        other.solver = "shotgun".into();
        assert_ne!(base.checksum(), other.checksum());
        let mut other = base.clone();
        other.family = FamilyKind::Gaussian;
        assert_ne!(base.checksum(), other.checksum());
        let mut other = base.clone();
        other.enet_alpha = 0.5;
        assert_ne!(base.checksum(), other.checksum());
        let mut other = base.clone();
        other.entries[0].1 = 1.0000001;
        assert_ne!(base.checksum(), other.checksum());
    }

    #[test]
    fn family_metadata_roundtrips_and_defaults_write_no_tokens() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("dglmnet_model_family_{}.txt", std::process::id()));
        // default fit: the header carries no family/alpha tokens at all,
        // so the artifact bytes are exactly what the pre-family code wrote
        let m = SparseModel::from_dense(&[1.0, 0.0, -0.5], 0.5).with_meta(10, "dglmnet");
        m.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.contains("family=") && !text.contains("alpha="), "{text}");
        let loaded = SparseModel::load(&path).unwrap();
        assert_eq!(loaded.family, FamilyKind::Logistic);
        assert_eq!(loaded.enet_alpha, 1.0);

        // non-default fit: tokens round-trip exactly (α down to the bits —
        // 0.1 + 0.6 is not exactly representable)
        let g = m.clone().with_family(FamilyKind::Poisson, 0.1 + 0.6);
        g.save(&path).unwrap();
        let g2 = SparseModel::load(&path).unwrap();
        assert_eq!(g2.family, FamilyKind::Poisson);
        assert_eq!(g2.enet_alpha.to_bits(), (0.1f64 + 0.6).to_bits());
        assert_eq!(g, g2);
        assert_ne!(g.checksum(), m.checksum());

        // unknown family names are rejected, not silently defaulted
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("family=poisson", "family=tweedie")).unwrap();
        let err = SparseModel::load(&path).unwrap_err().to_string();
        assert!(err.contains("unknown GLM family 'tweedie'"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn predict_mean_follows_the_family_link() {
        let mut x = CsrMatrix::new(2);
        x.push_row(&[(0, 1.0), (1, 2.0)]);
        let base = SparseModel::from_dense(&[0.5, 0.25], 0.0);
        let margin = base.predict_margins(&x)[0];
        // logistic: mean is the probability, bit-for-bit
        assert_eq!(
            base.predict_mean(&x)[0].to_bits(),
            base.predict_proba(&x)[0].to_bits()
        );
        // gaussian: identity link
        let gau = base.clone().with_family(FamilyKind::Gaussian, 1.0);
        assert_eq!(gau.predict_mean(&x)[0].to_bits(), margin.to_bits());
        // poisson: log link
        let poi = base.clone().with_family(FamilyKind::Poisson, 1.0);
        assert_eq!(poi.predict_mean(&x)[0], (margin as f64).exp() as f32);
    }

    #[test]
    fn with_meta_sanitizes_whitespace_in_solver_names() {
        let m = SparseModel::from_dense(&[1.0], 0.0).with_meta(1, "my solver");
        assert_eq!(m.solver, "my-solver");
    }
}
