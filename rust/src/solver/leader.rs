//! Leader-side compute: working statistics (paper eq. (4)) and the O(n)
//! loss part of the line search (Alg 3). Runs the AOT `stats` /
//! `line_search` kernels through PJRT (with the `xla` feature), or the
//! native fallback — selected by the solver's engine kind so the whole hot
//! path stays on one stack.
//!
//! The leader does *not* perform comm-layer merge work: under the
//! allgather-Δβ exchange it consumes a Δm that was recombined from the
//! workers' shard-local products by `WorkerPool` merge tasks (see
//! `cluster::comm`), and under reduce-Δm the tree merges likewise run on
//! the worker threads.

use crate::config::{EngineKind, TrainConfig};
use crate::error::Result;
use crate::family::FamilyKind;
#[cfg(feature = "xla")]
use crate::runtime::{lit_vec, XlaContext};

/// Leader compute context.
pub enum LeaderCompute {
    Native {
        y: Vec<f32>,
        family: FamilyKind,
    },
    #[cfg(feature = "xla")]
    Xla {
        ctx: XlaContext,
        stats_unit: String,
        ls_unit: String,
        n: usize,
        n_pad: usize,
        k: usize,
        y_pad: Vec<f32>,
        /// prebuilt literals reused every call
        y_lit: xla::Literal,
        mask_lit: xla::Literal,
        /// scratch padded buffers
        buf_a: Vec<f32>,
        buf_b: Vec<f32>,
    },
}

impl LeaderCompute {
    pub fn new(cfg: &TrainConfig, y: &[f32], artifacts_dir: &std::path::Path) -> Result<Self> {
        // Auto: the leader kernels are plain O(n) elementwise work — use XLA
        // whenever the feature is compiled in, artifacts exist, and n fits a
        // compiled tile. The AOT kernels are logistic-only, so any other
        // family resolves to Native (explicit Xla + non-logistic is already
        // rejected by TrainConfig::validate).
        let kind = match cfg.engine {
            EngineKind::Auto => {
                let ok = cfg!(feature = "xla")
                    && cfg.family == FamilyKind::Logistic
                    && crate::runtime::Manifest::load(artifacts_dir)
                        .and_then(|m| m.pick_n(y.len()))
                        .is_ok();
                if ok {
                    EngineKind::Xla
                } else {
                    EngineKind::Native
                }
            }
            k => k,
        };
        match kind {
            EngineKind::Auto => unreachable!(),
            EngineKind::Native => {
                Ok(LeaderCompute::Native { y: y.to_vec(), family: cfg.family })
            }
            #[cfg(not(feature = "xla"))]
            EngineKind::Xla => Err(crate::error::DlrError::Artifact(
                "XLA leader requested but this build has no `xla` feature \
                 (rebuild with --features xla and run `make artifacts`)"
                    .into(),
            )),
            #[cfg(feature = "xla")]
            EngineKind::Xla => {
                let mut ctx = XlaContext::new(artifacts_dir)?;
                let n = y.len();
                let n_pad = ctx.manifest().pick_n(n)?;
                let k = ctx.manifest().k_alphas;
                let stats_unit = ctx.manifest().find("stats", n_pad, None)?.name.clone();
                let ls_unit = {
                    let unit = ctx
                        .manifest()
                        .units
                        .iter()
                        .find(|u| u.fn_name == "line_search" && u.n == n_pad)
                        .ok_or_else(|| {
                            crate::error::DlrError::Artifact(format!(
                                "no line_search unit for n = {n_pad}"
                            ))
                        })?;
                    unit.name.clone()
                };
                ctx.ensure_compiled(&stats_unit)?;
                ctx.ensure_compiled(&ls_unit)?;
                let mut y_pad = vec![0f32; n_pad];
                y_pad[..n].copy_from_slice(y);
                let mut mask = vec![0f32; n_pad];
                mask[..n].fill(1.0);
                let y_lit = lit_vec(&y_pad);
                let mask_lit = lit_vec(&mask);
                Ok(LeaderCompute::Xla {
                    ctx,
                    stats_unit,
                    ls_unit,
                    n,
                    n_pad,
                    k,
                    y_pad,
                    y_lit,
                    mask_lit,
                    buf_a: vec![0f32; n_pad],
                    buf_b: vec![0f32; n_pad],
                })
            }
        }
    }

    /// Loss sum at the current margins — the only leader-side statistic
    /// the protocol-era iteration needs (the worker nodes derive their own
    /// `(w, z)` from their margins copies). Bit-identical to the loss
    /// accumulation of [`LeaderCompute::stats_into`] (same element order,
    /// same f64 ops).
    pub fn loss(&mut self, margins: &[f32]) -> Result<f64> {
        match self {
            LeaderCompute::Native { y, family } => {
                Ok(family.family().loss_sum(margins, y))
            }
            #[cfg(feature = "xla")]
            LeaderCompute::Xla { .. } => {
                // the stats kernel returns the loss alongside (w, z)
                let (mut w, mut z) = (Vec::new(), Vec::new());
                self.stats_into(margins, &mut w, &mut z)
            }
        }
    }

    /// (w, z, loss_sum) at the current margins. Compatibility wrapper over
    /// [`LeaderCompute::stats_into`] — hot loops should hold reusable w/z
    /// buffers and call that instead.
    pub fn stats(&mut self, margins: &[f32]) -> Result<(Vec<f32>, Vec<f32>, f64)> {
        let mut w = Vec::new();
        let mut z = Vec::new();
        let loss = self.stats_into(margins, &mut w, &mut z)?;
        Ok((w, z, loss))
    }

    /// (w, z) into caller-reused buffers (cleared and refilled; capacities
    /// persist so steady-state calls allocate nothing); returns the loss
    /// sum. Bit-identical to [`LeaderCompute::stats`].
    pub fn stats_into(
        &mut self,
        margins: &[f32],
        w: &mut Vec<f32>,
        z: &mut Vec<f32>,
    ) -> Result<f64> {
        match self {
            LeaderCompute::Native { y, family } => {
                Ok(family.family().working_stats_into(margins, y, w, z))
            }
            #[cfg(feature = "xla")]
            LeaderCompute::Xla { ctx, stats_unit, n, buf_a, y_lit, mask_lit, .. } => {
                buf_a[..*n].copy_from_slice(margins);
                let m_lit = lit_vec(buf_a);
                let out = ctx.run_f32(stats_unit, &[&m_lit, y_lit, mask_lit])?;
                let mut it = out.into_iter();
                let w_out = it.next().unwrap();
                let z_out = it.next().unwrap();
                let loss = it.next().unwrap()[0] as f64;
                w.clear();
                z.clear();
                w.extend_from_slice(&w_out[..*n]);
                z.extend_from_slice(&z_out[..*n]);
                Ok(loss)
            }
        }
    }

    /// Loss part of f(β + αΔβ) for each α in `alphas` (any length — the XLA
    /// path chunks through the compiled K-grid).
    pub fn line_losses(
        &mut self,
        margins: &[f32],
        dmargins: &[f32],
        alphas: &[f64],
    ) -> Result<Vec<f64>> {
        match self {
            LeaderCompute::Native { y, family } => {
                let fam = family.family();
                Ok(alphas.iter().map(|&a| fam.line_loss_sum(margins, dmargins, a, y)).collect())
            }
            #[cfg(feature = "xla")]
            LeaderCompute::Xla {
                ctx, ls_unit, n, k, buf_a, buf_b, y_lit, mask_lit, ..
            } => {
                buf_a[..*n].copy_from_slice(margins);
                buf_b[..*n].copy_from_slice(dmargins);
                let m_lit = lit_vec(buf_a);
                let dm_lit = lit_vec(buf_b);
                let mut out = Vec::with_capacity(alphas.len());
                for chunk in alphas.chunks(*k) {
                    // pad the α-grid by repeating the last entry
                    let mut grid: Vec<f32> = chunk.iter().map(|&a| a as f32).collect();
                    let last = *grid.last().unwrap_or(&0.0);
                    grid.resize(*k, last);
                    let a_lit = lit_vec(&grid);
                    let losses =
                        ctx.run_f32(ls_unit, &[&m_lit, &dm_lit, y_lit, mask_lit, &a_lit])?;
                    out.extend(losses[0][..chunk.len()].iter().map(|&l| l as f64));
                }
                Ok(out)
            }
        }
    }

    pub fn engine_name(&self) -> &'static str {
        match self {
            LeaderCompute::Native { .. } => "native",
            #[cfg(feature = "xla")]
            LeaderCompute::Xla { .. } => "xla",
        }
    }
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn artifacts() -> Option<std::path::PathBuf> {
        let d = crate::runtime::default_artifacts_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    fn toy(n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let margins: Vec<f32> = (0..n).map(|i| (i as f32 / n as f32) - 0.5).collect();
        let dmargins: Vec<f32> = (0..n).map(|i| 0.1 * ((i % 7) as f32 - 3.0)).collect();
        let y: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
        (margins, dmargins, y)
    }

    #[test]
    fn xla_leader_matches_native_leader() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (margins, dmargins, y) = toy(700);
        let cfg_n = TrainConfig::builder().engine(crate::config::EngineKind::Native).build();
        let cfg_x = TrainConfig::builder().engine(crate::config::EngineKind::Xla).build();
        let mut ln = LeaderCompute::new(&cfg_n, &y, &dir).unwrap();
        let mut lx = LeaderCompute::new(&cfg_x, &y, &dir).unwrap();

        let (wn, zn, lossn) = ln.stats(&margins).unwrap();
        let (wx, zx, lossx) = lx.stats(&margins).unwrap();
        assert_eq!(wx.len(), 700);
        for i in (0..700).step_by(41) {
            assert!((wn[i] - wx[i]).abs() < 1e-5, "w[{i}]");
            assert!((zn[i] - zx[i]).abs() < 2e-3 * (1.0 + zn[i].abs()), "z[{i}]");
        }
        assert!((lossn - lossx).abs() / lossn < 1e-4);

        // line losses across a 20-α grid (exercises chunking: 20 > K = 16)
        let alphas: Vec<f64> = (0..20).map(|i| i as f64 / 19.0).collect();
        let a = ln.line_losses(&margins, &dmargins, &alphas).unwrap();
        let b = lx.line_losses(&margins, &dmargins, &alphas).unwrap();
        assert_eq!(a.len(), 20);
        for i in 0..20 {
            assert!((a[i] - b[i]).abs() / a[i] < 1e-4, "alpha[{i}]: {} vs {}", a[i], b[i]);
        }
    }
}
