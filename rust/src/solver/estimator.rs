//! The unified training abstraction: every solver in the crate — d-GLMNET
//! and all three §4.3 baselines — implements [`Estimator`], and every fit
//! streams per-iteration progress through a [`FitObserver`].
//!
//! This is the layer that lets the regularization path, the baseline grid,
//! the bench harness and the CLI treat solvers interchangeably (`&mut dyn
//! Estimator`), with no solver-specific branches: a workload written once
//! against this trait (early stopping, live metrics, checkpointing drivers,
//! head-to-head tournaments) works for every current and future algorithm.
//!
//! ## Contract
//!
//! * [`Estimator::fit`] trains **from the estimator's current state** — a
//!   second `fit` call warmstarts (that is what Algorithm 5's λ ladder
//!   needs). Call [`Estimator::reset`] first for a cold start.
//! * The observer's [`FitObserver::on_iteration`] runs once per iteration
//!   (d-GLMNET iteration, online pass, or shotgun round) *after* the
//!   iteration's update has been applied. Returning [`FitControl::Stop`]
//!   ends the fit early with `converged = false`; the already-recorded
//!   iterations are kept in the returned [`FitResult`] trace. The final
//!   (converged) iteration is also reported, but its control value is
//!   ignored — the fit is already over.
//! * [`FitStep::model`] materializes the coefficients *at that iteration*
//!   lazily, so observers that only read [`IterationRecord`]s cost nothing
//!   extra on the hot path.

use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::solver::dglmnet::{FitResult, IterationRecord};
use crate::solver::model::SparseModel;

/// What the observer wants the fit to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitControl {
    Continue,
    /// End the fit after this iteration (`converged = false` in the result).
    Stop,
}

/// One observed iteration: the record plus lazy access to the model as of
/// this iteration (materialized only when asked for).
pub struct FitStep<'a> {
    pub record: &'a IterationRecord,
    model_fn: &'a dyn Fn() -> SparseModel,
}

impl<'a> FitStep<'a> {
    pub fn new(record: &'a IterationRecord, model_fn: &'a dyn Fn() -> SparseModel) -> Self {
        Self { record, model_fn }
    }

    /// The coefficients after this iteration's update (O(p) to build).
    pub fn model(&self) -> SparseModel {
        (self.model_fn)()
    }
}

/// Per-iteration callback driving early stopping and live metrics.
pub trait FitObserver {
    fn on_iteration(&mut self, _step: &FitStep<'_>) -> FitControl {
        FitControl::Continue
    }
}

/// Observer that does nothing (the default for one-shot fits).
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopObserver;

impl FitObserver for NoopObserver {}

/// Observer that keeps a copy of every iteration record.
#[derive(Debug, Default)]
pub struct RecordingObserver {
    pub records: Vec<IterationRecord>,
}

impl FitObserver for RecordingObserver {
    fn on_iteration(&mut self, step: &FitStep<'_>) -> FitControl {
        self.records.push(step.record.clone());
        FitControl::Continue
    }
}

/// Observer that stops the fit once the relative objective decrease stays
/// below `min_rel_decrease` for `patience` consecutive iterations.
#[derive(Debug)]
pub struct EarlyStopObserver {
    pub min_rel_decrease: f64,
    pub patience: usize,
    last_objective: Option<f64>,
    stalled: usize,
}

impl EarlyStopObserver {
    pub fn new(min_rel_decrease: f64, patience: usize) -> Self {
        Self { min_rel_decrease, patience, last_objective: None, stalled: 0 }
    }
}

impl FitObserver for EarlyStopObserver {
    fn on_iteration(&mut self, step: &FitStep<'_>) -> FitControl {
        let f = step.record.objective;
        let stalled_now = match self.last_objective {
            Some(prev) => (prev - f) / prev.abs().max(1.0) < self.min_rel_decrease,
            None => false,
        };
        self.stalled = if stalled_now { self.stalled + 1 } else { 0 };
        self.last_objective = Some(f);
        if self.stalled >= self.patience.max(1) {
            FitControl::Stop
        } else {
            FitControl::Continue
        }
    }
}

/// A solver for the L1-regularized logistic regression objective
/// f(β) = L(β) + λ‖β‖₁, trainable through one uniform interface.
pub trait Estimator {
    /// Short stable identifier ("d-glmnet", "shotgun", ...).
    fn name(&self) -> &'static str;

    /// Train on `ds` from the current state (warmstart); see the module
    /// docs for the observer contract. Call [`Estimator::reset`] first for
    /// a cold fit.
    fn fit(&mut self, ds: &Dataset, observer: &mut dyn FitObserver) -> Result<FitResult>;

    /// The current coefficients as a sparse model (empty before any fit).
    fn model(&self) -> SparseModel;

    /// Reset the internal state to a cold start (β = 0, fresh RNG).
    fn reset(&mut self);

    /// The L1 strength (objective scale) the next `fit` will use.
    /// Estimators with per-example regularization (the online baselines)
    /// convert internally using the dataset size at fit time.
    fn lambda(&self) -> f64;

    fn set_lambda(&mut self, lambda: f64);
}

/// Reset-then-fit convenience: the cold-start fit every benchmark and grid
/// evaluation wants.
pub fn fit_cold(
    est: &mut dyn Estimator,
    ds: &Dataset,
    observer: &mut dyn FitObserver,
) -> Result<FitResult> {
    est.reset();
    est.fit(ds, observer)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(iter: usize, objective: f64) -> IterationRecord {
        IterationRecord {
            iter,
            objective,
            alpha: 1.0,
            fast_path: false,
            max_worker_secs: 0.0,
            sim_comm_secs: 0.0,
            comm_bytes: 0,
            exchange: None,
            wall_secs: 0.0,
        }
    }

    #[test]
    fn early_stop_waits_for_patience() {
        let mut obs = EarlyStopObserver::new(1e-3, 2);
        let model = || SparseModel::from_dense(&[], 0.0);
        let objectives = [100.0, 90.0, 89.999, 89.998, 89.997];
        let mut controls = Vec::new();
        for (i, &f) in objectives.iter().enumerate() {
            let rec = record(i + 1, f);
            controls.push(obs.on_iteration(&FitStep::new(&rec, &model)));
        }
        // iterations 3 and 4 stall; patience 2 trips on the 4th record
        assert_eq!(controls[1], FitControl::Continue);
        assert_eq!(controls[2], FitControl::Continue);
        assert_eq!(controls[3], FitControl::Stop);
    }

    #[test]
    fn recording_observer_keeps_every_record() {
        let mut obs = RecordingObserver::default();
        let model = || SparseModel::from_dense(&[1.0, 0.0], 0.5);
        for i in 1..=3 {
            let rec = record(i, 10.0 / i as f64);
            assert_eq!(obs.on_iteration(&FitStep::new(&rec, &model)), FitControl::Continue);
        }
        assert_eq!(obs.records.len(), 3);
        assert_eq!(obs.records[2].iter, 3);
        // lazy model materialization works through the step view
        let rec = record(4, 1.0);
        let step = FitStep::new(&rec, &model);
        assert_eq!(step.model().nnz(), 1);
    }
}
