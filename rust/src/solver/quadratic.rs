//! Leader-side O(n + p) pieces of the iteration: working statistics,
//! objective evaluation and the directional derivative D of Alg 3.

use crate::util::math::{l1_norm, log1pexp, sigmoid, working_stats};

/// Native (w, z, loss) computation — the leader fallback when not using the
/// AOT stats kernel; also the reference the XLA path is tested against.
pub fn stats_native(margins: &[f32], y: &[f32]) -> (Vec<f32>, Vec<f32>, f64) {
    let mut w = Vec::new();
    let mut z = Vec::new();
    let loss = stats_native_into(margins, y, &mut w, &mut z);
    (w, z, loss)
}

/// [`stats_native`] into caller-reused buffers (cleared and refilled;
/// capacities persist) — the per-iteration hot path holds these in its
/// scratch so steady-state stats computations allocate nothing. Returns the
/// loss sum.
pub fn stats_native_into(
    margins: &[f32],
    y: &[f32],
    w: &mut Vec<f32>,
    z: &mut Vec<f32>,
) -> f64 {
    debug_assert_eq!(margins.len(), y.len());
    w.clear();
    z.clear();
    w.reserve(margins.len());
    z.reserve(margins.len());
    let mut loss = 0f64;
    for (&m, &yy) in margins.iter().zip(y) {
        let (wi, zi) = working_stats(yy as f64, m as f64);
        w.push(wi as f32);
        z.push(zi as f32);
        loss += log1pexp(-(yy as f64) * m as f64);
    }
    loss
}

/// Full objective f(β) = L(margins) + λ‖β‖₁  (paper eq. (2)).
pub fn objective(margins: &[f32], y: &[f32], beta: &[f32], lambda: f64) -> f64 {
    crate::util::math::logloss_sum(margins, y) + lambda * l1_norm(beta)
}

/// ∇L(β)ᵀΔβ = Σ_i (p_i - (y_i+1)/2) · Δm_i — the smooth part of D
/// (Alg 3). O(n), computed from margins and the allreduced Δmargins.
pub fn grad_dot_delta(margins: &[f32], dmargins: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(margins.len(), dmargins.len());
    let mut acc = 0f64;
    for i in 0..margins.len() {
        let p = sigmoid(margins[i] as f64);
        acc += (p - (y[i] as f64 + 1.0) / 2.0) * dmargins[i] as f64;
    }
    acc
}

/// Support-union of β and Δβ (global feature ids) — the only coordinates the
/// line search's L1 term needs (O(nnz(β) + nnz(Δβ)) per evaluation).
pub fn support_union(beta: &[f32], delta: &[f32]) -> Vec<u32> {
    let mut out = Vec::new();
    support_union_into(beta, delta, &mut out);
    out
}

/// [`support_union`] into a caller-reused buffer (the solver's per-iteration
/// hot path keeps one across iterations to avoid reallocating).
pub fn support_union_into(beta: &[f32], delta: &[f32], out: &mut Vec<u32>) {
    debug_assert_eq!(beta.len(), delta.len());
    out.clear();
    out.extend(
        (0..beta.len() as u32)
            .filter(|&j| beta[j as usize] != 0.0 || delta[j as usize] != 0.0),
    );
}

/// λ‖β + αΔβ‖₁ evaluated over the support union.
pub fn l1_at_alpha(beta: &[f32], delta: &[f32], support: &[u32], alpha: f64, lambda: f64) -> f64 {
    let mut acc = 0f64;
    for &j in support {
        let j = j as usize;
        acc += (beta[j] as f64 + alpha * delta[j] as f64).abs();
    }
    lambda * acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_native_matches_closed_form() {
        let margins = [0f32, 1.0, -2.0];
        let y = [1f32, -1.0, 1.0];
        let (w, z, loss) = stats_native(&margins, &y);
        assert!((w[0] - 0.25).abs() < 1e-7);
        assert!((z[0] - 2.0).abs() < 1e-6);
        assert!(loss > 0.0);
        // loss at zero margins is n·ln2 per example with m=0
        let (_, _, l0) = stats_native(&[0.0, 0.0], &[1.0, -1.0]);
        assert!((l0 - 2.0 * (2f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn grad_dot_sign_of_descent() {
        // If Δm reduces loss (points toward labels), grad·Δβ < 0.
        let margins = [0f32; 4];
        let y = [1f32, 1.0, -1.0, -1.0];
        let dm = [1f32, 1.0, -1.0, -1.0]; // moves margins toward labels
        assert!(grad_dot_delta(&margins, &dm, &y) < 0.0);
        let dm_bad = [-1f32, -1.0, 1.0, 1.0];
        assert!(grad_dot_delta(&margins, &dm_bad, &y) > 0.0);
    }

    #[test]
    fn support_and_l1() {
        let beta = [0f32, 1.0, 0.0, -2.0];
        let delta = [0.5f32, 0.0, 0.0, 2.0];
        let s = support_union(&beta, &delta);
        assert_eq!(s, vec![0, 1, 3]);
        // α = 1: |0.5| + |1| + |0| = 1.5, λ = 2 -> 3
        assert!((l1_at_alpha(&beta, &delta, &s, 1.0, 2.0) - 3.0).abs() < 1e-9);
        // α = 0: |0| + |1| + |-2| = 3, λ = 2 -> 6
        assert!((l1_at_alpha(&beta, &delta, &s, 0.0, 2.0) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn objective_combines_loss_and_penalty() {
        let margins = [0f32, 0.0];
        let y = [1f32, -1.0];
        let beta = [1f32, -3.0];
        let f = objective(&margins, &y, &beta, 0.5);
        assert!((f - (2.0 * (2f64).ln() + 0.5 * 4.0)).abs() < 1e-9);
    }
}
