//! Leader-side O(n + p) pieces of the iteration: working statistics,
//! objective evaluation and the directional derivative D of Alg 3. The
//! loss-specific parts delegate to [`crate::family::GlmFamily`]; the
//! logistic wrappers here are kept for the baselines and as the historical
//! names (bit-identical to the pre-family code).

use crate::family::{FamilyKind, GlmFamily};
use crate::util::math::{l1_norm, sigmoid, sq_norm};

/// Native (w, z, loss) computation — the leader fallback when not using the
/// AOT stats kernel; also the reference the XLA path is tested against.
pub fn stats_native(margins: &[f32], y: &[f32]) -> (Vec<f32>, Vec<f32>, f64) {
    let mut w = Vec::new();
    let mut z = Vec::new();
    let loss = stats_native_into(margins, y, &mut w, &mut z);
    (w, z, loss)
}

/// [`stats_native`] into caller-reused buffers (cleared and refilled;
/// capacities persist) — the per-iteration hot path holds these in its
/// scratch so steady-state stats computations allocate nothing. Returns the
/// loss sum. Logistic only; family-generic callers go through
/// [`GlmFamily::working_stats_into`] (which this delegates to).
pub fn stats_native_into(
    margins: &[f32],
    y: &[f32],
    w: &mut Vec<f32>,
    z: &mut Vec<f32>,
) -> f64 {
    FamilyKind::Logistic.family().working_stats_into(margins, y, w, z)
}

/// Full objective f(β) = L(margins) + λ‖β‖₁  (paper eq. (2)). Logistic
/// pure-L1 only — the family/elastic-net generalization is
/// [`objective_family`].
pub fn objective(margins: &[f32], y: &[f32], beta: &[f32], lambda: f64) -> f64 {
    crate::util::math::logloss_sum(margins, y) + lambda * l1_norm(beta)
}

/// Family-generic objective with the elastic-net penalty:
/// `f(β) = Σᵢ ℓ(yᵢ, mᵢ) + λ(α‖β‖₁ + (1−α)/2·‖β‖₂²)`.
pub fn objective_family(
    family: &dyn GlmFamily,
    margins: &[f32],
    y: &[f32],
    beta: &[f32],
    lambda: f64,
    enet_alpha: f64,
) -> f64 {
    family.loss_sum(margins, y) + enet_penalty(beta, lambda, enet_alpha)
}

/// The elastic-net penalty `λ(α‖β‖₁ + (1−α)/2·‖β‖₂²)`. The `α = 1` branch
/// reproduces the historical `λ‖β‖₁` expression bit-for-bit (no dead ‖β‖₂²
/// pass, no `×1.0` detour).
pub fn enet_penalty(beta: &[f32], lambda: f64, enet_alpha: f64) -> f64 {
    if enet_alpha >= 1.0 {
        lambda * l1_norm(beta)
    } else {
        lambda * (enet_alpha * l1_norm(beta) + 0.5 * (1.0 - enet_alpha) * sq_norm(beta))
    }
}

/// ∇L(β)ᵀΔβ = Σ_i (p_i - (y_i+1)/2) · Δm_i — the smooth part of D
/// (Alg 3). O(n), computed from margins and the allreduced Δmargins.
pub fn grad_dot_delta(margins: &[f32], dmargins: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(margins.len(), dmargins.len());
    let mut acc = 0f64;
    for i in 0..margins.len() {
        let p = sigmoid(margins[i] as f64);
        acc += (p - (y[i] as f64 + 1.0) / 2.0) * dmargins[i] as f64;
    }
    acc
}

/// Support-union of β and Δβ (global feature ids) — the only coordinates the
/// line search's L1 term needs (O(nnz(β) + nnz(Δβ)) per evaluation).
pub fn support_union(beta: &[f32], delta: &[f32]) -> Vec<u32> {
    let mut out = Vec::new();
    support_union_into(beta, delta, &mut out);
    out
}

/// [`support_union`] into a caller-reused buffer (the solver's per-iteration
/// hot path keeps one across iterations to avoid reallocating).
pub fn support_union_into(beta: &[f32], delta: &[f32], out: &mut Vec<u32>) {
    debug_assert_eq!(beta.len(), delta.len());
    out.clear();
    out.extend(
        (0..beta.len() as u32)
            .filter(|&j| beta[j as usize] != 0.0 || delta[j as usize] != 0.0),
    );
}

/// λ‖β + αΔβ‖₁ evaluated over the support union.
pub fn l1_at_alpha(beta: &[f32], delta: &[f32], support: &[u32], alpha: f64, lambda: f64) -> f64 {
    let mut acc = 0f64;
    for &j in support {
        let j = j as usize;
        acc += (beta[j] as f64 + alpha * delta[j] as f64).abs();
    }
    lambda * acc
}

/// Elastic-net penalty of `β + αΔβ` evaluated over the support union —
/// the line search's per-α penalty term. The support union contains every
/// nonzero of β and Δβ, so the sums over it *are* the full norms. The
/// `enet_alpha = 1` branch is [`l1_at_alpha`] verbatim (bit-identical
/// default path).
pub fn penalty_at_alpha(
    beta: &[f32],
    delta: &[f32],
    support: &[u32],
    alpha: f64,
    lambda: f64,
    enet_alpha: f64,
) -> f64 {
    if enet_alpha >= 1.0 {
        return l1_at_alpha(beta, delta, support, alpha, lambda);
    }
    let mut l1 = 0f64;
    let mut l2 = 0f64;
    for &j in support {
        let j = j as usize;
        let b = beta[j] as f64 + alpha * delta[j] as f64;
        l1 += b.abs();
        l2 += b * b;
    }
    lambda * (enet_alpha * l1 + 0.5 * (1.0 - enet_alpha) * l2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_native_matches_closed_form() {
        let margins = [0f32, 1.0, -2.0];
        let y = [1f32, -1.0, 1.0];
        let (w, z, loss) = stats_native(&margins, &y);
        assert!((w[0] - 0.25).abs() < 1e-7);
        assert!((z[0] - 2.0).abs() < 1e-6);
        assert!(loss > 0.0);
        // loss at zero margins is n·ln2 per example with m=0
        let (_, _, l0) = stats_native(&[0.0, 0.0], &[1.0, -1.0]);
        assert!((l0 - 2.0 * (2f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn grad_dot_sign_of_descent() {
        // If Δm reduces loss (points toward labels), grad·Δβ < 0.
        let margins = [0f32; 4];
        let y = [1f32, 1.0, -1.0, -1.0];
        let dm = [1f32, 1.0, -1.0, -1.0]; // moves margins toward labels
        assert!(grad_dot_delta(&margins, &dm, &y) < 0.0);
        let dm_bad = [-1f32, -1.0, 1.0, 1.0];
        assert!(grad_dot_delta(&margins, &dm_bad, &y) > 0.0);
    }

    #[test]
    fn support_and_l1() {
        let beta = [0f32, 1.0, 0.0, -2.0];
        let delta = [0.5f32, 0.0, 0.0, 2.0];
        let s = support_union(&beta, &delta);
        assert_eq!(s, vec![0, 1, 3]);
        // α = 1: |0.5| + |1| + |0| = 1.5, λ = 2 -> 3
        assert!((l1_at_alpha(&beta, &delta, &s, 1.0, 2.0) - 3.0).abs() < 1e-9);
        // α = 0: |0| + |1| + |-2| = 3, λ = 2 -> 6
        assert!((l1_at_alpha(&beta, &delta, &s, 0.0, 2.0) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn objective_combines_loss_and_penalty() {
        let margins = [0f32, 0.0];
        let y = [1f32, -1.0];
        let beta = [1f32, -3.0];
        let f = objective(&margins, &y, &beta, 0.5);
        assert!((f - (2.0 * (2f64).ln() + 0.5 * 4.0)).abs() < 1e-9);
    }

    #[test]
    fn enet_penalty_defaults_bit_identical_to_l1() {
        let beta = [0.5f32, 0.0, -2.25, 1e-3];
        let lambda = 0.7;
        assert_eq!(
            enet_penalty(&beta, lambda, 1.0).to_bits(),
            (lambda * l1_norm(&beta)).to_bits()
        );
        // α = 0.5: λ(0.5·‖β‖₁ + 0.25·‖β‖₂²)
        let want = lambda * (0.5 * l1_norm(&beta) + 0.25 * sq_norm(&beta));
        assert!((enet_penalty(&beta, lambda, 0.5) - want).abs() < 1e-12);
        // family-generic objective reduces to the logistic one at defaults
        let margins = [0f32, 0.3];
        let y = [1f32, -1.0];
        let fam = FamilyKind::Logistic.family();
        assert_eq!(
            objective_family(fam, &margins, &y, &beta, lambda, 1.0).to_bits(),
            objective(&margins, &y, &beta, lambda).to_bits()
        );
    }

    #[test]
    fn penalty_at_alpha_matches_full_norms_over_support() {
        let beta = [0f32, 1.0, 0.0, -2.0];
        let delta = [0.5f32, 0.0, 0.0, 2.0];
        let s = support_union(&beta, &delta);
        // enet_alpha = 1 is l1_at_alpha verbatim
        assert_eq!(
            penalty_at_alpha(&beta, &delta, &s, 0.7, 2.0, 1.0).to_bits(),
            l1_at_alpha(&beta, &delta, &s, 0.7, 2.0).to_bits()
        );
        // enet_alpha < 1: compare against dense full-vector norms
        let step = 0.4;
        let stepped: Vec<f32> =
            beta.iter().zip(&delta).map(|(&b, &d)| (b as f64 + step * d as f64) as f32).collect();
        let lam = 1.3;
        let ea = 0.6;
        let want = lam * (ea * l1_norm(&stepped) + 0.5 * (1.0 - ea) * sq_norm(&stepped));
        let got = penalty_at_alpha(&beta, &delta, &s, step, lam, ea);
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }
}
