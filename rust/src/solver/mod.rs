//! The d-GLMNET coordinator (paper Algorithms 1–5) and the crate's unified
//! training interface: leader/worker iteration driver, line search,
//! convergence with sparsity precautions, the regularization-path runner —
//! and the [`Estimator`] / [`FitDriver`] API everything else plugs into.
//!
//! ## The training API, in three layers
//!
//! 1. **[`Estimator`]** — the uniform interface: `fit(&Dataset, observer)`,
//!    `model()`, `name()`, `reset()`, `lambda()`/`set_lambda()`.
//!    Implemented by [`DGlmnetSolver`] and all three baselines
//!    (`baselines::{ShotgunEstimator, TruncatedGradientEstimator,
//!    DistributedOnlineEstimator}`), so the regularization path
//!    ([`RegPath::run_estimator`]), the §4.3 grid (`baselines::grid`), the
//!    bench harness and the CLI drive every solver through `&mut dyn
//!    Estimator` with no solver-specific branches.
//! 2. **[`FitObserver`]** — the per-iteration callback. The contract: it
//!    fires once per iteration (d-GLMNET iteration / online pass / shotgun
//!    round) *after* the update is applied, receives a [`FitStep`] (the
//!    [`IterationRecord`] plus lazy model access), and may return
//!    `FitControl::Stop` to end the fit early with `converged = false`.
//!    The final iteration is also reported; its control value is ignored.
//! 3. **[`FitDriver`]** — stepwise control for d-GLMNET: one
//!    leader-stats → sweep → Δ-exchange → line-search → apply iteration
//!    per [`FitDriver::step`] call, executed as send/recv phases of the
//!    node protocol over each worker's `Transport` (in-process threads or
//!    remote socket processes — same code path, bit-identical
//!    trajectories). Workers hold their own β shard and margins; the
//!    Δ-exchange routes through `cluster::comm` (per-message wire codecs,
//!    the EWMA-sharpened reduce-Δm vs allgather-Δβ strategy pick,
//!    worker-pool merges, gather-only Δβ accounting). Driving `step()`
//!    to convergence is bit-identical (objective, β, comm-bytes ledger) to
//!    the one-shot `fit()` path — `fit_lambda` *is* this driver run with a
//!    no-op observer.
//!
//! ## Checkpoint / resume contract
//!
//! [`FitDriver::checkpoint`] captures a [`Checkpoint`] after any completed
//! iteration: λ, the iteration counter, the last objective, the cost
//! accumulators (sim compute/comm seconds, comm bytes, wall seconds),
//! **β and margins as f32 bit patterns** — margins are incremental sums and
//! are restored verbatim, never recomputed from β — plus the
//! **worker-held β shard states** (pulled over the node protocol and
//! verified bit-level against the leader at save time) and the comm
//! estimator's EWMA state. Stochastic estimators (shotgun) additionally
//! persist their xoshiro256++ state. Checkpoints round-trip through
//! `runtime::artifacts`-style JSON
//! ([`Checkpoint::save`]/[`Checkpoint::load`]), and resuming in a fresh
//! process (`DGlmnetSolver::driver_from_checkpoint` on a solver built from
//! the same dataset and config — in-process or socket transport alike)
//! reproduces the uninterrupted run's final objective *and* `comm_bytes`
//! ledger exactly. Budgets ([`crate::config::FitBudget`]) are enforced
//! between iterations and span resume boundaries.

pub mod dglmnet;
pub mod driver;
pub mod estimator;
pub mod leader;
pub mod line_search;
pub mod model;
pub mod pool;
pub mod quadratic;
pub mod regpath;
pub mod screening;

pub use dglmnet::{DGlmnetSolver, FitResult, IterationRecord};
pub use driver::{Checkpoint, FitDriver, StepOutcome, StopReason};
pub use estimator::{
    fit_cold, EarlyStopObserver, Estimator, FitControl, FitObserver, FitStep, NoopObserver,
    RecordingObserver,
};
pub use model::SparseModel;
pub use regpath::{lambda_max, PathPoint, RegPath};
