//! The d-GLMNET coordinator (paper Algorithms 1–5): leader/worker iteration
//! driver, line search, convergence with sparsity precautions, and the
//! regularization-path runner.

pub mod dglmnet;
pub mod leader;
pub mod line_search;
pub mod model;
pub mod pool;
pub mod quadratic;
pub mod regpath;
pub mod screening;

pub use dglmnet::{DGlmnetSolver, FitResult, IterationRecord};
pub use model::SparseModel;
pub use regpath::{lambda_max, PathPoint, RegPath};
