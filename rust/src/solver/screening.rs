//! Sequential strong-rule screening for the regularization path — the
//! standard GLMNET-family extension (Tibshirani et al. 2012, "Strong rules
//! for discarding predictors"): when moving from λ_prev to λ_new < λ_prev,
//! feature j can be (heuristically) discarded when
//!
//! ```text
//! |∇L_j(β(λ_prev))| < 2·λ_new − λ_prev
//! ```
//!
//! Discarded features skip the sweep entirely; a KKT check afterwards
//! catches the rare violations (|∇L_j| > λ at a zero coordinate), which are
//! then re-admitted. In d-GLMNET this shrinks every machine's shard —
//! worker work AND the Δβ AllReduce payload — between path steps.
//!
//! Shipped as a library utility (`bench_ablation`-grade experiments and
//! downstream users); the default path driver keeps the paper's exact
//! protocol, which does not screen.

use crate::data::dataset::Dataset;
use crate::util::math::sigmoid;

/// |∇L_j(β)| for every feature, from margins only: ∇L_j = Σ_i (p_i − (y_i+1)/2)·x_ij.
pub fn gradient_magnitudes(ds: &Dataset, margins: &[f32]) -> Vec<f64> {
    assert_eq!(margins.len(), ds.n_examples());
    let mut grad = vec![0f64; ds.n_features()];
    for i in 0..ds.n_examples() {
        let g = sigmoid(margins[i] as f64) - (ds.y[i] as f64 + 1.0) / 2.0;
        let (cols, vals) = ds.x.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            grad[c as usize] += g * v as f64;
        }
    }
    grad.iter_mut().for_each(|g| *g = g.abs());
    grad
}

/// Features *surviving* the sequential strong rule at λ_new, given the
/// gradient magnitudes at the λ_prev solution. Features already active
/// (β_j ≠ 0) always survive.
pub fn strong_rule_survivors(
    grad_abs: &[f64],
    beta: &[f32],
    lam_new: f64,
    lam_prev: f64,
) -> Vec<u32> {
    assert_eq!(grad_abs.len(), beta.len());
    let threshold = 2.0 * lam_new - lam_prev;
    (0..grad_abs.len())
        .filter(|&j| beta[j] != 0.0 || grad_abs[j] >= threshold)
        .map(|j| j as u32)
        .collect()
}

/// KKT violations at a candidate solution: zero coordinates whose gradient
/// magnitude exceeds λ (they must re-enter the active set), with slack for
/// f32 noise.
pub fn kkt_violations(grad_abs: &[f64], beta: &[f32], lam: f64, slack: f64) -> Vec<u32> {
    (0..grad_abs.len())
        .filter(|&j| beta[j] == 0.0 && grad_abs[j] > lam * (1.0 + slack))
        .map(|j| j as u32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, TrainConfig};
    use crate::data::synth;
    use crate::solver::{lambda_max, DGlmnetSolver};

    #[test]
    fn gradient_at_zero_matches_lambda_max() {
        let ds = synth::dna_like(400, 30, 5, 71);
        let grad = gradient_magnitudes(&ds, &vec![0f32; 400]);
        let max = grad.iter().cloned().fold(0.0, f64::max);
        // at beta = 0: |∇L_j| = |Σ x y|/2 · 2 ... lambda_max = max_j |Σ x y|/2
        // and ∇L_j(0) = Σ (1/2 - (y+1)/2) x = -Σ y x / 2 => equal.
        assert!((max - lambda_max(&ds)).abs() < 1e-9, "{max}");
    }

    #[test]
    fn survivors_superset_of_true_active_set() {
        // Fit at λ_new exactly; every feature active at λ_new must survive
        // the strong rule computed from the λ_prev solution (no false
        // discards on this data — strong rules are near-exact in practice).
        let ds = synth::dna_like(600, 40, 5, 72);
        let lm = lambda_max(&ds);
        // threshold = 2·λ_new − λ_prev must stay positive for the rule to
        // discard anything: use the paper-typical ~0.8 path ratio.
        let (lam_prev, lam_new) = (lm / 2.0, 0.8 * lm / 2.0);
        let cfg = TrainConfig::builder()
            .machines(2)
            .engine(EngineKind::Native)
            .lambda(lam_prev)
            .max_iter(60)
            .build();
        let mut s = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
        let prev = s.fit_lambda(lam_prev).unwrap();
        let grad = gradient_magnitudes(&ds, &s.margins);
        let survivors = strong_rule_survivors(&grad, &s.beta, lam_new, lam_prev);

        let next = s.fit_lambda(lam_new).unwrap();
        let active: Vec<u32> = next.model.entries.iter().map(|e| e.0).collect();
        for j in &active {
            assert!(
                survivors.contains(j),
                "active feature {j} was screened out (survivors = {survivors:?})"
            );
        }
        // and screening actually discards something on the sparse head
        assert!(survivors.len() < ds.n_features(), "nothing screened");
        let _ = prev;
    }

    #[test]
    fn kkt_flags_forced_zero() {
        // Solve, then zero out the largest coefficient: KKT must flag it.
        let ds = synth::dna_like(500, 25, 4, 73);
        let lm = lambda_max(&ds);
        let lam = lm / 8.0;
        let cfg = TrainConfig::builder()
            .machines(2)
            .engine(EngineKind::Native)
            .lambda(lam)
            .max_iter(60)
            .build();
        let mut s = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
        let fit = s.fit_lambda(lam).unwrap();
        let grad = gradient_magnitudes(&ds, &s.margins);
        // at the optimum: no violations
        assert!(kkt_violations(&grad, &s.beta, lam, 0.05).is_empty());

        let (j_max, _) = fit
            .model
            .entries
            .iter()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .copied()
            .map(|(j, w)| (j, w))
            .unwrap();
        let mut beta = s.beta.clone();
        beta[j_max as usize] = 0.0;
        let margins = ds.x.margins(&beta);
        let grad2 = gradient_magnitudes(&ds, &margins);
        let viol = kkt_violations(&grad2, &beta, lam, 0.05);
        assert!(viol.contains(&j_max), "violations = {viol:?}");
    }
}
