//! Line search — paper Algorithm 3, verbatim structure:
//!
//! 1. If α = 1 yields sufficient *relative* decrease, return α = 1
//!    (sparsity precaution: a full step keeps coordinates that landed
//!    exactly on 0).
//! 2. α_init = argmin_{δ ≤ α ≤ 1} f(β + αΔβ) over a K-point grid
//!    (one batched kernel evaluation).
//! 3. Armijo: largest α in {α_init·b^j} with
//!    f(β + αΔβ) ≤ f(β) + ασD,   D = ∇LᵀΔβ + γΔβᵀH̃Δβ + λ(‖β+Δβ‖₁ − ‖β‖₁).
//!
//! Loss evaluations go through a batched `losses(&[α])` closure so the AOT
//! `line_search_grid` kernel amortizes one HBM pass over the whole grid.

use crate::config::LineSearchConfig;
use crate::error::Result;

/// Outcome of one line search.
#[derive(Debug, Clone)]
pub struct LineSearchOutcome {
    pub alpha: f64,
    /// f(β + αΔβ) at the accepted α.
    pub f_new: f64,
    /// Step-1 shortcut fired (no search happened).
    pub fast_path: bool,
    /// Number of α-evaluations (batched counts each α).
    pub evals: usize,
}

/// Generic driver over a batched loss evaluator and an O(p)-support L1 term.
///
/// * `losses(alphas)` -> Σ_i log(1+exp(-y(m + αΔm))) for each α
/// * `l1_at(alpha)`   -> λ‖β + αΔβ‖₁
/// * `f0`             -> f(β) (current objective)
/// * `grad_dot`       -> ∇L(β)ᵀΔβ
/// * `quad_term`      -> ΔβᵀH̃Δβ (only needed when γ > 0; pass 0 for γ = 0)
pub fn line_search(
    losses: &mut dyn FnMut(&[f64]) -> Result<Vec<f64>>,
    l1_at: &dyn Fn(f64) -> f64,
    f0: f64,
    grad_dot: f64,
    quad_term: f64,
    cfg: &LineSearchConfig,
) -> Result<LineSearchOutcome> {
    let mut evals = 0usize;

    // D of Alg 3 (γ = 0 in the paper's experiments).
    let d = grad_dot + cfg.gamma * quad_term + (l1_at(1.0) - l1_at(0.0));

    // ---- step 1: full-step shortcut ------------------------------------
    let f1 = losses(&[1.0])?[0] + l1_at(1.0);
    evals += 1;
    let rel_dec = (f0 - f1) / f0.abs().max(1.0);
    if rel_dec >= cfg.sufficient_decrease {
        return Ok(LineSearchOutcome { alpha: 1.0, f_new: f1, fast_path: true, evals });
    }

    // ---- step 2: α_init = argmin on a grid ------------------------------
    let alpha_init = if cfg.skip_alpha_init {
        1.0
    } else {
        let k = cfg.grid.max(2);
        let grid: Vec<f64> = (0..k)
            .map(|i| cfg.alpha_min + (1.0 - cfg.alpha_min) * i as f64 / (k - 1) as f64)
            .collect();
        let ls = losses(&grid)?;
        evals += k;
        let mut best = (f1, 1.0);
        for (i, &a) in grid.iter().enumerate() {
            let f = ls[i] + l1_at(a);
            if f < best.0 {
                best = (f, a);
            }
        }
        best.1
    };

    // ---- step 3: Armijo backtracking from α_init ------------------------
    // Batch the whole geometric sequence {α_init·b^j} in grid-size chunks.
    let sigma_d = cfg.sigma * d;
    let mut alpha = alpha_init;
    let mut best_seen = (f1, 1.0);
    for _round in 0..8 {
        let batch: Vec<f64> = (0..cfg.grid.max(2))
            .map(|j| alpha * cfg.backtrack.powi(j as i32))
            .collect();
        let ls = losses(&batch)?;
        evals += batch.len();
        for (j, &a) in batch.iter().enumerate() {
            let f = ls[j] + l1_at(a);
            if f < best_seen.0 {
                best_seen = (f, a);
            }
            if f <= f0 + a * sigma_d {
                return Ok(LineSearchOutcome { alpha: a, f_new: f, fast_path: false, evals });
            }
        }
        alpha = batch.last().copied().unwrap() * cfg.backtrack;
        if alpha < 1e-12 {
            break;
        }
    }
    // Safeguard (should be unreachable for a true descent direction):
    // return the best α seen rather than diverging.
    Ok(LineSearchOutcome {
        alpha: best_seen.1,
        f_new: best_seen.0,
        fast_path: false,
        evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LineSearchConfig;

    /// Quadratic objective f(α) = (α - opt)² + c with exact "loss" closure.
    fn quad_eval(opt: f64, c: f64) -> impl FnMut(&[f64]) -> Result<Vec<f64>> {
        move |alphas: &[f64]| Ok(alphas.iter().map(|&a| (a - opt).powi(2) + c).collect())
    }

    #[test]
    fn fast_path_on_good_full_step() {
        let mut losses = quad_eval(1.0, 5.0); // minimum exactly at α = 1
        let l1 = |_a: f64| 0.0;
        let f0 = 6.0; // f(0) = 1 + 5
        let out =
            line_search(&mut losses, &l1, f0, -2.0, 0.0, &LineSearchConfig::default()).unwrap();
        assert!(out.fast_path);
        assert_eq!(out.alpha, 1.0);
        assert!((out.f_new - 5.0).abs() < 1e-12);
    }

    #[test]
    fn finds_interior_minimum_via_alpha_init() {
        // minimum at α = 0.3; full step barely improves => no fast path
        let mut cfg = LineSearchConfig::default();
        cfg.sufficient_decrease = 0.2; // force the search path
        let mut losses = quad_eval(0.3, 1.0);
        let l1 = |_a: f64| 0.0;
        let f0 = 0.3f64.powi(2) + 1.0; // f(0)
        let out = line_search(&mut losses, &l1, f0, -0.18, 0.0, &cfg).unwrap();
        assert!(!out.fast_path);
        assert!((out.alpha - 0.3).abs() < 0.15, "alpha = {}", out.alpha);
        assert!(out.f_new <= f0);
    }

    #[test]
    fn armijo_postcondition_holds() {
        let mut cfg = LineSearchConfig::default();
        cfg.sufficient_decrease = f64::INFINITY; // never take the shortcut
        let mut losses = quad_eval(0.5, 0.0);
        let l1 = |a: f64| 0.1 * (1.0 - a).abs(); // mild non-smooth extra
        let f0 = 0.25 + 0.1;
        let grad_dot = -0.5;
        let out = line_search(&mut losses, &l1, f0, grad_dot, 0.0, &cfg).unwrap();
        let d = grad_dot + (l1(1.0) - l1(0.0));
        let f_alpha = (out.alpha - 0.5).powi(2) + l1(out.alpha);
        assert!(f_alpha <= f0 + out.alpha * cfg.sigma * d + 1e-12);
        assert!((out.f_new - f_alpha).abs() < 1e-12);
    }

    #[test]
    fn skip_alpha_init_backtracks_from_one() {
        let mut cfg = LineSearchConfig::default();
        cfg.sufficient_decrease = f64::INFINITY;
        cfg.skip_alpha_init = true;
        // minimum at small α: plain Armijo from 1 must backtrack
        let mut losses = quad_eval(0.1, 0.0);
        let f0 = 0.01;
        let out = line_search(&mut losses, &|_| 0.0, f0, -0.02, 0.0, &cfg).unwrap();
        assert!(out.alpha < 1.0);
    }

    #[test]
    fn batched_eval_counts() {
        let mut calls = 0usize;
        let mut losses = |alphas: &[f64]| {
            calls += 1;
            Ok(alphas.iter().map(|&a| (a - 0.4).powi(2)).collect())
        };
        let mut cfg = LineSearchConfig::default();
        cfg.sufficient_decrease = f64::INFINITY;
        let out = line_search(&mut losses, &|_| 0.0, 0.16, -0.3, 0.0, &cfg).unwrap();
        // 1 (step 1) + 1 (grid) + ≥1 (armijo) batched calls
        assert!(calls <= 4, "calls = {calls}");
        assert!(out.evals >= 17);
    }
}
