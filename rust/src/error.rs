//! Crate-wide error type. Hand-rolled `Display`/`Error` impls — the default
//! build is dependency-free (no `thiserror` in the vendored set).

use std::fmt;

/// Unified error for every layer of the coordinator.
#[derive(Debug)]
pub enum DlrError {
    Io(std::io::Error),
    Xla(String),
    Parse { context: String, message: String },
    Config(String),
    Data(String),
    Artifact(String),
    Solver(String),
    Cli(String),
}

impl fmt::Display for DlrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DlrError::Io(e) => write!(f, "io error: {e}"),
            DlrError::Xla(m) => write!(f, "xla/pjrt error: {m}"),
            DlrError::Parse { context, message } => {
                write!(f, "parse error at {context}: {message}")
            }
            DlrError::Config(m) => write!(f, "config error: {m}"),
            DlrError::Data(m) => write!(f, "data error: {m}"),
            DlrError::Artifact(m) => write!(f, "artifact error: {m}"),
            DlrError::Solver(m) => write!(f, "solver error: {m}"),
            DlrError::Cli(m) => write!(f, "cli error: {m}"),
        }
    }
}

impl std::error::Error for DlrError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DlrError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DlrError {
    fn from(e: std::io::Error) -> Self {
        DlrError::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for DlrError {
    fn from(e: xla::Error) -> Self {
        DlrError::Xla(e.to_string())
    }
}

impl DlrError {
    /// Helper for parse-layer errors.
    pub fn parse(context: impl Into<String>, message: impl Into<String>) -> Self {
        DlrError::Parse { context: context.into(), message: message.into() }
    }
}

pub type Result<T> = std::result::Result<T, DlrError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_layer_prefix() {
        assert!(DlrError::Config("bad".into()).to_string().contains("config error"));
        assert!(DlrError::parse("spill", "short line")
            .to_string()
            .contains("parse error at spill"));
    }

    #[test]
    fn io_error_converts_and_sources() {
        let e: DlrError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("io error"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
