//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every layer of the coordinator.
#[derive(Error, Debug)]
pub enum DlrError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla/pjrt error: {0}")]
    Xla(String),

    #[error("parse error at {context}: {message}")]
    Parse { context: String, message: String },

    #[error("config error: {0}")]
    Config(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("solver error: {0}")]
    Solver(String),

    #[error("cli error: {0}")]
    Cli(String),
}

impl From<xla::Error> for DlrError {
    fn from(e: xla::Error) -> Self {
        DlrError::Xla(e.to_string())
    }
}

impl DlrError {
    /// Helper for parse-layer errors.
    pub fn parse(context: impl Into<String>, message: impl Into<String>) -> Self {
        DlrError::Parse { context: context.into(), message: message.into() }
    }
}

pub type Result<T> = std::result::Result<T, DlrError>;
