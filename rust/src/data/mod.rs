//! Data substrate: sparse matrices (by-example CSR and by-feature CSC —
//! the paper's §3 storage duality), libsvm and the paper's Table-1
//! by-feature text formats, synthetic dataset generators with the shape
//! signatures of the Pascal-challenge datasets, and the external
//! by-example → by-feature shuffle (the paper's Map/Reduce preprocessing).

pub mod dataset;
pub mod libsvm;
pub mod shuffle;
pub mod sparse;
pub mod synth;

pub use dataset::{Dataset, SplitDataset};
pub use sparse::{CscMatrix, CsrMatrix, SparseVec, Triplet};
