//! Data substrate, layered around the **sharded store**:
//!
//! * **In-memory matrices** ([`sparse`]): by-example CSR and by-feature CSC
//!   — the paper's §3 storage duality — plus the [`SparseVec`] message type
//!   the comm layer ships between machines.
//! * **Text formats** ([`libsvm`]): libsvm ingest and the paper's Table-1
//!   by-feature format.
//! * **The shard store** ([`store`]): the durable, out-of-core form of the
//!   by-feature layout. A store directory holds a JSON manifest (n, p,
//!   partition spec, per-shard nnz + FNV checksums), one binary CSC shard
//!   file per machine, and the labels in their own small `y.bin`. Workers
//!   self-load *only their own* shard file; the leader reads the manifest,
//!   the O(p) shard headers and `y.bin` — no process ever materializes the
//!   whole design matrix. Stores are written by the `dglmnet shard` CLI
//!   subcommand, by [`store::ShardStore::create`], or streamed by the
//!   external shuffle below.
//! * **The shuffle** ([`shuffle`]): the paper's Map/Reduce preprocessing —
//!   by-example → by-feature through spill files.
//!   [`shuffle::shuffle_to_store`] reduces each machine's partition
//!   straight into its shard file, holding one shard resident at a time.
//! * **Generators and containers** ([`synth`], [`dataset`]): synthetic
//!   datasets with the Pascal-challenge shape signatures, and the labeled
//!   [`Dataset`] with Table-2 summaries and train/test splitting.

pub mod dataset;
pub mod libsvm;
pub mod shuffle;
pub mod sparse;
pub mod store;
pub mod synth;

pub use dataset::{Dataset, SplitDataset};
pub use sparse::{CscMatrix, CsrMatrix, SparseVec, Triplet};
pub use store::{ShardStore, StoreManifest};
