//! Text formats: libsvm (`label idx:val ...`, by-example — the ingest
//! format) and the paper's Table-1 by-feature format
//! (`feature_id (example_id, value) (example_id, value) ...`) that workers
//! stream sequentially from disk.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::dataset::Dataset;
use crate::data::sparse::{CscMatrix, CsrMatrix};
use crate::error::{DlrError, Result};

/// Parse a libsvm stream. Feature ids may be 0- or 1-based; we keep them
/// as-is (0-based internally; 1-based files simply leave column 0 empty).
pub fn read_libsvm(reader: impl Read, name: &str) -> Result<Dataset> {
    let mut x = CsrMatrix::new(0);
    let mut y = Vec::new();
    let mut entries: Vec<(u32, f32)> = Vec::new();
    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        entries.clear();
        let mut parts = line.split_whitespace();
        let label_tok = parts
            .next()
            .ok_or_else(|| DlrError::parse(format!("line {}", lineno + 1), "empty line"))?;
        let label: f32 = label_tok.parse().map_err(|_| {
            DlrError::parse(format!("line {}", lineno + 1), format!("bad label '{label_tok}'"))
        })?;
        let label = if label > 0.0 { 1.0 } else { -1.0 };
        for tok in parts {
            let (idx, val) = tok.split_once(':').ok_or_else(|| {
                DlrError::parse(format!("line {}", lineno + 1), format!("bad pair '{tok}'"))
            })?;
            let idx: u32 = idx.parse().map_err(|_| {
                DlrError::parse(format!("line {}", lineno + 1), format!("bad index '{idx}'"))
            })?;
            let val: f32 = val.parse().map_err(|_| {
                DlrError::parse(format!("line {}", lineno + 1), format!("bad value '{val}'"))
            })?;
            entries.push((idx, val));
        }
        x.push_row(&entries);
        y.push(label);
    }
    Ok(Dataset::new(name, x, y))
}

pub fn read_libsvm_file(path: impl AsRef<Path>) -> Result<Dataset> {
    let name = path
        .as_ref()
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".into());
    read_libsvm(std::fs::File::open(path)?, &name)
}

pub fn write_libsvm(ds: &Dataset, writer: impl Write) -> Result<()> {
    let mut w = BufWriter::new(writer);
    for (i, &label) in ds.y.iter().enumerate() {
        let (cols, vals) = ds.x.row(i);
        write!(w, "{}", if label > 0.0 { "+1" } else { "-1" })?;
        for (&c, &v) in cols.iter().zip(vals) {
            write!(w, " {c}:{v}")?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Write the paper's Table-1 by-feature format: one line per feature,
/// `feature_id (example_id,value) (example_id,value) ...`
pub fn write_by_feature(csc: &CscMatrix, writer: impl Write) -> Result<()> {
    let mut w = BufWriter::new(writer);
    for j in 0..csc.n_cols {
        let (rows, vals) = csc.col(j);
        write!(w, "{j}")?;
        for (&r, &v) in rows.iter().zip(vals) {
            write!(w, " ({r},{v})")?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Read the by-feature format back. `n_rows` is required (the format does
/// not record the example count for features whose tail examples are zero).
pub fn read_by_feature(reader: impl Read, n_rows: usize) -> Result<CscMatrix> {
    let mut cols: Vec<(usize, Vec<(u32, f32)>)> = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let ctx = || format!("by-feature line {}", lineno + 1);
        let mut it = line.split_whitespace();
        let j: usize = it
            .next()
            .unwrap()
            .parse()
            .map_err(|_| DlrError::parse(ctx(), "bad feature id"))?;
        max_col = max_col.max(j);
        let mut entries = Vec::new();
        for tok in it {
            let inner = tok
                .strip_prefix('(')
                .and_then(|t| t.strip_suffix(')'))
                .ok_or_else(|| DlrError::parse(ctx(), format!("bad pair '{tok}'")))?;
            let (r, v) = inner
                .split_once(',')
                .ok_or_else(|| DlrError::parse(ctx(), format!("bad pair '{tok}'")))?;
            let r: u32 = r.parse().map_err(|_| DlrError::parse(ctx(), "bad example id"))?;
            if r as usize >= n_rows {
                return Err(DlrError::parse(ctx(), "example id out of range"));
            }
            let v: f32 = v.parse().map_err(|_| DlrError::parse(ctx(), "bad value"))?;
            entries.push((r, v));
        }
        entries.sort_by_key(|e| e.0);
        cols.push((j, entries));
    }
    let n_cols = max_col + 1;
    let mut csc = CscMatrix {
        n_rows,
        n_cols,
        indptr: vec![0; n_cols + 1],
        indices: vec![],
        values: vec![],
    };
    cols.sort_by_key(|c| c.0);
    let mut expected = 0usize;
    for (j, entries) in cols {
        // features between `expected` and `j` are absent => empty columns
        for k in expected..=j {
            csc.indptr[k] = csc.indices.len();
        }
        for (r, v) in entries {
            csc.indices.push(r);
            csc.values.push(v);
        }
        expected = j + 1;
    }
    for k in expected..=n_cols {
        csc.indptr[k] = csc.indices.len();
    }
    Ok(csc)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "+1 0:1.5 3:2.0\n-1 1:1.0\n# comment\n\n+1 3:0.5\n";

    #[test]
    fn read_libsvm_basics() {
        let ds = read_libsvm(SAMPLE.as_bytes(), "s").unwrap();
        assert_eq!(ds.n_examples(), 3);
        assert_eq!(ds.n_features(), 4);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x.row(0), (&[0u32, 3][..], &[1.5f32, 2.0][..]));
    }

    #[test]
    fn libsvm_roundtrip() {
        let ds = read_libsvm(SAMPLE.as_bytes(), "s").unwrap();
        let mut buf = Vec::new();
        write_libsvm(&ds, &mut buf).unwrap();
        let ds2 = read_libsvm(buf.as_slice(), "s").unwrap();
        assert_eq!(ds.y, ds2.y);
        assert_eq!(ds.x.indices, ds2.x.indices);
        assert_eq!(ds.x.values, ds2.x.values);
    }

    #[test]
    fn rejects_malformed() {
        assert!(read_libsvm("+1 3-2\n".as_bytes(), "s").is_err());
        assert!(read_libsvm("abc 0:1\n".as_bytes(), "s").is_err());
        assert!(read_libsvm("+1 x:1\n".as_bytes(), "s").is_err());
    }

    #[test]
    fn by_feature_roundtrip() {
        let ds = read_libsvm(SAMPLE.as_bytes(), "s").unwrap();
        let csc = ds.x.to_csc();
        let mut buf = Vec::new();
        write_by_feature(&csc, &mut buf).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.starts_with("0 (0,1.5)"), "{text}");
        let back = read_by_feature(buf.as_slice(), ds.n_examples()).unwrap();
        assert_eq!(back.indptr, csc.indptr);
        assert_eq!(back.indices, csc.indices);
        assert_eq!(back.values, csc.values);
    }

    #[test]
    fn by_feature_out_of_range_example() {
        assert!(read_by_feature("0 (9,1.0)\n".as_bytes(), 3).is_err());
    }
}
