//! Compressed sparse matrices and vectors. CSR is the by-example layout
//! (one row per training example — what online learners and the libsvm
//! format use); CSC is the by-feature layout d-GLMNET workers need (paper
//! §3, Table 1: `feature_id (example_id, value) ...`). [`SparseVec`] is the
//! sorted index/value message type the sparsity-aware AllReduce ships
//! between simulated machines.

use crate::error::{DlrError, Result};

/// Wire cost of one entry under the sparse `u32 + f32` codec (see
/// `cluster::codec` for the full codec set and the per-message cost model).
pub const SPARSE_ENTRY_BYTES: u64 = 8;

/// The canonical sparse margin kernel: `Σ_j x_j · β_j` accumulated in f64
/// over the example's features in **ascending index order**, skipping
/// zero (and out-of-range) weights. This is the single scoring definition
/// train and serve share — [`CsrMatrix::margins`], the native engine's
/// margin rebuild ([`CscMatrix::accumulate_margins_f64`], whose
/// per-example addition sequence is the same ascending-feature order) and
/// `serve`/`SparseModel::predict` all reduce to it, so a model scores an
/// example bit-identically wherever it runs. Indices beyond `beta` score
/// as zero weight (a served example may mention features the model never
/// saw).
pub fn dot_margin(cols: &[u32], vals: &[f32], beta: &[f32]) -> f64 {
    let mut acc = 0f64;
    for (&c, &v) in cols.iter().zip(vals) {
        let b = *beta.get(c as usize).unwrap_or(&0.0) as f64;
        if b == 0.0 {
            continue;
        }
        acc += v as f64 * b;
    }
    acc
}

/// A sparse vector message: parallel `(index, value)` arrays with indices
/// sorted ascending and unique. This is the unit of Δβ / Δmargin traffic
/// in the `cluster::comm` collectives; what it costs on the wire depends
/// on the codec the byte-cost model picks per message (`cluster::codec`) —
/// [`SparseVec::wire_bytes`] is its size under the classic sparse
/// `u32 + f32` format.
///
/// Buffers are designed for reuse: [`SparseVec::clear`] keeps capacity, so
/// a vector that round-trips through the worker pool allocates only until
/// its high-water mark.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparseVec {
    /// Logical length of the vector (indices are `< dim`).
    pub dim: usize,
    /// Sorted ascending, unique.
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseVec {
    /// Empty vector of logical length `dim`.
    pub fn new(dim: usize) -> Self {
        Self { dim, indices: Vec::new(), values: Vec::new() }
    }

    /// Gather the non-zeros of a dense slice.
    pub fn from_dense(dense: &[f32]) -> Self {
        let mut v = Self::new(dense.len());
        for (i, &x) in dense.iter().enumerate() {
            if x != 0.0 {
                v.indices.push(i as u32);
                v.values.push(x);
            }
        }
        v
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// nnz / dim (0 for a zero-dimensional vector).
    pub fn density(&self) -> f64 {
        if self.dim == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.dim as f64
        }
    }

    /// Simulated wire size of this message: `nnz · (4 + 4)` bytes.
    pub fn wire_bytes(&self) -> u64 {
        self.nnz() as u64 * SPARSE_ENTRY_BYTES
    }

    /// Reset to the empty vector of length `dim`, keeping capacity.
    pub fn clear(&mut self, dim: usize) {
        self.dim = dim;
        self.indices.clear();
        self.values.clear();
    }

    /// Append an entry. Indices must arrive in strictly ascending order
    /// (checked in debug builds). A producer that cannot guarantee order
    /// should write the public `indices`/`values` fields directly and call
    /// [`SparseVec::ensure_sorted`] afterwards (see `engine::streaming`).
    pub fn push(&mut self, index: u32, value: f32) {
        debug_assert!(
            self.indices.last().is_none_or(|&last| last < index),
            "SparseVec indices must be pushed in ascending order"
        );
        debug_assert!((index as usize) < self.dim, "index {index} >= dim {}", self.dim);
        self.indices.push(index);
        self.values.push(value);
    }

    /// Restore the sorted-unique invariant after a batch of raw pushes:
    /// sort by index if any entries are out of order (O(nnz) check, sort
    /// only when needed) and merge duplicate indices by summing their
    /// values — a producer that touches a coordinate twice (e.g. a
    /// by-feature file listing a feature twice) contributes the sum of its
    /// partial updates.
    pub fn ensure_sorted(&mut self) {
        if self.indices.windows(2).all(|w| w[0] < w[1]) {
            return;
        }
        let mut order: Vec<usize> = (0..self.indices.len()).collect();
        order.sort_unstable_by_key(|&k| self.indices[k]);
        let mut indices: Vec<u32> = Vec::with_capacity(order.len());
        let mut values: Vec<f32> = Vec::with_capacity(order.len());
        for &k in &order {
            if indices.last() == Some(&self.indices[k]) {
                *values.last_mut().unwrap() += self.values[k];
            } else {
                indices.push(self.indices[k]);
                values.push(self.values[k]);
            }
        }
        self.indices = indices;
        self.values = values;
    }

    /// `(index, value)` iterator.
    pub fn iter(&self) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.indices.iter().copied().zip(self.values.iter().copied())
    }

    /// Add `scale ·` this vector into a dense buffer (`out.len() == dim`).
    pub fn add_scaled_into(&self, out: &mut [f32], scale: f32) {
        debug_assert_eq!(out.len(), self.dim);
        for (i, v) in self.iter() {
            out[i as usize] += scale * v;
        }
    }

    /// Overwrite the touched coordinates of a dense buffer with this
    /// vector's values (untouched coordinates are left as-is — callers zero
    /// the buffer first when they need an exact densification).
    pub fn scatter_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        for (i, v) in self.iter() {
            out[i as usize] = v;
        }
    }

    /// Densify into a fresh `Vec` (tests and one-shot callers).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.dim];
        self.scatter_into(&mut out);
        out
    }
}

/// A single (row, col, value) entry, the interchange unit of the shuffle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Triplet {
    pub row: u32,
    pub col: u32,
    pub val: f32,
}

/// Compressed sparse row matrix (by-example).
#[derive(Debug, Clone, Default)]
pub struct CsrMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

/// Compressed sparse column matrix (by-feature).
#[derive(Debug, Clone, Default)]
pub struct CscMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>, // row (example) ids
    pub values: Vec<f32>,
}

impl CsrMatrix {
    pub fn new(n_cols: usize) -> Self {
        Self { n_rows: 0, n_cols, indptr: vec![0], indices: vec![], values: vec![] }
    }

    /// Append one row given (col, val) pairs; extends `n_cols` if needed.
    pub fn push_row(&mut self, entries: &[(u32, f32)]) {
        for &(c, v) in entries {
            if v != 0.0 {
                self.indices.push(c);
                self.values.push(v);
                self.n_cols = self.n_cols.max(c as usize + 1);
            }
        }
        self.indptr.push(self.indices.len());
        self.n_rows += 1;
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (col, val) slice pair for row `i`.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    pub fn iter_rows(&self) -> impl Iterator<Item = (&[u32], &[f32])> + '_ {
        (0..self.n_rows).map(move |i| self.row(i))
    }

    /// margins[i] = Σ_j x_ij β_j — by-example SpMV through the shared
    /// [`dot_margin`] kernel (bit-identical to the by-feature rebuild in
    /// [`CscMatrix::accumulate_margins_f64`] when rows are ascending).
    pub fn margins(&self, beta: &[f32]) -> Vec<f32> {
        assert!(beta.len() >= self.n_cols, "beta too short");
        let mut out = vec![0f32; self.n_rows];
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            out[i] = dot_margin(cols, vals, beta) as f32;
        }
        out
    }

    pub fn from_triplets(n_rows: usize, n_cols: usize, triplets: &[Triplet]) -> Result<Self> {
        let mut sorted: Vec<&Triplet> = triplets.iter().collect();
        sorted.sort_by_key(|t| (t.row, t.col));
        let mut m = CsrMatrix::new(n_cols);
        m.n_rows = n_rows;
        m.n_cols = n_cols;
        m.indptr = Vec::with_capacity(n_rows + 1);
        m.indptr.push(0);
        let mut cur = 0u32;
        for t in sorted {
            if (t.row as usize) >= n_rows || (t.col as usize) >= n_cols {
                return Err(DlrError::Data(format!(
                    "triplet ({}, {}) out of bounds ({n_rows}, {n_cols})",
                    t.row, t.col
                )));
            }
            while cur < t.row {
                m.indptr.push(m.indices.len());
                cur += 1;
            }
            if t.val != 0.0 {
                m.indices.push(t.col);
                m.values.push(t.val);
            }
        }
        while (m.indptr.len() as usize) < n_rows + 1 {
            m.indptr.push(m.indices.len());
        }
        Ok(m)
    }

    /// Transpose into the by-feature layout (counting sort — O(nnz + p)).
    pub fn to_csc(&self) -> CscMatrix {
        let mut counts = vec![0usize; self.n_cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for j in 0..self.n_cols {
            counts[j + 1] += counts[j];
        }
        let indptr = counts.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        let mut next = counts;
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let dst = next[c as usize];
                indices[dst] = i as u32;
                values[dst] = v;
                next[c as usize] += 1;
            }
        }
        CscMatrix {
            n_rows: self.n_rows,
            n_cols: self.n_cols,
            indptr,
            indices,
            values,
        }
    }

    /// Select a subset of rows (train/test splitting).
    pub fn select_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut m = CsrMatrix::new(self.n_cols);
        m.n_cols = self.n_cols;
        for &i in rows {
            let (cols, vals) = self.row(i);
            let entries: Vec<(u32, f32)> =
                cols.iter().copied().zip(vals.iter().copied()).collect();
            m.push_row(&entries);
        }
        m.n_cols = self.n_cols; // keep width even if trailing cols unused
        m
    }
}

impl CscMatrix {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// `acc[i] += Σ_j β_j x_ij` — the by-feature half of the canonical
    /// margin kernel. Columns are walked in ascending order and zero
    /// weights are skipped, so each example receives the exact addition
    /// sequence [`dot_margin`] performs row-wise (f64 multiplication is
    /// commutative): casting `acc[i]` to f32 is bit-identical to
    /// `dot_margin(row_i, beta) as f32`. The native engine's margin
    /// rebuild and the serve-side scorer agree through this.
    pub fn accumulate_margins_f64(&self, beta: &[f32], acc: &mut [f64]) {
        debug_assert_eq!(beta.len(), self.n_cols);
        debug_assert_eq!(acc.len(), self.n_rows);
        for (j, &b) in beta.iter().enumerate() {
            let b = b as f64;
            if b == 0.0 {
                continue;
            }
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                acc[i as usize] += b * v as f64;
            }
        }
    }

    /// (row ids, vals) for feature `j`.
    pub fn col(&self, j: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    pub fn col_nnz(&self, j: usize) -> usize {
        self.indptr[j + 1] - self.indptr[j]
    }

    /// Gather a subset of columns into a new CSC with remapped column ids
    /// 0..cols.len() (worker shard construction).
    pub fn select_cols(&self, cols: &[usize]) -> CscMatrix {
        let mut m = CscMatrix {
            n_rows: self.n_rows,
            n_cols: cols.len(),
            indptr: Vec::with_capacity(cols.len() + 1),
            indices: vec![],
            values: vec![],
        };
        m.indptr.push(0);
        for &j in cols {
            let (rows, vals) = self.col(j);
            m.indices.extend_from_slice(rows);
            m.values.extend_from_slice(vals);
            m.indptr.push(m.indices.len());
        }
        m
    }

    /// Round-trip back to CSR (used by tests).
    pub fn to_csr(&self) -> CsrMatrix {
        let mut counts = vec![0usize; self.n_rows + 1];
        for &r in &self.indices {
            counts[r as usize + 1] += 1;
        }
        for i in 0..self.n_rows {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        let mut next = counts;
        for j in 0..self.n_cols {
            let (rows, vals) = self.col(j);
            for (&r, &v) in rows.iter().zip(vals) {
                let dst = next[r as usize];
                indices[dst] = j as u32;
                values[dst] = v;
                next[r as usize] += 1;
            }
        }
        CsrMatrix { n_rows: self.n_rows, n_cols: self.n_cols, indptr, indices, values }
    }

    /// Densify columns `[j0, j0+width)` into a row-major (n_pad × width_pad)
    /// tile for the XLA engine. Rows ≥ n_rows and cols ≥ width stay zero.
    pub fn densify_block(
        &self,
        j0: usize,
        width: usize,
        n_pad: usize,
        width_pad: usize,
    ) -> Vec<f32> {
        assert!(n_pad >= self.n_rows && width_pad >= width);
        let mut tile = vec![0f32; n_pad * width_pad];
        for (local_j, j) in (j0..(j0 + width).min(self.n_cols)).enumerate() {
            let (rows, vals) = self.col(j);
            for (&r, &v) in rows.iter().zip(vals) {
                tile[r as usize * width_pad + local_j] = v;
            }
        }
        tile
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        let mut m = CsrMatrix::new(3);
        m.push_row(&[(0, 1.0), (2, 2.0)]);
        m.push_row(&[(1, 3.0)]);
        m.push_row(&[(0, 4.0), (2, 5.0)]);
        m
    }

    #[test]
    fn push_row_and_access() {
        let m = small();
        assert_eq!(m.n_rows, 3);
        assert_eq!(m.n_cols, 3);
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.row(1), (&[1u32][..], &[3.0f32][..]));
    }

    #[test]
    fn margins_spmv() {
        let m = small();
        let beta = [1.0f32, 10.0, 100.0];
        assert_eq!(m.margins(&beta), vec![201.0, 30.0, 504.0]);
    }

    #[test]
    fn csr_csc_roundtrip() {
        let m = small();
        let csc = m.to_csc();
        assert_eq!(csc.col(0), (&[0u32, 2][..], &[1.0f32, 4.0][..]));
        assert_eq!(csc.col(1), (&[1u32][..], &[3.0f32][..]));
        let back = csc.to_csr();
        assert_eq!(back.indptr, m.indptr);
        assert_eq!(back.indices, m.indices);
        assert_eq!(back.values, m.values);
    }

    #[test]
    fn from_triplets_sorts_and_validates() {
        let tr = [
            Triplet { row: 2, col: 0, val: 4.0 },
            Triplet { row: 0, col: 2, val: 2.0 },
            Triplet { row: 0, col: 0, val: 1.0 },
            Triplet { row: 1, col: 1, val: 3.0 },
            Triplet { row: 2, col: 2, val: 5.0 },
        ];
        let m = CsrMatrix::from_triplets(3, 3, &tr).unwrap();
        let s = small();
        assert_eq!(m.indptr, s.indptr);
        assert_eq!(m.indices, s.indices);
        assert_eq!(m.values, s.values);
        assert!(CsrMatrix::from_triplets(1, 1, &tr).is_err());
    }

    #[test]
    fn empty_rows_are_preserved() {
        let tr = [Triplet { row: 3, col: 1, val: 1.0 }];
        let m = CsrMatrix::from_triplets(5, 2, &tr).unwrap();
        assert_eq!(m.n_rows, 5);
        assert_eq!(m.row(0).0.len(), 0);
        assert_eq!(m.row(3).0, &[1u32]);
        assert_eq!(m.row(4).0.len(), 0);
    }

    #[test]
    fn select_cols_remaps() {
        let csc = small().to_csc();
        let sub = csc.select_cols(&[2, 0]);
        assert_eq!(sub.n_cols, 2);
        assert_eq!(sub.col(0), (&[0u32, 2][..], &[2.0f32, 5.0][..]));
        assert_eq!(sub.col(1), (&[0u32, 2][..], &[1.0f32, 4.0][..]));
    }

    #[test]
    fn densify_block_pads() {
        let csc = small().to_csc();
        let tile = csc.densify_block(1, 2, 4, 4);
        // cols 1..3 of the matrix land in tile cols 0..2
        assert_eq!(tile[0 * 4 + 1], 2.0); // (row 0, col 2)
        assert_eq!(tile[1 * 4 + 0], 3.0); // (row 1, col 1)
        assert_eq!(tile[2 * 4 + 1], 5.0); // (row 2, col 2)
        assert_eq!(tile[3 * 4 + 0], 0.0); // padded row
        assert_eq!(tile.iter().filter(|&&x| x != 0.0).count(), 3);
    }

    #[test]
    fn sparse_vec_round_trips_dense() {
        let dense = [0f32, 1.5, 0.0, -2.0, 0.0];
        let sv = SparseVec::from_dense(&dense);
        assert_eq!(sv.dim, 5);
        assert_eq!(sv.nnz(), 2);
        assert_eq!(sv.indices, vec![1, 3]);
        assert_eq!(sv.to_dense(), dense.to_vec());
        assert_eq!(sv.wire_bytes(), 16);
        assert!((sv.density() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn sparse_vec_clear_keeps_capacity() {
        let mut sv = SparseVec::from_dense(&[1.0, 2.0, 3.0]);
        let cap = sv.indices.capacity();
        sv.clear(7);
        assert_eq!(sv.dim, 7);
        assert_eq!(sv.nnz(), 0);
        assert!(sv.indices.capacity() >= cap);
        sv.push(2, 4.0);
        sv.push(6, -1.0);
        assert_eq!(sv.to_dense(), vec![0.0, 0.0, 4.0, 0.0, 0.0, 0.0, -1.0]);
    }

    #[test]
    fn sparse_vec_ensure_sorted_orders_entries() {
        let mut sv = SparseVec::new(10);
        // bypass push's ordering contract to simulate an unordered producer
        sv.indices.extend_from_slice(&[7, 2, 5]);
        sv.values.extend_from_slice(&[70.0, 20.0, 50.0]);
        sv.ensure_sorted();
        assert_eq!(sv.indices, vec![2, 5, 7]);
        assert_eq!(sv.values, vec![20.0, 50.0, 70.0]);
        // already-sorted input is a no-op
        sv.ensure_sorted();
        assert_eq!(sv.indices, vec![2, 5, 7]);
    }

    #[test]
    fn sparse_vec_ensure_sorted_merges_duplicates() {
        let mut sv = SparseVec::new(10);
        // a producer that touched coordinate 4 twice (partial updates sum)
        sv.indices.extend_from_slice(&[4, 1, 4]);
        sv.values.extend_from_slice(&[1.5, 9.0, 2.5]);
        sv.ensure_sorted();
        assert_eq!(sv.indices, vec![1, 4]);
        assert_eq!(sv.values, vec![9.0, 4.0]);
    }

    #[test]
    fn dot_margin_skips_zero_and_out_of_range_weights() {
        let cols = [0u32, 2, 4, 9];
        let vals = [1.0f32, 2.0, 3.0, 4.0];
        let beta = [2.0f32, 5.0, 0.0, 5.0, -1.0];
        // col 2 has zero weight, col 9 is beyond beta — both score as 0
        assert_eq!(dot_margin(&cols, &vals, &beta), 2.0 - 3.0);
        assert_eq!(dot_margin(&[], &[], &beta), 0.0);
    }

    #[test]
    fn margin_kernel_row_and_column_halves_agree_bit_for_bit() {
        // irrational-ish weights/values so the f64 accumulation order
        // matters: the by-example kernel and the by-feature rebuild must
        // still produce the same bits per example
        let mut x = CsrMatrix::new(0);
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        for i in 0..50 {
            let mut entries = Vec::new();
            for j in 0..40u32 {
                if (i + j as usize) % 3 == 0 {
                    entries.push((j, next()));
                }
            }
            x.push_row(&entries);
        }
        let beta: Vec<f32> =
            (0..40).map(|j| if j % 4 == 0 { 0.0 } else { next() }).collect();
        let by_row = x.margins(&beta);
        let csc = x.to_csc();
        let mut acc = vec![0f64; x.n_rows];
        csc.accumulate_margins_f64(&beta, &mut acc);
        for i in 0..x.n_rows {
            assert_eq!(
                by_row[i].to_bits(),
                (acc[i] as f32).to_bits(),
                "example {i}: {} vs {}",
                by_row[i],
                acc[i] as f32
            );
        }
    }

    #[test]
    fn sparse_vec_add_scaled() {
        let sv = SparseVec::from_dense(&[0.0, 2.0, 0.0, -1.0]);
        let mut out = vec![1f32; 4];
        sv.add_scaled_into(&mut out, 0.5);
        assert_eq!(out, vec![1.0, 2.0, 1.0, 0.5]);
    }

    #[test]
    fn select_rows_subsets() {
        let m = small();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.n_rows, 2);
        assert_eq!(s.row(0), (&[0u32, 2][..], &[4.0f32, 5.0][..]));
        assert_eq!(s.row(1), (&[0u32, 2][..], &[1.0f32, 2.0][..]));
    }
}
