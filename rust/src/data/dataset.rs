//! Labeled dataset container + Table-2-style summaries and splitting.

use crate::data::sparse::CsrMatrix;
use crate::error::{DlrError, Result};
use crate::util::rng::Xoshiro256;

/// A labeled classification dataset in by-example (CSR) layout.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub name: String,
    pub x: CsrMatrix,
    /// Labels in {-1, +1}.
    pub y: Vec<f32>,
}

/// Train/test pair produced by [`Dataset::split`].
#[derive(Debug, Clone)]
pub struct SplitDataset {
    pub train: Dataset,
    pub test: Dataset,
}

/// One row of the paper's Table 2.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSummary {
    pub name: String,
    pub n_examples: usize,
    pub n_features: usize,
    pub nnz: usize,
    pub avg_nonzeros: f64,
    pub positives: usize,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: CsrMatrix, y: Vec<f32>) -> Self {
        assert_eq!(x.n_rows, y.len(), "labels must match rows");
        Self { name: name.into(), x, y }
    }

    pub fn n_examples(&self) -> usize {
        self.x.n_rows
    }

    pub fn n_features(&self) -> usize {
        self.x.n_cols
    }

    pub fn summary(&self) -> DatasetSummary {
        DatasetSummary {
            name: self.name.clone(),
            n_examples: self.n_examples(),
            n_features: self.n_features(),
            nnz: self.x.nnz(),
            avg_nonzeros: if self.n_examples() == 0 {
                0.0
            } else {
                self.x.nnz() as f64 / self.n_examples() as f64
            },
            positives: self.y.iter().filter(|&&y| y > 0.0).count(),
        }
    }

    /// Deterministic shuffled split: `train_frac` of rows to train.
    ///
    /// An out-of-range (or NaN) `train_frac` is a caller error and returns
    /// an actionable [`DlrError::Config`] instead of panicking. When the
    /// split is degenerate (all rows to one side), the non-empty side is a
    /// single clone in the original row order — no shuffle and no
    /// row-by-row CSR rebuild for either half.
    pub fn split(&self, train_frac: f64, seed: u64) -> Result<SplitDataset> {
        if !(0.0..=1.0).contains(&train_frac) {
            return Err(DlrError::Config(format!(
                "train_frac must be within [0, 1], got {train_frac} — use 1.0 to \
                 train on everything (empty test set)"
            )));
        }
        let n = self.n_examples();
        let n_train = ((n as f64) * train_frac).round() as usize;
        let empty = |suffix: &str| {
            Dataset::new(
                format!("{}-{suffix}", self.name),
                CsrMatrix::new(self.n_features()),
                Vec::new(),
            )
        };
        // degenerate fast paths: one whole-matrix clone, zero rebuilds
        if n_train >= n {
            return Ok(SplitDataset {
                train: Dataset::new(
                    format!("{}-train", self.name),
                    self.x.clone(),
                    self.y.clone(),
                ),
                test: empty("test"),
            });
        }
        if n_train == 0 {
            return Ok(SplitDataset {
                train: empty("train"),
                test: Dataset::new(
                    format!("{}-test", self.name),
                    self.x.clone(),
                    self.y.clone(),
                ),
            });
        }
        let mut idx: Vec<usize> = (0..n).collect();
        Xoshiro256::new(seed ^ 0x5EED_5EED).shuffle(&mut idx);
        let (tr, te) = idx.split_at(n_train);
        Ok(SplitDataset {
            train: Dataset::new(
                format!("{}-train", self.name),
                self.x.select_rows(tr),
                tr.iter().map(|&i| self.y[i]).collect(),
            ),
            test: Dataset::new(
                format!("{}-test", self.name),
                self.x.select_rows(te),
                te.iter().map(|&i| self.y[i]).collect(),
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        let mut x = CsrMatrix::new(2);
        let mut y = Vec::new();
        for i in 0..n {
            x.push_row(&[(0, i as f32 + 1.0), (1, 1.0)]);
            y.push(if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        Dataset::new("toy", x, y)
    }

    #[test]
    fn summary_counts() {
        let d = toy(10);
        let s = d.summary();
        assert_eq!(s.n_examples, 10);
        assert_eq!(s.n_features, 2);
        assert_eq!(s.nnz, 20);
        assert!((s.avg_nonzeros - 2.0).abs() < 1e-12);
        assert_eq!(s.positives, 5);
    }

    #[test]
    fn split_partitions_rows() {
        let d = toy(100);
        let sp = d.split(0.8, 1).unwrap();
        assert_eq!(sp.train.n_examples(), 80);
        assert_eq!(sp.test.n_examples(), 20);
        assert_eq!(sp.train.n_features(), 2);
        // determinism
        let sp2 = d.split(0.8, 1).unwrap();
        assert_eq!(sp.train.y, sp2.train.y);
        // different seed -> (almost surely) different assignment
        let sp3 = d.split(0.8, 2).unwrap();
        assert_ne!(sp.train.y, sp3.train.y);
    }

    #[test]
    fn degenerate_splits_take_the_clone_fast_path() {
        let d = toy(10);
        // everything to train: original row order, empty test with the
        // feature count preserved
        let all = d.split(1.0, 3).unwrap();
        assert_eq!(all.train.y, d.y);
        assert_eq!(all.train.x.indptr, d.x.indptr);
        assert_eq!(all.train.x.indices, d.x.indices);
        assert_eq!(all.test.n_examples(), 0);
        assert_eq!(all.test.n_features(), 2);
        // everything to test
        let none = d.split(0.0, 3).unwrap();
        assert_eq!(none.test.y, d.y);
        assert_eq!(none.train.n_examples(), 0);
        assert_eq!(none.train.n_features(), 2);
        // a fraction that rounds to n behaves like 1.0
        let rounded = d.split(0.999, 3).unwrap();
        assert_eq!(rounded.train.n_examples(), 10);
        assert_eq!(rounded.test.n_examples(), 0);
    }

    #[test]
    fn out_of_range_train_frac_errors_instead_of_panicking() {
        let d = toy(10);
        for bad in [-0.1, 1.5, f64::NAN] {
            let err = d.split(bad, 1).unwrap_err().to_string();
            assert!(err.contains("train_frac"), "{err}");
        }
    }
}
