//! The paper's §3 preprocessing: transform a by-example dataset into M
//! by-feature shards "by means of a Reduce operation". We simulate the
//! Map/Reduce cluster with an external (spill-file) shuffle so the code path
//! matches the paper's: map emits (feature, example, value) triplets
//! partitioned by the feature partitioner; each reducer sorts its partition
//! and builds the machine-local CSC shard.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::cluster::partition::FeaturePartition;
use crate::data::dataset::Dataset;
use crate::data::sparse::{CscMatrix, CsrMatrix, Triplet};
use crate::data::store::{self, ShardStore, StoreManifest};
use crate::error::{DlrError, Result};

/// Statistics of one shuffle run (the paper reports this phase at 1–5% of
/// total path time; `bench_ablation -- comm` checks ours).
#[derive(Debug, Clone, Default)]
pub struct ShuffleStats {
    pub triplets: usize,
    pub spill_bytes: u64,
    pub map_secs: f64,
    pub reduce_secs: f64,
}

/// In-memory shard produced for machine m: the local CSC (columns remapped
/// to 0..local_p) plus the global feature ids for each local column.
#[derive(Debug, Clone)]
pub struct FeatureShard {
    pub machine: usize,
    pub global_cols: Vec<u32>,
    pub csc: CscMatrix,
}

/// External map/reduce shuffle through spill files under `spill_dir`.
pub fn shuffle_to_feature_shards(
    x: &CsrMatrix,
    partition: &FeaturePartition,
    spill_dir: &Path,
) -> Result<(Vec<FeatureShard>, ShuffleStats)> {
    let mut stats = map_phase(x, partition, spill_dir)?;

    // ---- reduce phase: per machine, sort by (feature, example) and build CSC
    let t1 = std::time::Instant::now();
    let m = partition.machines();
    let mut shards = Vec::with_capacity(m);
    for k in 0..m {
        shards.push(reduce_spill(x.n_rows, partition, k, spill_dir, &mut stats)?);
    }
    stats.reduce_secs = t1.elapsed().as_secs_f64();
    Ok((shards, stats))
}

/// External shuffle straight into a [`ShardStore`]: the map phase streams
/// rows into per-machine spill files, then each reducer builds its CSC
/// block and writes it directly to its shard file — only **one** shard is
/// ever resident, so peak memory beyond the streamed input is a single
/// machine's block. This is the path that makes the paper's "dataset
/// cannot fit one machine" preprocessing physically true.
pub fn shuffle_to_store(
    ds: &Dataset,
    partition: &FeaturePartition,
    partition_spec: &str,
    dir: &Path,
) -> Result<(ShardStore, ShuffleStats)> {
    std::fs::create_dir_all(dir)?;
    let mut stats = map_phase(&ds.x, partition, dir)?;

    let t1 = std::time::Instant::now();
    let m = partition.machines();
    let mut shard_metas = Vec::with_capacity(m);
    for k in 0..m {
        let shard = reduce_spill(ds.n_examples(), partition, k, dir, &mut stats)?;
        shard_metas.push(store::write_shard_file(
            &store::shard_path(dir, k),
            &shard,
            ds.n_examples(),
            ds.n_features(),
        )?);
        // `shard` drops here: one resident block at a time
    }
    stats.reduce_secs = t1.elapsed().as_secs_f64();
    let manifest = StoreManifest {
        name: ds.name.clone(),
        n: ds.n_examples(),
        p: ds.n_features(),
        machines: m,
        partition: partition_spec.to_string(),
        shards: shard_metas,
    };
    let store = ShardStore::finish_manifest(dir, manifest, &ds.y)?;
    Ok((store, stats))
}

/// Map phase: stream rows, emit `(feature, example, value)` triplets into
/// per-machine spill files under `spill_dir`.
fn map_phase(
    x: &CsrMatrix,
    partition: &FeaturePartition,
    spill_dir: &Path,
) -> Result<ShuffleStats> {
    std::fs::create_dir_all(spill_dir)?;
    let m = partition.machines();
    let mut stats = ShuffleStats::default();
    let t0 = std::time::Instant::now();
    let mut writers: Vec<BufWriter<std::fs::File>> = (0..m)
        .map(|k| -> Result<_> {
            let p = spill_path(spill_dir, k);
            Ok(BufWriter::new(std::fs::File::create(p)?))
        })
        .collect::<Result<_>>()?;
    for i in 0..x.n_rows {
        let (cols, vals) = x.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            let k = partition.machine_of(c as usize);
            writeln!(writers[k], "{c}\t{i}\t{v}")?;
            stats.triplets += 1;
        }
    }
    for mut w in writers {
        w.flush()?;
    }
    stats.map_secs = t0.elapsed().as_secs_f64();
    Ok(stats)
}

/// One reducer: read machine `k`'s spill, sort by (feature, example) and
/// build the machine-local CSC shard. Consumes (deletes) the spill file.
fn reduce_spill(
    n_rows: usize,
    partition: &FeaturePartition,
    k: usize,
    spill_dir: &Path,
    stats: &mut ShuffleStats,
) -> Result<FeatureShard> {
    let p = spill_path(spill_dir, k);
    stats.spill_bytes += std::fs::metadata(&p)?.len();
    let mut triplets: Vec<Triplet> = Vec::new();
    for line in BufReader::new(std::fs::File::open(&p)?).lines() {
        let line = line?;
        let mut it = line.split('\t');
        let mut next_tok = || -> Result<&str> {
            it.next().ok_or_else(|| DlrError::parse("spill", "short line"))
        };
        let c: u32 = next_tok()?
            .parse()
            .map_err(|_| DlrError::parse("spill", "bad col"))?;
        let r: u32 = next_tok()?
            .parse()
            .map_err(|_| DlrError::parse("spill", "bad row"))?;
        let v: f32 = next_tok()?
            .parse()
            .map_err(|_| DlrError::parse("spill", "bad val"))?;
        triplets.push(Triplet { row: r, col: c, val: v });
    }
    std::fs::remove_file(&p)?;
    // the reduce sort: by feature then example (Table-1 order)
    triplets.sort_by_key(|t| (t.col, t.row));
    let global_cols = partition.features_of(k);
    let mut col_pos = std::collections::HashMap::with_capacity(global_cols.len());
    for (local, &g) in global_cols.iter().enumerate() {
        col_pos.insert(g, local);
    }
    let mut csc = CscMatrix {
        n_rows,
        n_cols: global_cols.len(),
        indptr: vec![0; global_cols.len() + 1],
        indices: Vec::with_capacity(triplets.len()),
        values: Vec::with_capacity(triplets.len()),
    };
    // counting pass
    let mut counts = vec![0usize; global_cols.len()];
    for t in &triplets {
        let local = *col_pos.get(&t.col).ok_or_else(|| {
            DlrError::Data(format!("feature {} not owned by machine {k}", t.col))
        })?;
        counts[local] += 1;
    }
    for j in 0..global_cols.len() {
        csc.indptr[j + 1] = csc.indptr[j] + counts[j];
    }
    let mut next = csc.indptr.clone();
    csc.indices.resize(triplets.len(), 0);
    csc.values.resize(triplets.len(), 0.0);
    for t in &triplets {
        let local = col_pos[&t.col];
        let dst = next[local];
        csc.indices[dst] = t.row;
        csc.values[dst] = t.val;
        next[local] += 1;
    }
    Ok(FeatureShard { machine: k, global_cols, csc })
}

/// Fast in-memory variant (no spill files) — used when the dataset already
/// fits and by the unit tests of downstream modules.
pub fn shard_in_memory(x: &CsrMatrix, partition: &FeaturePartition) -> Vec<FeatureShard> {
    let csc = x.to_csc();
    (0..partition.machines())
        .map(|k| {
            let global_cols = partition.features_of(k);
            let cols_usize: Vec<usize> = global_cols.iter().map(|&c| c as usize).collect();
            FeatureShard { machine: k, global_cols, csc: csc.select_cols(&cols_usize) }
        })
        .collect()
}

fn spill_path(dir: &Path, machine: usize) -> PathBuf {
    dir.join(format!("spill_machine_{machine}.tsv"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::{FeaturePartition, PartitionStrategy};
    use crate::data::synth;

    #[test]
    fn external_shuffle_matches_in_memory() {
        let ds = synth::webspam_like(60, 300, 12, 5);
        let part = FeaturePartition::build(
            PartitionStrategy::RoundRobin,
            ds.n_features(),
            4,
            None,
        );
        let dir = std::env::temp_dir().join(format!("dglmnet_shuffle_test_{}", std::process::id()));
        let (ext, stats) = shuffle_to_feature_shards(&ds.x, &part, &dir).unwrap();
        let mem = shard_in_memory(&ds.x, &part);
        assert_eq!(stats.triplets, ds.x.nnz());
        assert!(stats.spill_bytes > 0);
        for (a, b) in ext.iter().zip(&mem) {
            assert_eq!(a.global_cols, b.global_cols);
            assert_eq!(a.csc.indptr, b.csc.indptr);
            assert_eq!(a.csc.indices, b.csc.indices);
            assert_eq!(a.csc.values, b.csc.values);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shuffle_to_store_matches_in_memory_create() {
        let ds = synth::webspam_like(80, 240, 10, 7);
        let part = FeaturePartition::build(
            PartitionStrategy::RoundRobin,
            ds.n_features(),
            3,
            None,
        );
        let base = std::env::temp_dir()
            .join(format!("dglmnet_shuffle_store_{}", std::process::id()));
        let (ext, stats) =
            shuffle_to_store(&ds, &part, "round-robin", &base.join("ext")).unwrap();
        assert_eq!(stats.triplets, ds.x.nnz());
        let mem =
            ShardStore::create(base.join("mem"), &ds, &part, "round-robin").unwrap();
        // identical manifests (bar nothing: same shards, same checksums)
        assert_eq!(ext.manifest(), mem.manifest());
        for k in 0..3 {
            let a = ext.load_shard(k).unwrap();
            let b = mem.load_shard(k).unwrap();
            assert_eq!(a.global_cols, b.global_cols);
            assert_eq!(a.csc.indptr, b.csc.indptr);
            assert_eq!(a.csc.indices, b.csc.indices);
            for (x, y) in a.csc.values.iter().zip(&b.csc.values) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn shards_cover_all_nnz_disjointly() {
        let ds = synth::dna_like(200, 50, 5, 6);
        let part =
            FeaturePartition::build(PartitionStrategy::Contiguous, ds.n_features(), 3, None);
        let shards = shard_in_memory(&ds.x, &part);
        let total: usize = shards.iter().map(|s| s.csc.nnz()).sum();
        assert_eq!(total, ds.x.nnz());
        let mut all_cols: Vec<u32> = shards.iter().flat_map(|s| s.global_cols.clone()).collect();
        all_cols.sort_unstable();
        assert_eq!(all_cols, (0..ds.n_features() as u32).collect::<Vec<_>>());
    }
}
