//! Sharded on-disk feature store — the out-of-core data plane.
//!
//! The paper's premise is that the training set "cannot fit the memory of a
//! single machine": each machine holds only its by-feature block, loaded
//! locally, and nothing ever ships the design matrix through a coordinator.
//! A [`ShardStore`] is the durable form of that layout:
//!
//! ```text
//! store/
//!   manifest.json     n, p, machines, partition spec, per-shard nnz + FNV
//!                     checksums — everything a leader needs to validate a
//!                     cluster without touching a single matrix entry
//!   y.bin             the labels (O(n) — the only example-indexed payload)
//!   shard_0000.bfcsc  machine 0's by-feature CSC block (global column ids
//!   shard_0001.bfcsc  + indptr/indices/values), one file per machine
//!   ...
//! ```
//!
//! Workers open *only their own* shard file
//! ([`WorkerNode::from_store`](crate::cluster::node::WorkerNode::from_store));
//! the leader reads the manifest, the shard *headers* (for the O(p) global
//! column lists) and `y.bin` — it never constructs a `CscMatrix` or
//! `CsrMatrix` of X. Stores are written by the `dglmnet shard` CLI
//! subcommand, by [`ShardStore::create`] (in-memory source), or streamed by
//! [`shuffle_to_store`](crate::data::shuffle::shuffle_to_store) (the
//! external Map/Reduce shuffle, one resident shard at a time).
//!
//! Every shard file carries an FNV-1a checksum in the manifest; loads
//! verify it, so a truncated or bit-rotted shard errors loudly instead of
//! silently corrupting a fit.

use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::cluster::partition::FeaturePartition;
use crate::cluster::protocol::crc_u32;
use crate::data::dataset::Dataset;
use crate::data::shuffle::FeatureShard;
use crate::data::sparse::CscMatrix;
use crate::error::{DlrError, Result};
use crate::util::json::{self, Json};

const MANIFEST_FILE: &str = "manifest.json";
const Y_FILE: &str = "y.bin";
const MANIFEST_KIND: &str = "dglmnet-shard-store";
const MANIFEST_VERSION: usize = 1;

const SHARD_MAGIC: &[u8; 4] = b"DGLS";
const Y_MAGIC: &[u8; 4] = b"DGLY";

// FNV-1a (same constants as the protocol checksums).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Per-machine shard metadata recorded in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMeta {
    pub machine: usize,
    /// Features this machine owns.
    pub local_features: usize,
    pub nnz: usize,
    /// `crc_u32` of the shard's ascending global column ids — the same
    /// identity the `Join` handshake announces, so a leader validates
    /// remote workers against the manifest without loading any shard.
    pub cols_checksum: u64,
    /// FNV-1a over the entire shard file (header included).
    pub payload_checksum: u64,
}

/// The store manifest: dataset shape, partition spec, shard identities.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreManifest {
    pub name: String,
    pub n: usize,
    pub p: usize,
    pub machines: usize,
    /// Human-readable partition spec (informational — the binding identity
    /// is the per-shard column lists in the shard files).
    pub partition: String,
    pub shards: Vec<ShardMeta>,
}

impl StoreManifest {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("kind".into(), Json::Str(MANIFEST_KIND.into()));
        m.insert("version".into(), Json::Num(MANIFEST_VERSION as f64));
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("n".into(), Json::Num(self.n as f64));
        m.insert("p".into(), Json::Num(self.p as f64));
        m.insert("machines".into(), Json::Num(self.machines as f64));
        m.insert("partition".into(), Json::Str(self.partition.clone()));
        m.insert(
            "shards".into(),
            Json::Arr(
                self.shards
                    .iter()
                    .map(|s| {
                        let mut sm = std::collections::BTreeMap::new();
                        sm.insert("machine".into(), Json::Num(s.machine as f64));
                        sm.insert(
                            "local_features".into(),
                            Json::Num(s.local_features as f64),
                        );
                        sm.insert("nnz".into(), Json::Num(s.nnz as f64));
                        sm.insert(
                            "cols_checksum".into(),
                            Json::Str(format!("{:016x}", s.cols_checksum)),
                        );
                        sm.insert(
                            "payload_checksum".into(),
                            Json::Str(format!("{:016x}", s.payload_checksum)),
                        );
                        Json::Obj(sm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    pub fn from_json(doc: &Json) -> Result<Self> {
        if doc.get("kind").and_then(Json::as_str) != Some(MANIFEST_KIND) {
            return Err(DlrError::parse("store manifest", "not a shard-store manifest"));
        }
        let version = doc.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != MANIFEST_VERSION {
            return Err(DlrError::parse(
                "store manifest",
                format!("unsupported version {version}"),
            ));
        }
        let num = |key: &str| -> Result<usize> {
            doc.get(key).and_then(Json::as_usize).ok_or_else(|| {
                DlrError::parse("store manifest", format!("missing '{key}'"))
            })
        };
        let hex = |v: Option<&Json>, key: &str| -> Result<u64> {
            let s = v.and_then(Json::as_str).ok_or_else(|| {
                DlrError::parse("store manifest", format!("missing '{key}'"))
            })?;
            u64::from_str_radix(s, 16)
                .map_err(|_| DlrError::parse("store manifest", format!("bad hex '{key}'")))
        };
        let shards = doc
            .get("shards")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| DlrError::parse("store manifest", "missing 'shards'"))?
            .iter()
            .map(|s| -> Result<ShardMeta> {
                let f = |key: &str| -> Result<usize> {
                    s.get(key).and_then(Json::as_usize).ok_or_else(|| {
                        DlrError::parse("store manifest", format!("missing shard '{key}'"))
                    })
                };
                Ok(ShardMeta {
                    machine: f("machine")?,
                    local_features: f("local_features")?,
                    nnz: f("nnz")?,
                    cols_checksum: hex(s.get("cols_checksum"), "cols_checksum")?,
                    payload_checksum: hex(s.get("payload_checksum"), "payload_checksum")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let manifest = Self {
            name: doc
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("store")
                .to_string(),
            n: num("n")?,
            p: num("p")?,
            machines: num("machines")?,
            partition: doc
                .get("partition")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            shards,
        };
        if manifest.shards.len() != manifest.machines {
            return Err(DlrError::parse(
                "store manifest",
                format!(
                    "{} shard entries but machines = {}",
                    manifest.shards.len(),
                    manifest.machines
                ),
            ));
        }
        if manifest.shards.iter().map(|s| s.local_features).sum::<usize>() != manifest.p {
            return Err(DlrError::parse(
                "store manifest",
                "shard column counts do not cover the feature space",
            ));
        }
        Ok(manifest)
    }
}

/// Handle to an on-disk shard store. Cheap to clone (directory + manifest);
/// shard payloads are read on demand, one machine at a time.
#[derive(Debug, Clone)]
pub struct ShardStore {
    dir: PathBuf,
    manifest: StoreManifest,
}

impl ShardStore {
    /// Open an existing store and validate its manifest.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).map_err(|e| {
            DlrError::Data(format!(
                "cannot open shard store at {} ({e}) — create one with `dglmnet shard`",
                dir.display()
            ))
        })?;
        let manifest = StoreManifest::from_json(&json::parse(&text)?)?;
        Ok(Self { dir, manifest })
    }

    /// Write a store from an in-memory dataset (the thin adapter the
    /// in-memory constructors use, and the fast path of `dglmnet shard`).
    /// Shards are built and written one machine at a time, so the peak
    /// overhead beyond the input dataset is a single shard.
    pub fn create(
        dir: impl AsRef<Path>,
        ds: &Dataset,
        partition: &FeaturePartition,
        partition_spec: &str,
    ) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let csc = ds.x.to_csc();
        let n = ds.n_examples();
        let p = ds.n_features();
        let mut shards = Vec::with_capacity(partition.machines());
        for k in 0..partition.machines() {
            let global_cols = partition.features_of(k);
            let cols_usize: Vec<usize> =
                global_cols.iter().map(|&c| c as usize).collect();
            let shard = FeatureShard {
                machine: k,
                global_cols,
                csc: csc.select_cols(&cols_usize),
            };
            shards.push(write_shard_file(&shard_path(&dir, k), &shard, n, p)?);
        }
        write_y_file(&dir.join(Y_FILE), &ds.y)?;
        let manifest = StoreManifest {
            name: ds.name.clone(),
            n,
            p,
            machines: partition.machines(),
            partition: partition_spec.to_string(),
            shards,
        };
        std::fs::write(
            dir.join(MANIFEST_FILE),
            format!("{}\n", manifest.to_json()),
        )?;
        Ok(Self { dir, manifest })
    }

    /// Finalize a store whose shard files are already on disk (the
    /// external shuffle writes them one reducer at a time): write `y.bin`
    /// and the manifest, and return the opened handle.
    pub fn finish_manifest(
        dir: impl AsRef<Path>,
        manifest: StoreManifest,
        y: &[f32],
    ) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        if y.len() != manifest.n {
            return Err(DlrError::Data(format!(
                "{} labels but the manifest says n = {}",
                y.len(),
                manifest.n
            )));
        }
        write_y_file(&dir.join(Y_FILE), y)?;
        std::fs::write(
            dir.join(MANIFEST_FILE),
            format!("{}\n", manifest.to_json()),
        )?;
        Ok(Self { dir, manifest })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &StoreManifest {
        &self.manifest
    }

    pub fn n(&self) -> usize {
        self.manifest.n
    }

    pub fn p(&self) -> usize {
        self.manifest.p
    }

    pub fn machines(&self) -> usize {
        self.manifest.machines
    }

    /// The labels — the only O(n) payload a leader loads.
    pub fn load_y(&self) -> Result<Vec<f32>> {
        let y = read_y_file(&self.dir.join(Y_FILE))?;
        if y.len() != self.manifest.n {
            return Err(DlrError::Data(format!(
                "y.bin holds {} labels but the manifest says n = {}",
                y.len(),
                self.manifest.n
            )));
        }
        Ok(y)
    }

    /// Load machine `k`'s full shard (header + CSC payload), verifying the
    /// manifest checksum — the *worker-side* read.
    pub fn load_shard(&self, machine: usize) -> Result<FeatureShard> {
        let meta = self.shard_meta(machine)?;
        let (shard, payload_checksum) =
            read_shard_file(&shard_path(&self.dir, machine), machine)?;
        if payload_checksum != meta.payload_checksum {
            return Err(DlrError::Data(format!(
                "shard {machine} payload checksum mismatch (file {payload_checksum:016x}, \
                 manifest {:016x}) — the store is corrupt or was partially rewritten",
                meta.payload_checksum
            )));
        }
        if shard.csc.n_rows != self.manifest.n
            || shard.global_cols.len() != meta.local_features
            || shard.csc.nnz() != meta.nnz
            || crc_u32(&shard.global_cols) != meta.cols_checksum
        {
            return Err(DlrError::Data(format!(
                "shard {machine} does not match its manifest entry"
            )));
        }
        Ok(shard)
    }

    /// Machine `k`'s ascending global column ids, read from the shard file
    /// *header only* — the leader's O(p)-total view of the partition; the
    /// O(nnz) CSC payload is never touched.
    pub fn shard_cols(&self, machine: usize) -> Result<Vec<u32>> {
        let meta = self.shard_meta(machine)?;
        let cols = read_shard_cols(&shard_path(&self.dir, machine), machine)?;
        if cols.len() != meta.local_features || crc_u32(&cols) != meta.cols_checksum {
            return Err(DlrError::Data(format!(
                "shard {machine} column header does not match the manifest"
            )));
        }
        Ok(cols)
    }

    /// Reconstruct the feature partition from the shard headers (O(p)).
    pub fn partition(&self) -> Result<FeaturePartition> {
        let lists: Vec<Vec<u32>> = (0..self.machines())
            .map(|k| self.shard_cols(k))
            .collect::<Result<_>>()?;
        FeaturePartition::from_feature_lists(&lists, self.p())
    }

    /// Per-column nnz over the whole feature space, recovered from the
    /// shard files (indptr diffs mapped through each shard's global column
    /// ids, one shard resident at a time). These are exactly the counts
    /// [`DGlmnetSolver::partition_for`] derives from the full dataset, so
    /// an elastic re-partition at a new machine count rebuilds the same
    /// [`FeaturePartition`] a fresh shard run over the original data would.
    ///
    /// [`DGlmnetSolver::partition_for`]:
    /// crate::solver::dglmnet::DGlmnetSolver::partition_for
    pub fn col_nnz(&self) -> Result<Vec<usize>> {
        let mut counts = vec![0usize; self.p()];
        for k in 0..self.machines() {
            let shard = self.load_shard(k)?;
            for (l, &g) in shard.global_cols.iter().enumerate() {
                counts[g as usize] = shard.csc.indptr[l + 1] - shard.csc.indptr[l];
            }
        }
        Ok(counts)
    }

    /// Redistribute this store's column payloads into a new store at `dir`
    /// sharded by `partition` — the elastic join/leave path (M → M ± 1
    /// machines between λ steps). Column payloads are copied bit-for-bit
    /// from the source shards, so the new store is byte-identical to one
    /// created directly from the original dataset under the same
    /// partition (pinned in the tests below). Peak memory is one source
    /// shard plus the destination shard being assembled — resharding
    /// stays out-of-core like every other store path.
    pub fn reshard(
        &self,
        dir: impl AsRef<Path>,
        partition: &FeaturePartition,
        partition_spec: &str,
    ) -> Result<ShardStore> {
        if partition.n_features() != self.p() {
            return Err(DlrError::Data(format!(
                "cannot reshard: the partition covers {} features but the store holds {}",
                partition.n_features(),
                self.p()
            )));
        }
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let n = self.n();
        let p = self.p();
        let mut shards = Vec::with_capacity(partition.machines());
        for k in 0..partition.machines() {
            let global_cols = partition.features_of(k);
            let slot: std::collections::HashMap<u32, usize> =
                global_cols.iter().enumerate().map(|(l, &g)| (g, l)).collect();
            // per-owned-column (indices, values) payloads, filled as the
            // source shards stream through one at a time
            let mut cols: Vec<Option<(Vec<u32>, Vec<f32>)>> = vec![None; global_cols.len()];
            for src in 0..self.machines() {
                let old = self.load_shard(src)?;
                for (l, &g) in old.global_cols.iter().enumerate() {
                    if let Some(&dst) = slot.get(&g) {
                        let lo = old.csc.indptr[l];
                        let hi = old.csc.indptr[l + 1];
                        cols[dst] = Some((
                            old.csc.indices[lo..hi].to_vec(),
                            old.csc.values[lo..hi].to_vec(),
                        ));
                    }
                }
            }
            let mut indptr = Vec::with_capacity(global_cols.len() + 1);
            indptr.push(0usize);
            let mut indices = Vec::new();
            let mut values = Vec::new();
            for (l, c) in cols.into_iter().enumerate() {
                let (idx, val) = c.ok_or_else(|| {
                    DlrError::Data(format!(
                        "cannot reshard: feature {} is missing from every source shard",
                        global_cols[l]
                    ))
                })?;
                indices.extend_from_slice(&idx);
                values.extend_from_slice(&val);
                indptr.push(indices.len());
            }
            let csc = CscMatrix {
                n_rows: n,
                n_cols: global_cols.len(),
                indptr,
                indices,
                values,
            };
            let shard = FeatureShard { machine: k, global_cols, csc };
            shards.push(write_shard_file(&shard_path(&dir, k), &shard, n, p)?);
        }
        let manifest = StoreManifest {
            name: self.manifest.name.clone(),
            n,
            p,
            machines: partition.machines(),
            partition: partition_spec.to_string(),
            shards,
        };
        Self::finish_manifest(&dir, manifest, &self.load_y()?)
    }

    fn shard_meta(&self, machine: usize) -> Result<&ShardMeta> {
        self.manifest
            .shards
            .iter()
            .find(|s| s.machine == machine)
            .ok_or_else(|| {
                DlrError::Data(format!(
                    "machine {machine} is not in this {}-machine store",
                    self.machines()
                ))
            })
    }
}

/// Path of machine `k`'s shard file inside `dir`.
pub fn shard_path(dir: &Path, machine: usize) -> PathBuf {
    dir.join(format!("shard_{machine:04}.bfcsc"))
}

// ---------------------------------------------------------------------------
// Binary shard / label files
// ---------------------------------------------------------------------------

struct ChecksumWriter<W: Write> {
    inner: W,
    hash: u64,
}

impl<W: Write> ChecksumWriter<W> {
    fn new(inner: W) -> Self {
        Self { inner, hash: FNV_OFFSET }
    }
}

impl<W: Write> Write for ChecksumWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.hash = fnv1a(self.hash, &buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

fn put_u32s(out: &mut impl Write, values: impl Iterator<Item = u32>) -> Result<()> {
    for v in values {
        out.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Write one by-feature shard file; returns its manifest entry.
pub fn write_shard_file(
    path: &Path,
    shard: &FeatureShard,
    n: usize,
    p: usize,
) -> Result<ShardMeta> {
    let file = BufWriter::new(std::fs::File::create(path)?);
    let mut w = ChecksumWriter::new(file);
    // header (checksummed like the payload — corruption anywhere fails)
    w.write_all(SHARD_MAGIC)?;
    w.write_all(&1u32.to_le_bytes())?; // version
    w.write_all(&(shard.machine as u32).to_le_bytes())?;
    w.write_all(&(n as u32).to_le_bytes())?;
    w.write_all(&(p as u32).to_le_bytes())?;
    w.write_all(&(shard.global_cols.len() as u32).to_le_bytes())?;
    w.write_all(&(shard.csc.nnz() as u64).to_le_bytes())?;
    put_u32s(&mut w, shard.global_cols.iter().copied())?;
    // payload: CSC indptr (u64), row indices (u32), values (f32 bits)
    for &v in &shard.csc.indptr {
        w.write_all(&(v as u64).to_le_bytes())?;
    }
    put_u32s(&mut w, shard.csc.indices.iter().copied())?;
    put_u32s(&mut w, shard.csc.values.iter().map(|v| v.to_bits()))?;
    let payload_checksum = w.hash;
    w.flush()?;
    Ok(ShardMeta {
        machine: shard.machine,
        local_features: shard.global_cols.len(),
        nnz: shard.csc.nnz(),
        cols_checksum: crc_u32(&shard.global_cols),
        payload_checksum,
    })
}

struct ShardReader {
    bytes: Vec<u8>,
    pos: usize,
}

impl ShardReader {
    fn take(&mut self, len: usize) -> Result<&[u8]> {
        let end = self
            .pos
            .checked_add(len)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| DlrError::parse("shard file", "truncated"))?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn u32_vec(&mut self, len: usize) -> Result<Vec<u32>> {
        let s = self.take(len.checked_mul(4).ok_or_else(|| {
            DlrError::parse("shard file", "length overflow")
        })?)?;
        Ok(s.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Decoded shard-file header (everything before the column list).
struct ShardHeader {
    n: usize,
    local_p: usize,
    nnz: usize,
}

/// Fixed-size prefix of a shard file: magic(4) + version(4) + machine(4) +
/// n(4) + p(4) + local_p(4) + nnz(8).
const SHARD_HEADER_BYTES: usize = 32;

fn parse_shard_header(
    r: &mut ShardReader,
    path: &Path,
    machine: usize,
) -> Result<ShardHeader> {
    if r.take(4)? != SHARD_MAGIC {
        return Err(DlrError::parse("shard file", "bad magic (not a .bfcsc shard)"));
    }
    let version = r.u32()?;
    if version != 1 {
        return Err(DlrError::parse(
            "shard file",
            format!("unsupported version {version}"),
        ));
    }
    let file_machine = r.u32()? as usize;
    if file_machine != machine {
        return Err(DlrError::Data(format!(
            "shard file {} belongs to machine {file_machine}, not {machine}",
            path.display()
        )));
    }
    let n = r.u32()? as usize;
    let _p = r.u32()? as usize;
    let local_p = r.u32()? as usize;
    let nnz = r.u64()? as usize;
    Ok(ShardHeader { n, local_p, nnz })
}

/// Header-only read: the shard's global column ids. This is the leader's
/// view of a shard, so it must stay O(local_p): only the fixed header and
/// the column list are read — the O(nnz) CSC payload bytes never enter
/// this process.
fn read_shard_cols(path: &Path, machine: usize) -> Result<Vec<u32>> {
    let mut file = std::fs::File::open(path).map_err(|e| {
        DlrError::Data(format!("cannot read shard file {} ({e})", path.display()))
    })?;
    let file_len = file.metadata()?.len();
    let mut head = vec![0u8; SHARD_HEADER_BYTES];
    file.read_exact(&mut head)
        .map_err(|_| DlrError::parse("shard file", "truncated"))?;
    let mut r = ShardReader { bytes: head, pos: 0 };
    let header = parse_shard_header(&mut r, path, machine)?;
    // a corrupt header must not drive a huge allocation or read: the
    // column list has to fit inside the file
    let cols_bytes = header.local_p.checked_mul(4).ok_or_else(|| {
        DlrError::parse("shard file", "length overflow")
    })?;
    if (SHARD_HEADER_BYTES + cols_bytes) as u64 > file_len {
        return Err(DlrError::parse("shard file", "truncated column header"));
    }
    let mut buf = vec![0u8; cols_bytes];
    file.read_exact(&mut buf)
        .map_err(|_| DlrError::parse("shard file", "truncated column header"))?;
    let mut r = ShardReader { bytes: buf, pos: 0 };
    r.u32_vec(header.local_p)
}

/// Full read: the shard plus the FNV checksum over the entire file (the
/// worker-side load — legitimately O(nnz)).
fn read_shard_file(path: &Path, machine: usize) -> Result<(FeatureShard, u64)> {
    let bytes = std::fs::read(path).map_err(|e| {
        DlrError::Data(format!("cannot read shard file {} ({e})", path.display()))
    })?;
    let mut r = ShardReader { bytes, pos: 0 };
    let header = parse_shard_header(&mut r, path, machine)?;
    let ShardHeader { n, local_p, nnz } = header;
    let checksum = fnv1a(FNV_OFFSET, &r.bytes);
    let global_cols = r.u32_vec(local_p)?;
    let mut indptr = Vec::with_capacity(local_p + 1);
    for _ in 0..=local_p {
        indptr.push(r.u64()? as usize);
    }
    if indptr.first() != Some(&0) || indptr.last() != Some(&nnz) {
        return Err(DlrError::parse("shard file", "inconsistent indptr"));
    }
    if indptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(DlrError::parse("shard file", "non-monotone indptr"));
    }
    let indices = r.u32_vec(nnz)?;
    if indices.iter().any(|&i| i as usize >= n) {
        return Err(DlrError::parse("shard file", "row index out of range"));
    }
    let values: Vec<f32> = r.u32_vec(nnz)?.into_iter().map(f32::from_bits).collect();
    if r.pos != r.bytes.len() {
        return Err(DlrError::parse("shard file", "trailing garbage"));
    }
    let csc = CscMatrix { n_rows: n, n_cols: local_p, indptr, indices, values };
    Ok((FeatureShard { machine, global_cols, csc }, checksum))
}

fn write_y_file(path: &Path, y: &[f32]) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(Y_MAGIC)?;
    w.write_all(&(y.len() as u32).to_le_bytes())?;
    put_u32s(&mut w, y.iter().map(|v| v.to_bits()))?;
    w.flush()?;
    Ok(())
}

fn read_y_file(path: &Path) -> Result<Vec<f32>> {
    let mut file = std::fs::File::open(path)
        .map_err(|e| DlrError::Data(format!("cannot read {} ({e})", path.display())))?;
    let mut bytes = Vec::new();
    file.read_to_end(&mut bytes)?;
    let mut r = ShardReader { bytes, pos: 0 };
    if r.take(4)? != Y_MAGIC {
        return Err(DlrError::parse("y.bin", "bad magic"));
    }
    let n = r.u32()? as usize;
    let y: Vec<f32> = r.u32_vec(n)?.into_iter().map(f32::from_bits).collect();
    if r.pos != r.bytes.len() {
        return Err(DlrError::parse("y.bin", "trailing garbage"));
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::PartitionStrategy;
    use crate::data::shuffle::shard_in_memory;
    use crate::data::synth;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dglmnet_store_{}_{name}", std::process::id()))
    }

    #[test]
    fn create_open_load_round_trips_bit_exactly() {
        let ds = synth::webspam_like(120, 500, 9, 77);
        let part =
            FeaturePartition::build(PartitionStrategy::RoundRobin, 500, 3, None);
        let dir = tmp("roundtrip");
        let store = ShardStore::create(&dir, &ds, &part, "round-robin").unwrap();
        assert_eq!(store.n(), 120);
        assert_eq!(store.p(), 500);
        assert_eq!(store.machines(), 3);

        let reopened = ShardStore::open(&dir).unwrap();
        assert_eq!(reopened.manifest(), store.manifest());
        let y = reopened.load_y().unwrap();
        for (a, b) in y.iter().zip(&ds.y) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mem = shard_in_memory(&ds.x, &part);
        for k in 0..3 {
            let loaded = reopened.load_shard(k).unwrap();
            assert_eq!(loaded.machine, mem[k].machine);
            assert_eq!(loaded.global_cols, mem[k].global_cols);
            assert_eq!(loaded.csc.indptr, mem[k].csc.indptr);
            assert_eq!(loaded.csc.indices, mem[k].csc.indices);
            for (a, b) in loaded.csc.values.iter().zip(&mem[k].csc.values) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            // header-only read agrees with the full read
            assert_eq!(reopened.shard_cols(k).unwrap(), loaded.global_cols);
        }
        // partition reconstruction covers the feature space
        let rebuilt = reopened.partition().unwrap();
        for k in 0..3 {
            assert_eq!(rebuilt.features_of(k), mem[k].global_cols);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_shard_payload_is_rejected() {
        let ds = synth::dna_like(60, 24, 4, 78);
        let part = FeaturePartition::build(PartitionStrategy::Contiguous, 24, 2, None);
        let dir = tmp("corrupt");
        let store = ShardStore::create(&dir, &ds, &part, "contiguous").unwrap();
        let path = shard_path(&dir, 1);
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40; // flip a value bit
        std::fs::write(&path, &bytes).unwrap();
        let err = store.load_shard(1).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // the untouched shard still loads
        store.load_shard(0).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_shard_file_errors_cleanly() {
        let ds = synth::dna_like(60, 24, 4, 79);
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 24, 2, None);
        let dir = tmp("truncated");
        let store = ShardStore::create(&dir, &ds, &part, "round-robin").unwrap();
        let path = shard_path(&dir, 0);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(store.load_shard(0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reshard_matches_a_direct_create_bit_for_bit() {
        // the elastic M -> M±1 path: a store resharded 3 -> 2 must be
        // byte-identical to one created directly from the dataset at M=2
        let ds = synth::webspam_like(100, 300, 8, 81);
        let p3 = FeaturePartition::build(PartitionStrategy::RoundRobin, 300, 3, None);
        let dir3 = tmp("reshard_src");
        let store3 = ShardStore::create(&dir3, &ds, &p3, "round-robin").unwrap();

        // nnz counts recovered from the shards equal the dataset-derived ones
        let counts = store3.col_nnz().unwrap();
        let mut direct_counts = vec![0usize; 300];
        for &c in &ds.x.indices {
            direct_counts[c as usize] += 1;
        }
        assert_eq!(counts, direct_counts);

        let p2 =
            FeaturePartition::build(PartitionStrategy::RoundRobin, 300, 2, Some(&counts));
        let dir_re = tmp("reshard_dst");
        let re = store3.reshard(&dir_re, &p2, "round-robin").unwrap();
        let dir2 = tmp("reshard_direct");
        let direct = ShardStore::create(&dir2, &ds, &p2, "round-robin").unwrap();
        for k in 0..2 {
            let a = re.load_shard(k).unwrap();
            let b = direct.load_shard(k).unwrap();
            assert_eq!(a.global_cols, b.global_cols);
            assert_eq!(a.csc.indptr, b.csc.indptr);
            assert_eq!(a.csc.indices, b.csc.indices);
            for (x, yv) in a.csc.values.iter().zip(&b.csc.values) {
                assert_eq!(x.to_bits(), yv.to_bits());
            }
        }
        // identical payloads => identical manifest checksums
        assert_eq!(re.manifest().shards, direct.manifest().shards);
        for d in [dir3, dir_re, dir2] {
            std::fs::remove_dir_all(&d).ok();
        }
    }

    #[test]
    fn missing_store_gives_actionable_error() {
        let err = ShardStore::open(tmp("missing")).unwrap_err().to_string();
        assert!(err.contains("dglmnet shard"), "{err}");
    }

    #[test]
    fn manifest_rejects_incoherent_shapes() {
        let ds = synth::dna_like(40, 10, 3, 80);
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 10, 2, None);
        let dir = tmp("badmanifest");
        let store = ShardStore::create(&dir, &ds, &part, "round-robin").unwrap();
        let mut doc = store.manifest().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("p".into(), Json::Num(11.0));
        }
        assert!(StoreManifest::from_json(&doc).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
