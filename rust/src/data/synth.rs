//! Synthetic dataset generators with the *shape signatures* of the paper's
//! Table-2 datasets (Pascal Large Scale Learning Challenge 2008), scaled to
//! laptop size. Each generator plants a sparse ground-truth β* and draws
//! labels from the logistic model, so the L1 regularization path has real
//! structure to recover (Figure 1's x-axis is nnz(β)).
//!
//! | paper dataset | signature                        | generator       |
//! |---------------|----------------------------------|-----------------|
//! | epsilon       | fully dense, p = 2000            | [`epsilon_like`] |
//! | webspam       | very sparse, p ≫ n, power-law    | [`webspam_like`] |
//! | dna           | tiny p, n ≫ p, short rows        | [`dna_like`]    |
//!
//! The GLM families get matching generators with the same planted-support
//! idea on non-logistic responses: [`gaussian_like`] (y = βᵀx + ε) and
//! [`poisson_like`] (exact Poisson(exp(βᵀx)) counts).

use crate::data::dataset::Dataset;
use crate::data::sparse::CsrMatrix;
use crate::util::math::sigmoid;
use crate::util::rng::Xoshiro256;

/// Ground-truth generating model attached to a synthetic dataset (tests use
/// it to check support recovery).
#[derive(Debug, Clone)]
pub struct GroundTruth {
    pub beta: Vec<f32>,
    pub noise: f64,
}

fn draw_sparse_beta(rng: &mut Xoshiro256, p: usize, k: usize, scale: f64) -> Vec<f32> {
    let mut beta = vec![0f32; p];
    for j in rng.sample_indices(p, k.min(p)) {
        // ±[0.5, 1.5) * scale: bounded away from zero so support is crisp
        let mag = scale * rng.uniform_in(0.5, 1.5);
        beta[j] = (if rng.bernoulli(0.5) { mag } else { -mag }) as f32;
    }
    beta
}

fn label_from_margin(rng: &mut Xoshiro256, margin: f64, noise: f64) -> f32 {
    // Draw from the logistic model with temperature `noise`: higher noise
    // => flatter probabilities => harder problem.
    let p = sigmoid(margin / noise.max(1e-9));
    rng.label(p)
}

/// Dense gaussian features (epsilon signature). ~`k_true = p/20` active.
pub fn epsilon_like(n: usize, p: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed);
    let beta = draw_sparse_beta(&mut rng, p, (p / 20).max(4), 1.0);
    let mut x = CsrMatrix::new(p);
    let mut y = Vec::with_capacity(n);
    let mut row: Vec<(u32, f32)> = Vec::with_capacity(p);
    for _ in 0..n {
        row.clear();
        let mut margin = 0f64;
        for j in 0..p {
            // standardized dense gaussian features, like epsilon
            let v = rng.normal() as f32;
            row.push((j as u32, v));
            margin += v as f64 * beta[j] as f64;
        }
        x.push_row(&row);
        y.push(label_from_margin(&mut rng, margin, 0.7));
    }
    let mut ds = Dataset::new("epsilon_like", x, y);
    ds.x.n_cols = p;
    ds
}

/// Very sparse, high-dimensional, power-law feature popularity (webspam
/// signature): p ≫ n, `nnz_per_row` non-zeros per row with tf-idf-ish
/// positive values; β* lives on moderately popular features.
pub fn webspam_like(n: usize, p: usize, nnz_per_row: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed);
    let k_true = (p / 100).clamp(8, 256);
    let beta = {
        // plant the support on the popular (low-rank) end so examples hit it
        let mut b = vec![0f32; p];
        for t in 0..k_true {
            let j = rng.zipf(p / 4, 1.05).min(p - 1);
            let mag = rng.uniform_in(0.8, 2.2);
            b[j] = (if t % 2 == 0 { mag } else { -mag }) as f32;
        }
        b
    };
    let mut x = CsrMatrix::new(p);
    let mut y = Vec::with_capacity(n);
    let mut cols: Vec<u32> = Vec::with_capacity(nnz_per_row);
    for _ in 0..n {
        cols.clear();
        let mut seen = std::collections::HashSet::new();
        while cols.len() < nnz_per_row {
            let j = rng.zipf(p, 1.05).min(p - 1) as u32;
            if seen.insert(j) {
                cols.push(j);
            }
        }
        cols.sort_unstable();
        let mut margin = 0f64;
        let entries: Vec<(u32, f32)> = cols
            .iter()
            .map(|&j| {
                let v = rng.uniform_in(0.2, 1.0) as f32; // tf-idf-ish weight
                margin += v as f64 * beta[j as usize] as f64;
                (j, v)
            })
            .collect();
        x.push_row(&entries);
        y.push(label_from_margin(&mut rng, margin, 0.8));
    }
    let mut ds = Dataset::new("webspam_like", x, y);
    ds.x.n_cols = p;
    ds
}

/// Few features, many examples, short categorical-ish rows (dna signature):
/// each row activates `nnz_per_row` of the p features with value 1.
pub fn dna_like(n: usize, p: usize, nnz_per_row: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed);
    let beta = draw_sparse_beta(&mut rng, p, (p / 10).max(8), 1.2);
    let mut x = CsrMatrix::new(p);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut idx = rng.sample_indices(p, nnz_per_row.min(p));
        idx.sort_unstable();
        let mut margin = 0f64;
        let entries: Vec<(u32, f32)> = idx
            .iter()
            .map(|&j| {
                margin += beta[j] as f64;
                (j as u32, 1.0f32)
            })
            .collect();
        x.push_row(&entries);
        // dna is class-imbalanced (splice sites are rare): shift the margin
        y.push(label_from_margin(&mut rng, margin - 1.0, 1.0));
    }
    let mut ds = Dataset::new("dna_like", x, y);
    ds.x.n_cols = p;
    ds
}

/// Sparse 0/1-ish rows with a gaussian response `y = βᵀx + ε` on a
/// planted sparse β — the least-squares analog of [`dna_like`], so the
/// gaussian family's L1 path has real support to recover.
pub fn gaussian_like(n: usize, p: usize, nnz_per_row: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed);
    let beta = draw_sparse_beta(&mut rng, p, (p / 10).max(8), 1.0);
    let mut x = CsrMatrix::new(p);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut idx = rng.sample_indices(p, nnz_per_row.min(p));
        idx.sort_unstable();
        let mut margin = 0f64;
        let entries: Vec<(u32, f32)> = idx
            .iter()
            .map(|&j| {
                let v = rng.uniform_in(0.5, 1.5) as f32;
                margin += v as f64 * beta[j] as f64;
                (j as u32, v)
            })
            .collect();
        x.push_row(&entries);
        y.push((margin + 0.25 * rng.normal()) as f32);
    }
    let mut ds = Dataset::new("gaussian_like", x, y);
    ds.x.n_cols = p;
    ds
}

/// Poisson counts with a sparse log-linear rate `μ = exp(βᵀx)`: same
/// short 0/1 rows as [`dna_like`], with small planted coefficients (and a
/// clamped margin) so the rates stay in a laptop-friendly range. Labels
/// are exact Poisson(μ) draws.
pub fn poisson_like(n: usize, p: usize, nnz_per_row: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed);
    // small |β| keeps exp(Σ β_j) tame for the default nnz_per_row
    let beta = draw_sparse_beta(&mut rng, p, (p / 10).max(8), 0.35);
    let mut x = CsrMatrix::new(p);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut idx = rng.sample_indices(p, nnz_per_row.min(p));
        idx.sort_unstable();
        let mut margin = 0f64;
        let entries: Vec<(u32, f32)> = idx
            .iter()
            .map(|&j| {
                margin += beta[j] as f64;
                (j as u32, 1.0f32)
            })
            .collect();
        x.push_row(&entries);
        y.push(poisson_draw(&mut rng, margin.clamp(-4.0, 4.0).exp()) as f32);
    }
    let mut ds = Dataset::new("poisson_like", x, y);
    ds.x.n_cols = p;
    ds
}

/// Exact Poisson(μ) sample by Knuth inversion — O(μ) uniforms per draw,
/// fine for the clamped μ ≤ e⁴ these generators produce.
fn poisson_draw(rng: &mut Xoshiro256, mu: f64) -> u64 {
    let floor = (-mu).exp();
    let mut k = 0u64;
    let mut prod = 1f64;
    loop {
        prod *= rng.uniform();
        if prod <= floor {
            return k;
        }
        k += 1;
    }
}

/// The three Table-2 analogs at the default laptop scale used by the
/// benchmark harness (EXPERIMENTS.md records these shapes).
pub fn paper_suite(seed: u64) -> Vec<Dataset> {
    vec![
        epsilon_like(8_000, 512, seed),
        webspam_like(4_000, 16_000, 60, seed + 1),
        dna_like(40_000, 400, 12, seed + 2),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_like_is_dense() {
        let ds = epsilon_like(50, 30, 1);
        assert_eq!(ds.n_examples(), 50);
        assert_eq!(ds.n_features(), 30);
        let s = ds.summary();
        assert!((s.avg_nonzeros - 30.0).abs() < 1.0); // dense rows
        assert!(s.positives > 5 && s.positives < 45); // both classes present
    }

    #[test]
    fn webspam_like_is_sparse_and_wide() {
        let ds = webspam_like(100, 5_000, 20, 2);
        let s = ds.summary();
        assert_eq!(s.n_features, 5_000);
        assert!((s.avg_nonzeros - 20.0).abs() < 1e-9);
        assert!(s.positives > 10 && s.positives < 90);
    }

    #[test]
    fn dna_like_is_short_rows() {
        let ds = dna_like(500, 80, 6, 3);
        let s = ds.summary();
        assert!((s.avg_nonzeros - 6.0).abs() < 1e-9);
        assert!(s.positives > 25, "positives = {}", s.positives);
        // imbalanced: negatives dominate
        assert!(s.positives < 250, "positives = {}", s.positives);
    }

    #[test]
    fn gaussian_like_has_continuous_two_sided_labels() {
        let ds = gaussian_like(300, 60, 8, 5);
        assert_eq!(ds.n_examples(), 300);
        assert_eq!(ds.n_features(), 60);
        let s = ds.summary();
        assert!((s.avg_nonzeros - 8.0).abs() < 1e-9);
        // continuous response: both signs, many distinct values
        assert!(ds.y.iter().any(|&v| v > 0.0) && ds.y.iter().any(|&v| v < 0.0));
        let mut uniq: Vec<i64> = ds.y.iter().map(|&v| (v as f64 * 1e4) as i64).collect();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() > 100, "only {} distinct labels", uniq.len());
    }

    #[test]
    fn poisson_like_labels_are_counts_with_signal() {
        let ds = poisson_like(500, 80, 6, 6);
        assert!(ds.y.iter().all(|&v| v >= 0.0 && v.fract() == 0.0));
        let mean = ds.y.iter().map(|&v| v as f64).sum::<f64>() / 500.0;
        assert!(mean > 0.1 && mean < 60.0, "mean count = {mean}");
        // not degenerate: more than one distinct count value
        assert!(ds.y.iter().any(|&v| v != ds.y[0]));
        // deterministic like the other generators
        assert_eq!(ds.y, poisson_like(500, 80, 6, 6).y);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = webspam_like(50, 500, 10, 9);
        let b = webspam_like(50, 500, 10, 9);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x.values, b.x.values);
        let c = webspam_like(50, 500, 10, 10);
        assert_ne!(a.x.indices, c.x.indices);
    }

    #[test]
    fn signal_is_learnable() {
        // A dataset whose labels a linear model can beat coin-flipping on:
        // check the planted margin actually predicts the labels.
        let mut rng = Xoshiro256::new(4);
        let beta = draw_sparse_beta(&mut rng, 20, 5, 1.0);
        assert_eq!(beta.iter().filter(|&&b| b != 0.0).count(), 5);
        let ds = epsilon_like(2_000, 40, 5);
        // rough sanity: classes not degenerate
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count();
        assert!(pos > 400 && pos < 1_600, "pos = {pos}");
    }
}
