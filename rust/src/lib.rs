//! # dglmnet — Distributed Coordinate Descent for L1-regularized Logistic Regression
//!
//! A production-shaped reproduction of **d-GLMNET** (Trofimov & Genkin, 2014):
//! parallel block-coordinate descent that splits *features* (not examples)
//! across machines, solves a block-diagonal GLMNET quadratic subproblem with
//! one cyclic coordinate-descent sweep per machine per iteration, AllReduces
//! the `O(n + p)` update state, and line-searches on the leader (Algorithms
//! 1–5 of the paper).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: simulated cluster (partitioning,
//!   tree AllReduce with a byte-accounted network model), leader/worker
//!   iteration driver, line search, regularization path, baselines, metrics.
//! * **L2 (python/compile)** — JAX compute graph, AOT-lowered once to HLO
//!   text under `artifacts/`.
//! * **L1 (python/compile/kernels)** — Pallas kernels: `cd_block_sweep`
//!   (the per-machine hot loop), `logistic_stats`, `line_search_grid`,
//!   `matvec_block`.
//!
//! Python never runs at training time: [`runtime`] loads the HLO text via
//! the PJRT CPU client and [`engine::XlaEngine`] drives it from the hot path.
//! [`engine::NativeEngine`] is the sparse pure-rust implementation of the
//! same math (the paper's original CPU formulation) and doubles as a
//! cross-check oracle.
//!
//! ## Quickstart — the `Estimator` API
//!
//! Every solver (d-GLMNET and the three §4.3 baselines) trains through one
//! interface: [`solver::Estimator`]. Observers stream per-iteration
//! progress and can stop the fit early:
//!
//! ```no_run
//! use dglmnet::config::TrainConfig;
//! use dglmnet::data::synth;
//! use dglmnet::solver::{DGlmnetSolver, Estimator, RecordingObserver};
//!
//! let ds = synth::epsilon_like(2_000, 200, 7).split(0.8, 7).unwrap();
//! let cfg = TrainConfig::builder().machines(4).lambda(2.0).build();
//! let mut solver = DGlmnetSolver::from_dataset(&ds.train, &cfg).unwrap();
//! let mut obs = RecordingObserver::default();
//! let fit = Estimator::fit(&mut solver, &ds.train, &mut obs).unwrap();
//! println!("nnz = {}, f = {} ({} iterations observed)",
//!          fit.nnz(), fit.objective, obs.records.len());
//! ```
//!
//! ## Stepwise control — `FitDriver`
//!
//! When you need to own the loop (checkpointing, budgets, live dashboards),
//! drive iterations yourself; stepping to convergence is bit-identical to
//! the one-shot fit:
//!
//! ```no_run
//! use dglmnet::config::TrainConfig;
//! use dglmnet::data::synth;
//! use dglmnet::solver::{DGlmnetSolver, StepOutcome};
//!
//! let ds = synth::dna_like(2_000, 200, 10, 7);
//! let cfg = TrainConfig::builder().machines(4).build();
//! let mut solver = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap();
//! let mut driver = solver.driver(0.5);
//! loop {
//!     match driver.step().unwrap() {
//!         StepOutcome::Progress(rec) => {
//!             if rec.iter % 10 == 0 {
//!                 driver.checkpoint().unwrap().save("fit.ckpt.json").unwrap();
//!             }
//!         }
//!         StepOutcome::Finished { .. } => break,
//!     }
//! }
//! let fit = driver.finish();
//! // later, even in a fresh process:
//! //   let ck = dglmnet::solver::Checkpoint::load("fit.ckpt.json")?;
//! //   let mut driver = solver.driver_from_checkpoint(&ck)?;
//! println!("converged = {} at f = {}", fit.converged, fit.objective);
//! ```
//!
//! ## Run from a sharded store — the out-of-core data plane
//!
//! The paper's premise is a dataset too large for any one machine. The
//! [`data::store::ShardStore`] makes that physical: `dglmnet shard` (or
//! [`data::shuffle::shuffle_to_store`], the external Map/Reduce shuffle)
//! writes one by-feature shard file per machine plus a JSON manifest and a
//! small `y.bin`. At fit time every worker self-loads **only its own**
//! shard file — in-process threads and remote `dglmnet worker --store`
//! processes alike — and the leader holds just `y`, β and the margins:
//! λ_max is a distributed reduce of per-shard gradients, line search and
//! loss are O(n) functions of the margins, so **no process ever
//! materializes the whole design matrix**. Trajectories are bit-identical
//! to the in-memory path (which is itself a thin adapter that writes a
//! temp store).
//!
//! ```no_run
//! use dglmnet::config::TrainConfig;
//! use dglmnet::data::store::ShardStore;
//! use dglmnet::solver::DGlmnetSolver;
//!
//! // preprocessing (once): `dglmnet shard --kind webspam --machines 4 --out store/`
//! let store = ShardStore::open("store").unwrap();
//! let cfg = TrainConfig::builder().machines(store.machines()).lambda(0.5).build();
//! let mut solver = DGlmnetSolver::from_store(&store, &cfg).unwrap();
//! let fit = solver.fit_lambda(0.5).unwrap();
//! println!("f = {} with a leader that never held X", fit.objective);
//! ```
//!
//! Over sockets the leader validates every `Join` handshake against the
//! manifest's shard identities (machine index, dataset shape, owned-column
//! checksum), so a worker holding a differently-partitioned or
//! wrong-shaped store is rejected before it can corrupt a fit. Note the
//! handshake checks *shape* identity, not content: a re-shard that keeps
//! the same partition but different values is indistinguishable at join
//! time — deployments must version store directories (each shard file's
//! payload checksum in the manifest makes two stores easy to diff).
//!
//! ## Self-healing clusters — supervision, failover, elasticity
//!
//! Long fits on real clusters lose workers. Four `[cluster]` knobs turn
//! the leader into a supervisor:
//!
//! * `supervise` (`--supervise`) — on a failed iteration, probe every
//!   link with a `Ping` heartbeat, roll back to the last in-memory
//!   recovery checkpoint, re-admit a replacement for each dead worker
//!   (socket replacements connect to the *same* listening address and are
//!   validated against the shard identity they must hold; in-process
//!   workers respawn from the store), and resume the fit.
//! * `heartbeat_timeout_secs` — how long a probed worker gets to answer
//!   the `Ping` before it is declared dead (default 5).
//! * `recv_timeout_secs` — a per-recv socket deadline so a wedged (alive
//!   but silent) peer becomes a clean error instead of a hang
//!   (default 0 = wait forever).
//! * `recovery_checkpoint_every` — refresh cadence for the in-memory
//!   recovery checkpoint (default 1 = every iteration).
//!
//! The contract is exact: a recovered fit reproduces the undisturbed
//! run's final β, objective trajectory, and charged comm ledger **bit for
//! bit** — recovery traffic is metered separately
//! ([`solver::DGlmnetSolver::recovery_comm_bytes`]) and the failed
//! iteration's partial charges are rolled back with the state
//! (`tests/failover.rs` pins all of it, with `cluster::FaultyTransport`
//! injecting the faults). Between λ steps the cluster is also elastic:
//! [`solver::DGlmnetSolver::elastic_resize`] re-partitions the `p`
//! features over `M ± 1` machines by resharding the store in place and
//! warm-starting from the current β — bit-identical to a fresh fit at the
//! new machine count warm-started from the same β.
//!
//! ## Scaling out — the peer-to-peer tree topology
//!
//! By default a socket cluster is a **star**: every worker ships its raw
//! sweep result to the leader, which runs the deterministic pairwise
//! merge bracket itself — simple, but the leader's bandwidth bill grows
//! linearly with the worker count M. `[cluster] topology = "tree"`
//! (`--topology tree` on **both** `train` and every `worker`) moves the
//! bracket's edges onto direct worker↔worker links: each worker folds its
//! bracket children's payloads into its own and forwards one pre-merged
//! message to its parent, so the leader's per-iteration data traffic is
//! **O(1) in M** — one `Sweep` down and one merged `TreeSwept` up, on the
//! root edge only (measure it: `leader_wire_bytes_sent/recv` in the train
//! output, next to `leader_peak_rss_bytes`).
//!
//! ```text
//! dglmnet shard --kind webspam --machines 8 --out store/
//! dglmnet train  --store store/ --workers 8 --transport socket --topology tree
//! dglmnet worker --store store/ --machine <k> --connect 127.0.0.1:4801 --topology tree
//! ```
//!
//! When to pick it: many workers, or a leader whose NIC (not the workers'
//! sweeps) is the iteration bottleneck. For small M the star is just as
//! fast and has fewer moving parts. The trajectory is **bit-identical**
//! either way — same merge bracket, exact f64 intermediates on interior
//! edges, the same f32 rounding at the bracket root — and so is the
//! charged comm ledger, which the leader replays from the nnz metadata
//! the merge carries up (see [`cluster`]'s topology matrix). Constraints:
//! tree requires the default lossless wire (`wire_f16_*` is rejected at
//! config validation), and `topology = tree` with the in-process
//! transport is accepted but stays leader-staged (there is no wire to
//! save). Supervision composes: a dead worker's recovery re-issues the
//! topology to every worker under a fresh epoch, tearing down and
//! rebuilding the peer links before the fit resumes.
//!
//! ## Tuning sweep speed — kernels and threads
//!
//! The per-iteration hot loop is the worker CD sweep, and
//! [`engine::NativeEngine`] offers two orthogonal `[engine]` knobs for it
//! (see the [`engine`] module docs for the full kernel matrix):
//!
//! * `naive_sweep` (`--naive-sweep`) — pick the sweep *kernel*. The
//!   default is the covariance-update kernel ([`engine::cov`]): one light
//!   O(nnz) correlation pass prices every coordinate, inactive columns are
//!   skipped without touching their residuals, and active-set Gram columns
//!   are cached across sweeps. The flag swaps back to the exact naive
//!   residual-update loop — the ablation escape hatch, bit-identical to
//!   the pre-kernel trajectories. The two kernels agree to quantization
//!   tolerance (~1e-3 relative), not bitwise; `tests/engine_equivalence.rs`
//!   pins the contract.
//! * `sweep_threads` (`--sweep-threads`, default 1, `0` = host
//!   parallelism) — sweep a worker's columns on T scoped threads. The
//!   sub-partition mirrors the machine partition strategy and the
//!   per-thread results merge through the same deterministic pairwise
//!   tree the AllReduce uses, so a worker sweeping on T threads is **bit
//!   for bit** the trajectory of T single-threaded machines — threads
//!   change wall-clock, never results. Requests wider than the narrowest
//!   shard fail fast at config validation.
//!
//! ```no_run
//! use dglmnet::config::TrainConfig;
//! use dglmnet::data::synth;
//! use dglmnet::solver::DGlmnetSolver;
//!
//! let ds = synth::webspam_like(4_000, 10_000, 40, 7);
//! let cfg = TrainConfig::builder()
//!     .machines(4)
//!     .sweep_threads(0) // auto: use what the host offers
//!     .lambda(0.5)
//!     .build();
//! let fit = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap().fit(None).unwrap();
//! println!("f = {}", fit.objective);
//! ```
//!
//! `cargo bench --bench bench_ablation -- kernels` measures all four
//! kernel × threading combinations on one shard and emits
//! `BENCH_ablation.json`; CI gates the speedup ratios so the win cannot
//! silently erode.
//!
//! ## Fit a GLM / elastic net — the `family` and `alpha` knobs
//!
//! The solver is loss-generic: the whole distributed stack touches the loss
//! only through the [`family::GlmFamily`] seam (per-example (w, z) working
//! stats, loss sums, the λ_max gradient scale and the prediction link), so
//! `[train] family` / `--family` swaps the problem being solved without
//! changing a single code path. `logistic` is the default and bit-identical
//! to the historical hardcoded behavior; `gaussian` (least squares) and
//! `poisson` (log-link counts) ride the same sharded store, socket cluster,
//! checkpoints, failover and serve layers. `[train] alpha` / `--alpha`
//! (∈ (0, 1], default 1.0 = pure L1) mixes in a ridge term glmnet-style:
//! the penalty becomes `λ(α‖β‖₁ + (1−α)/2·‖β‖₂²)`, folded into every
//! per-coordinate soft-threshold/denominator.
//!
//! ```no_run
//! use dglmnet::config::TrainConfig;
//! use dglmnet::data::synth;
//! use dglmnet::family::FamilyKind;
//! use dglmnet::solver::DGlmnetSolver;
//!
//! // Poisson counts with a sparse log-linear rate, elastic-net penalty
//! let ds = synth::poisson_like(4_000, 300, 12, 7);
//! let cfg = TrainConfig::builder()
//!     .machines(3)
//!     .family(FamilyKind::Poisson)
//!     .enet_alpha(0.5) // half L1, half ridge
//!     .lambda(0.05)
//!     .build();
//! let fit = DGlmnetSolver::from_dataset(&ds, &cfg).unwrap().fit(None).unwrap();
//! println!("nnz = {}, deviance-minimizing rate model at f = {}", fit.nnz(), fit.objective);
//! // predictions come back on the mean scale of the family:
//! //   dglmnet predict emits exp(margin) for poisson, the margin itself for
//! //   gaussian, and the probability for logistic — and artifacts record
//! //   family + alpha, so serve/predict refuse a mismatched model.
//! ```
//!
//! ## Serve a trained model — `dglmnet serve`
//!
//! The paper's models exist to answer live traffic; the [`serve`]
//! subsystem closes the loop. Train and export a checksummed artifact,
//! serve it over HTTP, and hot-swap it by rewriting the file — no
//! restart, no dropped requests:
//!
//! ```text
//! # 1. train → artifact (shape, λ, solver and an FNV checksum embedded)
//! dglmnet train --kind dna --examples 2000 --features 200 --lambda 0.5 \
//!     --model-out model.artifact
//!
//! # 2. serve it (prints "serve_ready addr=... model_version=...")
//! dglmnet serve --model model.artifact --listen 127.0.0.1:4890
//!
//! # 3. score one sparse example
//! curl -s http://127.0.0.1:4890/predict -d \
//!     '{"indices":[3,17,42],"values":[1,1,1]}'
//! #   → {"margin":-0.25,"model_version":"9f…","proba":0.4378…}
//!
//! # 4. batches stream back as ndjson, one line per example
//! curl -s http://127.0.0.1:4890/predict_batch -d \
//!     '{"examples":[{"indices":[3],"values":[1]},{"indices":[],"values":[]}]}'
//!
//! # 5. hot-swap: retrain at a new λ and atomically replace the file;
//! #    the watcher validates the new artifact and swaps it in — watch
//! #    model_version change on /healthz while traffic keeps flowing
//! dglmnet train --kind dna --examples 2000 --features 200 --lambda 0.25 \
//!     --model-out model.artifact.tmp && mv model.artifact.tmp model.artifact
//! ```
//!
//! Served predictions are **bit-identical** to offline `dglmnet predict`
//! and to the training cluster's own margins: all three score through the
//! shared [`data::sparse::dot_margin`] kernel. A corrupt or half-written
//! artifact never reaches the slot — the loader's checksum rejects it,
//! a warning is logged, and the old model keeps serving.

pub mod baselines;
pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod data;
pub mod engine;
pub mod error;
pub mod family;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod util;

pub use error::{DlrError, Result};
