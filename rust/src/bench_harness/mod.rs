//! Micro/meso benchmark harness (no `criterion` in the vendored set):
//! warmup + timed samples, robust stats, aligned reporting, and the
//! estimator-generic [`bench_fit`] that times any solver end-to-end
//! through `&mut dyn Estimator`.

use std::time::Instant;

use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::solver::dglmnet::FitResult;
use crate::solver::estimator::{fit_cold, Estimator, NoopObserver};

/// Summary statistics over the timed samples (seconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<f64>,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
}

impl BenchStats {
    fn from_samples(name: String, mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n;
        let median = if samples.len() % 2 == 1 {
            samples[samples.len() / 2]
        } else {
            0.5 * (samples[samples.len() / 2 - 1] + samples[samples.len() / 2])
        };
        Self {
            name,
            mean,
            median,
            stddev: var.sqrt(),
            min: samples[0],
            max: *samples.last().unwrap(),
            samples,
        }
    }

    /// `name  median ± stddev  (min … max, k samples)`
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:>10}  ({} … {}, {} samples)",
            self.name,
            fmt_secs(self.median),
            fmt_secs(self.stddev),
            fmt_secs(self.min),
            fmt_secs(self.max),
            self.samples.len()
        )
    }
}

/// Human-scale duration formatting.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` with `warmup` unmeasured and `samples` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    BenchStats::from_samples(name.to_string(), times)
}

/// Time cold fits of any [`Estimator`] on `ds`: `warmup` unmeasured +
/// `samples` measured reset-and-fit runs, identical protocol for every
/// solver (no solver-specific branches). Returns the last fit's result
/// alongside the timing stats.
pub fn bench_fit(
    name: &str,
    est: &mut dyn Estimator,
    ds: &Dataset,
    warmup: usize,
    samples: usize,
) -> Result<(FitResult, BenchStats)> {
    for _ in 0..warmup {
        fit_cold(est, ds, &mut NoopObserver)?;
    }
    let mut times = Vec::with_capacity(samples.max(1));
    let mut last = None;
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        last = Some(fit_cold(est, ds, &mut NoopObserver)?);
        times.push(t0.elapsed().as_secs_f64());
    }
    let fit = last.expect("at least one sample runs");
    Ok((fit, BenchStats::from_samples(name.to_string(), times)))
}

/// Measure a one-shot closure (end-to-end runs too slow to repeat).
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, BenchStats) {
    let t0 = Instant::now();
    let out = f();
    let stats = BenchStats::from_samples(name.to_string(), vec![t0.elapsed().as_secs_f64()]);
    (out, stats)
}

/// Print a section header in the bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let s = BenchStats::from_samples("t".into(), vec![3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_requested_samples() {
        let mut count = 0usize;
        let s = bench("inc", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.samples.len(), 5);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_secs(2.5).ends_with(" s"));
        assert!(fmt_secs(2.5e-3).ends_with(" ms"));
        assert!(fmt_secs(2.5e-6).ends_with(" µs"));
        assert!(fmt_secs(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, s) = bench_once("x", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(s.samples.len(), 1);
    }

    #[test]
    fn bench_fit_times_any_estimator() {
        use crate::baselines::shotgun::ShotgunEstimator;
        use crate::data::synth;
        let ds = synth::dna_like(150, 15, 3, 91);
        let mut est = ShotgunEstimator::new(0.5, 1, 5, 1);
        let (fit, stats) = bench_fit("shotgun", &mut est, &ds, 1, 2).unwrap();
        assert_eq!(fit.iterations, 5);
        assert_eq!(stats.samples.len(), 2);
        assert!(fit.objective.is_finite());
    }
}
