//! Evaluation metrics. Figure 1's y-axis is **area under the
//! Precision-Recall curve**; we also provide ROC-AUC, log-loss and accuracy
//! for the extended reports, plus family-generic [`deviance`] /
//! [`null_deviance`] for non-logistic GLM fits (the ranking metrics and
//! [`mean_logloss`] assume logistic ±1 labels).

use crate::family::FamilyKind;

/// Area under the precision-recall curve, computed exactly from the step
/// curve over the ranked scores (ties handled as a block, trapezoid between
/// distinct-score groups — the standard sklearn-style `auc(recall, precision)`
/// on the PR points would interpolate optimistically; we use the
/// conservative step integration a.k.a. average precision by mass).
pub fn auprc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let total_pos = labels.iter().filter(|&&y| y > 0.0).count();
    if total_pos == 0 || total_pos == labels.len() {
        return if total_pos == 0 { 0.0 } else { 1.0 };
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());

    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut area = 0f64;
    let mut prev_recall = 0f64;
    let mut i = 0usize;
    while i < order.len() {
        // consume the whole tie-block at this score
        let s = scores[order[i]];
        let mut j = i;
        while j < order.len() && scores[order[j]] == s {
            if labels[order[j]] > 0.0 {
                tp += 1;
            } else {
                fp += 1;
            }
            j += 1;
        }
        let recall = tp as f64 / total_pos as f64;
        let precision = tp as f64 / (tp + fp) as f64;
        area += (recall - prev_recall) * precision;
        prev_recall = recall;
        i = j;
    }
    area
}

/// ROC-AUC via the rank statistic (ties get midranks).
pub fn roc_auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&y| y > 0.0).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    let mut rank_sum_pos = 0f64;
    let mut i = 0usize;
    while i < order.len() {
        let s = scores[order[i]];
        let mut j = i;
        while j < order.len() && scores[order[j]] == s {
            j += 1;
        }
        // midrank of the tie block (ranks are 1-based)
        let midrank = (i + 1 + j) as f64 / 2.0;
        for &k in &order[i..j] {
            if labels[k] > 0.0 {
                rank_sum_pos += midrank;
            }
        }
        i = j;
    }
    (rank_sum_pos - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0) / (n_pos as f64 * n_neg as f64)
}

/// Mean **logistic** loss log(1 + exp(-y m)) over margins. Defined only
/// for logistic fits (labels in {-1, +1}); for gaussian/poisson models
/// report [`deviance`] instead.
pub fn mean_logloss(margins: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(margins.len(), labels.len());
    if margins.is_empty() {
        return 0.0;
    }
    crate::util::math::logloss_sum(margins, labels) / margins.len() as f64
}

/// Total residual deviance Σᵢ d(yᵢ, μᵢ) under a GLM family, with means
/// μᵢ = g⁻¹(mᵢ) from the margins via the family's inverse link. The
/// family-generic goodness-of-fit number (for logistic it is twice the
/// total log-loss up to the deviance clamp).
pub fn deviance(margins: &[f32], labels: &[f32], family: FamilyKind) -> f64 {
    assert_eq!(margins.len(), labels.len());
    let fam = family.family();
    margins
        .iter()
        .zip(labels)
        .map(|(&m, &y)| fam.unit_deviance(y as f64, fam.mean(m as f64)))
        .sum()
}

/// Null (intercept-only) deviance: Σᵢ d(yᵢ, μ̄) at the family's mean
/// response μ̄ — the denominator of explained-deviance ratios
/// (`1 - deviance/null_deviance` is the GLM analog of R²).
pub fn null_deviance(labels: &[f32], family: FamilyKind) -> f64 {
    let fam = family.family();
    let mu = fam.null_mean(labels);
    labels.iter().map(|&y| fam.unit_deviance(y as f64, mu)).sum()
}

/// 0/1 accuracy at threshold 0.
pub fn accuracy(margins: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(margins.len(), labels.len());
    if margins.is_empty() {
        return 0.0;
    }
    let correct = margins
        .iter()
        .zip(labels)
        .filter(|(&m, &y)| (m >= 0.0) == (y > 0.0))
        .count();
    correct as f64 / margins.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auprc_perfect_ranking_is_one() {
        let scores = [0.9f32, 0.8, 0.2, 0.1];
        let labels = [1f32, 1.0, -1.0, -1.0];
        assert!((auprc(&scores, &labels) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auprc_inverted_ranking_is_low() {
        let scores = [0.1f32, 0.2, 0.8, 0.9];
        let labels = [1f32, 1.0, -1.0, -1.0];
        let v = auprc(&scores, &labels);
        assert!(v < 0.5, "v = {v}");
    }

    #[test]
    fn auprc_known_value() {
        // ranking: +, -, +, - => points: r=.5 p=1; r=.5 p=.5; r=1 p=2/3; r=1 p=.5
        // step areas: .5*1 + 0 + .5*(2/3) + 0 = 0.8333...
        let scores = [0.9f32, 0.8, 0.7, 0.6];
        let labels = [1f32, -1.0, 1.0, -1.0];
        assert!((auprc(&scores, &labels) - (0.5 + 1.0 / 3.0)).abs() < 1e-9);
    }

    #[test]
    fn auprc_all_ties_equals_prevalence() {
        let scores = [0.5f32; 10];
        let labels: Vec<f32> = (0..10).map(|i| if i < 3 { 1.0 } else { -1.0 }).collect();
        assert!((auprc(&scores, &labels) - 0.3).abs() < 1e-9);
    }

    #[test]
    fn roc_auc_known_values() {
        let scores = [0.9f32, 0.8, 0.2, 0.1];
        let labels = [1f32, 1.0, -1.0, -1.0];
        assert!((roc_auc(&scores, &labels) - 1.0).abs() < 1e-12);
        let labels_inv = [-1f32, -1.0, 1.0, 1.0];
        assert!((roc_auc(&scores, &labels_inv)).abs() < 1e-12);
        let scores_tied = [0.5f32; 4];
        assert!((roc_auc(&scores_tied, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn accuracy_and_logloss() {
        let margins = [2.0f32, -3.0, 0.5, -0.5];
        let labels = [1f32, -1.0, -1.0, -1.0];
        assert!((accuracy(&margins, &labels) - 0.75).abs() < 1e-12);
        assert!(mean_logloss(&margins, &labels) > 0.0);
        // zero margins => ln 2
        let z = [0f32; 3];
        let l = [1f32, -1.0, 1.0];
        assert!((mean_logloss(&z, &l) - (2f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn deviance_per_family() {
        // logistic at zero margins: each example contributes −2 ln ½
        let m = [0f32; 4];
        let y = [1f32, -1.0, 1.0, -1.0];
        let d = deviance(&m, &y, FamilyKind::Logistic);
        assert!((d - 8.0 * (2f64).ln()).abs() < 1e-9, "{d}");
        // ... which is exactly the null deviance at prevalence ½
        assert!((null_deviance(&y, FamilyKind::Logistic) - d).abs() < 1e-9);
        // gaussian: squared residuals
        let m = [1.0f32, 2.0];
        let y = [3.0f32, 2.0];
        assert!((deviance(&m, &y, FamilyKind::Gaussian) - 4.0).abs() < 1e-12);
        // poisson: ~zero at a perfect fit (margin = ln y), positive off it
        let m = [(3f32).ln(), (1f32).ln()];
        let y = [3f32, 1.0];
        assert!(deviance(&m, &y, FamilyKind::Poisson).abs() < 1e-6);
        assert!(null_deviance(&y, FamilyKind::Poisson) > 0.0);
    }

    #[test]
    fn degenerate_label_sets() {
        assert_eq!(auprc(&[0.5, 0.4], &[-1.0, -1.0]), 0.0);
        assert_eq!(auprc(&[0.5, 0.4], &[1.0, 1.0]), 1.0);
        assert_eq!(roc_auc(&[0.5, 0.4], &[1.0, 1.0]), 0.5);
    }
}
