//! Disk-streaming subproblem engine — the paper's §3 deployment mode:
//! "This format of input file allows to read training dataset sequentially
//! from the disk and make coordinate updates (6) while solving sub-problem
//! (9). Our program stores into the RAM only vectors: y, (exp(βᵀxᵢ)),
//! (Δβᵀxᵢ), β, Δβ. Thus the total memory footprint of our implementation
//! is O(n + p)."
//!
//! Each sweep re-reads the shard's Table-1 by-feature file front to back,
//! holding one feature's postings at a time — the O(n + p) RAM contract.
//! Slower than the in-RAM engine on small data (the paper concedes the
//! same), but scales past RAM; `bench_ablation -- comm` reports the ratio.

use std::io::{BufRead, BufReader, Seek, SeekFrom};
use std::path::PathBuf;
use std::time::Instant;

use crate::data::shuffle::FeatureShard;
use crate::engine::{SubproblemEngine, SweepResult};
use crate::error::{DlrError, Result};
use crate::util::math::soft_threshold;

/// Sparse CD engine that streams its shard from a by-feature file.
pub struct StreamingEngine {
    path: PathBuf,
    n: usize,
    p_local: usize,
    /// O(n) working residual — the only example-indexed state.
    r: Vec<f64>,
    /// reusable postings buffer (one feature at a time)
    postings: Vec<(u32, f32)>,
}

impl StreamingEngine {
    /// Write `shard` to `path` in the paper's Table-1 format and stream
    /// from it afterwards. (Production would receive the file from the
    /// Map/Reduce transformation directly.)
    pub fn create(shard: &FeatureShard, n: usize, path: PathBuf) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        crate::data::libsvm::write_by_feature(&shard.csc, std::fs::File::create(&path)?)?;
        Ok(Self {
            path,
            n,
            p_local: shard.csc.n_cols,
            r: vec![0f64; n],
            postings: Vec::new(),
        })
    }

    /// Open an existing by-feature file (`p_local` features over `n`
    /// examples).
    pub fn open(path: PathBuf, n: usize, p_local: usize) -> Result<Self> {
        if !path.exists() {
            return Err(DlrError::Data(format!("{} does not exist", path.display())));
        }
        Ok(Self { path, n, p_local, r: vec![0f64; n], postings: Vec::new() })
    }

    fn parse_line(&mut self, line: &str) -> Result<usize> {
        self.postings.clear();
        let mut it = line.split_whitespace();
        let j: usize = it
            .next()
            .ok_or_else(|| DlrError::parse("by-feature", "empty line"))?
            .parse()
            .map_err(|_| DlrError::parse("by-feature", "bad feature id"))?;
        for tok in it {
            let inner = tok
                .strip_prefix('(')
                .and_then(|t| t.strip_suffix(')'))
                .ok_or_else(|| DlrError::parse("by-feature", "bad pair"))?;
            let (row, val) = inner
                .split_once(',')
                .ok_or_else(|| DlrError::parse("by-feature", "bad pair"))?;
            self.postings.push((
                row.parse().map_err(|_| DlrError::parse("by-feature", "bad row"))?,
                val.parse().map_err(|_| DlrError::parse("by-feature", "bad val"))?,
            ));
        }
        Ok(j)
    }
}

impl SubproblemEngine for StreamingEngine {
    fn sweep(
        &mut self,
        w: &[f32],
        z: &[f32],
        beta_local: &[f32],
        lam: f32,
        nu: f32,
        l2: f32,
        out: &mut SweepResult,
    ) -> Result<()> {
        let t0 = Instant::now();
        let n = self.n;
        debug_assert_eq!(beta_local.len(), self.p_local);
        for i in 0..n {
            self.r[i] = z[i] as f64;
        }
        let (lam, nu, l2) = (lam as f64, nu as f64, l2 as f64);
        out.delta_local.clear(self.p_local);

        let mut file = BufReader::new(std::fs::File::open(&self.path)?);
        file.seek(SeekFrom::Start(0))?;
        let mut line = String::new();
        loop {
            line.clear();
            if file.read_line(&mut line)? == 0 {
                break;
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let j = self.parse_line(trimmed)?;
            if j >= self.p_local {
                return Err(DlrError::Data(format!("feature {j} out of shard range")));
            }
            if self.postings.is_empty() {
                continue;
            }
            // coordinate update (6), identical to NativeEngine
            let mut a = nu;
            let mut wrx = 0f64;
            for &(i, v) in &self.postings {
                let wi = w[i as usize] as f64;
                let x = v as f64;
                a += wi * x * x;
                wrx += wi * self.r[i as usize] * x;
            }
            let bj = beta_local[j] as f64;
            let c = wrx + bj * a;
            let s = soft_threshold(c, lam) / (a + l2);
            let step = s - bj;
            if step != 0.0 {
                // file order is by feature id, but tolerate unordered files:
                // entries are re-sorted below if needed
                out.delta_local.indices.push(j as u32);
                out.delta_local.values.push(step as f32);
                for &(i, v) in &self.postings {
                    self.r[i as usize] -= step * v as f64;
                }
            }
        }
        out.delta_local.ensure_sorted();
        out.dmargins.clear(n);
        for i in 0..n {
            let zi = z[i] as f64;
            if self.r[i] != zi {
                out.dmargins.push(i as u32, (zi - self.r[i]) as f32);
            }
        }
        out.compute_secs = t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn lambda_max_local(&mut self, targets: &[f32], scale: f64) -> Result<f64> {
        debug_assert_eq!(targets.len(), self.n);
        let mut best = 0f64;
        let mut file = BufReader::new(std::fs::File::open(&self.path)?);
        let mut line = String::new();
        loop {
            line.clear();
            if file.read_line(&mut line)? == 0 {
                break;
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let j = self.parse_line(trimmed)?;
            if j >= self.p_local {
                return Err(DlrError::Data(format!("feature {j} out of shard range")));
            }
            let mut g = 0f64;
            for &(i, v) in &self.postings {
                g += v as f64 * targets[i as usize] as f64;
            }
            best = best.max(g.abs() * scale);
        }
        Ok(best)
    }

    fn margins_into(
        &mut self,
        beta_local: &[f32],
        out: &mut crate::data::sparse::SparseVec,
    ) -> Result<()> {
        debug_assert_eq!(beta_local.len(), self.p_local);
        let mut acc = vec![0f64; self.n];
        let mut file = BufReader::new(std::fs::File::open(&self.path)?);
        let mut line = String::new();
        loop {
            line.clear();
            if file.read_line(&mut line)? == 0 {
                break;
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let j = self.parse_line(trimmed)?;
            if j >= self.p_local {
                return Err(DlrError::Data(format!("feature {j} out of shard range")));
            }
            let b = beta_local[j] as f64;
            if b == 0.0 {
                continue;
            }
            for &(i, v) in &self.postings {
                acc[i as usize] += b * v as f64;
            }
        }
        out.clear(self.n);
        for (i, &v) in acc.iter().enumerate() {
            if v != 0.0 {
                out.push(i as u32, v as f32);
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "streaming"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::{FeaturePartition, PartitionStrategy};
    use crate::data::shuffle::shard_in_memory;
    use crate::data::synth;
    use crate::engine::NativeEngine;
    use crate::util::math::working_stats;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dglmnet_stream_{}_{name}", std::process::id()))
    }

    #[test]
    fn streaming_matches_in_memory_engine() {
        let ds = synth::webspam_like(200, 800, 12, 91);
        let part =
            FeaturePartition::build(PartitionStrategy::RoundRobin, 800, 1, None);
        let shard = shard_in_memory(&ds.x, &part).remove(0);
        let n = ds.n_examples();
        let path = tmp("match.byfeature");
        let mut se = StreamingEngine::create(&shard, n, path.clone()).unwrap();
        let mut ne = NativeEngine::new(shard, n);
        let (w, z): (Vec<f32>, Vec<f32>) = ds
            .y
            .iter()
            .map(|&y| {
                let (w, z) = working_stats(y as f64, 0.0);
                (w as f32, z as f32)
            })
            .unzip();
        let beta = vec![0f32; 800];
        let rs = se.sweep_alloc(&w, &z, &beta, 0.3, 1e-6).unwrap();
        let rn = ne.sweep_alloc(&w, &z, &beta, 0.3, 1e-6).unwrap();
        let (ds_s, ds_n) = (rs.delta_local.to_dense(), rn.delta_local.to_dense());
        for j in 0..800 {
            assert!((ds_s[j] - ds_n[j]).abs() < 1e-4, "delta[{j}]");
        }
        let (dm_s, dm_n) = (rs.dmargins.to_dense(), rn.dmargins.to_dense());
        for i in 0..n {
            assert!((dm_s[i] - dm_n[i]).abs() < 1e-4, "dm[{i}]");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn repeated_sweeps_reread_cleanly() {
        let ds = synth::dna_like(150, 40, 4, 92);
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 40, 1, None);
        let shard = shard_in_memory(&ds.x, &part).remove(0);
        let path = tmp("reread.byfeature");
        let mut se = StreamingEngine::create(&shard, 150, path.clone()).unwrap();
        let (w, z): (Vec<f32>, Vec<f32>) = ds
            .y
            .iter()
            .map(|&y| {
                let (w, z) = working_stats(y as f64, 0.0);
                (w as f32, z as f32)
            })
            .unzip();
        let a = se.sweep_alloc(&w, &z, &vec![0f32; 40], 0.1, 1e-6).unwrap();
        let b = se.sweep_alloc(&w, &z, &vec![0f32; 40], 0.1, 1e-6).unwrap();
        assert_eq!(a.delta_local, b.delta_local); // stateless across sweeps
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn open_missing_file_errors() {
        assert!(StreamingEngine::open(tmp("missing"), 10, 5).is_err());
    }
}
