//! Subproblem engines: the per-machine solve of paper eq. (9) / Alg 2.
//!
//! * [`XlaEngine`] (feature `xla`) — the AOT-Pallas hot path: the worker's
//!   feature shard is densified once into (N, B) tiles and every sweep
//!   executes the AOT `cd_block_sweep` through PJRT.
//! * [`NativeEngine`] — the paper's original sparse CPU formulation in pure
//!   rust; the default engine and the cross-check oracle for the XLA path.
//! * [`StreamingEngine`] — the paper's O(n + p)-RAM disk-streaming mode.
//!
//! All engines consume the same inputs and must produce the same update
//! (tested in `rust/tests/engine_equivalence.rs`).
//!
//! ## The native sweep-kernel matrix
//!
//! [`NativeEngine`] runs one of two kernels, on one or more threads — a
//! [`SweepKernel`] picked by `[engine] naive_sweep` / `sweep_threads`
//! (CLI `--naive-sweep` / `--sweep-threads`):
//!
//! | kernel              | per-sweep cost          | when it wins            |
//! |---------------------|-------------------------|-------------------------|
//! | naive (`--naive-sweep`) | O(nnz) heavy pass   | exact-ablation baseline |
//! | covariance (default)    | O(nnz) light pass + O(B·act) corrections | warm active set, stable weights |
//!
//! The **naive** kernel is the seed's loop kept byte-for-byte: per column one
//! fused pass computes `Σ w x²` and `Σ w r x` against the residual updated
//! Gauss-Seidel-style within the sweep. `--naive-sweep --sweep-threads 1`
//! therefore reproduces historical trajectories bit-for-bit.
//!
//! The **covariance** kernel ([`cov`]) restates the same Gauss-Seidel
//! recurrence through cached Gram columns (`Xᵀdiag(w̄)X` restricted to the
//! features that actually step): the per-column pass degenerates to a single
//! multiply-add stream against the sweep-start residual, column denominators
//! come from a weight-epoch cache, and earlier steps reach later columns via
//! O(row-nnz) Gram corrections instead of residual re-reads. Weights are
//! quantized (`w̄`) so the caches are a *pure function of the current sweep
//! inputs* — a resumed/failed-over engine with cold caches produces
//! bit-identical results to a warm one. Equivalence to the naive kernel is a
//! tolerance contract (ported from `python/tests/test_cov_kernel.py`), not a
//! bitwise one.
//!
//! **Threading** (`sweep_threads = T`, 0 = auto): the shard's columns are
//! sub-partitioned into T blocks (same strategy as the machine partition) and
//! swept Jacobi-style against the shared sweep-start residual — exactly the
//! math d-GLMNET already does *across machines* — then the per-thread Δm
//! accumulators combine through the same deterministic pairwise-f64 merge the
//! AllReduce tree uses. A T-threaded worker is pinned bit-identical to T
//! single-threaded machines under the matching sub-partition; per-thread Δm /
//! touched scratch trades O(T·n) memory for the parallelism.
//!
//! ## Zero-allocation sweep contract
//!
//! [`SubproblemEngine::sweep`] writes into a caller-owned [`SweepResult`]
//! whose [`SparseVec`] buffers are reused across iterations (the worker pool
//! round-trips them through its channels), so the steady-state hot path
//! performs no per-iteration heap allocation. Results are *sparse*: only the
//! coordinates the sweep actually moved are materialized — exactly what the
//! `cluster::comm` collectives ship (or, for `dmargins` under the
//! allgather-Δβ strategy, recombine locally without touching the wire).

pub mod cov;
pub mod native;
pub mod streaming;
#[cfg(feature = "xla")]
pub mod xla_engine;

pub use native::NativeEngine;
pub use streaming::StreamingEngine;
#[cfg(feature = "xla")]
pub use xla_engine::XlaEngine;

use crate::cluster::partition::PartitionStrategy;
use crate::config::{EngineKind, TrainConfig};
use crate::data::shuffle::FeatureShard;
use crate::data::sparse::SparseVec;
use crate::error::Result;

/// Which sweep kernel a [`NativeEngine`] runs, and on how many threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SweepKernel {
    /// `true` = the seed's exact naive loop (`--naive-sweep`); `false` = the
    /// covariance-update kernel ([`cov`]).
    pub naive: bool,
    /// Sweep threads (≥ 1; `resolve_sweep_threads` has already expanded 0).
    pub threads: usize,
    /// Strategy for the intra-worker column sub-partition when `threads > 1`
    /// — kept equal to the machine partition strategy so a T-threaded worker
    /// matches T machines.
    pub partition: PartitionStrategy,
}

impl Default for SweepKernel {
    /// The seed's exact behavior: naive kernel, single thread.
    fn default() -> Self {
        Self { naive: true, threads: 1, partition: PartitionStrategy::RoundRobin }
    }
}

impl SweepKernel {
    /// The kernel `cfg` asks for, with `sweep_threads = 0` resolved to the
    /// host's available parallelism.
    pub fn from_config(cfg: &TrainConfig) -> Self {
        Self {
            naive: cfg.naive_sweep,
            threads: resolve_sweep_threads(cfg.sweep_threads),
            partition: cfg.partition,
        }
    }

    /// Clamp the thread count so every sweep thread owns ≥ 1 column (the
    /// auto path; explicit over-wide counts are rejected earlier with
    /// [`TrainConfig::validate_sweep_threads_for`]).
    pub fn clamped_to(mut self, shard_cols: usize) -> Self {
        self.threads = self.threads.min(shard_cols.max(1));
        self
    }

    /// `"naive"` or `"cov"` — what `dglmnet train` prints next to the
    /// resolved thread count.
    pub fn kernel_name(&self) -> &'static str {
        if self.naive { "naive" } else { "cov" }
    }
}

/// Expand `[engine] sweep_threads` (`0` = auto) to a concrete thread count.
pub fn resolve_sweep_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Result of one machine-local subproblem solve (one cyclic CD sweep).
/// Owned by the caller and reused across sweeps — engines `clear` and refill
/// the sparse buffers rather than allocating.
#[derive(Debug, Clone, Default)]
pub struct SweepResult {
    /// Sparse update for the shard's features, in shard-local column order
    /// (`dim` = the shard's local feature count).
    pub delta_local: SparseVec,
    /// Sparse per-example margin delta contributed by this shard:
    /// `dmargins[i] = Δβ^m · x_i` for the touched examples (`dim` = n).
    pub dmargins: SparseVec,
    /// Wall-clock seconds of the local solve (for Table 3 / speedup).
    pub compute_secs: f64,
}

/// A machine-local engine. Lives entirely inside one worker thread (the
/// XLA variant holds a thread-bound PJRT client, hence `Self` need not be
/// `Send` — only the builder inputs cross the thread boundary).
pub trait SubproblemEngine {
    /// One cyclic coordinate-descent sweep over the shard, given the shared
    /// working weights `w` and responses `z` (length n) and the *current
    /// shard-local* coefficients `beta_local`. Fills `out` in place.
    ///
    /// `lam` is the L1 strength of the per-coordinate soft-threshold (the
    /// elastic-net λ·α), `l2` the ridge strength λ·(1−α) added to every
    /// coordinate's quadratic denominator. `l2 = 0` (pure L1, the default
    /// configuration) is bit-identical to the pre-elastic-net update.
    #[allow(clippy::too_many_arguments)]
    fn sweep(
        &mut self,
        w: &[f32],
        z: &[f32],
        beta_local: &[f32],
        lam: f32,
        nu: f32,
        l2: f32,
        out: &mut SweepResult,
    ) -> Result<()>;

    /// Per-shard λ_max contribution: `max_j |Σ_i x_ij t_i| · scale` over the
    /// shard's local features, with each feature's sum accumulated in f64
    /// in ascending example order — **bit-identical** per feature to the
    /// leader-side [`lambda_max`](crate::solver::regpath::lambda_max) scan
    /// of the full dataset (a CSC column stores exactly the CSR row-order
    /// contributions of that feature). The targets `t` and `scale` come from
    /// the family ([`GlmFamily::lambda_max_targets`] /
    /// [`GlmFamily::lambda_max_scale`]; logistic: `t = y`, `scale = 0.5` —
    /// ×0.5 ≡ the historical ÷2.0 bit-for-bit). The leader max-reduces these
    /// over machines, which is exact: max is order-independent and the
    /// feature partition is disjoint.
    ///
    /// [`GlmFamily::lambda_max_targets`]: crate::family::GlmFamily::lambda_max_targets
    /// [`GlmFamily::lambda_max_scale`]: crate::family::GlmFamily::lambda_max_scale
    fn lambda_max_local(&mut self, targets: &[f32], scale: f64) -> Result<f64>;

    /// Sparse shard-local margins product `out_i = Σ_{j ∈ shard} β_j x_ij`
    /// (f64 accumulation per example, emitted as f32). The distributed
    /// warmstart install sums these disjoint-feature contributions across
    /// machines to rebuild the global margins without any process holding
    /// X. Not a hot path — one call per warmstart install.
    fn margins_into(&mut self, beta_local: &[f32], out: &mut SparseVec) -> Result<()>;

    /// Allocating convenience wrapper (tests, one-shot callers) — pure L1
    /// (`l2 = 0`).
    fn sweep_alloc(
        &mut self,
        w: &[f32],
        z: &[f32],
        beta_local: &[f32],
        lam: f32,
        nu: f32,
    ) -> Result<SweepResult> {
        let mut out = SweepResult::default();
        self.sweep(w, z, beta_local, lam, nu, 0.0, &mut out)?;
        Ok(out)
    }

    fn name(&self) -> &'static str;
}

/// Per-worker dense-tile memory budget for the Auto engine (bytes).
#[cfg(feature = "xla")]
const AUTO_DENSE_BYTES_BUDGET: usize = 256 << 20;
/// Minimum shard density for Auto to pick the dense-tile path: below this
/// the O(n_pad·p) dense sweep wastes too much work vs the O(nnz) sparse one.
#[cfg(feature = "xla")]
const AUTO_MIN_DENSITY: f64 = 0.02;

/// Resolve [`EngineKind::Auto`] for a concrete shard.
#[cfg(feature = "xla")]
pub fn resolve_engine(
    cfg: &TrainConfig,
    shard: &FeatureShard,
    n: usize,
    artifacts_dir: &std::path::Path,
) -> EngineKind {
    match cfg.engine {
        EngineKind::Auto => {
            // the AOT kernels are logistic pure-L1 only — any other family
            // or elastic-net mix resolves to the native engine
            if cfg.family != crate::family::FamilyKind::Logistic || cfg.enet_alpha < 1.0 {
                return EngineKind::Native;
            }
            let Ok(manifest) = crate::runtime::Manifest::load(artifacts_dir) else {
                return EngineKind::Native;
            };
            let Ok(n_pad) = manifest.pick_n(n) else {
                return EngineKind::Native;
            };
            let p_local = shard.csc.n_cols.max(1);
            let dense_bytes = n_pad * crate::util::round_up(p_local, cfg.block) * 4;
            let density = shard.csc.nnz() as f64 / (n.max(1) * p_local) as f64;
            if dense_bytes <= AUTO_DENSE_BYTES_BUDGET && density >= AUTO_MIN_DENSITY {
                EngineKind::Xla
            } else {
                EngineKind::Native
            }
        }
        k => k,
    }
}

/// Without the `xla` feature, Auto always resolves to the native engine.
#[cfg(not(feature = "xla"))]
pub fn resolve_engine(
    cfg: &TrainConfig,
    _shard: &FeatureShard,
    _n: usize,
    _artifacts_dir: &std::path::Path,
) -> EngineKind {
    match cfg.engine {
        EngineKind::Auto => EngineKind::Native,
        k => k,
    }
}

/// Build an engine for `shard` inside the current thread.
pub fn build_engine(
    cfg: &TrainConfig,
    shard: FeatureShard,
    n: usize,
    artifacts_dir: &std::path::Path,
) -> Result<Box<dyn SubproblemEngine>> {
    match resolve_engine(cfg, &shard, n, artifacts_dir) {
        EngineKind::Native => {
            cfg.validate_sweep_threads_for(shard.csc.n_cols)?;
            let kernel = SweepKernel::from_config(cfg).clamped_to(shard.csc.n_cols);
            Ok(Box::new(NativeEngine::with_kernel(shard, n, kernel)))
        }
        #[cfg(feature = "xla")]
        _ => Ok(Box::new(XlaEngine::with_kernel(
            shard,
            n,
            cfg.block,
            artifacts_dir,
            cfg.naive_sweep,
        )?)),
        #[cfg(not(feature = "xla"))]
        _ => Err(crate::error::DlrError::Artifact(
            "XLA engine requested but this build has no `xla` feature \
             (rebuild with --features xla and run `make artifacts`)"
                .into(),
        )),
    }
}
