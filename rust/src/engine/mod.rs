//! Subproblem engines: the per-machine solve of paper eq. (9) / Alg 2.
//!
//! * [`XlaEngine`] (feature `xla`) — the AOT-Pallas hot path: the worker's
//!   feature shard is densified once into (N, B) tiles and every sweep
//!   executes the AOT `cd_block_sweep` through PJRT.
//! * [`NativeEngine`] — the paper's original sparse CPU formulation in pure
//!   rust; the default engine and the cross-check oracle for the XLA path.
//! * [`StreamingEngine`] — the paper's O(n + p)-RAM disk-streaming mode.
//!
//! All engines consume the same inputs and must produce the same update
//! (tested in `rust/tests/engine_equivalence.rs`).
//!
//! ## Zero-allocation sweep contract
//!
//! [`SubproblemEngine::sweep`] writes into a caller-owned [`SweepResult`]
//! whose [`SparseVec`] buffers are reused across iterations (the worker pool
//! round-trips them through its channels), so the steady-state hot path
//! performs no per-iteration heap allocation. Results are *sparse*: only the
//! coordinates the sweep actually moved are materialized — exactly what the
//! `cluster::comm` collectives ship (or, for `dmargins` under the
//! allgather-Δβ strategy, recombine locally without touching the wire).

pub mod native;
pub mod streaming;
#[cfg(feature = "xla")]
pub mod xla_engine;

pub use native::NativeEngine;
pub use streaming::StreamingEngine;
#[cfg(feature = "xla")]
pub use xla_engine::XlaEngine;

use crate::config::{EngineKind, TrainConfig};
use crate::data::shuffle::FeatureShard;
use crate::data::sparse::SparseVec;
use crate::error::Result;

/// Result of one machine-local subproblem solve (one cyclic CD sweep).
/// Owned by the caller and reused across sweeps — engines `clear` and refill
/// the sparse buffers rather than allocating.
#[derive(Debug, Clone, Default)]
pub struct SweepResult {
    /// Sparse update for the shard's features, in shard-local column order
    /// (`dim` = the shard's local feature count).
    pub delta_local: SparseVec,
    /// Sparse per-example margin delta contributed by this shard:
    /// `dmargins[i] = Δβ^m · x_i` for the touched examples (`dim` = n).
    pub dmargins: SparseVec,
    /// Wall-clock seconds of the local solve (for Table 3 / speedup).
    pub compute_secs: f64,
}

/// A machine-local engine. Lives entirely inside one worker thread (the
/// XLA variant holds a thread-bound PJRT client, hence `Self` need not be
/// `Send` — only the builder inputs cross the thread boundary).
pub trait SubproblemEngine {
    /// One cyclic coordinate-descent sweep over the shard, given the shared
    /// working weights `w` and responses `z` (length n) and the *current
    /// shard-local* coefficients `beta_local`. Fills `out` in place.
    fn sweep(
        &mut self,
        w: &[f32],
        z: &[f32],
        beta_local: &[f32],
        lam: f32,
        nu: f32,
        out: &mut SweepResult,
    ) -> Result<()>;

    /// Per-shard λ_max contribution: `max_j |Σ_i x_ij y_i| / 2` over the
    /// shard's local features, with each feature's sum accumulated in f64
    /// in ascending example order — **bit-identical** per feature to the
    /// leader-side [`lambda_max`](crate::solver::regpath::lambda_max) scan
    /// of the full dataset (a CSC column stores exactly the CSR row-order
    /// contributions of that feature). The leader max-reduces these over
    /// machines, which is exact: max is order-independent and the feature
    /// partition is disjoint.
    fn lambda_max_local(&mut self, y: &[f32]) -> Result<f64>;

    /// Sparse shard-local margins product `out_i = Σ_{j ∈ shard} β_j x_ij`
    /// (f64 accumulation per example, emitted as f32). The distributed
    /// warmstart install sums these disjoint-feature contributions across
    /// machines to rebuild the global margins without any process holding
    /// X. Not a hot path — one call per warmstart install.
    fn margins_into(&mut self, beta_local: &[f32], out: &mut SparseVec) -> Result<()>;

    /// Allocating convenience wrapper (tests, one-shot callers).
    fn sweep_alloc(
        &mut self,
        w: &[f32],
        z: &[f32],
        beta_local: &[f32],
        lam: f32,
        nu: f32,
    ) -> Result<SweepResult> {
        let mut out = SweepResult::default();
        self.sweep(w, z, beta_local, lam, nu, &mut out)?;
        Ok(out)
    }

    fn name(&self) -> &'static str;
}

/// Per-worker dense-tile memory budget for the Auto engine (bytes).
#[cfg(feature = "xla")]
const AUTO_DENSE_BYTES_BUDGET: usize = 256 << 20;
/// Minimum shard density for Auto to pick the dense-tile path: below this
/// the O(n_pad·p) dense sweep wastes too much work vs the O(nnz) sparse one.
#[cfg(feature = "xla")]
const AUTO_MIN_DENSITY: f64 = 0.02;

/// Resolve [`EngineKind::Auto`] for a concrete shard.
#[cfg(feature = "xla")]
pub fn resolve_engine(
    cfg: &TrainConfig,
    shard: &FeatureShard,
    n: usize,
    artifacts_dir: &std::path::Path,
) -> EngineKind {
    match cfg.engine {
        EngineKind::Auto => {
            let Ok(manifest) = crate::runtime::Manifest::load(artifacts_dir) else {
                return EngineKind::Native;
            };
            let Ok(n_pad) = manifest.pick_n(n) else {
                return EngineKind::Native;
            };
            let p_local = shard.csc.n_cols.max(1);
            let dense_bytes = n_pad * crate::util::round_up(p_local, cfg.block) * 4;
            let density = shard.csc.nnz() as f64 / (n.max(1) * p_local) as f64;
            if dense_bytes <= AUTO_DENSE_BYTES_BUDGET && density >= AUTO_MIN_DENSITY {
                EngineKind::Xla
            } else {
                EngineKind::Native
            }
        }
        k => k,
    }
}

/// Without the `xla` feature, Auto always resolves to the native engine.
#[cfg(not(feature = "xla"))]
pub fn resolve_engine(
    cfg: &TrainConfig,
    _shard: &FeatureShard,
    _n: usize,
    _artifacts_dir: &std::path::Path,
) -> EngineKind {
    match cfg.engine {
        EngineKind::Auto => EngineKind::Native,
        k => k,
    }
}

/// Build an engine for `shard` inside the current thread.
pub fn build_engine(
    cfg: &TrainConfig,
    shard: FeatureShard,
    n: usize,
    artifacts_dir: &std::path::Path,
) -> Result<Box<dyn SubproblemEngine>> {
    match resolve_engine(cfg, &shard, n, artifacts_dir) {
        EngineKind::Native => Ok(Box::new(NativeEngine::new(shard, n))),
        #[cfg(feature = "xla")]
        _ => Ok(Box::new(XlaEngine::with_kernel(
            shard,
            n,
            cfg.block,
            artifacts_dir,
            cfg.naive_sweep,
        )?)),
        #[cfg(not(feature = "xla"))]
        _ => Err(crate::error::DlrError::Artifact(
            "XLA engine requested but this build has no `xla` feature \
             (rebuild with --features xla and run `make artifacts`)"
                .into(),
        )),
    }
}
