//! Subproblem engines: the per-machine solve of paper eq. (9) / Alg 2.
//!
//! * [`XlaEngine`] — the production hot path: the worker's feature shard is
//!   densified once into (N, B) tiles and every sweep executes the AOT
//!   Pallas `cd_block_sweep` through PJRT.
//! * [`NativeEngine`] — the paper's original sparse CPU formulation in pure
//!   rust; used for shards too large/sparse for dense tiles and as the
//!   cross-check oracle for the XLA path.
//!
//! Both consume the same inputs and must produce the same update (tested in
//! `rust/tests/engine_equivalence.rs`).

pub mod native;
pub mod streaming;
pub mod xla_engine;

pub use native::NativeEngine;
pub use streaming::StreamingEngine;
pub use xla_engine::XlaEngine;

use crate::config::{EngineKind, TrainConfig};
use crate::data::shuffle::FeatureShard;
use crate::error::Result;

/// Result of one machine-local subproblem solve (one cyclic CD sweep).
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Update for the shard's features, in shard-local column order.
    pub delta_local: Vec<f32>,
    /// Per-example margin delta contributed by this shard:
    /// dmargins[i] = Δβ^m · x_i, length n (unpadded).
    pub dmargins: Vec<f32>,
    /// Wall-clock seconds of the local solve (for Table 3 / speedup).
    pub compute_secs: f64,
}

/// A machine-local engine. Lives entirely inside one worker thread (the
/// XLA variant holds a thread-bound PJRT client, hence `Self` need not be
/// `Send` — only the builder inputs cross the thread boundary).
pub trait SubproblemEngine {
    /// One cyclic coordinate-descent sweep over the shard, given the shared
    /// working weights `w` and responses `z` (length n) and the *current
    /// shard-local* coefficients `beta_local`.
    fn sweep(
        &mut self,
        w: &[f32],
        z: &[f32],
        beta_local: &[f32],
        lam: f32,
        nu: f32,
    ) -> Result<SweepResult>;

    fn name(&self) -> &'static str;
}

/// Per-worker dense-tile memory budget for the Auto engine (bytes).
const AUTO_DENSE_BYTES_BUDGET: usize = 256 << 20;
/// Minimum shard density for Auto to pick the dense-tile path: below this
/// the O(n_pad·p) dense sweep wastes too much work vs the O(nnz) sparse one.
const AUTO_MIN_DENSITY: f64 = 0.02;

/// Resolve [`EngineKind::Auto`] for a concrete shard.
pub fn resolve_engine(
    cfg: &TrainConfig,
    shard: &FeatureShard,
    n: usize,
    artifacts_dir: &std::path::Path,
) -> EngineKind {
    match cfg.engine {
        EngineKind::Auto => {
            let Ok(manifest) = crate::runtime::Manifest::load(artifacts_dir) else {
                return EngineKind::Native;
            };
            let Ok(n_pad) = manifest.pick_n(n) else {
                return EngineKind::Native;
            };
            let p_local = shard.csc.n_cols.max(1);
            let dense_bytes = n_pad * crate::util::round_up(p_local, cfg.block) * 4;
            let density = shard.csc.nnz() as f64 / (n.max(1) * p_local) as f64;
            if dense_bytes <= AUTO_DENSE_BYTES_BUDGET && density >= AUTO_MIN_DENSITY {
                EngineKind::Xla
            } else {
                EngineKind::Native
            }
        }
        k => k,
    }
}

/// Build an engine for `shard` inside the current thread.
pub fn build_engine(
    cfg: &TrainConfig,
    shard: FeatureShard,
    n: usize,
    artifacts_dir: &std::path::Path,
) -> Result<Box<dyn SubproblemEngine>> {
    match resolve_engine(cfg, &shard, n, artifacts_dir) {
        EngineKind::Native => Ok(Box::new(NativeEngine::new(shard, n))),
        _ => Ok(Box::new(XlaEngine::with_kernel(
            shard,
            n,
            cfg.block,
            artifacts_dir,
            cfg.naive_sweep,
        )?)),
    }
}
