//! Pure-rust sparse subproblem engine — the paper's original by-feature CPU
//! formulation (§3): stream the shard's columns, apply the closed-form
//! coordinate update (6), maintain the working Δmargin incrementally.
//! O(nnz + touched) per sweep; results are emitted as sparse vectors into
//! caller-owned buffers (no per-sweep allocation).
//!
//! The working residual is *derived*, not stored: `r_i = z_i - Δm_i`, with
//! `Δm` a per-example accumulator that is all-zero at sweep start. Resetting
//! it costs O(touched examples from the previous sweep) — not the seed's
//! O(n) re-read of `z` into a residual buffer — so an all-zero update
//! (λ ≥ λ_max regimes, converged shards) never pays an O(n) scan.

use std::time::Instant;

use crate::data::shuffle::FeatureShard;
use crate::engine::{SubproblemEngine, SweepResult};
use crate::error::Result;
use crate::util::math::soft_threshold;

/// Sparse coordinate-descent engine over a by-feature (CSC) shard.
pub struct NativeEngine {
    shard: FeatureShard,
    n: usize,
    /// Accumulated Δβ·x per example within the current sweep (f64 for
    /// accumulation stability); zero outside `touched`.
    dm: Vec<f64>,
    /// Examples the current sweep has moved (unsorted until emission).
    touched: Vec<u32>,
    /// Membership flags for `touched` (O(1) dedup; reset via the list).
    in_touched: Vec<bool>,
}

impl NativeEngine {
    pub fn new(shard: FeatureShard, n: usize) -> Self {
        assert_eq!(shard.csc.n_rows, n);
        Self { shard, n, dm: vec![0f64; n], touched: Vec::new(), in_touched: vec![false; n] }
    }

    pub fn shard(&self) -> &FeatureShard {
        &self.shard
    }
}

impl SubproblemEngine for NativeEngine {
    fn sweep(
        &mut self,
        w: &[f32],
        z: &[f32],
        beta_local: &[f32],
        lam: f32,
        nu: f32,
        out: &mut SweepResult,
    ) -> Result<()> {
        let t0 = Instant::now();
        let n = self.n;
        debug_assert_eq!(w.len(), n);
        debug_assert_eq!(z.len(), n);
        let p_local = self.shard.csc.n_cols;
        debug_assert_eq!(beta_local.len(), p_local);

        // incremental reset: only the entries the previous sweep moved
        for &i in &self.touched {
            self.dm[i as usize] = 0.0;
            self.in_touched[i as usize] = false;
        }
        self.touched.clear();

        let (lam, nu) = (lam as f64, nu as f64);
        out.delta_local.clear(p_local);

        for j in 0..p_local {
            let (rows, vals) = self.shard.csc.col(j);
            if rows.is_empty() {
                continue;
            }
            // A = Σ w x² + ν ;  c = Σ w r x + β_j A, with r_i = z_i - Δm_i
            let mut a = nu;
            let mut wrx = 0f64;
            for (&i, &v) in rows.iter().zip(vals) {
                let ii = i as usize;
                let wi = w[ii] as f64;
                let x = v as f64;
                a += wi * x * x;
                wrx += wi * (z[ii] as f64 - self.dm[ii]) * x;
            }
            let bj = beta_local[j] as f64;
            let c = wrx + bj * a;
            let s = soft_threshold(c, lam) / a;
            let step = s - bj;
            if step != 0.0 {
                out.delta_local.push(j as u32, step as f32);
                for (&i, &v) in rows.iter().zip(vals) {
                    let ii = i as usize;
                    self.dm[ii] += step * v as f64;
                    if !self.in_touched[ii] {
                        self.in_touched[ii] = true;
                        self.touched.push(i);
                    }
                }
            }
        }

        // Δβ^m · x_i = Δm_i, non-zero only for touched examples — emission
        // costs O(touched log touched), not O(n)
        self.touched.sort_unstable();
        out.dmargins.clear(n);
        for &i in &self.touched {
            let v = self.dm[i as usize];
            if v != 0.0 {
                out.dmargins.push(i, v as f32);
            }
        }
        out.compute_secs = t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn lambda_max_local(&mut self, y: &[f32]) -> Result<f64> {
        debug_assert_eq!(y.len(), self.n);
        let mut best = 0f64;
        for j in 0..self.shard.csc.n_cols {
            let (rows, vals) = self.shard.csc.col(j);
            let mut g = 0f64;
            for (&i, &v) in rows.iter().zip(vals) {
                g += v as f64 * y[i as usize] as f64;
            }
            best = best.max(g.abs() / 2.0);
        }
        Ok(best)
    }

    fn margins_into(
        &mut self,
        beta_local: &[f32],
        out: &mut crate::data::sparse::SparseVec,
    ) -> Result<()> {
        debug_assert_eq!(beta_local.len(), self.shard.csc.n_cols);
        let mut acc = vec![0f64; self.n];
        // the shared canonical margin kernel (data::sparse): ascending
        // feature order, f64 accumulation, zero weights skipped — what
        // CsrMatrix::margins / SparseModel::predict compute row-wise
        self.shard.csc.accumulate_margins_f64(beta_local, &mut acc);
        out.clear(self.n);
        for (i, &v) in acc.iter().enumerate() {
            if v != 0.0 {
                out.push(i as u32, v as f32);
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::{FeaturePartition, PartitionStrategy};
    use crate::data::shuffle::shard_in_memory;
    use crate::data::synth;
    use crate::util::math::working_stats;

    fn one_shard(ds: &crate::data::Dataset) -> FeatureShard {
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, ds.n_features(), 1, None);
        shard_in_memory(&ds.x, &part).remove(0)
    }

    fn stats_of(ds: &crate::data::Dataset, margins: &[f32]) -> (Vec<f32>, Vec<f32>) {
        margins
            .iter()
            .zip(&ds.y)
            .map(|(&m, &y)| {
                let (w, z) = working_stats(y as f64, m as f64);
                (w as f32, z as f32)
            })
            .unzip()
    }

    #[test]
    fn zero_lambda_sweep_decreases_loss() {
        let ds = synth::dna_like(400, 30, 5, 1);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let margins = vec![0f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let beta = vec![0f32; 30];
        let res = eng.sweep_alloc(&w, &z, &beta, 0.0, 1e-6).unwrap();
        // apply full step, loss must drop
        let dm = res.dmargins.to_dense();
        let new_margins: Vec<f32> =
            margins.iter().zip(&dm).map(|(&m, &d)| m + d).collect();
        let before = crate::util::math::logloss_sum(&margins, &ds.y);
        let after = crate::util::math::logloss_sum(&new_margins, &ds.y);
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn huge_lambda_gives_zero_update() {
        let ds = synth::dna_like(200, 20, 4, 2);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let margins = vec![0f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let res = eng.sweep_alloc(&w, &z, &vec![0f32; 20], 1e9, 1e-6).unwrap();
        assert!(res.delta_local.is_empty());
        assert!(res.dmargins.is_empty());
        assert_eq!(res.delta_local.dim, 20);
        assert_eq!(res.dmargins.dim, 200);
    }

    #[test]
    fn dmargins_consistent_with_delta() {
        let ds = synth::webspam_like(150, 600, 15, 3);
        let shard = one_shard(&ds);
        let csc = shard.csc.clone();
        let mut eng = NativeEngine::new(shard, ds.n_examples());
        let margins = vec![0.1f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let res = eng.sweep_alloc(&w, &z, &vec![0f32; 600], 0.5, 1e-6).unwrap();
        // recompute Δβ·x_i from scratch and compare
        let delta = res.delta_local.to_dense();
        let mut want = vec![0f64; ds.n_examples()];
        for j in 0..600 {
            let (rows, vals) = csc.col(j);
            let d = delta[j] as f64;
            if d != 0.0 {
                for (&i, &v) in rows.iter().zip(vals) {
                    want[i as usize] += d * v as f64;
                }
            }
        }
        let dm = res.dmargins.to_dense();
        for i in 0..ds.n_examples() {
            assert!(
                (dm[i] as f64 - want[i]).abs() < 1e-4,
                "i={i}: {} vs {}",
                dm[i],
                want[i]
            );
        }
    }

    #[test]
    fn sweep_reuses_buffers_without_reallocating() {
        // the zero-allocation contract: a second sweep through the same
        // SweepResult must not grow the sparse buffers' capacity
        let ds = synth::webspam_like(200, 500, 10, 9);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let margins = vec![0f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let beta = vec![0f32; 500];
        let mut out = SweepResult::default();
        eng.sweep(&w, &z, &beta, 0.3, 1e-6, &mut out).unwrap();
        let first = out.delta_local.clone();
        let (cap_d, cap_m) = (out.delta_local.indices.capacity(), out.dmargins.indices.capacity());
        eng.sweep(&w, &z, &beta, 0.3, 1e-6, &mut out).unwrap();
        assert_eq!(out.delta_local, first, "sweeps must be deterministic");
        assert_eq!(out.delta_local.indices.capacity(), cap_d);
        assert_eq!(out.dmargins.indices.capacity(), cap_m);
    }

    #[test]
    fn incremental_reset_matches_a_fresh_engine_across_sweeps() {
        // the Δm accumulator must be indistinguishable from a fresh engine
        // even when w/z change between sweeps (the stale-state hazard the
        // incremental reset must not introduce)
        let ds = synth::webspam_like(250, 300, 8, 5);
        let mut persistent = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let beta = vec![0f32; 300];

        // sweep 1 at zero margins
        let margins0 = vec![0f32; ds.n_examples()];
        let (w0, z0) = stats_of(&ds, &margins0);
        let first = persistent.sweep_alloc(&w0, &z0, &beta, 0.4, 1e-6).unwrap();
        assert!(!first.dmargins.is_empty(), "need a non-trivial first sweep");

        // sweep 2 at shifted margins: persistent engine vs fresh engine
        let margins1: Vec<f32> = first.dmargins.to_dense().iter().map(|d| 0.5 * d).collect();
        let (w1, z1) = stats_of(&ds, &margins1);
        let warm = persistent.sweep_alloc(&w1, &z1, &beta, 0.4, 1e-6).unwrap();
        let mut fresh = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let cold = fresh.sweep_alloc(&w1, &z1, &beta, 0.4, 1e-6).unwrap();
        assert_eq!(warm.delta_local, cold.delta_local);
        assert_eq!(warm.dmargins, cold.dmargins);

        // an all-zero update (huge λ) leaves no stale touched state behind
        let none = persistent.sweep_alloc(&w1, &z1, &beta, 1e9, 1e-6).unwrap();
        assert!(none.delta_local.is_empty() && none.dmargins.is_empty());
        let again = persistent.sweep_alloc(&w1, &z1, &beta, 0.4, 1e-6).unwrap();
        assert_eq!(again.delta_local, cold.delta_local);
        assert_eq!(again.dmargins, cold.dmargins);
    }

    #[test]
    fn lambda_max_local_matches_full_scan_on_one_shard() {
        // a single shard owns every feature, so its local λ_max IS the
        // dataset's — and must match the leader-side scan bit-for-bit
        let ds = synth::webspam_like(150, 400, 10, 6);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let got = eng.lambda_max_local(&ds.y).unwrap();
        let want = crate::solver::regpath::lambda_max(&ds);
        assert_eq!(want.to_bits(), got.to_bits());
    }

    #[test]
    fn margins_into_matches_by_example_spmv() {
        let ds = synth::dna_like(120, 30, 4, 7);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let beta: Vec<f32> = (0..30)
            .map(|j| if j % 4 == 0 { (j as f32) * 0.1 - 1.0 } else { 0.0 })
            .collect();
        let mut out = crate::data::sparse::SparseVec::new(0);
        eng.margins_into(&beta, &mut out).unwrap();
        assert_eq!(out.dim, 120);
        let got = out.to_dense();
        let want = ds.x.margins(&beta);
        for i in 0..120 {
            assert!(
                (got[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                "margins[{i}]: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn sweep_moves_beta_back_toward_zero_when_overshooting() {
        // A feature whose current beta is large positive while data says 0:
        // the sweep should produce negative delta (shrinkage works from warm
        // starts, the mechanism behind the paper's sparsity discussion §2).
        let ds = synth::dna_like(300, 10, 3, 4);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let mut beta = vec![0f32; 10];
        beta[0] = 5.0;
        let margins = ds.x.margins(&beta);
        let (w, z) = stats_of(&ds, &margins);
        let res = eng.sweep_alloc(&w, &z, &beta, 1.0, 1e-6).unwrap();
        let delta = res.delta_local.to_dense();
        assert!(delta[0] < 0.0, "delta0 = {}", delta[0]);
    }
}
