//! Pure-rust sparse subproblem engine — the paper's original by-feature CPU
//! formulation (§3): stream the shard's columns, apply the closed-form
//! coordinate update (6), maintain the working Δmargin incrementally.
//! O(nnz + touched) per sweep; results are emitted as sparse vectors into
//! caller-owned buffers (no per-sweep allocation on the default path).
//!
//! The working residual is *derived*, not stored: `r_i = z_i - Δm_i`, with
//! `Δm` a per-example accumulator that is all-zero at sweep start. Resetting
//! it costs O(touched examples from the previous sweep) — not the seed's
//! O(n) re-read of `z` into a residual buffer — so an all-zero update
//! (λ ≥ λ_max regimes, converged shards) never pays an O(n) scan.
//!
//! ## Kernel matrix
//!
//! The engine runs one [`SweepKernel`]: the **naive** column loop below kept
//! byte-for-byte from the seed (`--naive-sweep`, the exact-ablation
//! baseline), or the **covariance-update** kernel ([`cov`](crate::engine::cov),
//! the default). With `sweep_threads = T > 1` the shard's columns are
//! sub-partitioned into T blocks (same [`FeaturePartition`] machinery and
//! strategy as the machine partition) and swept Jacobi-style on a scoped
//! thread pool; per-block Δm accumulators then combine through the same
//! deterministic pairwise-f64 tree merge
//! ([`merge_sorted_into`](crate::cluster::allreduce)) the AllReduce uses, so
//! a T-threaded worker is bit-identical to T single-threaded machines under
//! the matching sub-partition. `T = 1` bypasses the staging entirely and
//! writes straight into the caller's buffers — the seed's exact code path.

use std::time::Instant;

use crate::cluster::allreduce::merge_sorted_into;
use crate::cluster::partition::FeaturePartition;
use crate::data::shuffle::FeatureShard;
use crate::data::sparse::SparseVec;
use crate::engine::cov::{cov_block_compute, CovBlock, GRAM_CACHE_BUDGET_BYTES};
use crate::engine::{SubproblemEngine, SweepKernel, SweepResult};
use crate::error::Result;
use crate::util::math::{gather_dot4, soft_threshold};

/// One sweep thread's slice of the shard: its columns plus a private Δm
/// accumulator (O(n) each — T threads trade O(T·n) memory for parallelism).
struct BlockState {
    /// Shard-local column ids this block owns, ascending.
    cols: Vec<u32>,
    /// Accumulated Δβ·x per example within the current sweep (f64 for
    /// accumulation stability); zero outside `touched`.
    dm: Vec<f64>,
    /// Examples the current sweep has moved (unsorted until emission).
    touched: Vec<u32>,
    /// Membership flags for `touched` (O(1) dedup; reset via the list).
    in_touched: Vec<bool>,
    /// Covariance-kernel caches (None under `--naive-sweep`).
    cov: Option<CovBlock>,
}

/// Sparse coordinate-descent engine over a by-feature (CSC) shard.
pub struct NativeEngine {
    shard: FeatureShard,
    n: usize,
    kernel: SweepKernel,
    blocks: Vec<BlockState>,
    /// Per-block staged (delta, dmargins) leaf results (T > 1 only).
    staged: Vec<(SparseVec, SparseVec)>,
    /// Widened f64 per-block Δm accumulators + merge scratch (T > 1 only).
    acc_idx: Vec<Vec<u32>>,
    acc_val: Vec<Vec<f64>>,
    tmp_idx: Vec<u32>,
    tmp_val: Vec<f64>,
    /// k-way delta-merge cursors (T > 1 only).
    kpos: Vec<usize>,
    /// Precomputed `w_i · z_i` products shared across blocks (cov kernel).
    wz: Vec<f64>,
}

impl NativeEngine {
    /// The seed's exact engine: naive kernel, single thread.
    pub fn new(shard: FeatureShard, n: usize) -> Self {
        Self::with_kernel(shard, n, SweepKernel::default())
    }

    /// Engine with an explicit kernel/thread configuration. Thread count is
    /// clamped so every block owns ≥ 1 column; the T-block sub-partition
    /// uses the same strategy (and nnz counts) as the machine partition, so
    /// at M = 1 the blocks equal the shards of a T-machine run.
    pub fn with_kernel(shard: FeatureShard, n: usize, kernel: SweepKernel) -> Self {
        assert_eq!(shard.csc.n_rows, n);
        let p_local = shard.csc.n_cols;
        let kernel = kernel.clamped_to(p_local);
        let t = kernel.threads;
        let cols_per_block: Vec<Vec<u32>> = if t <= 1 {
            vec![(0..p_local as u32).collect()]
        } else {
            let counts: Vec<usize> = (0..p_local).map(|j| shard.csc.col_nnz(j)).collect();
            let part = FeaturePartition::build(kernel.partition, p_local, t, Some(&counts));
            (0..t).map(|b| part.features_of(b)).collect()
        };
        let budget = GRAM_CACHE_BUDGET_BYTES / t.max(1);
        let blocks: Vec<BlockState> = cols_per_block
            .into_iter()
            .map(|cols| {
                let cov = (!kernel.naive).then(|| CovBlock::new(&shard, &cols, budget));
                BlockState {
                    cols,
                    dm: vec![0f64; n],
                    touched: Vec::new(),
                    in_touched: vec![false; n],
                    cov,
                }
            })
            .collect();
        let staged = if t > 1 {
            (0..t).map(|_| (SparseVec::new(p_local), SparseVec::new(n))).collect()
        } else {
            Vec::new()
        };
        Self {
            shard,
            n,
            kernel,
            blocks,
            staged,
            acc_idx: vec![Vec::new(); if t > 1 { t } else { 0 }],
            acc_val: vec![Vec::new(); if t > 1 { t } else { 0 }],
            tmp_idx: Vec::new(),
            tmp_val: Vec::new(),
            kpos: vec![0; t],
            wz: Vec::new(),
        }
    }

    pub fn shard(&self) -> &FeatureShard {
        &self.shard
    }

    /// The kernel this engine resolved to (post-clamp).
    pub fn kernel(&self) -> SweepKernel {
        self.kernel
    }
}

/// One block's sweep: incremental Δm reset, the column loop (naive or cov),
/// then the leaf emission — sorted touched examples, f64-exact zeros
/// skipped, values narrowed to f32. This emission IS what a single-threaded
/// machine ships into the AllReduce, which is exactly what makes the
/// threaded merge below reproduce a T-machine run.
#[allow(clippy::too_many_arguments)]
fn sweep_block(
    shard: &FeatureShard,
    blk: &mut BlockState,
    w: &[f32],
    z: &[f32],
    beta_local: &[f32],
    lam: f64,
    nu: f64,
    l2: f64,
    wz: &[f64],
    delta_out: &mut SparseVec,
    dm_out: &mut SparseVec,
) {
    // incremental reset: only the entries the previous sweep moved
    for &i in &blk.touched {
        blk.dm[i as usize] = 0.0;
        blk.in_touched[i as usize] = false;
    }
    blk.touched.clear();

    match &mut blk.cov {
        Some(cov) => {
            cov.begin_sweep(w);
            cov_block_compute(
                shard,
                &blk.cols,
                cov,
                &mut blk.dm,
                &mut blk.touched,
                &mut blk.in_touched,
                wz,
                beta_local,
                lam,
                nu,
                l2,
                delta_out,
            );
        }
        None => {
            for &c in &blk.cols {
                let j = c as usize;
                let (rows, vals) = shard.csc.col(j);
                if rows.is_empty() {
                    continue;
                }
                // A = Σ w x² + ν ;  c = Σ w r x + β_j A, with r_i = z_i - Δm_i
                let mut a = nu;
                let mut wrx = 0f64;
                for (&i, &v) in rows.iter().zip(vals) {
                    let ii = i as usize;
                    let wi = w[ii] as f64;
                    let x = v as f64;
                    a += wi * x * x;
                    wrx += wi * (z[ii] as f64 - blk.dm[ii]) * x;
                }
                let bj = beta_local[j] as f64;
                // elastic net: the ridge share λ(1−α) enters only the
                // denominator (a already carries β_j through cnum; l2 = 0
                // reproduces the pure-L1 update bit-for-bit)
                let cnum = wrx + bj * a;
                let s = soft_threshold(cnum, lam) / (a + l2);
                let step = s - bj;
                if step != 0.0 {
                    delta_out.push(c, step as f32);
                    for (&i, &v) in rows.iter().zip(vals) {
                        let ii = i as usize;
                        blk.dm[ii] += step * v as f64;
                        if !blk.in_touched[ii] {
                            blk.in_touched[ii] = true;
                            blk.touched.push(i);
                        }
                    }
                }
            }
        }
    }

    // Δβ^m · x_i = Δm_i, non-zero only for touched examples — emission
    // costs O(touched log touched), not O(n)
    blk.touched.sort_unstable();
    for &i in &blk.touched {
        let v = blk.dm[i as usize];
        if v != 0.0 {
            dm_out.push(i, v as f32);
        }
    }
}

impl SubproblemEngine for NativeEngine {
    fn sweep(
        &mut self,
        w: &[f32],
        z: &[f32],
        beta_local: &[f32],
        lam: f32,
        nu: f32,
        l2: f32,
        out: &mut SweepResult,
    ) -> Result<()> {
        let t0 = Instant::now();
        let n = self.n;
        debug_assert_eq!(w.len(), n);
        debug_assert_eq!(z.len(), n);
        let p_local = self.shard.csc.n_cols;
        debug_assert_eq!(beta_local.len(), p_local);
        let (lam, nu, l2) = (lam as f64, nu as f64, l2 as f64);
        out.delta_local.clear(p_local);

        // cov kernel: every block's c0 pass gathers against the same w·z
        // products a single-machine engine would compute, shared per sweep
        if !self.kernel.naive {
            self.wz.clear();
            self.wz.extend(w.iter().zip(z).map(|(&wi, &zi)| wi as f64 * zi as f64));
        }

        let t = self.kernel.threads;
        if t <= 1 {
            out.dmargins.clear(n);
            sweep_block(
                &self.shard,
                &mut self.blocks[0],
                w,
                z,
                beta_local,
                lam,
                nu,
                l2,
                &self.wz,
                &mut out.delta_local,
                &mut out.dmargins,
            );
            out.compute_secs = t0.elapsed().as_secs_f64();
            return Ok(());
        }

        // ---- T > 1: Jacobi blocks on scoped threads -------------------
        {
            let shard = &self.shard;
            let wz = &self.wz[..];
            let mut work: Vec<_> = self.blocks.iter_mut().zip(self.staged.iter_mut()).collect();
            std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(work.len().saturating_sub(1));
                // block 0 runs on the calling thread; the rest spawn
                for (blk, st) in work.drain(1..) {
                    handles.push(s.spawn(move || {
                        st.0.clear(p_local);
                        st.1.clear(n);
                        sweep_block(
                            shard, blk, w, z, beta_local, lam, nu, l2, wz, &mut st.0, &mut st.1,
                        );
                    }));
                }
                let (blk, st) = work.pop().expect("at least one sweep block");
                st.0.clear(p_local);
                st.1.clear(n);
                sweep_block(shard, blk, w, z, beta_local, lam, nu, l2, wz, &mut st.0, &mut st.1);
                for h in handles {
                    h.join().expect("sweep thread panicked");
                }
            });
        }

        // Δβ merge: blocks own disjoint column sets, each staged ascending —
        // a k-way index merge, values untouched (each block computed the
        // identical f32 step a machine owning those columns would ship)
        self.kpos.iter_mut().for_each(|p| *p = 0);
        loop {
            let mut best: Option<(u32, usize)> = None;
            for (b, &p) in self.kpos.iter().enumerate() {
                if let Some(&idx) = self.staged[b].0.indices.get(p) {
                    if best.is_none_or(|(bi, _)| idx < bi) {
                        best = Some((idx, b));
                    }
                }
            }
            let Some((idx, b)) = best else { break };
            out.delta_local.push(idx, self.staged[b].0.values[self.kpos[b]]);
            self.kpos[b] += 1;
        }

        // Δm merge: mirror of `sparse_tree_exchange` — widen leaves f32→f64
        // keeping every entry, pairwise-merge (result in the left slot, odd
        // leftover carries), then the root emits ALL merged entries as f32,
        // f64-exact zeros included, exactly as the AllReduce root does.
        for b in 0..t {
            self.acc_idx[b].clear();
            self.acc_val[b].clear();
            let st = &self.staged[b].1;
            self.acc_idx[b].extend_from_slice(&st.indices);
            self.acc_val[b].extend(st.values.iter().map(|&v| v as f64));
        }
        let mut active: Vec<usize> = (0..t).collect();
        while active.len() > 1 {
            let mut next = Vec::with_capacity(active.len().div_ceil(2));
            let mut k = 0;
            while k + 1 < active.len() {
                let (a, b) = (active[k], active[k + 1]);
                debug_assert!(a < b);
                let (left, right) = self.acc_idx.split_at_mut(b);
                let (lv, rv) = self.acc_val.split_at_mut(b);
                merge_sorted_into(
                    &left[a],
                    &lv[a],
                    &right[0],
                    &rv[0],
                    &mut self.tmp_idx,
                    &mut self.tmp_val,
                );
                std::mem::swap(&mut left[a], &mut self.tmp_idx);
                std::mem::swap(&mut lv[a], &mut self.tmp_val);
                next.push(a);
                k += 2;
            }
            if k < active.len() {
                next.push(active[k]);
            }
            active = next;
        }
        out.dmargins.clear(n);
        let root = active[0];
        for (&idx, &v) in self.acc_idx[root].iter().zip(&self.acc_val[root]) {
            out.dmargins.push(idx, v as f32);
        }
        out.compute_secs = t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn lambda_max_local(&mut self, targets: &[f32], scale: f64) -> Result<f64> {
        debug_assert_eq!(targets.len(), self.n);
        let mut best = 0f64;
        for j in 0..self.shard.csc.n_cols {
            let (rows, vals) = self.shard.csc.col(j);
            best = best.max(gather_dot4(rows, vals, targets).abs() * scale);
        }
        Ok(best)
    }

    fn margins_into(
        &mut self,
        beta_local: &[f32],
        out: &mut crate::data::sparse::SparseVec,
    ) -> Result<()> {
        debug_assert_eq!(beta_local.len(), self.shard.csc.n_cols);
        // reuse block 0's Δm scratch instead of a fresh O(n) allocation —
        // same ascending-feature f64 accumulation as the canonical
        // CscMatrix::accumulate_margins_f64 kernel, zero-β columns skipped
        let blk = &mut self.blocks[0];
        for &i in &blk.touched {
            blk.dm[i as usize] = 0.0;
            blk.in_touched[i as usize] = false;
        }
        blk.touched.clear();
        for (j, &b) in beta_local.iter().enumerate() {
            if b == 0.0 {
                continue;
            }
            let (rows, vals) = self.shard.csc.col(j);
            let bd = b as f64;
            for (&i, &v) in rows.iter().zip(vals) {
                let ii = i as usize;
                blk.dm[ii] += bd * v as f64;
                if !blk.in_touched[ii] {
                    blk.in_touched[ii] = true;
                    blk.touched.push(i);
                }
            }
        }
        blk.touched.sort_unstable();
        out.clear(self.n);
        for &i in &blk.touched {
            let v = blk.dm[i as usize];
            if v != 0.0 {
                out.push(i, v as f32);
            }
        }
        // leave the scratch clean so the next sweep's incremental reset
        // (which trusts `touched`) stays consistent
        for &i in &blk.touched {
            blk.dm[i as usize] = 0.0;
            blk.in_touched[i as usize] = false;
        }
        blk.touched.clear();
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::{FeaturePartition, PartitionStrategy};
    use crate::data::shuffle::shard_in_memory;
    use crate::data::synth;
    use crate::util::math::working_stats;

    fn one_shard(ds: &crate::data::Dataset) -> FeatureShard {
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, ds.n_features(), 1, None);
        shard_in_memory(&ds.x, &part).remove(0)
    }

    fn stats_of(ds: &crate::data::Dataset, margins: &[f32]) -> (Vec<f32>, Vec<f32>) {
        margins
            .iter()
            .zip(&ds.y)
            .map(|(&m, &y)| {
                let (w, z) = working_stats(y as f64, m as f64);
                (w as f32, z as f32)
            })
            .unzip()
    }

    #[test]
    fn zero_lambda_sweep_decreases_loss() {
        let ds = synth::dna_like(400, 30, 5, 1);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let margins = vec![0f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let beta = vec![0f32; 30];
        let res = eng.sweep_alloc(&w, &z, &beta, 0.0, 1e-6).unwrap();
        // apply full step, loss must drop
        let dm = res.dmargins.to_dense();
        let new_margins: Vec<f32> =
            margins.iter().zip(&dm).map(|(&m, &d)| m + d).collect();
        let before = crate::util::math::logloss_sum(&margins, &ds.y);
        let after = crate::util::math::logloss_sum(&new_margins, &ds.y);
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn huge_lambda_gives_zero_update() {
        let ds = synth::dna_like(200, 20, 4, 2);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let margins = vec![0f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let res = eng.sweep_alloc(&w, &z, &vec![0f32; 20], 1e9, 1e-6).unwrap();
        assert!(res.delta_local.is_empty());
        assert!(res.dmargins.is_empty());
        assert_eq!(res.delta_local.dim, 20);
        assert_eq!(res.dmargins.dim, 200);
    }

    #[test]
    fn dmargins_consistent_with_delta() {
        let ds = synth::webspam_like(150, 600, 15, 3);
        let shard = one_shard(&ds);
        let csc = shard.csc.clone();
        let mut eng = NativeEngine::new(shard, ds.n_examples());
        let margins = vec![0.1f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let res = eng.sweep_alloc(&w, &z, &vec![0f32; 600], 0.5, 1e-6).unwrap();
        // recompute Δβ·x_i from scratch and compare
        let delta = res.delta_local.to_dense();
        let mut want = vec![0f64; ds.n_examples()];
        for j in 0..600 {
            let (rows, vals) = csc.col(j);
            let d = delta[j] as f64;
            if d != 0.0 {
                for (&i, &v) in rows.iter().zip(vals) {
                    want[i as usize] += d * v as f64;
                }
            }
        }
        let dm = res.dmargins.to_dense();
        for i in 0..ds.n_examples() {
            assert!(
                (dm[i] as f64 - want[i]).abs() < 1e-4,
                "i={i}: {} vs {}",
                dm[i],
                want[i]
            );
        }
    }

    #[test]
    fn sweep_reuses_buffers_without_reallocating() {
        // the zero-allocation contract: a second sweep through the same
        // SweepResult must not grow the sparse buffers' capacity
        let ds = synth::webspam_like(200, 500, 10, 9);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let margins = vec![0f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let beta = vec![0f32; 500];
        let mut out = SweepResult::default();
        eng.sweep(&w, &z, &beta, 0.3, 1e-6, 0.0, &mut out).unwrap();
        let first = out.delta_local.clone();
        let (cap_d, cap_m) = (out.delta_local.indices.capacity(), out.dmargins.indices.capacity());
        eng.sweep(&w, &z, &beta, 0.3, 1e-6, 0.0, &mut out).unwrap();
        assert_eq!(out.delta_local, first, "sweeps must be deterministic");
        assert_eq!(out.delta_local.indices.capacity(), cap_d);
        assert_eq!(out.dmargins.indices.capacity(), cap_m);
    }

    #[test]
    fn incremental_reset_matches_a_fresh_engine_across_sweeps() {
        // the Δm accumulator must be indistinguishable from a fresh engine
        // even when w/z change between sweeps (the stale-state hazard the
        // incremental reset must not introduce)
        let ds = synth::webspam_like(250, 300, 8, 5);
        let mut persistent = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let beta = vec![0f32; 300];

        // sweep 1 at zero margins
        let margins0 = vec![0f32; ds.n_examples()];
        let (w0, z0) = stats_of(&ds, &margins0);
        let first = persistent.sweep_alloc(&w0, &z0, &beta, 0.4, 1e-6).unwrap();
        assert!(!first.dmargins.is_empty(), "need a non-trivial first sweep");

        // sweep 2 at shifted margins: persistent engine vs fresh engine
        let margins1: Vec<f32> = first.dmargins.to_dense().iter().map(|d| 0.5 * d).collect();
        let (w1, z1) = stats_of(&ds, &margins1);
        let warm = persistent.sweep_alloc(&w1, &z1, &beta, 0.4, 1e-6).unwrap();
        let mut fresh = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let cold = fresh.sweep_alloc(&w1, &z1, &beta, 0.4, 1e-6).unwrap();
        assert_eq!(warm.delta_local, cold.delta_local);
        assert_eq!(warm.dmargins, cold.dmargins);

        // an all-zero update (huge λ) leaves no stale touched state behind
        let none = persistent.sweep_alloc(&w1, &z1, &beta, 1e9, 1e-6).unwrap();
        assert!(none.delta_local.is_empty() && none.dmargins.is_empty());
        let again = persistent.sweep_alloc(&w1, &z1, &beta, 0.4, 1e-6).unwrap();
        assert_eq!(again.delta_local, cold.delta_local);
        assert_eq!(again.dmargins, cold.dmargins);
    }

    #[test]
    fn cov_kernel_warm_caches_match_a_fresh_engine_bitwise() {
        // warmth-independence: the covariance caches are memoization, not
        // state — a persistent engine whose Gram/denominator caches are warm
        // must emit the same bits as a cold engine built mid-path (the
        // checkpoint-resume / failover-replacement scenario)
        let ds = synth::webspam_like(250, 300, 8, 5);
        let kernel = SweepKernel { naive: false, threads: 1, ..Default::default() };
        let mut persistent =
            NativeEngine::with_kernel(one_shard(&ds), ds.n_examples(), kernel);
        let beta = vec![0f32; 300];
        let margins0 = vec![0f32; ds.n_examples()];
        let (w0, z0) = stats_of(&ds, &margins0);
        let first = persistent.sweep_alloc(&w0, &z0, &beta, 0.4, 1e-6).unwrap();
        assert!(!first.dmargins.is_empty());
        // same inputs again: caches now hot, result must not move a bit
        let hot = persistent.sweep_alloc(&w0, &z0, &beta, 0.4, 1e-6).unwrap();
        assert_eq!(hot.delta_local, first.delta_local);
        assert_eq!(hot.dmargins, first.dmargins);
        // shifted weights: warm (invalidating) engine vs cold engine
        let margins1: Vec<f32> = first.dmargins.to_dense().iter().map(|d| 0.5 * d).collect();
        let (w1, z1) = stats_of(&ds, &margins1);
        let warm = persistent.sweep_alloc(&w1, &z1, &beta, 0.4, 1e-6).unwrap();
        let mut fresh = NativeEngine::with_kernel(one_shard(&ds), ds.n_examples(), kernel);
        let cold = fresh.sweep_alloc(&w1, &z1, &beta, 0.4, 1e-6).unwrap();
        assert_eq!(warm.delta_local, cold.delta_local);
        assert_eq!(warm.dmargins, cold.dmargins);
    }

    #[test]
    fn cov_kernel_tracks_naive_to_tolerance() {
        let ds = synth::webspam_like(250, 300, 8, 5);
        let mut naive = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let kernel = SweepKernel { naive: false, threads: 1, ..Default::default() };
        let mut cov = NativeEngine::with_kernel(one_shard(&ds), ds.n_examples(), kernel);
        let beta = vec![0f32; 300];
        let margins = vec![0f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let a = naive.sweep_alloc(&w, &z, &beta, 0.3, 1e-6).unwrap();
        let b = cov.sweep_alloc(&w, &z, &beta, 0.3, 1e-6).unwrap();
        let (da, db) = (a.delta_local.to_dense(), b.delta_local.to_dense());
        for j in 0..300 {
            assert!(
                (da[j] - db[j]).abs() <= 2e-3 * (1.0 + da[j].abs()),
                "delta[{j}]: naive {} vs cov {}",
                da[j],
                db[j]
            );
        }
    }

    #[test]
    fn lambda_max_local_matches_full_scan_on_one_shard() {
        // a single shard owns every feature, so its local λ_max IS the
        // dataset's — and must match the leader-side scan bit-for-bit
        let ds = synth::webspam_like(150, 400, 10, 6);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let got = eng.lambda_max_local(&ds.y, 0.5).unwrap();
        let want = crate::solver::regpath::lambda_max(&ds);
        assert_eq!(want.to_bits(), got.to_bits());
    }

    #[test]
    fn elastic_net_l2_shrinks_the_update() {
        // same sweep with a ridge share: every stepped coordinate shrinks
        // toward zero relative to the pure-L1 step (denominator grows by l2)
        let ds = synth::dna_like(300, 30, 5, 8);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let margins = vec![0f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let beta = vec![0f32; 30];
        let mut l1_only = SweepResult::default();
        eng.sweep(&w, &z, &beta, 0.2, 1e-6, 0.0, &mut l1_only).unwrap();
        let mut mixed = SweepResult::default();
        eng.sweep(&w, &z, &beta, 0.2, 1e-6, 5.0, &mut mixed).unwrap();
        assert!(!l1_only.delta_local.is_empty());
        // Gauss-Seidel couples coordinates, so compare in aggregate: the
        // ridge share must strictly shrink the update's mass, and the first
        // stepped coordinate (which sees identical residuals) exactly.
        let (a, b) = (l1_only.delta_local.to_dense(), mixed.delta_local.to_dense());
        let mass = |v: &[f32]| v.iter().map(|&x| (x as f64).abs()).sum::<f64>();
        assert!(mass(&b) < mass(&a), "{} !< {}", mass(&b), mass(&a));
        let j0 = l1_only.delta_local.indices[0] as usize;
        assert!(b[j0].abs() < a[j0].abs(), "first step must shrink: {} vs {}", b[j0], a[j0]);
        // l2 = 0 is the pure-L1 update bit-for-bit
        let mut again = SweepResult::default();
        eng.sweep(&w, &z, &beta, 0.2, 1e-6, 0.0, &mut again).unwrap();
        assert_eq!(again.delta_local, l1_only.delta_local);
        assert_eq!(again.dmargins, l1_only.dmargins);
    }

    #[test]
    fn margins_into_matches_by_example_spmv() {
        let ds = synth::dna_like(120, 30, 4, 7);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let beta: Vec<f32> = (0..30)
            .map(|j| if j % 4 == 0 { (j as f32) * 0.1 - 1.0 } else { 0.0 })
            .collect();
        let mut out = crate::data::sparse::SparseVec::new(0);
        eng.margins_into(&beta, &mut out).unwrap();
        assert_eq!(out.dim, 120);
        let got = out.to_dense();
        let want = ds.x.margins(&beta);
        for i in 0..120 {
            assert!(
                (got[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                "margins[{i}]: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn margins_into_leaves_sweep_state_clean() {
        // margins_into borrows block 0's Δm scratch; a sweep right after it
        // must behave exactly as on a fresh engine
        let ds = synth::webspam_like(200, 300, 8, 11);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let beta: Vec<f32> =
            (0..300).map(|j| if j % 7 == 0 { 0.05 * (j as f32 + 1.0) } else { 0.0 }).collect();
        let mut scratch = crate::data::sparse::SparseVec::new(0);
        eng.margins_into(&beta, &mut scratch).unwrap();
        let margins = vec![0f32; ds.n_examples()];
        let (w, z) = stats_of(&ds, &margins);
        let zero = vec![0f32; 300];
        let after = eng.sweep_alloc(&w, &z, &zero, 0.3, 1e-6).unwrap();
        let mut fresh = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let clean = fresh.sweep_alloc(&w, &z, &zero, 0.3, 1e-6).unwrap();
        assert_eq!(after.delta_local, clean.delta_local);
        assert_eq!(after.dmargins, clean.dmargins);
    }

    #[test]
    fn sweep_moves_beta_back_toward_zero_when_overshooting() {
        // A feature whose current beta is large positive while data says 0:
        // the sweep should produce negative delta (shrinkage works from warm
        // starts, the mechanism behind the paper's sparsity discussion §2).
        let ds = synth::dna_like(300, 10, 3, 4);
        let mut eng = NativeEngine::new(one_shard(&ds), ds.n_examples());
        let mut beta = vec![0f32; 10];
        beta[0] = 5.0;
        let margins = ds.x.margins(&beta);
        let (w, z) = stats_of(&ds, &margins);
        let res = eng.sweep_alloc(&w, &z, &beta, 1.0, 1e-6).unwrap();
        let delta = res.delta_local.to_dense();
        assert!(delta[0] < 0.0, "delta0 = {}", delta[0]);
    }
}
