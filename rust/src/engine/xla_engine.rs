//! XLA subproblem engine: the AOT Pallas `cd_block_sweep` driven from rust.
//!
//! At construction the shard's sparse columns are densified once into
//! (n_pad × B) row-major tiles and uploaded as PJRT literals; every sweep
//! then runs `tiles` sequential kernel executions, threading the working
//! residual `r` through them (the same residual-carry contract the kernel
//! test `test_cd_sweep_carries_residual_across_blocks` pins down).

use std::time::Instant;

use crate::data::shuffle::FeatureShard;
use crate::engine::{SubproblemEngine, SweepResult};
use crate::error::{DlrError, Result};
use crate::runtime::{lit_vec, pad_to, XlaContext};

/// One densified (n_pad × b) column block.
struct Tile {
    x_lit: xla::Literal,
    /// shard-local column range [start, start+width)
    start: usize,
    width: usize,
}

/// Dense-tile engine executing the AOT `cd_sweep_n{n_pad}_b{b}` unit.
pub struct XlaEngine {
    ctx: XlaContext,
    unit: String,
    shard: FeatureShard,
    tiles: Vec<Tile>,
    n: usize,
    n_pad: usize,
    b: usize,
    /// reusable padded buffers
    w_pad: Vec<f32>,
    r_pad: Vec<f32>,
}

impl XlaEngine {
    /// Default: the optimized covariance-update sweep kernel.
    pub fn new(
        shard: FeatureShard,
        n: usize,
        block: usize,
        artifacts_dir: &std::path::Path,
    ) -> Result<Self> {
        Self::with_kernel(shard, n, block, artifacts_dir, false)
    }

    /// `naive = true` selects the per-column reference kernel (perf
    /// ablation; EXPERIMENTS.md §Perf).
    pub fn with_kernel(
        shard: FeatureShard,
        n: usize,
        block: usize,
        artifacts_dir: &std::path::Path,
        naive: bool,
    ) -> Result<Self> {
        let mut ctx = XlaContext::new(artifacts_dir)?;
        let n_pad = ctx.manifest().pick_n(n)?;
        let b = ctx.manifest().pick_b(block)?;
        let fn_name = if naive { "cd_sweep" } else { "cd_sweep_cov" };
        let unit = ctx.manifest().find(fn_name, n_pad, Some(b))?.name.clone();
        ctx.ensure_compiled(&unit)?;

        let p_local = shard.csc.n_cols;
        let mut tiles = Vec::with_capacity(p_local.div_ceil(b));
        let mut start = 0usize;
        while start < p_local {
            let width = (p_local - start).min(b);
            let dense = shard.csc.densify_block(start, width, n_pad, b);
            let x_lit = crate::runtime::lit_mat(&dense, n_pad, b)?;
            tiles.push(Tile { x_lit, start, width });
            start += width;
        }
        if p_local == 0 {
            return Err(DlrError::Solver("empty shard for XlaEngine".into()));
        }
        Ok(Self {
            ctx,
            unit,
            shard,
            tiles,
            n,
            n_pad,
            b,
            w_pad: vec![0f32; n_pad],
            r_pad: vec![0f32; n_pad],
        })
    }

    pub fn n_pad(&self) -> usize {
        self.n_pad
    }

    pub fn tiles(&self) -> usize {
        self.tiles.len()
    }

    pub fn shard(&self) -> &FeatureShard {
        &self.shard
    }

    /// Approximate VMEM-resident bytes per sweep call (the §Perf estimate).
    pub fn vmem_bytes_per_tile(&self) -> usize {
        // X tile + w + r (+ out r) + small block vectors
        4 * (self.n_pad * self.b + 3 * self.n_pad + 3 * self.b + 2)
    }
}

impl SubproblemEngine for XlaEngine {
    fn sweep(
        &mut self,
        w: &[f32],
        z: &[f32],
        beta_local: &[f32],
        lam: f32,
        nu: f32,
        l2: f32,
        out: &mut SweepResult,
    ) -> Result<()> {
        if l2 != 0.0 {
            return Err(DlrError::Solver(
                "the AOT cd_sweep kernels are pure-L1: elastic-net alpha < 1 requires \
                 the native engine (set [train] engine = \"native\" or alpha = 1.0)"
                    .into(),
            ));
        }
        let t0 = Instant::now();
        let n = self.n;
        debug_assert_eq!(w.len(), n);
        debug_assert_eq!(beta_local.len(), self.shard.csc.n_cols);

        self.w_pad[..n].copy_from_slice(w);
        self.r_pad[..n].copy_from_slice(z); // r starts at z; padded rows stay 0
        let w_lit = lit_vec(&self.w_pad);
        let lam_lit = lit_vec(&[lam]);
        let nu_lit = lit_vec(&[nu]);

        out.delta_local.clear(beta_local.len());
        let mut r_lit = lit_vec(&self.r_pad);
        for tile in &self.tiles {
            let beta_b = pad_to(&beta_local[tile.start..tile.start + tile.width], self.b);
            let beta_lit = lit_vec(&beta_b);
            let delta_lit = lit_vec(&vec![0f32; self.b]);
            let outputs = self.ctx.run(
                &self.unit,
                &[&tile.x_lit, &w_lit, &r_lit, &beta_lit, &delta_lit, &lam_lit, &nu_lit],
            )?;
            let mut it = outputs.into_iter();
            let d_out = it
                .next()
                .ok_or_else(|| DlrError::Xla("cd_sweep returned no outputs".into()))?;
            r_lit = it
                .next()
                .ok_or_else(|| DlrError::Xla("cd_sweep returned 1 output".into()))?;
            let d_vec = d_out.to_vec::<f32>()?;
            // tiles are visited in ascending column order, so pushes stay
            // sorted; only materialize the coordinates the kernel moved
            for (local_j, &d) in d_vec[..tile.width].iter().enumerate() {
                if d != 0.0 {
                    out.delta_local.push((tile.start + local_j) as u32, d);
                }
            }
        }
        let r_final = r_lit.to_vec::<f32>()?;
        out.dmargins.clear(n);
        for (i, (&zi, &ri)) in z.iter().zip(&r_final[..n]).enumerate() {
            if zi != ri {
                out.dmargins.push(i as u32, zi - ri);
            }
        }
        out.compute_secs = t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn lambda_max_local(&mut self, targets: &[f32], scale: f64) -> Result<f64> {
        // plain CPU scan of the retained sparse shard: λ_max is a one-shot
        // setup statistic, not worth a kernel launch, and the f64 column
        // sums must match the native computation bit-for-bit
        debug_assert_eq!(targets.len(), self.n);
        let mut best = 0f64;
        for j in 0..self.shard.csc.n_cols {
            let (rows, vals) = self.shard.csc.col(j);
            let mut g = 0f64;
            for (&i, &v) in rows.iter().zip(vals) {
                g += v as f64 * targets[i as usize] as f64;
            }
            best = best.max(g.abs() * scale);
        }
        Ok(best)
    }

    fn margins_into(
        &mut self,
        beta_local: &[f32],
        out: &mut crate::data::sparse::SparseVec,
    ) -> Result<()> {
        debug_assert_eq!(beta_local.len(), self.shard.csc.n_cols);
        let mut acc = vec![0f64; self.n];
        for (j, &b) in beta_local.iter().enumerate() {
            let b = b as f64;
            if b == 0.0 {
                continue;
            }
            let (rows, vals) = self.shard.csc.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                acc[i as usize] += b * v as f64;
            }
        }
        out.clear(self.n);
        for (i, &v) in acc.iter().enumerate() {
            if v != 0.0 {
                out.push(i as u32, v as f32);
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::{FeaturePartition, PartitionStrategy};
    use crate::data::shuffle::shard_in_memory;
    use crate::data::synth;
    use crate::engine::NativeEngine;
    use crate::runtime::default_artifacts_dir;
    use crate::util::math::working_stats;

    fn artifacts() -> Option<std::path::PathBuf> {
        let d = default_artifacts_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn xla_engine_matches_native_engine() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ds = synth::dna_like(600, 90, 6, 11);
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 90, 1, None);
        let shard = shard_in_memory(&ds.x, &part).remove(0);
        let n = ds.n_examples();

        let margins = vec![0f32; n];
        let (w, z): (Vec<f32>, Vec<f32>) = margins
            .iter()
            .zip(&ds.y)
            .map(|(&m, &y)| {
                let (w, z) = working_stats(y as f64, m as f64);
                (w as f32, z as f32)
            })
            .unzip();
        let beta = vec![0f32; 90];
        let (lam, nu) = (0.8f32, 1e-6f32);

        let mut xe = XlaEngine::new(shard.clone(), n, 64, &dir).unwrap();
        let mut ne = NativeEngine::new(shard, n);
        let rx = xe.sweep_alloc(&w, &z, &beta, lam, nu).unwrap();
        let rn = ne.sweep_alloc(&w, &z, &beta, lam, nu).unwrap();

        let (dx, dn) = (rx.delta_local.to_dense(), rn.delta_local.to_dense());
        assert_eq!(dx.len(), dn.len());
        for (j, (a, b)) in dx.iter().zip(&dn).enumerate() {
            assert!(
                (a - b).abs() < 5e-3 * (1.0 + b.abs()),
                "delta[{j}]: xla {a} vs native {b}"
            );
        }
        let (mx, mn) = (rx.dmargins.to_dense(), rn.dmargins.to_dense());
        for i in (0..n).step_by(37) {
            assert!(
                (mx[i] - mn[i]).abs() < 5e-3 * (1.0 + mn[i].abs()),
                "dmargins[{i}]"
            );
        }
    }

    #[test]
    fn multi_tile_shard_works() {
        let Some(dir) = artifacts() else {
            return;
        };
        // 150 local features with b=64 -> 3 tiles (residual threading path)
        let ds = synth::dna_like(300, 150, 8, 12);
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 150, 1, None);
        let shard = shard_in_memory(&ds.x, &part).remove(0);
        let n = ds.n_examples();
        let mut xe = XlaEngine::new(shard.clone(), n, 64, &dir).unwrap();
        assert_eq!(xe.tiles(), 3);
        let (w, z): (Vec<f32>, Vec<f32>) = ds
            .y
            .iter()
            .map(|&y| {
                let (w, z) = working_stats(y as f64, 0.0);
                (w as f32, z as f32)
            })
            .unzip();
        let rx = xe.sweep_alloc(&w, &z, &vec![0f32; 150], 0.3, 1e-6).unwrap();
        let mut ne = NativeEngine::new(shard, n);
        let rn = ne.sweep_alloc(&w, &z, &vec![0f32; 150], 0.3, 1e-6).unwrap();
        let (dx, dn) = (rx.delta_local.to_dense(), rn.delta_local.to_dense());
        for (j, (a, b)) in dx.iter().zip(&dn).enumerate() {
            assert!((a - b).abs() < 5e-3 * (1.0 + b.abs()), "delta[{j}]: {a} vs {b}");
        }
    }
}
