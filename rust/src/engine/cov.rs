//! Covariance-update CD sweep — the Gram-cached fast kernel behind
//! [`NativeEngine`](crate::engine::NativeEngine) when `naive_sweep` is off.
//!
//! Ported from the reference Pallas kernel
//! `python/compile/kernels/cd_sweep_cov.py` (the §Perf iteration-1 hot path)
//! and restated for sparse CPU shards. The naive sweep pays, per column, a
//! fused `Σ w x²` / `Σ w (z − Δm) x` pass whose residual term depends on
//! every earlier step of the same sweep (Gauss-Seidel). The covariance form
//! splits that into
//!
//! ```text
//! c0_j  = Σ_i (w_i z_i) x_ij          one dependency-free multiply-add
//!                                      stream per column (4-way unrolled)
//! corr_j = Σ_{stepped k < j} step_k · Ḡ_kj     O(row-nnz) Gram scatters
//! num    = c0_j − corr_j + β_j A_j
//! ```
//!
//! with `Ḡ = Xᵀ diag(w̄) X` restricted to the block and `A_j = ν + Σ w̄ x²`.
//! Identical math to the naive recurrence modulo floating-point order and
//! the weight quantization below; equivalence is a tolerance contract
//! (`tests/engine_equivalence.rs`, ported from `python/tests/test_cov_kernel.py`).
//!
//! ## Caching without history: the quantized weight epoch
//!
//! The expensive parts — Gram rows for the features that step (the active
//! set) and the `A_j` denominators — are cached across sweeps. IRLS reweights
//! every iteration, so a cache keyed on exact `w` would never hit; instead
//! both are computed from **quantized** weights `w̄` ([`quantize_weight`]:
//! the low [`WEIGHT_QUANT_BITS`] mantissa bits dropped, relative error
//! < 2⁻¹¹). Near convergence the margins — and therefore `w̄` — freeze, and
//! active-set sweeps stop touching the Gram builder entirely.
//!
//! Crucially every cached value is a *pure function of the current sweep's
//! inputs*: the cache is memoization, not state. A cold engine (checkpoint
//! resume, failover replacement, elastic reshard) recomputes exactly the
//! bits a warm engine reused, so run-vs-run trajectory pins hold with the
//! cov kernel as the default. The byte budget only decides what is *kept* —
//! over-budget Gram rows are built into scratch, used, and dropped.

use crate::data::shuffle::FeatureShard;
use crate::data::sparse::{CscMatrix, SparseVec};
use crate::util::math::{gather_dot4_f64, soft_threshold, weighted_sq_norm4};

/// Mantissa bits dropped by [`quantize_weight`] — relative quantization
/// error < 2^-(23-WEIGHT_QUANT_BITS) = 2⁻¹¹ ≈ 4.9e-4, well inside the
/// naive-equivalence tolerance and coarse enough that the cache epoch
/// freezes once the IRLS weights stabilize.
pub const WEIGHT_QUANT_BITS: u32 = 12;

/// Default engine-wide Gram cache budget (split across sweep threads).
pub(crate) const GRAM_CACHE_BUDGET_BYTES: usize = 32 << 20;

/// Drop the low mantissa bits of an IRLS weight — the epoch key and the
/// weight the Gram/denominator caches are built under.
#[inline]
pub fn quantize_weight(w: f32) -> f32 {
    f32::from_bits(w.to_bits() & (u32::MAX << WEIGHT_QUANT_BITS))
}

/// One cached sparse Gram row: `Ḡ_kj` for every block column j sharing an
/// example with column k (block-local indices, ascending).
#[derive(Debug, Default)]
struct GramRow {
    idx: Vec<u32>,
    val: Vec<f64>,
}

impl GramRow {
    fn bytes(&self) -> usize {
        self.idx.len() * (std::mem::size_of::<u32>() + std::mem::size_of::<f64>())
    }
}

/// Per-sweep-thread covariance state for one column block.
#[derive(Debug)]
pub(crate) struct CovBlock {
    /// CSR mirror of the block's columns (`row → (block-local col, x)`),
    /// built once — the Gram-row builder's row gather.
    row_ptr: Vec<usize>,
    row_cols: Vec<u32>,
    row_vals: Vec<f32>,
    /// Quantized weight snapshot the caches were built under; empty = cold.
    wq: Vec<f32>,
    wq_scratch: Vec<f32>,
    /// `Σ w̄ x²` per block column (valid iff `abar_ok`).
    abar: Vec<f64>,
    abar_ok: Vec<bool>,
    /// Cached Gram rows for columns that stepped under this epoch.
    rows: Vec<Option<GramRow>>,
    cached_bytes: usize,
    budget_bytes: usize,
    /// Scratch for over-budget Gram-row builds (used then overwritten).
    row_scratch: GramRow,
    /// Per-sweep scratch: sweep-start inner products and the running
    /// Gauss-Seidel correction (incrementally reset like the engine's Δm).
    c0: Vec<f64>,
    corr: Vec<f64>,
    corr_touched: Vec<u32>,
    in_corr: Vec<bool>,
    /// Gram-row build accumulator over block-local columns.
    g_dense: Vec<f64>,
    g_touched: Vec<u32>,
    g_in: Vec<bool>,
}

impl CovBlock {
    /// Build the block's row mirror and empty caches. `cols` are the
    /// shard-local columns this sweep thread owns (ascending).
    pub(crate) fn new(shard: &FeatureShard, cols: &[u32], budget_bytes: usize) -> Self {
        let n = shard.csc.n_rows;
        let b = cols.len();
        let mut counts = vec![0usize; n + 1];
        for &c in cols {
            let (rows, _) = shard.csc.col(c as usize);
            for &i in rows {
                counts[i as usize + 1] += 1;
            }
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let row_ptr = counts.clone();
        let mut next = counts;
        let nnz = row_ptr[n];
        let mut row_cols = vec![0u32; nnz];
        let mut row_vals = vec![0f32; nnz];
        // columns walked ascending → each row's entries land in ascending
        // block-local order, which keeps Gram-row builds deterministic
        for (bi, &c) in cols.iter().enumerate() {
            let (rows, vals) = shard.csc.col(c as usize);
            for (&i, &v) in rows.iter().zip(vals) {
                let dst = next[i as usize];
                row_cols[dst] = bi as u32;
                row_vals[dst] = v;
                next[i as usize] += 1;
            }
        }
        Self {
            row_ptr,
            row_cols,
            row_vals,
            wq: Vec::new(),
            wq_scratch: Vec::new(),
            abar: vec![0f64; b],
            abar_ok: vec![false; b],
            rows: (0..b).map(|_| None).collect(),
            cached_bytes: 0,
            budget_bytes,
            row_scratch: GramRow::default(),
            c0: vec![0f64; b],
            corr: vec![0f64; b],
            corr_touched: Vec::new(),
            in_corr: vec![false; b],
            g_dense: vec![0f64; b],
            g_touched: Vec::new(),
            g_in: vec![false; b],
        }
    }

    /// Re-key the caches on the current quantized weights. Everything kept
    /// is a pure function of `w̄`, so a hit reproduces a cold rebuild
    /// bit-for-bit; a mismatch drops the lot.
    fn refresh_epoch(&mut self, w: &[f32]) {
        self.wq_scratch.clear();
        self.wq_scratch.extend(w.iter().map(|&x| quantize_weight(x)));
        if self.wq_scratch != self.wq {
            std::mem::swap(&mut self.wq, &mut self.wq_scratch);
            for ok in &mut self.abar_ok {
                *ok = false;
            }
            for r in &mut self.rows {
                *r = None;
            }
            self.cached_bytes = 0;
        }
    }

    /// Per-sweep entry: re-key the caches on the current weights. Must run
    /// before [`cov_block_compute`] each sweep.
    pub(crate) fn begin_sweep(&mut self, w: &[f32]) {
        self.refresh_epoch(w);
    }

    /// Look up (or build) column `bi`'s Gram row and fold `step · Ḡ_kj`
    /// into the running correction. `shard_col` is `cols[bi]`.
    fn scatter_correction(
        &mut self,
        bi: usize,
        shard_col: usize,
        step: f64,
        shard: &FeatureShard,
    ) {
        // field-disjoint borrows: the Gram row is read (shared) while the
        // correction accumulator mutates
        let Self {
            row_ptr,
            row_cols,
            row_vals,
            wq,
            rows,
            cached_bytes,
            budget_bytes,
            row_scratch,
            corr,
            corr_touched,
            in_corr,
            g_dense,
            g_touched,
            g_in,
            ..
        } = self;
        if rows[bi].is_none() {
            let (rows_k, vals_k) = shard.csc.col(shard_col);
            g_touched.clear();
            for (&i, &xik) in rows_k.iter().zip(vals_k) {
                let ii = i as usize;
                let wxi = wq[ii] as f64 * xik as f64;
                for idx in row_ptr[ii]..row_ptr[ii + 1] {
                    let jb = row_cols[idx] as usize;
                    if !g_in[jb] {
                        g_in[jb] = true;
                        g_touched.push(jb as u32);
                    }
                    g_dense[jb] += wxi * row_vals[idx] as f64;
                }
            }
            g_touched.sort_unstable();
            row_scratch.idx.clear();
            row_scratch.val.clear();
            for &jb in g_touched.iter() {
                let jbu = jb as usize;
                row_scratch.idx.push(jb);
                row_scratch.val.push(g_dense[jbu]);
                g_dense[jbu] = 0.0;
                g_in[jbu] = false;
            }
            let bytes = row_scratch.bytes();
            if *cached_bytes + bytes <= *budget_bytes {
                // keep it: the active set re-steps every sweep, and this row
                // stays valid until the weight epoch moves
                *cached_bytes += bytes;
                rows[bi] = Some(GramRow {
                    idx: row_scratch.idx.clone(),
                    val: row_scratch.val.clone(),
                });
            }
        }
        let row = rows[bi].as_ref().unwrap_or(&*row_scratch);
        for (&jb, &g) in row.idx.iter().zip(&row.val) {
            let jbu = jb as usize;
            if !in_corr[jbu] {
                in_corr[jbu] = true;
                corr_touched.push(jb);
            }
            corr[jbu] += step * g;
        }
    }
}

/// One covariance-update CD sweep over a column block. Shares the engine's
/// Δm machinery (`dm` / `touched` / `in_touched`) and pushes
/// `(shard-local col, step)` into `delta_out` — the emission contract of the
/// naive block sweep, so the two kernels are interchangeable behind
/// [`NativeEngine`](crate::engine::NativeEngine).
///
/// `wz[i]` must hold `w_i as f64 * z_i as f64` (the engine precomputes it
/// once per sweep and shares it across sweep threads).
///
/// `lam` is the L1 strength (λ·α under the elastic net) and `l2` the ridge
/// strength λ·(1−α); the ridge share enters only the update's denominator
/// (`l2 = 0` reproduces the pure-L1 kernel bit-for-bit).
#[allow(clippy::too_many_arguments)]
pub(crate) fn cov_block_compute(
    shard: &FeatureShard,
    cols: &[u32],
    cov: &mut CovBlock,
    dm: &mut [f64],
    touched: &mut Vec<u32>,
    in_touched: &mut [bool],
    wz: &[f64],
    beta_local: &[f32],
    lam: f64,
    nu: f64,
    l2: f64,
    delta_out: &mut SparseVec,
) {
    debug_assert_eq!(
        cov.wq.len(),
        shard.csc.n_rows,
        "CovBlock::begin_sweep(w) must run before cov_block_compute"
    );
    // incremental correction reset (the previous sweep's stepped support)
    {
        let CovBlock { corr, corr_touched, in_corr, .. } = &mut *cov;
        for &jb in corr_touched.iter() {
            corr[jb as usize] = 0.0;
            in_corr[jb as usize] = false;
        }
        corr_touched.clear();
    }

    // sweep-start inner products: one dependency-free gather-dot per column
    for (bi, &c) in cols.iter().enumerate() {
        let (rows, vals) = shard.csc.col(c as usize);
        cov.c0[bi] = gather_dot4_f64(rows, vals, wz);
    }

    for (bi, &c) in cols.iter().enumerate() {
        let cu = c as usize;
        let (rows, vals) = shard.csc.col(cu);
        if rows.is_empty() {
            continue; // zero columns never move (naive-kernel parity)
        }
        let bj = beta_local[cu] as f64;
        let num0 = cov.c0[bi] - cov.corr[bi];
        // inactive columns that stay below the threshold are decided
        // without touching the denominator cache: soft(num0, λ) == 0
        if bj == 0.0 && num0.abs() <= lam {
            continue;
        }
        if !cov.abar_ok[bi] {
            cov.abar[bi] = weighted_sq_norm4(rows, vals, &cov.wq);
            cov.abar_ok[bi] = true;
        }
        let a = nu + cov.abar[bi];
        let s = soft_threshold(num0 + bj * a, lam) / (a + l2);
        let step = s - bj;
        if step == 0.0 {
            continue;
        }
        delta_out.push(c, step as f32);
        // exact Δm scatter — the engine's dmargins output must not inherit
        // the weight quantization, so this uses the raw column values
        for (&i, &v) in rows.iter().zip(vals) {
            let ii = i as usize;
            dm[ii] += step * v as f64;
            if !in_touched[ii] {
                in_touched[ii] = true;
                touched.push(i);
            }
        }
        cov.scatter_correction(bi, cu, step, shard);
    }
}

// ---------------------------------------------------------------------------
// Standalone block-sweep kernels: the rust ports of the reference Pallas
// kernels' contracts (`cd_sweep.py` / `cd_sweep_cov.py`), used by the
// equivalence tests in `tests/engine_equivalence.rs`. Both take a CSC block
// and run one full cyclic sweep with an explicit `delta_in` carry.
// ---------------------------------------------------------------------------

/// Naive cyclic CD sweep over a CSC block — the f64 transcription of
/// `cd_block_sweep` (and of `ref.ref_cd_block_sweep`): per column
/// `A = Σ w x² + ν`, `c = Σ w r x + u (A − ν) + β_j A`, residual updated
/// in place. Returns `(delta, r_out)`.
pub fn cd_block_sweep_naive(
    x: &CscMatrix,
    w: &[f32],
    r: &[f32],
    beta: &[f32],
    delta_in: &[f32],
    lam: f32,
    nu: f32,
) -> (Vec<f32>, Vec<f32>) {
    let (lam, nu) = (lam as f64, nu as f64);
    let mut res: Vec<f64> = r.iter().map(|&v| v as f64).collect();
    let mut delta: Vec<f64> = delta_in.iter().map(|&v| v as f64).collect();
    for j in 0..x.n_cols {
        let (rows, vals) = x.col(j);
        let mut a = nu;
        let mut wrx = 0f64;
        for (&i, &v) in rows.iter().zip(vals) {
            let ii = i as usize;
            let wi = w[ii] as f64;
            let xv = v as f64;
            a += wi * xv * xv;
            wrx += wi * res[ii] * xv;
        }
        let u = delta[j];
        let bj = beta[j] as f64;
        let c = wrx + u * (a - nu) + bj * a;
        let s = soft_threshold(c, lam) / a;
        let step = s - bj - u;
        if step != 0.0 {
            for (&i, &v) in rows.iter().zip(vals) {
                res[i as usize] -= step * v as f64;
            }
        }
        delta[j] = s - bj;
    }
    (
        delta.iter().map(|&d| d as f32).collect(),
        res.iter().map(|&v| v as f32).collect(),
    )
}

/// Covariance-update cyclic CD sweep over a CSC block — the rust port of
/// `cd_block_sweep_cov`: one Gram + one matvec up front, then an O(B²)
/// sequential loop, then one matvec to realize the residual. Same contract
/// as [`cd_block_sweep_naive`]; agreement is a tolerance test.
pub fn cd_block_sweep_cov(
    x: &CscMatrix,
    w: &[f32],
    r: &[f32],
    beta: &[f32],
    delta_in: &[f32],
    lam: f32,
    nu: f32,
) -> (Vec<f32>, Vec<f32>) {
    let b = x.n_cols;
    let (lam, nu) = (lam as f64, nu as f64);
    // G = Xᵀ diag(w) X and c0 = Xᵀ (w ⊙ r): the only O(n) work
    let mut wx = vec![0f64; x.n_rows]; // per-column scratch: w ⊙ x_k
    let mut gram = vec![0f64; b * b];
    let mut c = vec![0f64; b];
    for k in 0..b {
        let (rows_k, vals_k) = x.col(k);
        for (&i, &v) in rows_k.iter().zip(vals_k) {
            wx[i as usize] = w[i as usize] as f64 * v as f64;
        }
        for j in 0..b {
            let (rows_j, vals_j) = x.col(j);
            let mut g = 0f64;
            for (&i, &v) in rows_j.iter().zip(vals_j) {
                g += wx[i as usize] * v as f64;
            }
            gram[k * b + j] = g;
        }
        let mut c0 = 0f64;
        for &i in rows_k {
            c0 += wx[i as usize] * r[i as usize] as f64;
        }
        c[k] = c0;
        for &i in rows_k {
            wx[i as usize] = 0.0;
        }
    }
    let mut delta: Vec<f64> = delta_in.iter().map(|&v| v as f64).collect();
    for j in 0..b {
        let a = gram[j * b + j] + nu;
        let u = delta[j];
        let bj = beta[j] as f64;
        let num = c[j] + u * (a - nu) + bj * a;
        let s = soft_threshold(num, lam) / a;
        let step = s - bj - u;
        // the covariance update: later columns see this step through G
        for jj in 0..b {
            c[jj] -= step * gram[j * b + jj];
        }
        delta[j] = s - bj;
    }
    // one matvec realizes every residual update at once
    let mut res: Vec<f64> = r.iter().map(|&v| v as f64).collect();
    for j in 0..b {
        let d = delta[j] - delta_in[j] as f64;
        if d != 0.0 {
            let (rows, vals) = x.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                res[i as usize] -= d * v as f64;
            }
        }
    }
    (
        delta.iter().map(|&d| d as f32).collect(),
        res.iter().map(|&v| v as f32).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_weight_drops_only_low_mantissa_bits() {
        for &w in &[0.25f32, 0.1, 1e-10, 0.2499999] {
            let q = quantize_weight(w);
            assert!(q <= w && q >= 0.0);
            assert!(
                (w as f64 - q as f64) <= w as f64 * 2f64.powi(-(23 - WEIGHT_QUANT_BITS as i32)),
                "{w} → {q}"
            );
            // idempotent: the epoch key is stable
            assert_eq!(quantize_weight(q).to_bits(), q.to_bits());
        }
        assert_eq!(quantize_weight(0.0), 0.0);
    }

    #[test]
    fn standalone_kernels_agree_on_a_tiny_block() {
        // 3 examples × 2 features, hand-checkable
        let x = crate::data::sparse::CsrMatrix::from_triplets(
            3,
            2,
            &[
                crate::data::sparse::Triplet { row: 0, col: 0, val: 1.0 },
                crate::data::sparse::Triplet { row: 1, col: 0, val: -2.0 },
                crate::data::sparse::Triplet { row: 1, col: 1, val: 0.5 },
                crate::data::sparse::Triplet { row: 2, col: 1, val: 1.5 },
            ],
        )
        .unwrap()
        .to_csc();
        let w = [0.25f32, 0.2, 0.25];
        let r = [1.0f32, -0.5, 2.0];
        let beta = [0.3f32, 0.0];
        let zero = [0f32, 0.0];
        let (d1, r1) = cd_block_sweep_naive(&x, &w, &r, &beta, &zero, 0.05, 1e-6);
        let (d2, r2) = cd_block_sweep_cov(&x, &w, &r, &beta, &zero, 0.05, 1e-6);
        for j in 0..2 {
            assert!((d1[j] - d2[j]).abs() < 1e-5, "delta[{j}]: {} vs {}", d1[j], d2[j]);
        }
        for i in 0..3 {
            assert!((r1[i] - r2[i]).abs() < 1e-5, "r[{i}]: {} vs {}", r1[i], r2[i]);
        }
    }
}
