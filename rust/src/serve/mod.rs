//! `dglmnet serve` — the model-serving subsystem.
//!
//! Turns a trained artifact (`train --model-out`) into an HTTP scoring
//! service, closing the paper's train→deploy loop: the sparse L1 models
//! d-GLMNET exists to produce are what answer live traffic.
//!
//! # Request lifecycle
//!
//! [`server::Server::start`] loads and validates the artifact (rejecting
//! corrupt or dimension-inconsistent files up front), binds a
//! `TcpListener`, and spawns `ServeConfig::threads` accept threads. Each
//! connection is HTTP/1.1 with keep-alive: a thread parses one request
//! ([`http::read_request`]), dispatches it, writes the response, and
//! loops until the client closes or shutdown is signalled. Malformed
//! requests (bad framing, bad JSON, wrong shapes, oversized bodies) get
//! a 4xx with a JSON error body — never a panic and never a hang.
//!
//! Endpoints:
//! - `POST /predict` — one sparse example `{"indices":[..],"values":[..]}`
//!   → `{"margin":m,"model_version":v,"proba":p}`.
//! - `POST /predict_batch` — `{"examples":[{..},..]}` (at most
//!   `max_batch`, else 413) → a chunked ndjson stream, one
//!   [`prediction_line`] per example in order.
//! - `GET /healthz` — model shape + version; `GET /metrics` — counters.
//!
//! # Batching
//!
//! A batch takes **one** model snapshot ([`server::ModelSlot::get`]) and
//! scores every example against it, streaming each result line as soon
//! as it is computed — a hot-swap mid-batch never mixes model versions
//! within one response. Scoring goes through the shared
//! [`crate::data::sparse::dot_margin`] kernel, so served predictions are
//! bit-identical to the training cluster's margins and to offline
//! `dglmnet predict` output for the same examples.
//!
//! # Swap semantics
//!
//! The live model is an `Arc<ServedModel>` behind a `RwLock`
//! ([`server::ModelSlot`]). Request threads clone the `Arc` under a
//! brief read lock and then score lock-free: in-flight requests finish
//! on the model they started with, new requests see the new model —
//! zero downtime, no torn state. A watcher thread ([`swap::spawn_watcher`])
//! polls the artifact's `(mtime, len)` fingerprint; on change it loads
//! and fully validates the new file *before* swapping. A corrupt or
//! half-written artifact is skipped with one logged warning (per
//! offending fingerprint) and the old model keeps serving until a good
//! artifact appears.

pub mod http;
pub mod server;
pub mod swap;

pub use server::{ModelSlot, ServeStats, Server, ServerHandle};

use std::fmt::Write as _;

use crate::data::sparse::dot_margin;
use crate::error::Result;
use crate::solver::model::SparseModel;

/// A validated, score-ready model: the artifact plus its densified β and
/// version string (the artifact checksum — two models answer identically
/// iff their versions match).
#[derive(Debug)]
pub struct ServedModel {
    pub model: SparseModel,
    beta: Vec<f32>,
    pub version: String,
}

impl ServedModel {
    /// Load + validate an artifact (checksum, nnz, dimension checks all
    /// happen in [`SparseModel::load`]).
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let model = SparseModel::load(path)?;
        Ok(Self::from_model(model))
    }

    pub fn from_model(model: SparseModel) -> Self {
        let beta = model.to_dense();
        let version = format!("{:016x}", model.checksum());
        Self { model, beta, version }
    }

    /// Score one canonical example (ascending feature ids) through the
    /// shared train/serve margin kernel. Returns `(margin, mean)` with
    /// exactly the offline `predict` rounding: f64-accumulated dot,
    /// rounded to f32, then the model family's inverse link of that f32
    /// margin — the sigmoid probability for logistic models
    /// (bit-identical to the pre-family serve path), the identity for
    /// gaussian, exp for poisson.
    pub fn score(&self, cols: &[u32], vals: &[f32]) -> (f32, f32) {
        let margin = dot_margin(cols, vals, &self.beta) as f32;
        let mean = self.model.family.family().mean(margin as f64) as f32;
        (margin, mean)
    }
}

/// Sort an example's `(feature, value)` pairs ascending and merge
/// duplicate features by summing — the canonical form [`ServedModel::score`]
/// expects (what a `CsrMatrix` row built from sorted libsvm input is).
pub fn canonicalize(mut pairs: Vec<(u32, f32)>) -> (Vec<u32>, Vec<f32>) {
    pairs.sort_by_key(|&(j, _)| j);
    let mut cols = Vec::with_capacity(pairs.len());
    let mut vals: Vec<f32> = Vec::with_capacity(pairs.len());
    for (j, v) in pairs {
        if cols.last() == Some(&j) {
            *vals.last_mut().unwrap() += v;
        } else {
            cols.push(j);
            vals.push(v);
        }
    }
    (cols, vals)
}

/// The one ndjson result line both the batch endpoint and offline
/// `dglmnet predict` emit — shared so e2e can diff the two byte-for-byte.
/// f32 `Display` prints the shortest round-trip representation, so equal
/// bits always produce equal text. The `proba` field carries the model
/// family's mean prediction — an actual probability for logistic models,
/// the identity/exp mean for gaussian/poisson ones (the key is kept
/// stable so clients never need to branch on family).
pub fn prediction_line(id: usize, margin: f32, proba: f32) -> String {
    let mut s = String::with_capacity(48);
    write!(s, "{{\"id\":{id},\"margin\":{margin},\"proba\":{proba}}}").unwrap();
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonicalize_sorts_and_merges_duplicates() {
        let (cols, vals) = canonicalize(vec![(3, 1.0), (1, 2.0), (3, 0.5)]);
        assert_eq!(cols, vec![1, 3]);
        assert_eq!(vals, vec![2.0, 1.5]);
        let (cols, vals) = canonicalize(vec![]);
        assert!(cols.is_empty() && vals.is_empty());
    }

    #[test]
    fn score_matches_offline_predict_rounding() {
        let model = SparseModel::from_dense(&[0.5, 0.0, -1.25], 0.1);
        let served = ServedModel::from_model(model.clone());
        let mut x = crate::data::sparse::CsrMatrix::new(3);
        x.push_row(&[(0, 2.0), (2, 1.0)]);
        let offline_margin = model.predict_margins(&x)[0];
        let (m, p) = served.score(&[0, 2], &[2.0, 1.0]);
        assert_eq!(m.to_bits(), offline_margin.to_bits());
        assert_eq!(
            p.to_bits(),
            (crate::util::math::sigmoid(offline_margin as f64) as f32).to_bits()
        );
        // out-of-model features score zero contribution, not a panic
        let (m, _) = served.score(&[7], &[3.0]);
        assert_eq!(m, 0.0);
    }

    #[test]
    fn prediction_line_is_deterministic_compact_json() {
        assert_eq!(prediction_line(3, 1.5, 0.25), r#"{"id":3,"margin":1.5,"proba":0.25}"#);
        assert_eq!(prediction_line(0, -0.0, 0.5), r#"{"id":0,"margin":-0,"proba":0.5}"#);
        // round-trips through the crate JSON parser
        let v = crate::util::json::parse(&prediction_line(1, 2.0, 0.875)).unwrap();
        assert_eq!(v.get("id").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("margin").unwrap().as_f64(), Some(2.0));
    }
}
