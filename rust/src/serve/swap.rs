//! The artifact watcher: polls the model file's `(mtime, len)`
//! fingerprint and hot-swaps the [`super::server::ModelSlot`] when a new
//! *valid* artifact appears. A corrupt or half-written file is rejected
//! by the loader's checksum/shape validation, logged once (per offending
//! fingerprint), and the old model keeps serving; the next write changes
//! the fingerprint and triggers a fresh attempt.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, SystemTime};

use super::server::{ModelSlot, ServeStats};
use super::ServedModel;

type Fingerprint = (SystemTime, u64);

fn fingerprint(path: &Path) -> Option<Fingerprint> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.modified().ok()?, meta.len()))
}

/// How often the sleep loop checks the shutdown flag, independent of the
/// (possibly long) poll interval.
const SHUTDOWN_TICK: Duration = Duration::from_millis(100);

pub fn spawn_watcher(
    path: PathBuf,
    slot: Arc<ModelSlot>,
    stats: Arc<ServeStats>,
    poll: Duration,
    shutdown: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("serve-watch".into())
        .spawn(move || {
            // what the watcher last examined (loaded OR rejected); starting
            // at None costs one redundant load on the first poll but closes
            // the race where the artifact is replaced between the server's
            // initial load and this thread starting
            let mut last_seen: Option<Fingerprint> = None;
            loop {
                let mut waited = Duration::ZERO;
                while waited < poll {
                    if shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    let step = SHUTDOWN_TICK.min(poll - waited);
                    std::thread::sleep(step);
                    waited += step;
                }
                // a briefly-missing file (mid-replace) is not a change:
                // keep serving and look again next poll
                let Some(fp) = fingerprint(&path) else { continue };
                if Some(fp) == last_seen {
                    continue;
                }
                match ServedModel::load(&path) {
                    Ok(m) => {
                        last_seen = Some(fp);
                        let live = slot.get();
                        if m.model.family != live.model.family {
                            // a family change silently alters what `proba`
                            // means to every client — never hot-swap across
                            // it; restart the server on the new artifact
                            stats.swap_failures.fetch_add(1, Ordering::Relaxed);
                            eprintln!(
                                "[serve] warning: rejected new artifact at {} \
                                 (family {} != served {}; restart to change \
                                 family)",
                                path.display(),
                                m.model.family.name(),
                                live.model.family.name()
                            );
                        } else if m.version != live.version {
                            let version = m.version.clone();
                            slot.swap(m);
                            stats.swaps.fetch_add(1, Ordering::Relaxed);
                            eprintln!(
                                "[serve] hot-swapped model from {} (version {version})",
                                path.display()
                            );
                        }
                    }
                    Err(e) => {
                        // never swap in a bad artifact: warn once for this
                        // fingerprint and keep answering from the old model
                        last_seen = Some(fp);
                        stats.swap_failures.fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "[serve] warning: rejected new artifact at {} \
                             (keeping the old model): {e}",
                            path.display()
                        );
                    }
                }
            }
        })
        .expect("spawn watcher thread")
}
