//! Minimal HTTP/1.1 framing for the scoring server — from scratch on
//! `std::net`, like every other wire layer in this crate (the vendor set
//! has no tokio/hyper). Covers exactly what `dglmnet serve` needs:
//! request-line + header parsing, `Content-Length` bodies with a hard
//! size cap, `Expect: 100-continue` (curl sends it for bodies > 1 KiB),
//! keep-alive, fixed-length responses, and chunked streaming responses
//! for `/predict_batch`.

use std::io::{BufReader, Read, Write};
use std::net::TcpStream;

/// One parsed request. Header names are lower-cased at parse time.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 default is keep-alive unless the client opts out.
    pub fn keep_alive(&self) -> bool {
        !self
            .header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// Why a request could not be read; the server maps these to responses.
#[derive(Debug)]
pub enum ReadError {
    /// Connection closed cleanly before a request line: not an error,
    /// just the end of a keep-alive session.
    Closed,
    /// Unparseable framing → 400.
    Bad(String),
    /// Declared body exceeds the cap → 413 (read nothing of the body,
    /// the connection is then closed — its stream is no longer synced).
    TooLarge { declared: usize, limit: usize },
    Io(std::io::Error),
}

impl From<std::io::Error> for ReadError {
    fn from(e: std::io::Error) -> Self {
        match e.kind() {
            std::io::ErrorKind::UnexpectedEof => ReadError::Closed,
            _ => ReadError::Io(e),
        }
    }
}

const MAX_HEADER_LINE: usize = 8 * 1024;
const MAX_HEADERS: usize = 64;

fn read_crlf_line(reader: &mut BufReader<TcpStream>) -> Result<String, ReadError> {
    let mut line = Vec::with_capacity(128);
    loop {
        let mut byte = [0u8; 1];
        match reader.read_exact(&mut byte) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof && line.is_empty() => {
                return Err(ReadError::Closed);
            }
            Err(e) => return Err(e.into()),
        }
        if byte[0] == b'\n' {
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            return String::from_utf8(line)
                .map_err(|_| ReadError::Bad("non-utf8 header line".into()));
        }
        line.push(byte[0]);
        if line.len() > MAX_HEADER_LINE {
            return Err(ReadError::Bad("header line too long".into()));
        }
    }
}

/// Read one request off a keep-alive connection. `max_body` caps the
/// accepted `Content-Length`; `100-continue` expectations are answered
/// before the body is read (otherwise curl stalls for a second — or
/// forever — waiting for the interim response).
pub fn read_request(
    reader: &mut BufReader<TcpStream>,
    stream: &mut TcpStream,
    max_body: usize,
) -> Result<Request, ReadError> {
    let request_line = read_crlf_line(reader)?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| ReadError::Bad("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| ReadError::Bad("request line has no path".into()))?
        .to_string();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(ReadError::Bad(format!("unsupported protocol '{version}'")));
    }

    let mut headers = Vec::new();
    loop {
        let line = match read_crlf_line(reader) {
            Ok(l) => l,
            Err(ReadError::Closed) => {
                return Err(ReadError::Bad("connection closed mid-headers".into()))
            }
            Err(e) => return Err(e),
        };
        if line.is_empty() {
            break;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| ReadError::Bad(format!("malformed header '{line}'")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        if headers.len() > MAX_HEADERS {
            return Err(ReadError::Bad("too many headers".into()));
        }
    }

    let req = Request { method, path, headers, body: Vec::new() };
    let content_length = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| ReadError::Bad(format!("bad content-length '{v}'")))?,
    };
    if content_length > max_body {
        return Err(ReadError::TooLarge { declared: content_length, limit: max_body });
    }
    if req
        .header("expect")
        .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
    {
        stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
        stream.flush()?;
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader
            .read_exact(&mut body)
            .map_err(|_| ReadError::Bad("connection closed mid-body".into()))?;
    }
    Ok(Request { body, ..req })
}

pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a fixed-length JSON response.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        status_reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Chunked-transfer response writer for the streamed batch endpoint:
/// one `write_chunk` per result line, `finish` terminates the stream.
pub struct ChunkedWriter<'a> {
    stream: &'a mut TcpStream,
}

impl<'a> ChunkedWriter<'a> {
    pub fn start(
        stream: &'a mut TcpStream,
        status: u16,
        content_type: &str,
        keep_alive: bool,
        extra_headers: &[(&str, &str)],
    ) -> std::io::Result<Self> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: {}\r\n",
            status,
            status_reason(status),
            content_type,
            if keep_alive { "keep-alive" } else { "close" },
        );
        for (k, v) in extra_headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        Ok(Self { stream })
    }

    pub fn write_chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.stream, "{:x}\r\n", data.len())?;
        self.stream.write_all(data)?;
        self.stream.write_all(b"\r\n")
    }

    pub fn finish(self) -> std::io::Result<()> {
        self.stream.write_all(b"0\r\n\r\n")?;
        self.stream.flush()
    }
}
